"""AOT path: lowering is deterministic, manifest is well-formed, HLO text
carries the shapes the Rust runtime will bucket on."""

import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    rows = aot.build(out, chunk_shapes=[(16, 16), (16, 8)],
                     point_shapes=[(16, 16, 3)], steps=2)
    return out, rows


def test_artifact_files_exist(built):
    out, rows = built
    assert len(rows) == 4  # 2 chunks + gibbs + barycentric
    for name, _, _ in rows:
        assert os.path.exists(os.path.join(out, f"{name}.hlo.txt"))
    assert os.path.exists(os.path.join(out, "manifest.txt"))


def test_manifest_parses(built):
    out, rows = built
    with open(os.path.join(out, "manifest.txt")) as f:
        lines = [l for l in f.read().splitlines() if l and not l.startswith("#")]
    assert len(lines) == len(rows)
    for line in lines:
        name, *kvs = line.split()
        fields = dict(kv.split("=", 1) for kv in kvs)
        assert fields["file"] == f"{name}.hlo.txt"
        assert fields["kind"] in {"uot_chunk", "gibbs_init", "barycentric"}
        assert int(fields["m"]) > 0 and int(fields["n"]) > 0


def test_hlo_is_text_with_entry_layout(built):
    out, rows = built
    for name, fields, text in rows:
        assert text.startswith("HloModule"), name
        assert "entry_computation_layout" in text
        if fields["kind"] == "uot_chunk":
            m, n = fields["m"], fields["n"]
            assert f"f32[{m},{n}]" in text
            # tupled return: plan, colsum, scalar error
            assert "f32[]" in text


def test_lowering_is_deterministic():
    t1, f1 = aot.lower_uot_chunk(16, 16, 2)
    t2, f2 = aot.lower_uot_chunk(16, 16, 2)
    assert t1 == t2 and f1 == f2


def test_chunk_block_m_recorded(built):
    _, rows = built
    chunk_fields = [f for _, f, _ in rows if f["kind"] == "uot_chunk"]
    for f in chunk_fields:
        assert f["m"] % f["block_m"] == 0
