"""Column-tiled two-phase kernel vs the oracle and the fused kernel."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import mapuot, ref, tiled

F32 = np.float32


def make_problem(rng, m, n):
    A = jnp.asarray(rng.uniform(0.05, 2.0, (m, n)).astype(F32))
    rpd = jnp.asarray(rng.uniform(0.3, 1.7, m).astype(F32))
    cpd = jnp.asarray(rng.uniform(0.3, 1.7, n).astype(F32))
    return A, jnp.sum(A, axis=0), rpd, cpd


def divisors(x):
    return [d for d in range(1, x + 1) if x % d == 0]


@st.composite
def tilings(draw):
    m = draw(st.integers(2, 20))
    n = draw(st.integers(2, 20))
    bm = draw(st.sampled_from(divisors(m)))
    bn = draw(st.sampled_from(divisors(n)))
    seed = draw(st.integers(0, 2**31 - 1))
    fi = draw(st.floats(0.1, 1.0))
    return m, n, bm, bn, seed, fi


@settings(max_examples=30, deadline=None)
@given(tilings())
def test_tiled_matches_oracle(p):
    m, n, bm, bn, seed, fi = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    r_A, r_cs = ref.uot_iteration(A, cs, rpd, cpd, fi)
    t_A, t_cs = tiled.tiled_uot_iteration(A, cs, rpd, cpd, fi, block_m=bm, block_n=bn)
    np.testing.assert_allclose(t_A, r_A, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(t_cs, r_cs, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(tilings())
def test_tiled_matches_fused(p):
    m, n, bm, bn, seed, fi = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    f_A, f_cs = mapuot.fused_uot_iteration(A, cs, rpd, cpd, fi, block_m=1)
    t_A, t_cs = tiled.tiled_uot_iteration(A, cs, rpd, cpd, fi, block_m=bm, block_n=bn)
    np.testing.assert_allclose(t_A, f_A, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(t_cs, f_cs, rtol=1e-4, atol=1e-5)


def test_tiling_must_divide():
    rng = np.random.default_rng(0)
    A, cs, rpd, cpd = make_problem(rng, 10, 10)
    try:
        tiled.tiled_uot_iteration(A, cs, rpd, cpd, 0.5, block_m=3, block_n=5)
        raise AssertionError("should have raised")
    except ValueError:
        pass


def test_structural_traffic_ratio():
    assert tiled.hbm_traffic_ratio_vs_fused() == 2.0
