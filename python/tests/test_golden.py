"""The checked-in golden file must stay reproducible from the oracle."""

import os

import numpy as np

from tests import make_golden


def golden_path():
    return os.path.join(
        os.path.dirname(__file__), "..", "..", "data", "golden_uot_12x9.txt"
    )


def test_golden_file_matches_oracle():
    path = golden_path()
    assert os.path.exists(path), "run `python -m tests.make_golden` and commit data/"
    rows = []
    with open(path) as f:
        for line in f:
            if line.startswith("#"):
                continue
            rows.append([float(x) for x in line.split()])
    stored = np.array(rows, dtype=np.float32)
    fresh = make_golden.solve()
    np.testing.assert_allclose(stored, fresh, rtol=1e-5, atol=1e-7)
