"""L2 correctness: the AOT-facing graphs (chunk, gibbs init, barycentric)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

F32 = np.float32


def make_problem(rng, m, n):
    A = jnp.asarray(rng.uniform(0.05, 2.0, (m, n)).astype(F32))
    rpd = jnp.asarray(rng.uniform(0.3, 1.7, m).astype(F32))
    cpd = jnp.asarray(rng.uniform(0.3, 1.7, n).astype(F32))
    return A, jnp.sum(A, axis=0), rpd, cpd


@settings(max_examples=15, deadline=None)
@given(
    st.integers(2, 16), st.integers(2, 16),
    st.integers(1, 6), st.integers(0, 2**31 - 1),
)
def test_chunk_equals_repeated_oracle(m, n, steps, seed):
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    cA, ccs, err = model.uot_chunk(A, cs, rpd, cpd, 0.7, n_steps=steps, block_m=1)
    rA, rcs = A, cs
    for _ in range(steps):
        rA, rcs = ref.uot_iteration(rA, rcs, rpd, cpd, 0.7)
    np.testing.assert_allclose(cA, rA, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(ccs, rcs, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(err, ref.marginal_error(rA, rpd, cpd), rtol=1e-4, atol=1e-6)


def test_chunk_converges_to_fixed_point():
    """UOT with fi<1 converges to a *relaxed* fixed point: the marginal
    error plateaus at a nonzero value (mass relaxation) but the plan itself
    stops moving. We assert plan-delta → 0 and error monotone non-increasing."""
    rng = np.random.default_rng(5)
    A, cs, rpd, cpd = make_problem(rng, 24, 24)
    errs, deltas = [], []
    prev = np.asarray(A)
    for _ in range(6):
        A, cs, err = model.uot_chunk(A, cs, rpd, cpd, 0.8, n_steps=4, block_m=8)
        errs.append(float(err))
        cur = np.asarray(A)
        deltas.append(float(np.max(np.abs(cur - prev))))
        prev = cur
    assert all(e2 <= e1 + 1e-6 for e1, e2 in zip(errs, errs[1:])), errs
    assert deltas[-1] < deltas[0] * 1e-3, deltas


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_gibbs_init_matches_manual(m, n, d, seed):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(m, d)).astype(F32))
    Y = jnp.asarray(rng.normal(size=(n, d)).astype(F32))
    eps = jnp.asarray([0.5], F32)
    K, cs = model.gibbs_init(X, Y, eps)
    C = np.asarray(
        ((np.asarray(X)[:, None, :] - np.asarray(Y)[None, :, :]) ** 2).sum(-1)
    )
    np.testing.assert_allclose(K, np.exp(-C / 0.5), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(cs, np.asarray(K).sum(0), rtol=1e-5, atol=1e-6)


def test_gibbs_kernel_properties():
    """K in (0, 1]; diagonal of self-transport is exactly 1."""
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(9, 3)).astype(F32))
    K, _ = model.gibbs_init(X, X, jnp.asarray([0.2], F32))
    k = np.asarray(K)
    assert (k > 0).all() and (k <= 1.0 + 1e-6).all()
    np.testing.assert_allclose(np.diag(k), 1.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_barycentric_constant_target(m, n, seed):
    """If every target point is c, the barycentric image is c for all rows."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.uniform(0.1, 1.0, (m, n)).astype(F32))
    c = np.asarray([0.25, -1.5, 3.0], F32)
    Y = jnp.broadcast_to(jnp.asarray(c), (n, 3))
    out = model.barycentric_map(A, Y)
    np.testing.assert_allclose(out, np.tile(c, (m, 1)), rtol=1e-5)


def test_barycentric_is_convex_combination():
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.uniform(0.01, 1.0, (7, 9)).astype(F32))
    Y = jnp.asarray(rng.uniform(0.0, 1.0, (9, 3)).astype(F32))
    out = np.asarray(model.barycentric_map(A, Y))
    y = np.asarray(Y)
    assert (out >= y.min(0) - 1e-5).all() and (out <= y.max(0) + 1e-5).all()


def test_end_to_end_color_pipeline():
    """gibbs_init → chunks to convergence → barycentric map, all through L2."""
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.uniform(0, 1, (16, 3)).astype(F32))
    Y = jnp.asarray(rng.uniform(0, 1, (16, 3)).astype(F32))
    A, cs = model.gibbs_init(X, Y, jnp.asarray([0.1], F32))
    rpd = jnp.full((16,), 1.0 / 16, F32)
    cpd = jnp.full((16,), 1.0 / 16, F32)
    err = None
    for _ in range(10):
        A, cs, err = model.uot_chunk(A, cs, rpd, cpd, 1.0, n_steps=8, block_m=4)
    assert float(err) < 1e-4
    mapped = np.asarray(model.barycentric_map(A, Y))
    assert mapped.shape == (16, 3)
    assert (mapped >= -1e-4).all() and (mapped <= 1.0 + 1e-4).all()
