"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The hypothesis sweeps are the core correctness signal for the AOT path: the
fused kernel must be indistinguishable (to FP tolerance) from POT semantics
across shapes, panel sizes, dtypes, relaxation exponents and value scales.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import baseline, mapuot, ref

F32 = np.float32


def make_problem(rng, m, n, lo=0.05, hi=2.0):
    A = jnp.asarray(rng.uniform(lo, hi, (m, n)).astype(F32))
    rpd = jnp.asarray(rng.uniform(0.3, 1.7, m).astype(F32))
    cpd = jnp.asarray(rng.uniform(0.3, 1.7, n).astype(F32))
    return A, jnp.sum(A, axis=0), rpd, cpd


def divisors(m):
    return [d for d in range(1, m + 1) if m % d == 0]


@st.composite
def problems(draw):
    m = draw(st.integers(2, 24))
    n = draw(st.integers(2, 24))
    seed = draw(st.integers(0, 2**31 - 1))
    fi = draw(st.floats(0.05, 1.0))
    block_m = draw(st.sampled_from(divisors(m)))
    return m, n, seed, fi, block_m


@settings(max_examples=40, deadline=None)
@given(problems())
def test_fused_matches_oracle(p):
    m, n, seed, fi, block_m = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    r_A, r_cs = ref.uot_iteration(A, cs, rpd, cpd, fi)
    f_A, f_cs = mapuot.fused_uot_iteration(A, cs, rpd, cpd, fi, block_m=block_m)
    np.testing.assert_allclose(f_A, r_A, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(f_cs, r_cs, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(problems())
def test_baseline_matches_oracle(p):
    m, n, seed, fi, block_m = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    r_A, r_cs = ref.uot_iteration(A, cs, rpd, cpd, fi)
    b_A, b_cs = baseline.baseline_uot_iteration(A, cs, rpd, cpd, fi, block_m=block_m)
    np.testing.assert_allclose(b_A, r_A, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b_cs, r_cs, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(problems(), st.integers(2, 5))
def test_multi_iteration_composition(p, iters):
    """K fused iterations == K oracle iterations (carried colsum survives)."""
    m, n, seed, fi, block_m = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    fA, fcs = A, cs
    for _ in range(iters):
        fA, fcs = mapuot.fused_uot_iteration(fA, fcs, rpd, cpd, fi, block_m=block_m)
    rA, rcs = A, cs
    for _ in range(iters):
        rA, rcs = ref.uot_iteration(rA, rcs, rpd, cpd, fi)
    np.testing.assert_allclose(fA, rA, rtol=5e-4, atol=1e-5)
    np.testing.assert_allclose(fcs, rcs, rtol=5e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_fixed_point_is_preserved(m, n, seed):
    """If the marginals already hold, both rescalings are identity."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.uniform(0.1, 1.0, (m, n)).astype(F32))
    rpd, cpd = jnp.sum(A, axis=1), jnp.sum(A, axis=0)
    f_A, f_cs = mapuot.fused_uot_iteration(A, jnp.sum(A, axis=0), rpd, cpd, 0.5, block_m=1)
    np.testing.assert_allclose(f_A, A, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(f_cs, cpd, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_balanced_row_marginal_exact(m, n, seed):
    """fi=1 (balanced Sinkhorn): row marginals match RPD right after the
    row rescaling — the classic Sinkhorn invariant."""
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    f_A, _ = mapuot.fused_uot_iteration(A, cs, rpd, cpd, 1.0, block_m=m)
    np.testing.assert_allclose(jnp.sum(f_A, axis=1), rpd, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(problems())
def test_nextsum_col_is_colsum_of_output(p):
    """Computation IV really accumulates colsum(A') across the whole grid."""
    m, n, seed, fi, block_m = p
    A, cs, rpd, cpd = make_problem(np.random.default_rng(seed), m, n)
    f_A, f_cs = mapuot.fused_uot_iteration(A, cs, rpd, cpd, fi, block_m=block_m)
    np.testing.assert_allclose(f_cs, jnp.sum(f_A, axis=0), rtol=1e-4, atol=1e-5)


def test_convergence_reduces_marginal_error():
    rng = np.random.default_rng(7)
    A, cs, rpd, cpd = make_problem(rng, 32, 24)
    err0 = float(ref.marginal_error(A, rpd, cpd))
    out = A
    colsum = cs
    for _ in range(50):
        out, colsum = mapuot.fused_uot_iteration(out, colsum, rpd, cpd, 0.9, block_m=8)
    err1 = float(ref.marginal_error(out, rpd, cpd))
    assert err1 < err0 * 0.05, (err0, err1)


def test_pot_4sweep_equivalence():
    """Paper Fig. 1: 4-sweep NumPy form == carried-colsum form (fresh colsum)."""
    rng = np.random.default_rng(3)
    A, cs, rpd, cpd = make_problem(rng, 10, 14)
    a1, _ = ref.uot_iteration(A, cs, rpd, cpd, 0.6)
    a2 = ref.pot_iteration_4sweep(A, rpd, cpd, 0.6)
    np.testing.assert_allclose(a1, a2, rtol=1e-6)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.05)])
def test_dtypes(dtype, rtol):
    rng = np.random.default_rng(11)
    A = jnp.asarray(rng.uniform(0.1, 1.0, (8, 12)), dtype=dtype)
    rpd = jnp.asarray(rng.uniform(0.5, 1.5, 8), dtype=dtype)
    cpd = jnp.asarray(rng.uniform(0.5, 1.5, 12), dtype=dtype)
    cs = jnp.sum(A, axis=0)
    r_A, _ = ref.uot_iteration(A, cs, rpd, cpd, 0.5)
    f_A, _ = mapuot.fused_uot_iteration(A, cs, rpd, cpd, 0.5, block_m=4)
    np.testing.assert_allclose(
        np.asarray(f_A, np.float32), np.asarray(r_A, np.float32), rtol=rtol, atol=rtol
    )


def test_block_m_must_divide():
    rng = np.random.default_rng(0)
    A, cs, rpd, cpd = make_problem(rng, 10, 10)
    with pytest.raises(ValueError):
        mapuot.fused_uot_iteration(A, cs, rpd, cpd, 0.5, block_m=3)


@given(st.integers(1, 4096), st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_choose_block_m_properties(m, n):
    bm = mapuot.choose_block_m(m, n)
    assert m % bm == 0
    assert bm >= 1
    # fits budget unless even a single row overflows it
    if mapuot.vmem_bytes(1, n) <= mapuot.VMEM_BUDGET:
        assert mapuot.vmem_bytes(bm, n) <= mapuot.VMEM_BUDGET
    # maximality among divisors that fit
    for d in range(bm + 1, m + 1):
        if m % d == 0 and mapuot.vmem_bytes(d, n) <= mapuot.VMEM_BUDGET:
            raise AssertionError(f"{d} also fits but {bm} chosen")


def test_hbm_traffic_ratio_is_three():
    """Paper §3.1: baseline traffic / fused traffic == 3 (6MN vs 2MN)."""
    assert baseline.hbm_traffic_elements(1024, 512, fused=False) == 3 * baseline.hbm_traffic_elements(1024, 512, fused=True)
