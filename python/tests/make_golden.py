"""Generate the cross-language golden file consumed by the Rust test
`golden_cross_language` (rust/tests/golden.rs).

Both sides construct the same deterministic problem from closed-form
formulas (no RNG coupling needed), run 10 UOT iterations, and must agree:

    A[i][j]  = 0.05 + ((3*i + 5*j) % 11) / 11
    RPD[i]   = 0.3 + (i % 5) / 5
    CPD[j]   = 0.4 + (j % 4) / 4
    fi       = 0.7,  M = 12, N = 9, iterations = 10

Run from `python/`:  python -m tests.make_golden
"""

import os

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref

M, N, FI, ITERS = 12, 9, 0.7, 10


def make_problem():
    A = np.array(
        [[0.05 + ((3 * i + 5 * j) % 11) / 11 for j in range(N)] for i in range(M)],
        dtype=np.float32,
    )
    rpd = np.array([0.3 + (i % 5) / 5 for i in range(M)], dtype=np.float32)
    cpd = np.array([0.4 + (j % 4) / 4 for j in range(N)], dtype=np.float32)
    return A, rpd, cpd


def solve():
    A, rpd, cpd = make_problem()
    out = jnp.asarray(A)
    colsum = jnp.sum(out, axis=0)
    for _ in range(ITERS):
        out, colsum = ref.uot_iteration(out, colsum, jnp.asarray(rpd), jnp.asarray(cpd), FI)
    return np.asarray(out)


def main():
    out = solve()
    path = os.path.join(os.path.dirname(__file__), "..", "..", "data", "golden_uot_12x9.txt")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(f"# golden: {M}x{N} fi={FI} iters={ITERS} — see make_golden.py\n")
        for i in range(M):
            f.write(" ".join(f"{v:.8e}" for v in out[i]) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
