"""L2: the JAX compute graphs AOT-lowered for the Rust runtime.

Three graphs, all built on the L1 fused kernel, all shape-specialized at
lowering time (the Rust coordinator buckets requests by shape and picks the
matching artifact):

- :func:`uot_chunk`       — ``n_steps`` fused UOT iterations + marginal
  error. The solver's convergence loop lives in L3: the coordinator runs
  chunks and stops when the returned error clears its threshold, so no
  dynamic control flow needs to cross the AOT boundary.
- :func:`gibbs_init`      — squared-Euclidean cost + Gibbs kernel
  ``exp(-C/eps)``: the initial transport plan for entropic UOT.
- :func:`barycentric_map` — barycentric projection ``diag(1/rowsum) A Y``:
  the output step of the color-transfer / domain-adaptation apps (Fig 17).

Everything here is build-time Python; the lowered HLO text in
``artifacts/`` is the only thing the request path touches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import mapuot, ref


@functools.partial(jax.jit, static_argnames=("n_steps", "block_m"))
def uot_chunk(A, colsum, rpd, cpd, fi, *, n_steps: int, block_m: int):
    """Run ``n_steps`` fused iterations; return ``(A', colsum', err)``.

    ``err`` is the L-inf marginal error of ``A'`` (cheap O(M·N) reduction,
    fused by XLA into the last iteration's sweep), letting L3 decide whether
    to schedule another chunk without pulling the plan off the device.
    """

    def body(_, carry):
        a, cs = carry
        return mapuot.fused_uot_iteration(a, cs, rpd, cpd, fi, block_m=block_m)

    A, colsum = jax.lax.fori_loop(0, n_steps, body, (A, colsum))
    err = ref.marginal_error(A, rpd, cpd)
    return A, colsum, err


@jax.jit
def gibbs_init(X, Y, eps):
    """Initial plan ``K = exp(-C/eps)`` with ``C`` squared Euclidean.

    Args:
        X: source points ``(M, D)``; Y: target points ``(N, D)``;
        eps: entropic regularizer, shape ``(1,)``.

    Returns:
        ``(K, colsum(K))`` ready to feed :func:`uot_chunk`.
    """
    sq = (
        jnp.sum(X * X, axis=1)[:, None]
        + jnp.sum(Y * Y, axis=1)[None, :]
        - 2.0 * X @ Y.T
    )
    K = jnp.exp(-jnp.maximum(sq, 0.0) / eps[0])
    return K, jnp.sum(K, axis=0)


@jax.jit
def barycentric_map(A, Y):
    """Barycentric projection of the target points under plan ``A``.

    ``mapped_i = (Σ_j A_ij · Y_j) / (Σ_j A_ij)`` — the color-transfer map.
    """
    rowsum = jnp.sum(A, axis=1)
    return (A @ Y) / rowsum[:, None]


def solve_reference(A, rpd, cpd, fi, n_iter: int, block_m: int):
    """Build-time convenience: full solve through the fused kernel (tests)."""
    colsum = jnp.sum(A, axis=0)
    for _ in range(n_iter):
        A, colsum = mapuot.fused_uot_iteration(A, colsum, rpd, cpd, fi, block_m=block_m)
    return A
