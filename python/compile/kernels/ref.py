"""Pure-jnp oracle for the UOT Sinkhorn iteration (POT semantics).

This module is the single source of truth for solver numerics. Every other
implementation — the Pallas fused kernel (`mapuot.py`), the L2 graph
(`model.py`) and the three native Rust solvers — must match it to FP
tolerance after each full iteration.

Semantics (paper §2.1, Figure 1): entropic unbalanced optimal transport via
Sinkhorn with relaxation exponent ``fi = er / (er + ep)``. One iteration,
in the column-then-row order of Algorithm 1:

    Factor_col = (CPD / colsum(A)) ** fi          # from stored colsum
    A         *= Factor_col[None, :]
    Factor_row = (RPD / rowsum(A)) ** fi
    A         *= Factor_row[:, None]
    colsum'    = colsum(A)                        # carried to next iter

The POT/NumPy baseline performs the same mathematics with four full matrix
sweeps per iteration (sum cols, scale cols, sum rows, scale rows); MAP-UOT
fuses them into one sweep. Numerics are identical; only memory traffic
differs.
"""

from __future__ import annotations

import jax.numpy as jnp


def col_factors(colsum, cpd, fi):
    """Column rescaling factors ``(CPD / colsum)^fi`` (paper §4.1.1)."""
    return jnp.power(cpd / colsum, fi)


def row_factors(rowsum, rpd, fi):
    """Row rescaling factors ``(RPD / rowsum)^fi`` (paper §2.1)."""
    return jnp.power(rpd / rowsum, fi)


def uot_iteration(A, colsum, rpd, cpd, fi):
    """One full UOT iteration (column rescaling then row rescaling).

    Args:
        A: transport plan, shape (M, N).
        colsum: column sums of ``A`` carried from the previous iteration
            (or computed fresh at solver start), shape (N,).
        rpd / cpd: row / column probability distributions, shapes (M,), (N,).
        fi: relaxation exponent ``er / (er + ep)`` (scalar; 1.0 = balanced).

    Returns:
        ``(A', colsum')`` after one column + one row rescaling.
    """
    fcol = col_factors(colsum, cpd, fi)
    A = A * fcol[None, :]
    rowsum = jnp.sum(A, axis=1)
    frow = row_factors(rowsum, rpd, fi)
    A = A * frow[:, None]
    return A, jnp.sum(A, axis=0)


def marginal_error(A, rpd, cpd):
    """L-inf distance of the plan's marginals from (RPD, CPD).

    The solver's stopping criterion; L3 evaluates it between AOT chunks.
    """
    row_err = jnp.max(jnp.abs(jnp.sum(A, axis=1) - rpd))
    col_err = jnp.max(jnp.abs(jnp.sum(A, axis=0) - cpd))
    return jnp.maximum(row_err, col_err)


def uot_solve(A, rpd, cpd, fi, n_iter: int):
    """Reference solver: ``n_iter`` full iterations, Python loop (oracle only)."""
    colsum = jnp.sum(A, axis=0)
    for _ in range(n_iter):
        A, colsum = uot_iteration(A, colsum, rpd, cpd, fi)
    return A


def pot_iteration_4sweep(A, rpd, cpd, fi):
    """POT's literal 4-sweep formulation (paper Fig. 1 NumPy demo).

    Mathematically identical to :func:`uot_iteration` modulo the carried
    colsum; used by tests to pin the equivalence the paper asserts.
    """
    A = A * col_factors(jnp.sum(A, axis=0), cpd, fi)[None, :]
    A = A * row_factors(jnp.sum(A, axis=1), rpd, fi)[:, None]
    return A
