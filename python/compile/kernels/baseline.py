"""L1 Pallas kernels: the un-fused POT-style baseline, for comparison.

Two separate kernels per iteration — a column pass and a row pass — each of
which streams the whole matrix through fast memory once (and the column pass
must *re-read* it after scaling to produce row sums, matching the NumPy
``A *= f; A.sum(1)`` traffic). Total HBM traffic per iteration is ``6·M·N``
elements versus the fused kernel's ``2·M·N``; this 3× ratio is the paper's
Fig. 3 / §3.1 claim and is checked structurally in the tests.

Numerics are identical to the fused kernel and to ``ref.py``; only the
sweep structure differs. Used by the L1 ablation bench and as a second
independent implementation in the pytest oracle cross-check.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _col_scale_kernel(fcol_ref, a_ref, out_ref, rowsum_ref):
    """Sweep 1+2: scale columns of a row-panel, emit its row sums."""
    a = a_ref[...] * fcol_ref[...][None, :]
    out_ref[...] = a
    rowsum_ref[...] = jnp.sum(a, axis=1)


def _row_scale_kernel(frow_ref, a_ref, out_ref, colsum_ref):
    """Sweep 3+4: scale rows of a row-panel, emit partial column sums."""
    step = pl.program_id(0)
    a = a_ref[...] * frow_ref[...][:, None]
    out_ref[...] = a

    @pl.when(step == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(a, axis=0)


@functools.partial(jax.jit, static_argnames=("block_m",))
def baseline_uot_iteration(A, colsum, rpd, cpd, fi, *, block_m: int):
    """One UOT iteration as two separate Pallas passes (POT sweep structure)."""
    m, n = A.shape
    if m % block_m:
        raise ValueError(f"block_m={block_m} must divide M={m}")
    grid = (m // block_m,)
    panel = pl.BlockSpec((block_m, n), lambda i: (i, 0))
    vec_m = pl.BlockSpec((block_m,), lambda i: (i,))
    vec_n = pl.BlockSpec((n,), lambda i: (0,))

    fcol = ref.col_factors(colsum, cpd, fi).astype(A.dtype)
    A1, rowsum = pl.pallas_call(
        _col_scale_kernel,
        grid=grid,
        in_specs=[vec_n, panel],
        out_specs=[panel, vec_m],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), A.dtype),
            jax.ShapeDtypeStruct((m,), A.dtype),
        ],
        interpret=True,
    )(fcol, A)

    frow = ref.row_factors(rowsum, rpd, fi).astype(A.dtype)
    A2, ncs = pl.pallas_call(
        _row_scale_kernel,
        grid=grid,
        in_specs=[vec_m, panel],
        out_specs=[panel, vec_n],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), A.dtype),
            jax.ShapeDtypeStruct((n,), A.dtype),
        ],
        interpret=True,
    )(frow, A1)
    return A2, ncs


def hbm_traffic_elements(m: int, n: int, fused: bool) -> int:
    """Structural HBM traffic per iteration in elements (paper §3.1).

    Fused: one read + one write of the matrix. Baseline: the col pass reads
    and writes it, the row-sum re-read is folded into the same pass here but
    POT's NumPy version re-reads (``A.sum(1)``) — we count POT's traffic:
    read+write (col scale), read (row sum), read+write (row scale), read
    (col sum) = 6·M·N.
    """
    return 2 * m * n if fused else 6 * m * n
