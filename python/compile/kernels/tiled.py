"""L1 extension: column-tiled two-phase kernel for N too large for VMEM.

The fused kernel in :mod:`mapuot` holds a whole ``(block_m, N)`` row-panel
in VMEM. When a single row exceeds the VMEM budget (huge N), the fused
single-pass schedule is infeasible on TPU — the row factor needs the *full*
row sum before any element can be row-rescaled. This kernel is the
principled fallback: a 2-D grid over ``(row panels × column tiles)`` run as
two phases, which is exactly the COFFEE sweep structure expressed in
BlockSpecs (each phase streams the matrix through VMEM once → ``4·M·N``
HBM traffic instead of the fused kernel's ``2·M·N``; the ablation bench
quantifies the gap and motivates preferring the fused kernel whenever the
panel fits).

Phase A: grid (M/bm, N/bn) — scale tile by Factor_col, emit per-tile row
         partial sums, accumulated across the column-tile grid axis.
Phase B: grid (M/bm, N/bn) — scale tile by Factor_row, accumulate
         NextSum_col across the row-panel grid axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _phase_a_kernel(fcol_ref, a_ref, out_ref, rowsum_ref):
    """Tile: column rescale + row partial sums (accumulated over axis 1)."""
    j = pl.program_id(1)
    a = a_ref[...] * fcol_ref[...][None, :]
    out_ref[...] = a

    @pl.when(j == 0)
    def _init():
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)

    rowsum_ref[...] += jnp.sum(a, axis=1)


def _phase_b_kernel(frow_ref, a_ref, out_ref, ncs_ref):
    """Tile: row rescale + next column sums.

    Phase B's grid is transposed — ``(N/bn, M/bm)`` — so the accumulated
    ``NextSum_col`` block is revisited on *consecutive* grid steps (the
    fast axis walks row panels), which real-TPU Pallas requires for output
    revisiting; interpret mode is indifferent but we keep the layout
    TPU-honest.
    """
    i = pl.program_id(1)
    a = a_ref[...] * frow_ref[...][:, None]
    out_ref[...] = a

    @pl.when(i == 0)
    def _init():
        ncs_ref[...] = jnp.zeros_like(ncs_ref)

    ncs_ref[...] += jnp.sum(a, axis=0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def tiled_uot_iteration(A, colsum, rpd, cpd, fi, *, block_m: int, block_n: int):
    """One UOT iteration with ``(block_m, block_n)`` VMEM tiles.

    Equivalent to :func:`ref.uot_iteration` for any divisor tiling;
    asserted by the hypothesis sweep in ``tests/test_tiled.py``.
    """
    m, n = A.shape
    if m % block_m or n % block_n:
        raise ValueError(f"tiling {block_m}x{block_n} must divide {m}x{n}")
    grid = (m // block_m, n // block_n)
    tile = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
    col_vec = pl.BlockSpec((block_n,), lambda i, j: (j,))
    row_vec = pl.BlockSpec((block_m,), lambda i, j: (i,))

    fcol = ref.col_factors(colsum, cpd, fi).astype(A.dtype)
    A1, rowsum = pl.pallas_call(
        _phase_a_kernel,
        grid=grid,
        in_specs=[col_vec, tile],
        out_specs=[tile, row_vec],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), A.dtype),
            jax.ShapeDtypeStruct((m,), A.dtype),
        ],
        interpret=True,
    )(fcol, A)

    # Transposed grid for phase B (see kernel docstring).
    grid_b = (n // block_n, m // block_m)
    tile_b = pl.BlockSpec((block_m, block_n), lambda j, i: (i, j))
    col_vec_b = pl.BlockSpec((block_n,), lambda j, i: (j,))
    row_vec_b = pl.BlockSpec((block_m,), lambda j, i: (i,))

    frow = ref.row_factors(rowsum, rpd, fi).astype(A.dtype)
    A2, ncs = pl.pallas_call(
        _phase_b_kernel,
        grid=grid_b,
        in_specs=[row_vec_b, tile_b],
        out_specs=[tile_b, col_vec_b],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), A.dtype),
            jax.ShapeDtypeStruct((n,), A.dtype),
        ],
        interpret=True,
    )(frow, A1)
    return A2, ncs


def hbm_traffic_ratio_vs_fused() -> float:
    """Structural HBM cost of the tiled fallback vs the fused kernel."""
    return 2.0  # 4·M·N vs 2·M·N
