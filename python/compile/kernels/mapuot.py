"""L1 Pallas kernel: the fused, interweaved MAP-UOT iteration.

Paper mapping (§4.1, Algorithm 1, Figure 6). One grid step processes one
row-panel of the plan and performs, while the panel is resident in fast
memory, all four per-element computations of the paper's double-loop:

    Computation I   — multiply by ``Factor_col`` (column rescaling)
    Computation II  — accumulate ``Sum_row`` (row sums of the scaled panel)
    Computation III — multiply by ``Factor_row`` (row rescaling)
    Computation IV  — accumulate ``NextSum_col`` (column sums for the next
                      iteration's ``Factor_col``)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU
version keeps the *current row* cache-resident and its GPU version keeps a
``(Ty·Ny) × Tx`` tile in shared memory. On TPU the analogous fast memory is
VMEM, so the BlockSpec carves ``(block_m, N)`` row-panels; the grid
dimension over panels replaces the threadblock grid; and the revisited
``NextSum_col`` output block (same block index at every grid step) replaces
the paper's ``atomicAdd`` into global memory — Pallas guarantees sequential
grid order, so the accumulation is race-free by construction.

The matrix is read and written exactly once per iteration (HBM traffic
``2·M·N`` elements — the Roofline-model minimum of paper §3.1), versus four
sweeps (``6·M·N``) for the POT baseline.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode tracing lowers the kernel to plain HLO ops
so the AOT artifact runs on the Rust CPU client. Structural TPU metrics
(VMEM bytes per panel) are reported by :func:`vmem_bytes`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: VMEM budget per TensorCore we size panels against (bytes). Real TPUs have
#: 16 MiB (v4/v5p) per core; we keep a 2× safety margin for double-buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def choose_block_m(m: int, n: int, itemsize: int = 4, budget: int = VMEM_BUDGET) -> int:
    """Largest divisor of ``m`` whose (in + out) panels fit the VMEM budget.

    Mirrors the paper's Fig. 8 tiling search, but statically: panel bytes are
    ``2 · block_m · n · itemsize`` (input + aliased output) plus the two
    factor vectors, and we want the largest panel that fits so the grid (and
    its per-step launch overhead) is shortest.
    """
    if m <= 0 or n <= 0:
        raise ValueError(f"matrix dims must be positive, got {m}x{n}")
    best = 1
    for bm in range(1, m + 1):
        if m % bm:
            continue
        panel = 2 * bm * n * itemsize + 2 * n * itemsize + bm * itemsize
        if panel <= budget:
            best = bm
        else:
            break
    return best


def vmem_bytes(block_m: int, n: int, itemsize: int = 4) -> int:
    """Structural VMEM footprint of one grid step (perf metric for §Perf)."""
    return 2 * block_m * n * itemsize + 2 * n * itemsize + block_m * itemsize


def _fused_kernel(fi_ref, fcol_ref, rpd_ref, a_ref, out_ref, ncs_ref):
    """One row-panel: col-scale, row-reduce, row-scale, col-partial-sum."""
    step = pl.program_id(0)
    fi = fi_ref[0]
    # Computation I — column rescaling of the resident panel.
    a = a_ref[...] * fcol_ref[...][None, :]
    # Computation II — Sum_row for every row of the panel.
    rowsum = jnp.sum(a, axis=1)
    # Factor_row = (RPD_i / Sum_row)^fi  (Algorithm 1, line 10).
    frow = jnp.power(rpd_ref[...] / rowsum, fi)
    # Computation III — row rescaling.
    a = a * frow[:, None]
    out_ref[...] = a

    # Computation IV — NextSum_col accumulation. The output block index is
    # constant across the grid, so the buffer persists between steps; the
    # first step zero-initializes it (per-thread NextSum_col in Algorithm 1
    # is initialized to zeros before the double-loop).
    @pl.when(step == 0)
    def _init():
        ncs_ref[...] = jnp.zeros_like(ncs_ref)

    ncs_ref[...] += jnp.sum(a, axis=0)


@functools.partial(jax.jit, static_argnames=("block_m",))
def fused_uot_iteration(A, colsum, rpd, cpd, fi, *, block_m: int | None = None):
    """One full UOT iteration via the fused Pallas kernel.

    Equivalent to :func:`ref.uot_iteration`; asserted by pytest/hypothesis.

    Args:
        A: transport plan ``(M, N)``.
        colsum: carried column sums ``(N,)``.
        rpd / cpd: marginal constraints ``(M,)`` / ``(N,)``.
        fi: relaxation exponent, scalar or 0-d array.
        block_m: rows per panel; must divide ``M``. Default: VMEM-sized.

    Returns:
        ``(A', colsum')``.
    """
    m, n = A.shape
    if block_m is None:
        block_m = choose_block_m(m, n, A.dtype.itemsize)
    if m % block_m:
        raise ValueError(f"block_m={block_m} must divide M={m}")

    # Parts ①/③ of §4 (O(N) work): Factor_col from the carried colsum.
    fcol = ref.col_factors(colsum, cpd, fi).astype(A.dtype)
    fi_arr = jnp.asarray(fi, A.dtype).reshape(1)

    grid = (m // block_m,)
    out, ncs = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # fi (scalar)
            pl.BlockSpec((n,), lambda i: (0,)),            # Factor_col, whole
            pl.BlockSpec((block_m,), lambda i: (i,)),      # RPD panel
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),  # A panel
        ],
        out_specs=[
            pl.BlockSpec((block_m, n), lambda i: (i, 0)),  # A' panel
            pl.BlockSpec((n,), lambda i: (0,)),            # NextSum_col (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), A.dtype),
            jax.ShapeDtypeStruct((n,), A.dtype),
        ],
        interpret=True,
    )(fi_arr, fcol, rpd.astype(A.dtype), A)
    return out, ncs
