"""L1 Pallas kernels for MAP-UOT.

- :mod:`.mapuot`   — the fused interweaved iteration (the paper's contribution)
- :mod:`.baseline` — POT-style separate sweeps (comparator)
- :mod:`.ref`      — pure-jnp oracle; source of truth for numerics
"""

from . import baseline, mapuot, ref  # noqa: F401
