"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Each artifact is shape-specialized; ``manifest.txt`` (one line per
artifact: ``key=value`` pairs) tells the Rust runtime what exists. Python
runs exactly once, at build time (``make artifacts``); the request path is
pure Rust + PJRT.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import mapuot

#: (M, N) shape buckets for the UOT chunk executables. The coordinator
#: routes a request to the smallest bucket that fits (padding with zero
#: mass rows/cols preserves the solution on the real support).
CHUNK_SHAPES = [(256, 256), (512, 512), (512, 256), (1024, 1024)]

#: Iterations fused into one chunk executable. Chosen so the L3 convergence
#: check (a host scalar read) amortizes across enough device work.
CHUNK_STEPS = 8

#: Point-cloud buckets for gibbs_init / barycentric_map (D = 3: RGB space).
POINT_SHAPES = [(256, 256, 3), (512, 512, 3), (1024, 1024, 3)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation (tupled) → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_uot_chunk(m: int, n: int, steps: int):
    """Lower one UOT chunk bucket; returns (hlo_text, manifest_fields)."""
    block_m = mapuot.choose_block_m(m, n)
    fn = lambda A, cs, rpd, cpd, fi: model.uot_chunk(
        A, cs, rpd, cpd, fi[0], n_steps=steps, block_m=block_m
    )
    lowered = jax.jit(fn).lower(
        _spec((m, n)), _spec((n,)), _spec((m,)), _spec((n,)), _spec((1,))
    )
    fields = dict(kind="uot_chunk", m=m, n=n, steps=steps, block_m=block_m)
    return to_hlo_text(lowered), fields


def lower_gibbs_init(m: int, n: int, d: int):
    lowered = jax.jit(model.gibbs_init).lower(
        _spec((m, d)), _spec((n, d)), _spec((1,))
    )
    return to_hlo_text(lowered), dict(kind="gibbs_init", m=m, n=n, d=d)


def lower_barycentric(m: int, n: int, d: int):
    lowered = jax.jit(model.barycentric_map).lower(_spec((m, n)), _spec((n, d)))
    return to_hlo_text(lowered), dict(kind="barycentric", m=m, n=n, d=d)


def build(out_dir: str, chunk_shapes=None, point_shapes=None, steps=CHUNK_STEPS):
    """Lower every bucket and write artifacts + manifest. Returns manifest rows."""
    os.makedirs(out_dir, exist_ok=True)
    rows = []

    for m, n in chunk_shapes if chunk_shapes is not None else CHUNK_SHAPES:
        text, fields = lower_uot_chunk(m, n, steps)
        name = f"uot_chunk_{m}x{n}_s{steps}"
        rows.append((name, fields, text))

    for m, n, d in point_shapes if point_shapes is not None else POINT_SHAPES:
        text, fields = lower_gibbs_init(m, n, d)
        rows.append((f"gibbs_init_{m}x{n}x{d}", fields, text))
        text, fields = lower_barycentric(m, n, d)
        rows.append((f"barycentric_{m}x{n}x{d}", fields, text))

    manifest_lines = []
    for name, fields, text in rows:
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        kv = " ".join(f"{k}={v}" for k, v in fields.items())
        manifest_lines.append(f"{name} file={fname} {kv}")
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# MAP-UOT AOT artifact manifest: name file=... kind=... <shape fields>\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(rows)} artifacts in {out_dir}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--steps", type=int, default=CHUNK_STEPS)
    p.add_argument(
        "--small", action="store_true",
        help="only the smallest bucket of each kind (CI smoke)",
    )
    args = p.parse_args()
    chunks = CHUNK_SHAPES[:1] if args.small else None
    points = POINT_SHAPES[:1] if args.small else None
    build(args.out_dir, chunks, points, args.steps)


if __name__ == "__main__":
    main()
