//! Domain example: document similarity search with Sinkhorn Word Mover's
//! Distance — the NLP workload the paper's related work (Tithi & Petrini;
//! COFFEE) accelerates, built on the same fused rescaling primitive.
//!
//!     cargo run --release --example wmd_search

use map_uot::apps::wmd::{make_document, make_vocabulary, wmd, run, Config};

fn main() {
    // Corpus-level benchmark: pairwise WMD + 1-NN topic retrieval.
    let out = run(Config { words: 128, topics: 4, dim: 8, docs_per_topic: 4, ..Default::default() });
    println!(
        "corpus search: {} pairwise Sinkhorn solves in {:.0} ms (UOT {:.1}% of total)",
        out.report.iters / Config::default().iters,
        out.report.total_s * 1e3,
        out.report.uot_share() * 100.0
    );
    println!("1-NN topic retrieval accuracy: {:.0}%\n", out.knn_accuracy * 100.0);

    // Single-query walkthrough.
    let vocab = make_vocabulary(128, 4, 8, 5);
    let query = make_document(&vocab, 2, 60, 999);
    println!("query document (topic 2) vs one candidate per topic:");
    for topic in 0..4 {
        let cand = make_document(&vocab, topic, 60, 100 + topic as u64);
        let d = wmd(&vocab, &query, &cand, 0.5, 50);
        println!(
            "  topic {topic}: WMD = {d:.4}{}",
            if topic == 2 { "   <-- should be smallest" } else { "" }
        );
    }
}
