//! End-to-end driver: the full three-layer stack under a real workload.
//!
//! Starts the L3 coordinator with the **PJRT backend**, so every solve
//! executes the AOT artifact chain: Pallas fused kernel (L1) inside the
//! jax chunk graph (L2), compiled from HLO text and run by the Rust
//! runtime — Python is nowhere in this process. A mixed burst of color
//! -transfer-style and random UOT requests is submitted; the example
//! reports latency/throughput and cross-checks a sample answer against
//! the native solver.
//!
//! Requires artifacts: `make artifacts` first. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example serve

use map_uot::algo::{Problem, SolverKind, SolverSession, StopRule};
use map_uot::config::{Backend, ServiceConfig};
use map_uot::coordinator::Service;
use map_uot::util::{Timer, XorShift};

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("no artifacts/ — run `make artifacts` first");
        std::process::exit(1);
    }

    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    let cfg = ServiceConfig {
        workers: 4,
        batch_max: 8,
        backend: Backend::Pjrt,
        stop,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).expect("service start");
    println!("coordinator up: 4 workers, PJRT backend, dynamic batching\n");

    // Mixed workload: three shape classes, padded into artifact buckets by
    // the router (100->256, 200x140->256, 256 exact).
    let mut rng = XorShift::new(7);
    let n_requests = 48;
    let timer = Timer::start();
    let mut rxs = Vec::new();
    let mut sample = None;
    for i in 0..n_requests {
        let (m, n) = match rng.below(3) {
            0 => (256, 256),
            1 => (100, 100),
            _ => (200, 140),
        };
        let p = Problem::random(m, n, 0.8, i);
        if i == 0 {
            sample = Some(p.clone());
        }
        rxs.push(svc.submit(p).expect("submit"));
    }

    let mut ok = 0;
    let mut sample_plan = None;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("reply");
        match resp.result {
            Ok(solved) => {
                ok += 1;
                if i == 0 {
                    sample_plan = Some(solved.plan);
                }
            }
            Err(e) => eprintln!("request {i} failed: {e}"),
        }
    }
    let wall = timer.elapsed().as_secs_f64();
    let m = svc.metrics();

    println!("workload   : {n_requests} requests, 3 shape classes (bucketed to 256x256)");
    println!("completed  : {ok}/{n_requests} in {wall:.2}s  ->  {:.1} req/s", ok as f64 / wall);
    println!("batching   : {} batches, mean size {:.2}", m.batches, m.mean_batch_size);
    println!(
        "latency    : mean {:.1} ms, p50 <= {:.1} ms, p99 <= {:.1} ms",
        m.mean_latency_ms,
        m.latency_percentile_ms(50.0),
        m.latency_percentile_ms(99.0)
    );
    println!("iterations : {} total fused iterations on the PJRT path", m.iterations);

    // Cross-check one answer against the native MAP-UOT solver.
    let p = sample.expect("sample problem");
    let mut native_session = SolverSession::builder(SolverKind::MapUot).stop(stop).build(&p);
    native_session.solve(&p).expect("native cross-check");
    let diff = sample_plan.expect("sample plan").max_rel_diff(native_session.plan(), 1e-5);
    println!("\ncross-check vs native solver: max rel diff = {diff:.2e}");
    assert!(diff < 2e-2, "PJRT and native answers diverged");
    println!("three-layer stack verified: pallas kernel -> jax chunk -> HLO text -> PJRT -> coordinator");

    svc.shutdown();
}
