//! Domain example: image color transfer (paper §5.5, Fig. 17).
//!
//! Transfers the color distribution of a synthetic target image onto a
//! synthetic source image through a palette-to-palette UOT plan, and
//! compares end-to-end time across the three solvers, reporting the
//! solver's share of the pipeline (the Fig. 2 observation).
//!
//!     cargo run --release --example color_transfer

use map_uot::apps::color_transfer::{run, Config};
use map_uot::algo::SolverKind;

fn main() {
    let base = Config {
        width: 960,
        height: 640,
        palette: 512,
        eps: 0.05,
        fi: 0.9,
        threads: 1,
        max_iter: 300,
        ..Config::default()
    };

    println!(
        "color transfer: {}x{} image, {} palette colors, fi={}\n",
        base.width, base.height, base.palette, base.fi
    );

    let mut total_pot = 0.0;
    for kind in SolverKind::ALL {
        let out = run(Config { solver: kind, ..base });
        let r = out.report;
        if kind == SolverKind::Pot {
            total_pot = r.total_s;
        }
        println!(
            "  {:8} total {:7.1} ms | uot {:7.1} ms ({:4.1}% of app) | {:3} iters | speedup vs POT {:.2}x",
            kind.name(),
            r.total_s * 1e3,
            r.uot_s * 1e3,
            r.uot_share() * 100.0,
            r.iters,
            total_pot / r.total_s,
        );
        // Show the mapped palette actually moved colors.
        let p0 = out.mapped_palette[0];
        if kind == SolverKind::MapUot {
            println!(
                "\n  first mapped palette entry: ({:.3}, {:.3}, {:.3})",
                p0[0], p0[1], p0[2]
            );
            let px = &out.recolored.pixels[..4];
            println!("  first recolored pixels: {px:?}");
        }
    }
}
