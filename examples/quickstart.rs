//! Quickstart: solve one unbalanced-optimal-transport problem with each
//! solver and verify they agree.
//!
//!     cargo run --release --example quickstart

use map_uot::algo::{solve, Problem, SolveOptions, SolverKind, StopRule};

fn main() {
    // A 512x512 problem: random positive plan, random positive marginals,
    // relaxation exponent fi = er/(er+ep) = 0.7.
    let problem = Problem::random(512, 512, 0.7, 42);
    let opts = SolveOptions {
        threads: 1,
        stop: StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 2000 },
        check_every: 8,
    };

    println!("solving 512x512 UOT (fi = 0.7) with all three solvers...\n");
    let mut plans = Vec::new();
    for kind in SolverKind::ALL {
        let (plan, report) = solve(kind, &problem, opts);
        println!(
            "  {:8} iters={:4}  err={:.3e}  {:7.1} ms  ({:.3} ms/iter)",
            kind.name(),
            report.iters,
            report.err,
            report.seconds * 1e3,
            report.seconds * 1e3 / report.iters.max(1) as f64,
        );
        plans.push(plan);
    }

    // All three implement identical numerics; only memory traffic differs.
    let d_pot = plans[2].max_rel_diff(&plans[0], 1e-6);
    let d_cof = plans[2].max_rel_diff(&plans[1], 1e-6);
    println!("\nmax relative deviation of MAP-UOT vs POT:    {d_pot:.2e}");
    println!("max relative deviation of MAP-UOT vs COFFEE: {d_cof:.2e}");
    assert!(d_pot < 1e-2 && d_cof < 1e-2);
    println!("\nall solvers agree — MAP-UOT just reads the matrix 3x less.");
}
