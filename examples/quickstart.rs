//! Quickstart: the workspace-centric session API.
//!
//! Builds one `SolverSession` per solver kind, solves the same problem
//! with each (watching convergence through an observer), verifies all
//! three agree, then shows the steady-state pattern: one reused session
//! solving a batch with zero heap allocations after warmup — serial and
//! threaded.
//!
//! Threading model in one paragraph: `.threads(t)` gives the session a
//! persistent worker pool (`algo::pool::ThreadPool`). Its `t - 1` workers
//! spawn once at `build` time, park between iterations, and wake on an
//! epoch barrier (atomic generation counter + park/unpark), so a threaded
//! iteration costs zero thread spawns and zero heap allocations — the
//! pool lives exactly as long as the session (or as long as any session
//! sharing its `Arc` via `SessionBuilder::pool`, the pattern
//! `solve_batch` and the coordinator workers use: one pool per OS worker
//! thread, reused for every request). `.affinity(AffinityHint::Pinned)`
//! pins workers to cores; `.backend(ParallelBackend::SpawnPerIter)` keeps
//! the legacy scope-per-iteration dispatch for comparison benches.
//!
//!     cargo run --release --example quickstart
//!
//! Correctness tooling: the unsafe/allocation/concurrency contracts the
//! pool engine relies on are machine-checked — `cargo run -p uotlint`
//! lints `rust/src` for them in seconds (call-graph-aware: an allocation
//! reachable from a hot loop through any chain of helpers is flagged
//! with its chain; exemptions are written `// uotlint: allow(alloc) —
//! reason` above the fn or site, `// uotlint: allow(panic) — reason`
//! for provably-infallible sites in service code), and
//! `cargo run -p uotlint -- --model-check` exhaustively interleaves the
//! pool's epoch-barrier state machine to prove no lost wakeup, no
//! deadlock, exactly-once part execution. Both are required CI gates;
//! nightly Miri/TSan/ASan legs re-run the edge-case and property suites
//! under interpretation and sanitizers. Commands and what each gate
//! guarantees: `EXPERIMENTS.md` §Correctness tooling.

use std::time::Duration;

use map_uot::algo::{
    AffinityHint, CheckEvent, CostKind, Deadline, GeomProblem, KernelKind, ObserverAction,
    Problem, SolverKind, SolverSession, SparseProblem, StopRule, TileSpec,
};
use map_uot::coordinator::{classify_geom, ProblemClass, ONED_AXIS_TOL};
use map_uot::util::telemetry::Roofline;

fn main() {
    // A 512x512 problem: random positive plan, random positive marginals,
    // relaxation exponent fi = er/(er+ep) = 0.7.
    let problem = Problem::random(512, 512, 0.7, 42);
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 2000 };

    println!("solving 512x512 UOT (fi = 0.7) with all three solvers...\n");
    let mut plans = Vec::new();
    for kind in SolverKind::ALL {
        // The builder owns all the knobs; `build` sizes the workspace once.
        let mut session = SolverSession::builder(kind)
            .threads(1)
            .stop(stop)
            .check_every(8)
            .build(&problem);
        let report = session.solve(&problem).expect("no observer to cancel");
        println!(
            "  {:8} iters={:4}  err={:.3e}  {:7.1} ms  ({:.3} ms/iter)",
            kind.name(),
            report.iters,
            report.err,
            report.seconds * 1e3,
            report.seconds * 1e3 / report.iters.max(1) as f64,
        );
        plans.push(session.into_plan());
    }

    // All three implement identical numerics; only memory traffic differs.
    let d_pot = plans[2].max_rel_diff(&plans[0], 1e-6);
    let d_cof = plans[2].max_rel_diff(&plans[1], 1e-6);
    println!("\nmax relative deviation of MAP-UOT vs POT:    {d_pot:.2e}");
    println!("max relative deviation of MAP-UOT vs COFFEE: {d_cof:.2e}");
    assert!(d_pot < 1e-2 && d_cof < 1e-2);
    println!("all solvers agree — MAP-UOT just reads the matrix 3x less.\n");

    // Observers see every check boundary and can cancel (typed
    // Error::Canceled); here one just narrates the first solve's tail.
    let mut watched = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(64)
        .observer(|ev: CheckEvent| {
            println!("  [observer] iter {:4}  err={:.3e}  delta={:.3e}", ev.iters, ev.err, ev.delta);
            ObserverAction::Continue
        })
        .build(&problem);
    watched.solve(&problem).expect("continue-only observer");

    // Steady state: one session, many same-shape problems. After the first
    // solve the hot loop performs zero heap allocations — the service's
    // workers run exactly this pattern.
    let batch: Vec<Problem> = (0..4).map(|s| Problem::random(512, 512, 0.7, s)).collect();
    let mut session = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .build(&batch[0]);
    println!("\nbatch of {} through one reused workspace:", batch.len());
    for (i, outcome) in session.solve_batch(&batch).into_iter().enumerate() {
        let (_plan, report) = outcome.expect("batch solve");
        println!("  problem {i}: iters={:4}  err={:.3e}", report.iters, report.err);
    }

    // Threaded steady state: same contract, persistent pool. The workers
    // spawn once here (at build) and every solve in the batch reuses them
    // — no spawn/join per iteration, no allocations after warmup.
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(2).min(4);
    let mut pooled = SolverSession::builder(SolverKind::MapUot)
        .threads(threads)
        .affinity(AffinityHint::Pinned)
        .stop(stop)
        .build(&batch[0]);
    println!("\nsame batch on a persistent {threads}-thread pinned pool:");
    for (i, outcome) in pooled.solve_batch(&batch).into_iter().enumerate() {
        let (_plan, report) = outcome.expect("pooled batch solve");
        println!(
            "  problem {i}: iters={:4}  err={:.3e}  {:6.1} ms",
            report.iters,
            report.err,
            report.seconds * 1e3
        );
    }

    // Kernel backends and cache tiling. By default (`KernelKind::Auto`,
    // `TileSpec::Auto` — also the CLI's `solve --kernel auto --tile auto`)
    // the session picks the fastest SIMD backend the CPU supports at
    // runtime (AVX2+FMA where detected, with non-temporal plan stores
    // once the matrix outgrows the last-level cache) and sizes the fused
    // sweep's column panels from the detected L1/L2. Everything is
    // overridable for measurement or reproducibility — all backends and
    // tile widths agree within 1e-5 relative (tests/prop_kernels.rs):
    let auto = SolverSession::builder(SolverKind::MapUot).stop(stop).build(&problem);
    println!(
        "\nkernel dispatch: auto resolved to [kernel={} tile={}]",
        auto.policy().kind().name(),
        match auto.policy().tile_cols() {
            0 => "off".to_string(),
            c => c.to_string(),
        }
    );
    let mut portable = SolverSession::builder(SolverKind::MapUot)
        .kernel(KernelKind::Scalar) // portable reference (CLI: --kernel scalar)
        .tile(TileSpec::Off) //        untiled sweep      (CLI: --tile off)
        .stop(stop)
        .build(&problem);
    let report = portable.solve(&problem).expect("no observer to cancel");
    println!(
        "scalar reference, untiled: iters={:4}  err={:.3e}  ({} also honors \
         MAP_UOT_KERNEL / MAP_UOT_TILE env overrides)",
        report.iters,
        report.err,
        "auto"
    );

    // Sparse problems: the same session machinery drives a fused CSR sweep
    // (paper §6 future work). A `SparseProblem` is a validated CSR plan
    // plus the marginals; one iteration streams nnz entries once instead
    // of M·N cells, row blocks are balanced by nonzero count, and the
    // threaded engines reuse the session's persistent pool. Same
    // allocation contract, same observer/cancel support, same CLI surface
    // (`solve --sparse <threshold>`, `[solver] sparse` in the service
    // config).
    let sparse = SparseProblem::from_problem(&problem, 1.5).expect("finite nonnegative plan");
    let mut csr = SolverSession::builder(SolverKind::MapUot)
        .threads(threads)
        .stop(stop)
        .build_sparse(&sparse);
    let report = csr.solve_sparse(&sparse).expect("no observer to cancel");
    println!(
        "\nsparse CSR ({} nnz of {}, density {:.3}): iters={:4}  err={:.3e}  {:6.1} ms",
        sparse.nnz(),
        512 * 512,
        sparse.plan.density(),
        report.iters,
        report.err,
        report.seconds * 1e3
    );
    let _csr_plan = csr.sparse_plan().expect("solve ran"); // still CSR — no densify

    // Materialization-free problems: when the kernel is *geometric*
    // (point clouds + an entropic cost), the plan never needs to exist.
    // Every MAP-UOT iterate is diag(u)·A·diag(v), so the session carries
    // only the scaling vectors u, v — O(m+n) state — and regenerates
    // kernel entries exp(-cost/eps) on the fly with a SIMD fast-exp, on
    // the same engines (same pool), same stop rule/observer/cancel, and
    // the same kernel/tile policy (it selects the exp backend and the
    // generation panel width). Marginal errors come from the carried
    // sums, so convergence checks are O(m+n) too. This is the backend for
    // shapes where the dense plan cannot even be allocated: a 10^5×10^5
    // plan is 40 GB; its matfree state is under 2 MB. CLI:
    // `solve --matfree <eps> --dim 3 --cost sqeuclid`; service:
    // `[solver] matfree = on` + `Service::submit_geom`.
    let geom = GeomProblem::random(2048, 2048, 3, CostKind::SqEuclidean, 0.25, 0.7, 42);
    let mut matfree = SolverSession::builder(SolverKind::MapUot)
        .threads(threads)
        .stop(stop)
        .build_matfree(&geom);
    let report = matfree.solve_matfree(&geom).expect("no observer to cancel");
    let (u, v) = matfree.matfree_scaling().expect("solve ran");
    println!(
        "\nmatfree 2048x2048 (plan never materialized — {} floats of scaling state vs {} plan \
         cells): iters={:4}  err={:.3e}  {:6.1} ms",
        u.len() + v.len(),
        2048usize * 2048,
        report.iters,
        report.err,
        report.seconds * 1e3
    );
    // On-demand output: regenerate any plan row (or materialize the full
    // plan — the one deliberate O(m·n) allocation, only if you ask).
    let mut row = vec![0f32; 2048];
    matfree.matfree_plan_row(&geom, 0, &mut row).expect("row 0 exists");
    println!("matfree plan row 0 mass: {:.4}", row.iter().sum::<f32>());

    // Exact 1D fast path: when the supports live on a line and the cost
    // is |x - y| (the Laplace kernel), every kernel product in the sweep
    // is computed *exactly* in O(m + n) by two prefix/suffix decay
    // recursions over the sorted supports — same fixed point as matfree,
    // near-linear total work, and the answer comes back as the scaling
    // vectors plus a sparse monotone transport list (at most m + n
    // entries) instead of any plan. Backend routing, in decision-table
    // form (the service applies it per request via `classify_geom`;
    // `solve --oned auto|on|off` and `[solver] oned` expose the knob):
    //
    //   d == 1, cost = euclid            -> oned   (exact, O(m+n)/iter)
    //   d > 1 but one axis varies (tol)  -> oned   (projected to that axis)
    //   cost = sqeuclid (Gaussian)       -> matfree (kernel doesn't factor)
    //   d > 1, several axes vary         -> matfree (O(m·n)/iter, O(m+n) state)
    //   plan given, geometry unknown     -> dense / sparse sessions above
    let line = GeomProblem::random(4096, 4096, 1, CostKind::Euclidean, 0.25, 0.7, 42);
    match classify_geom(&line, ONED_AXIS_TOL) {
        ProblemClass::Oned { axis } => println!("\nrouter: 1D-eligible (axis {axis})"),
        ProblemClass::General { reason } => println!("\nrouter: general ({reason})"),
    }
    let mut oned = SolverSession::builder(SolverKind::MapUot).stop(stop).build_oned(&line);
    let report = oned.solve_oned(&line).expect("no observer to cancel");
    let transport = oned.oned_transport().expect("solve ran");
    println!(
        "oned 4096x4096 exact sweep: iters={:4}  err={:.3e}  {:6.1} ms — {} transport \
         entries, created={:.3}, destroyed={:.3}",
        report.iters,
        report.err,
        report.seconds * 1e3,
        transport.entries.len(),
        transport.created,
        transport.destroyed
    );

    // Iteration-count accelerators (the third axis, after memory traffic
    // and parallelism): `.warm(cap)` gives the session an LRU cache of
    // converged scalings keyed by a problem fingerprint — a re-solve of a
    // similar problem (same shape/solver/fi/ε, nearest marginal sketch)
    // starts next to the old fixed point instead of at u = v = 1.
    // `.ti(true)` adds a translation-invariant mass correction before each
    // sweep, removing the slowest (global-mass) convergence mode.
    // `.eps_schedule(from, steps)` runs matfree cache misses down a
    // geometric ε ladder from a coarse bandwidth. All three are exact:
    // they move the starting point or the trajectory, never the fixed
    // point, so the converged plan matches the plain solve within 1e-5
    // (tests/prop_warmstart.rs). CLI: `solve --warm 8 --ti
    // --eps-schedule 1.0:2`; service config: `[solver] warm/ti/
    // eps_schedule`.
    let mut accel = SolverSession::builder(SolverKind::MapUot)
        .threads(threads)
        .stop(stop)
        .warm(8)
        .ti(true)
        .eps_schedule(1.0, 2)
        .build_matfree(&geom);
    let cold_run = accel.solve_matfree(&geom).expect("first solve (cache miss)");
    let warm_run = accel.solve_matfree(&geom).expect("re-solve (cache hit)");
    let (hits, misses) = accel.warm_stats().expect("warm cache is on");
    println!(
        "\naccelerated matfree re-solve: {} iters cold (ε-laddered miss) -> {} iters warm \
         (cache {hits} hits / {misses} misses); converged plans match the plain solve",
        cold_run.iters, warm_run.iters
    );

    // Anytime solves: a `Deadline` observer turns the latency budget into
    // a typed outcome — `Ok(report)` if converged in time, else
    // `Err(Error::Canceled { iters })` with the state intact at the last
    // check boundary (read the partial scaling out of the session).
    let mut bounded = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .observer(Deadline::within(Duration::from_secs(5)))
        .build_matfree(&geom);
    match bounded.solve_matfree(&geom) {
        Ok(r) => println!("deadline-bounded solve finished in {:.1} ms", r.seconds * 1e3),
        Err(e) => println!("deadline hit first: {e}"),
    }

    // In-band telemetry: `.trace(path)` arms the lock-free span recorder —
    // every sweep phase (kernel generation, fused sweep, reduction,
    // convergence check) lands in fixed-capacity per-thread rings, and the
    // record path is allocation-free, so tracing keeps the zero-alloc
    // steady-state contract above. `export_trace()` drains what was
    // recorded: a `.jsonl` path gets one event object per line, any other
    // path gets chrome://tracing JSON (open at ui.perfetto.dev — one track
    // per recording thread, pool workers included). CLI: `solve --trace
    // <path>`, plus `map-uot stats` for the versioned service-metrics
    // JSON and `stats --check-trace <path>` to validate an export.
    let trace_path = std::env::temp_dir().join("quickstart_trace.json");
    let trace_path = trace_path.to_str().expect("utf-8 temp path").to_string();
    let mut traced = SolverSession::builder(SolverKind::MapUot)
        .threads(threads)
        .stop(stop)
        .trace(trace_path.clone())
        .build(&batch[0]);
    let report = traced.solve(&batch[0]).expect("traced solve");
    let spans = traced.export_trace().expect("trace export");
    println!("\ntelemetry: {spans} spans -> {trace_path} (chrome://tracing format)");
    // The analytic roofline line the CLI prints for traced solves, from
    // the solver's pass/access accounting (MAP-UOT: 1 pass, 2 accesses).
    let roof = Roofline::materialized(
        (512 * 512) as u64,
        SolverKind::MapUot.passes_per_iter() as u64,
        SolverKind::MapUot.accesses_per_element() as u64,
        4,
        report.iters as u64,
    );
    println!("{}", roof.cli_line(report.seconds));
}
