//! Domain example: projected supercomputer scaling (paper §5.4, Fig. 16).
//!
//! Uses the Tianhe-1 cluster model to project MAP-UOT / COFFEE / POT
//! distributed scaling at M=N=20480 for both node configurations the
//! paper evaluates, and prints the crossover where communication starts
//! to dominate.
//!
//!     cargo run --release --example cluster_scaling

use map_uot::algo::SolverKind;
use map_uot::config::presets;
use map_uot::sim::cluster;

fn main() {
    const M: usize = 20480;
    for ppn in [8usize, 12] {
        let cfg = presets::tianhe1_cluster(ppn);
        println!("== Tianhe-1 model, {ppn} processes/node, M=N={M} ==");
        println!("{:>6} {:>10} {:>10} {:>10} {:>12}", "procs", "POT", "COFFEE", "MAP-UOT", "MAP eff/proc");
        let procs: &[usize] = if ppn == 8 {
            &[8, 16, 32, 64, 128, 256, 512]
        } else {
            &[12, 24, 48, 96, 192, 384, 768]
        };
        for &p in procs {
            let s = |k| cluster::speedup_vs_pot1(&cfg, k, M, M, p);
            println!(
                "{:>6} {:>9.0}x {:>9.0}x {:>9.0}x {:>11.1}%",
                p,
                s(SolverKind::Pot),
                s(SolverKind::Coffee),
                s(SolverKind::MapUot),
                s(SolverKind::MapUot) / p as f64 * 100.0
            );
        }
        // Communication share at the largest configuration.
        let p = *procs.last().unwrap();
        let comm = cfg.allreduce_s(M, p);
        let total = cluster::iter_time_s(&cfg, SolverKind::MapUot, M, M, p);
        println!(
            "at {p} procs: allreduce is {:.0}% of a MAP-UOT iteration\n",
            comm / total * 100.0
        );
    }
    println!("(model parameters in config::presets::tianhe1_cluster; see DESIGN.md §Substitutions)");
}
