//! Pass 2, interprocedural: the transitive-allocation ban.
//!
//! The per-file v1 rule only saw allocations written *inside* a hot fn;
//! a `vec!` hidden one call away (allocation laundering through a helper)
//! passed. This pass builds a call graph over the whole symbol table from
//! [`crate::parse`] and walks it: any fn reachable from a hot root may
//! not allocate.
//!
//! Resolution is name-based with two precision aids: method calls
//! (`x.f(...)`) resolve only to impl/trait-defined fns, and qualified
//! calls (`Type::f(...)`) prefer fns whose enclosing impl names `Type`.
//! The universe is restricted to the hot core and its helper layer
//! (`algo/`, `util/`): dispatch and setup layers call INTO the core, and
//! resolving into them by bare name only manufactures phantom chains.
//!
//! Escape hatch: `// uotlint: allow(alloc) — reason` above a fn exempts
//! it AND cuts its outgoing edges (an allowed-to-allocate fn's callees
//! are its own business); on an allocation line it exempts that site.

use std::collections::BTreeSet;
use std::collections::HashMap;

use crate::parse::FnDef;

/// Files whose `iterate*` / `fused_*` / `*_pool*` fns are the hot roots.
pub const HOT_FILES: [&str; 8] = [
    "algo/mapuot.rs",
    "algo/pot.rs",
    "algo/coffee.rs",
    "algo/sparse.rs",
    "algo/matfree.rs",
    "algo/parallel.rs",
    "algo/kernels.rs",
    "algo/oned.rs",
];

/// The reachability universe: the hot core plus the helper layer it is
/// allowed to call.
pub const ALLOC_UNIVERSE: [&str; 2] = ["algo/", "util/"];

/// A violation attributed across files (unlike `rules::Violation`, which
/// is per-file).
#[derive(Debug)]
pub struct GlobalViolation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Result of the interprocedural pass, plus the stats the summary prints.
#[derive(Debug, Default)]
pub struct Analysis {
    pub violations: Vec<GlobalViolation>,
    /// Non-test fns in the universe.
    pub fns: usize,
    pub roots: usize,
    pub reachable: usize,
    /// `allow(alloc)` markers honored (fn-level + site-level).
    pub allow_allocs: usize,
}

/// Sweep-kernel name shape; `with_pool`-style builders share the `_pool`
/// suffix but are constructors, not sweep kernels.
pub fn is_hot_name(name: &str) -> bool {
    if name.starts_with("with_") {
        return false;
    }
    name.starts_with("iterate")
        || name.starts_with("fused_")
        || name.contains("_pool")
        || name.starts_with("pool_")
}

/// Run the transitive-allocation rule over the whole tree's fn defs.
/// `all_fns` must be in deterministic (sorted-by-file) order so edge sets
/// and chains are stable run to run.
pub fn analyze(all_fns: &[FnDef]) -> Analysis {
    let fns: Vec<&FnDef> = all_fns
        .iter()
        .filter(|f| !f.is_test && ALLOC_UNIVERSE.iter().any(|d| f.file.starts_with(d)))
        .collect();

    let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, f) in fns.iter().enumerate() {
        by_name.entry(&f.name).or_default().push(i);
    }

    // Edges: method calls resolve to impl/trait fns only; qualified calls
    // prefer a matching impl type; bare calls to any fn of that name.
    let mut edges: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); fns.len()];
    for (i, f) in fns.iter().enumerate() {
        if f.allow_alloc {
            continue;
        }
        for call in &f.calls {
            let Some(cands) = by_name.get(call.name.as_str()) else {
                continue;
            };
            if let Some(qual) = &call.qual {
                let typed: Vec<usize> = cands
                    .iter()
                    .copied()
                    .filter(|&j| fns[j].impl_type.as_deref() == Some(qual.as_str()))
                    .collect();
                if !typed.is_empty() {
                    edges[i].extend(typed);
                    continue;
                }
            }
            for &j in cands {
                if call.is_method && !fns[j].in_impl {
                    continue;
                }
                edges[i].insert(j);
            }
        }
    }

    let roots: Vec<usize> = fns
        .iter()
        .enumerate()
        .filter(|(_, f)| HOT_FILES.contains(&f.file.as_str()) && is_hot_name(&f.name))
        .map(|(i, _)| i)
        .collect();

    // BFS with parent pointers for chain reporting.
    let mut parent: Vec<Option<usize>> = vec![None; fns.len()];
    let mut seen: Vec<bool> = vec![false; fns.len()];
    for &r in &roots {
        seen[r] = true;
    }
    let mut order: Vec<usize> = roots.clone();
    let mut qi = 0;
    while qi < order.len() {
        let u = order[qi];
        qi += 1;
        for &v in &edges[u] {
            if !seen[v] {
                seen[v] = true;
                parent[v] = Some(u);
                order.push(v);
            }
        }
    }

    let mut out = Analysis {
        fns: fns.len(),
        roots: roots.len(),
        reachable: order.len(),
        ..Analysis::default()
    };
    for &i in &order {
        let f = fns[i];
        if f.allow_alloc {
            out.allow_allocs += 1;
            continue;
        }
        for site in &f.allocs {
            if site.allowed {
                out.allow_allocs += 1;
                continue;
            }
            let mut chain = vec![f.name.as_str()];
            let mut k = i;
            while let Some(p) = parent[k] {
                k = p;
                chain.push(fns[k].name.as_str());
            }
            chain.reverse();
            out.violations.push(GlobalViolation {
                file: f.file.clone(),
                line: site.line,
                rule: "alloc",
                msg: format!(
                    "`{}` in `{}`, reachable from hot root via {}",
                    site.pattern,
                    f.name,
                    chain.join(" -> ")
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::parse::parse_file;

    fn analyze_sources(files: &[(&str, &str)]) -> Analysis {
        let mut all = Vec::new();
        for (rel, src) in files {
            all.extend(parse_file(rel, &lex(src)));
        }
        analyze(&all)
    }

    #[test]
    fn cross_file_allocation_laundering_is_caught() {
        // The hot root itself is clean; the helper it calls (in another
        // file) allocates — exactly what the per-file v1 rule missed.
        let a = analyze_sources(&[
            ("algo/kernels.rs", "pub fn iterate_row(n: usize) {\n    helper(n);\n}\n"),
            ("util/scratch.rs", "pub fn helper(n: usize) {\n    let v = vec![0f32; n];\n}\n"),
        ]);
        assert_eq!(a.violations.len(), 1);
        let v = &a.violations[0];
        assert_eq!(v.file, "util/scratch.rs");
        assert!(v.msg.contains("iterate_row -> helper"), "chain in {}", v.msg);
    }

    #[test]
    fn unreachable_allocations_pass() {
        let a = analyze_sources(&[
            ("algo/kernels.rs", "pub fn iterate_row(n: usize) {\n    let x = n + 1;\n}\n"),
            ("util/setup.rs", "pub fn build(n: usize) -> Vec<f32> {\n    vec![0f32; n]\n}\n"),
        ]);
        assert!(a.violations.is_empty());
        assert_eq!(a.roots, 1);
        assert_eq!(a.reachable, 1);
    }

    #[test]
    fn qualified_calls_prefer_the_matching_impl() {
        // Two `new` fns; the hot root calls `Scratch::new`, whose impl is
        // clean. The allocating `Pod::new` must not be dragged in by the
        // bare name.
        let a = analyze_sources(&[
            (
                "algo/kernels.rs",
                "pub fn iterate_row(n: usize) {\n    let s = Scratch::new(n);\n}\nimpl Scratch {\n    fn new(n: usize) -> Self {\n        Scratch\n    }\n}\n",
            ),
            (
                "util/pod.rs",
                "impl Pod {\n    fn new(n: usize) -> Self {\n        let v = vec![0u8; n];\n        Pod\n    }\n}\n",
            ),
        ]);
        assert!(a.violations.is_empty(), "{:?}", a.violations);
    }

    #[test]
    fn with_builders_are_not_hot_roots() {
        let a = analyze_sources(&[(
            "algo/parallel.rs",
            "pub fn with_pool(n: usize) {\n    let v = Vec::with_capacity(n);\n}\n",
        )]);
        assert!(a.violations.is_empty());
        assert_eq!(a.roots, 0);
    }

    #[test]
    fn allow_marker_cuts_the_fns_outgoing_edges() {
        // `baseline` is allowed to allocate, so its callee's allocation
        // must not be reported either — the marker cuts the whole edge.
        let a = analyze_sources(&[(
            "algo/kernels.rs",
            "// uotlint: allow(alloc) — comparator, not a hot path.\npub fn iterate_baseline(n: usize) {\n    alloc_helper(n);\n}\npub fn alloc_helper(n: usize) {\n    let v = vec![0f32; n];\n}\n",
        )]);
        assert!(a.violations.is_empty(), "{:?}", a.violations);
        assert_eq!(a.allow_allocs, 1);
    }

    #[test]
    fn outside_universe_calls_do_not_form_chains() {
        // A coordinator fn with the same name as a hot callee must not be
        // resolved into (phantom chain) — it is outside the universe.
        let a = analyze_sources(&[
            ("algo/kernels.rs", "pub fn iterate_row(n: usize) {\n    dispatch(n);\n}\n"),
            (
                "coordinator/service.rs",
                "pub fn dispatch(n: usize) {\n    let v = vec![0f32; n];\n}\n",
            ),
        ]);
        assert!(a.violations.is_empty());
        assert_eq!(a.fns, 1, "coordinator fn excluded from the universe");
    }

    #[test]
    fn test_fns_are_excluded() {
        let a = analyze_sources(&[(
            "algo/kernels.rs",
            "#[cfg(test)]\nmod tests {\n    fn iterate_fake(n: usize) {\n        let v = vec![0f32; n];\n    }\n}\n",
        )]);
        assert!(a.violations.is_empty());
        assert_eq!(a.fns, 0);
    }
}
