//! uotlint — repo-local static analysis for the MAP-UOT core.
//!
//! Enforces the contracts the solver's soundness and performance rest on
//! (see [`rules`] for the rule set). Run from anywhere in the workspace:
//!
//! ```text
//! cargo run -p uotlint            # lint rust/src (CI gate; exit 1 on violations)
//! cargo run -p uotlint -- <path>  # lint another file/tree (rule self-tests, demos)
//! ```
//!
//! Output is `path:line: [rule] message`, one line per violation, plus a
//! summary with the unsafe-site and exemption counts so audit drift is
//! visible even when the tree is clean.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let (root, display_prefix) = match std::env::args().nth(1) {
        Some(arg) => (PathBuf::from(arg), String::new()),
        // Resolve relative to this crate so `cargo run -p uotlint` works
        // from any CWD in the workspace.
        None => (
            Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src"),
            "rust/src/".to_string(),
        ),
    };
    if !root.exists() {
        eprintln!("uotlint: no such path: {}", root.display());
        return ExitCode::FAILURE;
    }

    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    files.sort();

    let mut violations = 0usize;
    let mut unsafe_sites = 0usize;
    let mut alloc_allows = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let rel = if rel.is_empty() {
            // `root` was a single file: rules key off the path suffix, so
            // use the file name itself.
            path.file_name().unwrap_or_default().to_string_lossy().into_owned()
        } else {
            rel
        };
        let source = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("uotlint: cannot read {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        let report = rules::check_file(&rel, &source);
        unsafe_sites += report.unsafe_sites;
        alloc_allows += report.alloc_allows;
        violations += report.violations.len();
        for v in &report.violations {
            println!("{display_prefix}{rel}:{}: [{}] {}", v.line, v.rule, v.msg);
        }
    }

    println!(
        "uotlint: {} files, {} unsafe sites, {} allow(alloc) exemptions, {} violation{}",
        files.len(),
        unsafe_sites,
        alloc_allows,
        violations,
        if violations == 1 { "" } else { "s" },
    );
    if violations == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Recursively gather `.rs` files under `path` (or `path` itself).
fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return;
    }
    let Ok(entries) = std::fs::read_dir(path) else {
        return;
    };
    for entry in entries.flatten() {
        collect_rs_files(&entry.path(), out);
    }
}
