//! uotlint — repo-local static analysis for the MAP-UOT core.
//!
//! Two engines behind one binary:
//!
//! * **Lint** — the per-file contract rules ([`rules`]) plus the
//!   interprocedural transitive-allocation rule ([`callgraph`], built on
//!   the [`parse`] symbol table): any fn reachable from a hot root
//!   (`iterate*` / `fused_*` / `*_pool*` in the solver files) may not
//!   allocate, no matter how many calls deep.
//! * **Model check** — [`sched`] exhaustively interleaves the pool
//!   epoch-barrier state machine (`map_uot::algo::pool::model`) and
//!   proves no lost wakeup, no deadlock, exactly-once part execution and
//!   barrier drain on panic; the mutation matrix seeds known protocol
//!   bugs and requires each to be caught.
//!
//! ```text
//! cargo run -p uotlint                          # lint rust/src (CI gate)
//! cargo run -p uotlint -- <path>                # lint another file/tree
//! cargo run -p uotlint -- --model-check         # fast interleaving sweep (CI gate)
//! cargo run -p uotlint -- --model-check-full    # 3-worker sweep (nightly)
//! cargo run -p uotlint -- --model-check-mutations  # seeded-bug matrix (CI gate)
//! ```
//!
//! Lint output is `path:line: [rule] message`, one line per violation,
//! plus a summary with per-rule violation counts and the unsafe-site /
//! exemption tallies so audit drift is visible even when the tree is
//! clean. Exit code 1 on any violation, escaped mutation, or
//! counterexample.

mod callgraph;
mod lexer;
mod parse;
mod rules;
mod sched;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    match std::env::args().nth(1).as_deref() {
        Some("--model-check") => model_check(false),
        Some("--model-check-full") => model_check(true),
        Some("--model-check-mutations") => model_check_mutations(),
        arg => lint(arg),
    }
}

/// Lint mode: per-file rules + the call-graph allocation rule.
fn lint(arg: Option<&str>) -> ExitCode {
    let (root, display_prefix) = match arg {
        Some(arg) => (PathBuf::from(arg), String::new()),
        // Resolve relative to this crate so `cargo run -p uotlint` works
        // from any CWD in the workspace.
        None => (
            Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src"),
            "rust/src/".to_string(),
        ),
    };
    if !root.exists() {
        eprintln!("uotlint: no such path: {}", root.display());
        return ExitCode::FAILURE;
    }

    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    files.sort();

    // (file, line, rule, msg) across both passes, sorted for stable output.
    let mut findings: Vec<(String, usize, &'static str, String)> = Vec::new();
    let mut unsafe_sites = 0usize;
    let mut panic_allows = 0usize;
    let mut lock_sites = 0usize;
    let mut all_fns: Vec<parse::FnDef> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let rel = if rel.is_empty() {
            // `root` was a single file: rules key off the path suffix, so
            // use the file name itself.
            path.file_name().unwrap_or_default().to_string_lossy().into_owned()
        } else {
            rel
        };
        let source = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("uotlint: cannot read {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        // Lex once; both passes read the same token stream.
        let lines = lexer::lex(&source);
        let report = rules::check_file(&rel, &lines);
        unsafe_sites += report.unsafe_sites;
        panic_allows += report.panic_allows;
        lock_sites += report.lock_sites;
        findings.extend(
            report.violations.into_iter().map(|v| (rel.clone(), v.line, v.rule, v.msg)),
        );
        all_fns.extend(parse::parse_file(&rel, &lines));
    }

    let analysis = callgraph::analyze(&all_fns);
    findings.extend(analysis.violations.into_iter().map(|v| (v.file, v.line, v.rule, v.msg)));
    findings.sort();

    let mut per_rule: BTreeMap<&'static str, usize> = BTreeMap::new();
    for (file, line, rule, msg) in &findings {
        *per_rule.entry(*rule).or_insert(0) += 1;
        println!("{display_prefix}{file}:{line}: [{rule}] {msg}");
    }
    let by_rule: Vec<String> =
        ["alloc", "panic", "lock", "safety", "sendsync", "encapsulation", "telemetry"]
            .iter()
            .map(|r| format!("{r} {}", per_rule.get(r).copied().unwrap_or(0)))
            .collect();

    println!(
        "uotlint: {} files, {} fns, {} hot roots, {} reachable, {} unsafe sites, \
         {} allow(alloc), {} allow(panic), {} lock sites",
        files.len(),
        analysis.fns,
        analysis.roots,
        analysis.reachable,
        unsafe_sites,
        analysis.allow_allocs,
        panic_allows,
        lock_sites,
    );
    println!(
        "uotlint: {} violation{} ({})",
        findings.len(),
        if findings.len() == 1 { "" } else { "s" },
        by_rule.join(", "),
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Exhaustive interleaving sweep over the pool epoch-barrier model.
fn model_check(full: bool) -> ExitCode {
    match sched::check_protocol(full) {
        Ok(lines) => {
            for line in lines {
                println!("{line}");
            }
            ExitCode::SUCCESS
        }
        Err(cx) => {
            print!("{}", sched::render(&cx));
            ExitCode::FAILURE
        }
    }
}

/// Seeded-bug matrix: the checker must catch every known mutation.
fn model_check_mutations() -> ExitCode {
    match sched::check_mutations(false) {
        Ok(lines) => {
            for line in lines {
                println!("{line}");
            }
            ExitCode::SUCCESS
        }
        Err(msg) => {
            println!("{msg}");
            ExitCode::FAILURE
        }
    }
}

/// Recursively gather `.rs` files under `path` (or `path` itself).
fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return;
    }
    let Ok(entries) = std::fs::read_dir(path) else {
        return;
    };
    for entry in entries.flatten() {
        collect_rs_files(&entry.path(), out);
    }
}
