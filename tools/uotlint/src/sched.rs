//! The interleaving checker: exhaustive DFS over schedules of the pool
//! epoch-barrier model (`map_uot::algo::pool::model`, `model_check`
//! feature).
//!
//! Each state's runnable threads fan out one shared-memory op at a time
//! under sequential consistency; visited-state pruning keeps the space
//! finite (spin iterations are stutter steps, so "park after one failed
//! read" covers every spin count). Properties checked:
//!
//! * every runnable schedule terminates (no deadlock — in particular no
//!   lost wakeup: park tokens have NO spurious wakes here, so a protocol
//!   that relies on them deadlocks in the model);
//! * every `(epoch, part)` executes exactly once (no stale-epoch rerun,
//!   no skipped part);
//! * the job slot read by a worker always belongs to the current epoch;
//! * `remaining` never underflows;
//! * the dispatcher observes `poisoned` exactly when a worker panicked
//!   that epoch (barrier drains on panic instead of deadlocking).
//!
//! The mutation matrix (`--model-check-mutations`) seeds each known
//! protocol-breaking edit (`model::BUGS`) and requires the checker to
//! catch every one — the checker is itself under test.

use std::collections::HashSet;
use std::rc::Rc;

use map_uot::algo::pool::model::{trace_to_vec, Config, State, Step, TraceNode, BUGS};

/// Hard cap on explored states per config (explosion guard; the full
/// sweep's largest config is ~11k states, so this is two decades of
/// headroom).
const MAX_STATES: usize = 2_000_000;

/// A schedule that broke a property: the config, what broke, and the
/// op-by-op interleaving that got there.
#[derive(Debug)]
pub struct Counterexample {
    pub config: Config,
    pub message: String,
    pub trace: Vec<String>,
}

/// One config fully explored.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub states: usize,
    pub maximal_runs: usize,
}

/// Exhaustively explore every schedule of `cfg`.
pub fn explore(cfg: &Config) -> Result<Stats, Counterexample> {
    let fail = |message: String, trace: &Option<Rc<TraceNode>>| Counterexample {
        config: *cfg,
        message,
        trace: trace_to_vec(trace),
    };
    let mut visited: HashSet<State> = HashSet::new();
    let mut maximal_runs = 0usize;
    let mut stack: Vec<(State, Option<Rc<TraceNode>>)> = vec![(State::initial(cfg), None)];
    while let Some((st, trace)) = stack.pop() {
        if !visited.insert(st.clone()) {
            continue;
        }
        if visited.len() > MAX_STATES {
            return Err(fail(format!("state-space explosion (> {MAX_STATES} states)"), &trace));
        }
        let threads = st.runnable();
        if threads.is_empty() {
            if st.is_final() {
                st.check_final(cfg).map_err(|m| fail(m, &trace))?;
                maximal_runs += 1;
                continue;
            }
            return Err(fail(
                format!("deadlock: live threads but nothing runnable ({})", st.describe_threads()),
                &trace,
            ));
        }
        for tid in threads {
            match st.step(tid, cfg) {
                Step::Next(next, label) => {
                    let node = Rc::new(TraceNode { label, prev: trace.clone() });
                    stack.push((next, Some(node)));
                }
                Step::Violation(message) => return Err(fail(message, &trace)),
            }
        }
    }
    Ok(Stats { states: visited.len(), maximal_runs })
}

/// The checker's configuration sweep. `full` (nightly) adds the 3-worker
/// shapes; the fast (per-commit) sweep stops at 2 workers. Every shape
/// runs 2 epochs — the minimum that exercises re-publish over parked
/// workers, where the lost-wakeup and stale-token hazards live — plus a
/// dispatcher-panic and a worker-panic variant.
pub fn sweep(full: bool) -> Vec<Config> {
    let worker_counts: &[usize] = if full { &[1, 2, 3] } else { &[1, 2] };
    let mut out = Vec::new();
    for &workers in worker_counts {
        for parts in 2..=workers + 1 {
            let base = Config { workers, parts, epochs: 2, panic: None, bug: None };
            out.push(base);
            out.push(Config { panic: Some((0, 0)), ..base });
            out.push(Config { panic: Some((1, parts - 1)), ..base });
        }
    }
    out
}

/// Run the sweep; `Ok` carries per-config lines for the report.
pub fn check_protocol(full: bool) -> Result<Vec<String>, Counterexample> {
    let mut lines = Vec::new();
    let mut total = 0usize;
    for cfg in sweep(full) {
        let stats = explore(&cfg)?;
        total += stats.states;
        lines.push(format!(
            "ok   {}: {} states, {} maximal runs",
            cfg.describe(),
            stats.states,
            stats.maximal_runs
        ));
    }
    lines.push(format!("model check: {total} states explored, every schedule sound"));
    Ok(lines)
}

/// Seed every known protocol-breaking mutation and require the checker to
/// catch it. `Err` names the first mutation that escaped.
pub fn check_mutations(full: bool) -> Result<Vec<String>, String> {
    let mut lines = Vec::new();
    for bug in BUGS {
        let caught = sweep(full).into_iter().find_map(|base| {
            let cfg = Config { bug: Some(bug), ..base };
            explore(&cfg).err().map(|cx| (cfg, cx))
        });
        match caught {
            Some((cfg, cx)) => lines.push(format!(
                "ok   mutation {bug:?} caught in {}: {}",
                cfg.describe(),
                cx.message
            )),
            None => return Err(format!("MUTATION ESCAPED: {bug:?} passed every sweep config")),
        }
    }
    lines.push(format!("mutation matrix: {}/{} seeded bugs caught", BUGS.len(), BUGS.len()));
    Ok(lines)
}

/// Format a counterexample for the console: config, property, then the
/// tail of the interleaving that broke it.
pub fn render(cx: &Counterexample) -> String {
    let mut out = format!("FAIL {}: {}\n", cx.config.describe(), cx.message);
    let tail_from = cx.trace.len().saturating_sub(20);
    if tail_from > 0 {
        out.push_str(&format!("    ... {tail_from} earlier steps elided ...\n"));
    }
    for line in &cx.trace[tail_from..] {
        out.push_str(&format!("    {line}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use map_uot::algo::pool::model::Bug;

    #[test]
    fn faithful_protocol_passes_exhaustively() {
        let cfg = Config { workers: 1, parts: 2, epochs: 2, panic: None, bug: None };
        let stats = explore(&cfg).unwrap_or_else(|cx| panic!("{}", render(&cx)));
        assert!(stats.states > 0 && stats.maximal_runs > 0);
    }

    #[test]
    fn worker_panic_still_drains_the_barrier() {
        let cfg = Config { workers: 2, parts: 3, epochs: 2, panic: Some((1, 2)), bug: None };
        explore(&cfg).unwrap_or_else(|cx| panic!("{}", render(&cx)));
    }

    #[test]
    fn dropped_unpark_is_caught_as_deadlock() {
        // The seeded-bug satellite: the barrier-closing worker forgets
        // `caller.unpark()`; with no spurious wakes the dispatcher must
        // park forever, and the checker must see that as a deadlock.
        let caught = sweep(false).into_iter().find_map(|base| {
            explore(&Config { bug: Some(Bug::DropWorkerUnpark), ..base }).err()
        });
        let cx = caught.expect("DropWorkerUnpark must be caught");
        assert!(cx.message.contains("deadlock"), "{}", cx.message);
        assert!(!cx.trace.is_empty(), "counterexample carries its interleaving");
    }

    #[test]
    fn full_fast_sweep_is_clean() {
        let lines = check_protocol(false).unwrap_or_else(|cx| panic!("{}", render(&cx)));
        assert!(lines.last().is_some_and(|l| l.contains("every schedule sound")));
    }

    #[test]
    fn every_seeded_mutation_is_caught() {
        let lines = check_mutations(false).expect("no mutation may escape");
        assert!(lines.last().is_some_and(|l| l.contains("5/5")));
    }
}
