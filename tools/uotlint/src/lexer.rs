//! Line-oriented lexical pass: split each source line into its *code* part
//! (string/char literal contents blanked, comments removed) and its
//! *comment* part (line comments and block-comment interiors).
//!
//! The rules only need token-level facts — "does `unsafe` appear as code
//! on this line", "does the comment above say `SAFETY:`" — so a full
//! parse is unnecessary; what *is* necessary is never mistaking a comment
//! or a string literal for code (a doc example mentioning `_mm256_add_ps`
//! must not trip the intrinsics rule). Block comments carry state across
//! lines; everything else is line-local.

/// One source line after lexing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Line {
    /// Code with comments removed and literal contents blanked (string
    /// literals become `""`, char literals become `' '`).
    pub code: String,
    /// Comment text on this line (line comment or block-comment interior).
    pub comment: String,
}

/// Lex a whole file into per-line code/comment splits.
pub fn lex(source: &str) -> Vec<Line> {
    let mut out = Vec::new();
    // Nesting depth of /* */ (Rust block comments nest).
    let mut block_depth = 0usize;
    for raw in source.lines() {
        out.push(lex_line(raw, &mut block_depth));
    }
    out
}

fn lex_line(raw: &str, block_depth: &mut usize) -> Line {
    let bytes = raw.as_bytes();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    while i < bytes.len() {
        if *block_depth > 0 {
            if bytes[i..].starts_with(b"*/") {
                *block_depth -= 1;
                i += 2;
            } else if bytes[i..].starts_with(b"/*") {
                *block_depth += 1;
                i += 2;
            } else {
                comment.push(bytes[i] as char);
                i += 1;
            }
            continue;
        }
        if bytes[i..].starts_with(b"//") {
            comment.push_str(&raw[i..]);
            break;
        }
        if bytes[i..].starts_with(b"/*") {
            *block_depth += 1;
            i += 2;
            continue;
        }
        match bytes[i] {
            b'"' => i = skip_string(bytes, i, &mut code),
            // Raw strings: r"..." / r#"..."# (one guard level is all the
            // tree uses; deeper nesting would need a counter).
            b'r' if bytes[i..].starts_with(b"r\"") || bytes[i..].starts_with(b"r#\"") => {
                i = skip_raw_string(bytes, i, &mut code)
            }
            b'\'' => i = skip_char_or_lifetime(bytes, i, &mut code),
            b => {
                code.push(b as char);
                i += 1;
            }
        }
    }
    Line { code, comment }
}

/// Skip a `"..."` literal (escapes honored); pushes `""` onto `code`.
fn skip_string(bytes: &[u8], start: usize, code: &mut String) -> usize {
    code.push_str("\"\"");
    let mut i = start + 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    // Unterminated on this line (multi-line string): treat the rest as
    // literal content. Multi-line strings do not occur in rust/src; if one
    // appears the next line is misread as code, which is conservative for
    // every rule (it can only over-report, never hide a violation).
    i
}

/// Skip `r"..."` / `r#"..."#`; pushes `""` onto `code`.
fn skip_raw_string(bytes: &[u8], start: usize, code: &mut String) -> usize {
    code.push_str("\"\"");
    let hashed = bytes[start + 1] == b'#';
    let close: &[u8] = if hashed { b"\"#" } else { b"\"" };
    let mut i = start + if hashed { 3 } else { 2 };
    while i < bytes.len() {
        if bytes[i..].starts_with(close) {
            return i + close.len();
        }
        i += 1;
    }
    i
}

/// Distinguish a char literal (`'x'`, `'\n'`) from a lifetime (`'a`,
/// `'static`): a char literal closes with `'` within one (possibly
/// escaped) character; a lifetime never closes. Pushes `' '` for char
/// literals, the bare quote for lifetimes.
fn skip_char_or_lifetime(bytes: &[u8], start: usize, code: &mut String) -> usize {
    let rest = &bytes[start + 1..];
    let lit_len = match rest {
        [b'\\', _, b'\'', ..] => Some(4),             // '\n'
        [c, b'\'', ..] if *c != b'\'' => Some(3),     // 'x'
        _ => None,
    };
    match lit_len {
        Some(len) => {
            code.push_str("' '");
            start + len
        }
        None => {
            code.push('\'');
            start + 1
        }
    }
}

/// Comment text of the run of comment-only / attribute-only lines
/// immediately above `idx` (no blank lines allowed in between).
pub fn comment_run_above(lines: &[Line], idx: usize) -> String {
    let mut texts: Vec<&str> = Vec::new();
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        if code.is_empty() && !l.comment.trim().is_empty() {
            texts.push(&l.comment);
        } else if code.starts_with("#[") || code.starts_with("#![") {
            continue;
        } else {
            break;
        }
    }
    texts.join("\n")
}

/// True if `needle` occurs in `hay` as a whole word (not a substring of a
/// longer identifier).
pub fn contains_word(hay: &str, needle: &str) -> bool {
    find_words(hay, needle).next().is_some()
}

/// Byte offsets of whole-word occurrences of `needle` in `hay`. A word
/// boundary is only required on the sides where the needle itself starts
/// or ends with an identifier character (so `".collect()"` matches after
/// an identifier, but `"collect"` does not match inside `recollect`).
pub fn find_words<'a>(hay: &'a str, needle: &'a str) -> impl Iterator<Item = usize> + 'a {
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let needs_before = needle.as_bytes().first().copied().is_some_and(is_ident);
    let needs_after = needle.as_bytes().last().copied().is_some_and(is_ident);
    hay.match_indices(needle).filter_map(move |(i, _)| {
        let before_ok = !needs_before || i == 0 || !is_ident(hay.as_bytes()[i - 1]);
        let end = i + needle.len();
        let after_ok = !needs_after || end >= hay.len() || !is_ident(hay.as_bytes()[end]);
        (before_ok && after_ok).then_some(i)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_split_out() {
        let l = &lex("let x = 1; // SAFETY: fine")[0];
        assert_eq!(l.code, "let x = 1; ");
        assert_eq!(l.comment, "// SAFETY: fine");
    }

    #[test]
    fn block_comments_span_lines_and_nest() {
        let src = "a /* one\n /* two */ still\n done */ b";
        let c = codes(src);
        assert_eq!(c[0], "a ");
        assert_eq!(c[1], "");
        assert_eq!(c[2], " b");
        let l = &lex(src)[1];
        assert!(l.comment.contains("still"));
    }

    #[test]
    fn strings_are_blanked() {
        assert_eq!(codes(r#"call("unsafe // not code")"#)[0], r#"call("")"#);
        assert_eq!(codes(r#"x = r"vec! inside raw";"#)[0], "x = \"\";");
        assert_eq!(codes("m = r#\"quoted \" mark\"#;")[0], "m = \"\";");
        assert_eq!(codes(r#"s = "esc \" quote unsafe";"#)[0], "s = \"\";");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        assert_eq!(codes(r"let c = '\n'; let q = '{';")[0], "let c = ' '; let q = ' ';");
        assert_eq!(codes("fn f<'a>(x: &'a str) {}")[0], "fn f<'a>(x: &'a str) {}");
        // A brace inside a char literal must not change brace depth.
        assert!(!codes("let open = '{';")[0].contains('{'));
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("unsafe {", "unsafe"));
        assert!(!contains_word("unsafe_op_in_unsafe_fn", "unsafe"));
        assert!(contains_word("x.collect()", ".collect()"));
        assert!(!contains_word("recollect()", "collect"));
    }
}
