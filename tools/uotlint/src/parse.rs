//! Pass 1 of the two-pass analyzer: item boundaries, fn signatures, call
//! sites and allocation sites for every file — the symbol table the
//! call-graph rules (see [`crate::callgraph`]) are built from.
//!
//! Still a lexer-grade parser (zero deps, no `syn`): brace depth tracks
//! item nesting, `impl`/`trait` headers record the self type so
//! `Type::method(...)` calls resolve precisely, and multi-line fn
//! signatures are carried until their `{` opens. The restricted grammar
//! the rules need — who defines fns, who calls whom, who allocates — is
//! exactly what survives this approximation.

use crate::lexer::{comment_run_above, contains_word, Line};

/// Allocating constructs the transitive-allocation rule bans on hot
/// paths.
pub const ALLOC_PATTERNS: [&str; 9] = [
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    ".to_vec()",
    ".collect()",
    "Box::new",
    "String::new",
    ".to_string()",
    "format!",
];

/// The escape marker for the allocation rules.
pub const ALLOW_ALLOC: &str = "uotlint: allow(alloc)";

/// Reserved words that look like call/indexing prefixes but are not.
pub const KEYWORDS: [&str; 37] = [
    "if", "else", "while", "match", "for", "loop", "return", "in", "as", "let", "move", "ref",
    "mut", "pub", "fn", "impl", "use", "mod", "struct", "enum", "trait", "type", "where",
    "unsafe", "dyn", "box", "break", "continue", "crate", "self", "Self", "super", "static",
    "const", "extern", "async", "await",
];

/// One call site inside a fn body.
#[derive(Debug, Clone)]
pub struct Call {
    pub name: String,
    /// Preceded by `.` — resolves only to impl/trait-defined fns.
    pub is_method: bool,
    /// `Qual::name(...)` path qualifier (last segment), if any.
    pub qual: Option<String>,
}

/// One allocation site inside a fn body.
#[derive(Debug, Clone)]
pub struct AllocSite {
    pub pattern: &'static str,
    pub line: usize,
    /// Carries a same-line `allow(alloc)` marker.
    pub allowed: bool,
}

/// One parsed fn definition.
#[derive(Debug, Clone)]
pub struct FnDef {
    pub name: String,
    /// Lint-root-relative path with `/` separators.
    pub file: String,
    pub line: usize,
    /// Defined inside an `impl` or `trait` block (method-call target).
    pub in_impl: bool,
    /// Self type of the enclosing impl/trait, for qualified resolution.
    pub impl_type: Option<String>,
    /// Defined under a depth-0 `#[cfg(test)]`.
    pub is_test: bool,
    /// Carries an `allow(alloc)` marker above the definition: its own
    /// allocations are exempt AND its outgoing calls are cut from the
    /// reachability traversal (an allowed-to-allocate fn's callees are
    /// its own business).
    pub allow_alloc: bool,
    pub calls: Vec<Call>,
    pub allocs: Vec<AllocSite>,
}

/// Parse one lexed file into its fn definitions.
pub fn parse_file(rel: &str, lines: &[Line]) -> Vec<FnDef> {
    let mut fns: Vec<FnDef> = Vec::new();
    let mut depth = 0usize;
    let mut in_test = false;
    // (entry depth, self type) of open impl/trait blocks.
    let mut impl_stack: Vec<(usize, Option<String>)> = Vec::new();
    let mut pending_impl: Option<Option<String>> = None;
    // (index into fns, entry depth) of open fn bodies.
    let mut fn_stack: Vec<(usize, usize)> = Vec::new();
    // A fn header seen but its `{` not yet (multi-line signatures).
    let mut pending_fn: Option<FnDef> = None;

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = line.code.as_str();
        let trimmed = code.trim();
        if !in_test && depth == 0 && trimmed.starts_with("#[cfg(test)]") {
            in_test = true;
        }

        // impl/trait block entry (method-call resolution targets).
        if starts_item(trimmed) {
            let ty = impl_self_type(trimmed);
            if code.contains('{') {
                impl_stack.push((depth, ty));
            } else if !code.contains(';') {
                pending_impl = Some(ty);
            }
        } else if let Some(ty) = pending_impl.take() {
            if code.contains('{') {
                impl_stack.push((depth, ty));
            } else if !code.contains(';') {
                pending_impl = Some(ty);
            }
        }

        // fn definition tracking.
        let mut fn_def_end: Option<usize> = None;
        if let Some(off) = crate::lexer::find_words(code, "fn").next() {
            let after = &code[off + 2..];
            let ws = after.len() - after.trim_start().len();
            let rest = &after[ws..];
            let name_len = ident_len(rest);
            if name_len > 0 {
                let name = &rest[..name_len];
                fn_def_end = Some(off + 2 + ws + name_len);
                let above = comment_run_above(lines, idx);
                let allow = above.contains(ALLOW_ALLOC) || line.comment.contains(ALLOW_ALLOC);
                let def = FnDef {
                    name: name.to_string(),
                    file: rel.to_string(),
                    line: lineno,
                    in_impl: !impl_stack.is_empty(),
                    impl_type: impl_stack.last().and_then(|(_, t)| t.clone()),
                    is_test: in_test,
                    allow_alloc: allow,
                    calls: Vec::new(),
                    allocs: Vec::new(),
                };
                let tail = &code[off..];
                if tail.contains('{') {
                    fns.push(def);
                    fn_stack.push((fns.len() - 1, depth));
                    pending_fn = None;
                } else if tail.contains(';') {
                    pending_fn = None; // trait declaration, no body
                } else {
                    pending_fn = Some(def);
                }
            }
        }
        if pending_fn.is_some() && fn_def_end.is_none() {
            if code.contains('{') {
                if let Some(def) = pending_fn.take() {
                    fns.push(def);
                    fn_stack.push((fns.len() - 1, depth));
                }
            } else if code.contains(';') {
                pending_fn = None;
            }
        }

        // Call + alloc sites, attributed to the innermost open fn.
        if let Some(&(fi, _)) = fn_stack.last() {
            collect_call_sites(code, fn_def_end, &mut fns[fi].calls);
            for pat in ALLOC_PATTERNS {
                if contains_word(code, pat) {
                    fns[fi].allocs.push(AllocSite {
                        pattern: pat,
                        line: lineno,
                        allowed: line.comment.contains(ALLOW_ALLOC),
                    });
                }
            }
        }

        // Brace upkeep.
        for ch in code.chars() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth = depth.saturating_sub(1);
                    if fn_stack.last().is_some_and(|&(_, d)| d == depth) {
                        fn_stack.pop();
                    }
                    if impl_stack.last().is_some_and(|&(d, _)| d == depth) {
                        impl_stack.pop();
                    }
                }
                _ => {}
            }
        }
    }
    fns
}

/// The line introduces an `impl`/`trait` item (not e.g. `impl Trait` in a
/// return type): trimmed code starts with the keyword, optionally behind
/// `pub` / `unsafe`.
fn starts_item(trimmed: &str) -> bool {
    let mut t = trimmed;
    for prefix in ["pub ", "unsafe "] {
        t = t.strip_prefix(prefix).unwrap_or(t);
    }
    for kw in ["impl", "trait"] {
        if let Some(rest) = t.strip_prefix(kw) {
            if rest.starts_with([' ', '<']) {
                return true;
            }
        }
    }
    false
}

/// Self-type name of an `impl`/`trait` header: the last path segment
/// (generics stripped) after `for`, else the first type after the
/// keyword. `impl<T> fmt::Debug for Foo<T>` -> `Foo`.
fn impl_self_type(trimmed: &str) -> Option<String> {
    let mut t = trimmed;
    for prefix in ["pub ", "unsafe "] {
        t = t.strip_prefix(prefix).unwrap_or(t);
    }
    let rest = ["impl", "trait"].iter().find_map(|kw| t.strip_prefix(kw))?;
    let mut rest = rest.trim_start();
    // Skip generic params on the keyword itself.
    if let Some(inner) = rest.strip_prefix('<') {
        let mut angle = 1usize;
        // Unbalanced on this line (multi-line generics) consumes the rest,
        // yielding no self type — matching the header-on-one-line reality
        // of the tree.
        let mut consumed = inner.len();
        for (i, ch) in inner.char_indices() {
            match ch {
                '<' => angle += 1,
                '>' => {
                    angle -= 1;
                    if angle == 0 {
                        consumed = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        rest = &inner[consumed..];
    }
    if let Some((_, after)) = rest.split_once(" for ") {
        rest = after;
    }
    let rest = rest.split('{').next().unwrap_or("").split('<').next().unwrap_or("").trim();
    let seg = rest.rsplit("::").next().unwrap_or("").trim();
    let len = ident_len(seg);
    (len > 0).then(|| seg[..len].to_string())
}

/// Identifier-followed-by-`(` occurrences on one code line (strings and
/// comments already stripped by the lexer). `fn_def_end` is the byte end
/// of the line's own fn-definition name, excluded from the call list.
fn collect_call_sites(code: &str, fn_def_end: Option<usize>, out: &mut Vec<Call>) {
    let bytes = code.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if !is_ident_start(bytes[i]) || (i > 0 && is_ident_byte(bytes[i - 1])) {
            i += 1;
            continue;
        }
        let start = i;
        let mut end = i;
        while end < bytes.len() && is_ident_byte(bytes[end]) {
            end += 1;
        }
        i = end;
        let name = &code[start..end];
        if KEYWORDS.contains(&name) || Some(end) == fn_def_end {
            continue;
        }
        // Optional turbofish `::<...>` between the name and `(`.
        let mut j = end;
        if code[j..].starts_with("::<") {
            let mut angle = 1usize;
            j += 3;
            while j < bytes.len() && angle > 0 {
                match bytes[j] {
                    b'<' => angle += 1,
                    b'>' => angle -= 1,
                    _ => {}
                }
                j += 1;
            }
        }
        if bytes.get(j) != Some(&b'(') {
            continue;
        }
        // Classify by what precedes the name (skipping spaces).
        let mut back = start;
        while back > 0 && bytes[back - 1] == b' ' {
            back -= 1;
        }
        let is_method = back > 0 && bytes[back - 1] == b'.';
        let qual = (back >= 2 && &code[back - 2..back] == "::")
            .then(|| {
                let qend = back - 2;
                let mut qstart = qend;
                while qstart > 0 && is_ident_byte(bytes[qstart - 1]) {
                    qstart -= 1;
                }
                (qstart < qend && is_ident_start(bytes[qstart])).then(|| code[qstart..qend].to_string())
            })
            .flatten();
        out.push(Call { name: name.to_string(), is_method, qual });
    }
}

/// Length of the leading identifier of `s` (0 if none).
pub fn ident_len(s: &str) -> usize {
    let bytes = s.as_bytes();
    if bytes.is_empty() || !is_ident_start(bytes[0]) {
        return 0;
    }
    bytes.iter().take_while(|&&b| is_ident_byte(b)).count()
}

pub fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn parse(rel: &str, src: &str) -> Vec<FnDef> {
        parse_file(rel, &lex(src))
    }

    #[test]
    fn fn_defs_and_call_sites_are_collected() {
        let src = "fn outer(n: usize) {\n    helper(n);\n    x.method(n);\n}\nfn helper(n: usize) {}\n";
        let fns = parse("algo/a.rs", src);
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].name, "outer");
        let calls: Vec<(&str, bool)> =
            fns[0].calls.iter().map(|c| (c.name.as_str(), c.is_method)).collect();
        assert_eq!(calls, vec![("helper", false), ("method", true)]);
    }

    #[test]
    fn qualified_calls_record_the_last_path_segment() {
        let src = "fn f() {\n    let p = Partition::new(4, 2, 8);\n    let q = algo::pool::Partition::new(1, 1, 1);\n}\n";
        let fns = parse("algo/a.rs", src);
        let quals: Vec<Option<&str>> =
            fns[0].calls.iter().map(|c| c.qual.as_deref()).collect();
        assert_eq!(quals, vec![Some("Partition"), Some("Partition")]);
    }

    #[test]
    fn impl_blocks_record_the_self_type() {
        let src = "impl<T> std::fmt::Debug for Foo<T> {\n    fn fmt(&self) {}\n}\nimpl Bar {\n    fn new() -> Self { Bar }\n}\n";
        let fns = parse("algo/a.rs", src);
        assert_eq!(fns[0].impl_type.as_deref(), Some("Foo"));
        assert_eq!(fns[1].impl_type.as_deref(), Some("Bar"));
        assert!(fns.iter().all(|f| f.in_impl));
    }

    #[test]
    fn multiline_signatures_and_trait_decls() {
        let src = "trait K {\n    fn decl(\n        &self,\n    ) -> f32;\n}\nfn real(\n    n: usize,\n) -> f32 {\n    body(n)\n}\n";
        let fns = parse("algo/a.rs", src);
        // The bodyless trait declaration contributes no def with a body;
        // the multi-line `real` still collects its call sites.
        let real = fns.iter().find(|f| f.name == "real").expect("real parsed");
        assert_eq!(real.calls.len(), 1);
        assert_eq!(real.calls[0].name, "body");
    }

    #[test]
    fn allow_marker_and_alloc_sites() {
        let src = "// uotlint: allow(alloc) — baseline comparator.\nfn baseline(n: usize) {\n    let v = vec![0f32; n];\n}\nfn hot(n: usize) {\n    let v = Vec::with_capacity(n); // uotlint: allow(alloc): bootstrap\n    let w = vec![0; n];\n}\n";
        let fns = parse("algo/a.rs", src);
        assert!(fns[0].allow_alloc);
        assert!(!fns[1].allow_alloc);
        assert_eq!(fns[1].allocs.len(), 2);
        assert!(fns[1].allocs[0].allowed, "same-line marker grants the site");
        assert!(!fns[1].allocs[1].allowed);
    }

    #[test]
    fn macros_are_not_call_sites() {
        let src = "fn f() {\n    let v = vec![0; 4];\n    assert!(true);\n    g::<f32>(1.0);\n}\n";
        let fns = parse("algo/a.rs", src);
        let names: Vec<&str> = fns[0].calls.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["g"], "turbofish call kept, macros dropped");
    }

    #[test]
    fn test_modules_mark_their_fns() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n";
        let fns = parse("algo/a.rs", src);
        assert!(!fns[0].is_test);
        assert!(fns[1].is_test);
    }
}
