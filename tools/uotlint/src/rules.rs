//! The four contract rules.
//!
//! * **safety** — every `unsafe` block / fn / impl is immediately preceded
//!   by a `// SAFETY:` comment (attributes and further comment lines may
//!   sit between), every `pub unsafe fn` carries a `# Safety` doc section,
//!   and at most one unsafe block sits on a line (1:1 site-to-comment by
//!   construction).
//! * **sendsync** — every `unsafe impl Send`/`Sync` names its
//!   disjointness/ownership argument in the SAFETY comment.
//! * **alloc** — the PR 1 allocation contract: no allocating calls inside
//!   `iterate*` / `fused_*` / `*_pool*` bodies in the hot solver files.
//!   A documented `// uotlint: allow(alloc)` marker above the fn (or on
//!   the offending line) grants an exemption; exemptions are counted and
//!   reported.
//! * **encapsulation** — thread spawns only in the pool / engine /
//!   service-lifecycle files; `core::arch` intrinsics only in the kernel
//!   modules.
//!
//! `#[cfg(test)]` at brace depth 0 cuts the rest of the file from the
//! alloc and spawn rules (tests may allocate and spawn freely); the
//! safety rules apply everywhere, tests included.

use crate::lexer::{contains_word, find_words, lex, Line};

/// Hot solver files under the allocation contract.
const HOT_FILES: [&str; 8] = [
    "algo/mapuot.rs",
    "algo/pot.rs",
    "algo/coffee.rs",
    "algo/sparse.rs",
    "algo/matfree.rs",
    "algo/parallel.rs",
    "algo/kernels.rs",
    "algo/oned.rs",
];

/// Allocating constructs forbidden in hot-path fn bodies.
const ALLOC_PATTERNS: [&str; 9] = [
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    ".to_vec()",
    ".collect()",
    "Box::new",
    "String::new",
    ".to_string()",
    "format!",
];

/// Files allowed to touch `std::thread` spawn/scope/Builder, with the
/// reason each is on the list.
const SPAWN_ALLOWED: [(&str, &str); 5] = [
    ("algo/pool.rs", "the persistent worker pool itself"),
    ("algo/parallel.rs", "the legacy thread::scope dispatch engine"),
    ("coordinator/service.rs", "coordinator worker lifecycle (spawn-once, not per-solve)"),
    ("coordinator/pjrt_exec.rs", "the single-threaded PJRT executor thread"),
    ("bench/figures.rs", "bench harness parallel figure generation (not solver code)"),
];

/// Files allowed to use raw SIMD intrinsics / `core::arch`.
const INTRIN_ALLOWED: [&str; 2] = ["algo/kernels.rs", "util/simd.rs"];

/// Vocabulary an `unsafe impl Send`/`Sync` SAFETY comment must draw from
/// to count as naming its disjointness/ownership argument.
const SENDSYNC_KEYWORDS: [&str; 13] = [
    "disjoint",
    "distinct",
    "exclusive",
    "owns",
    "owner",
    "sole",
    "lock",
    "serialized",
    "immutable",
    "atomic",
    "aliasing",
    "outlive",
    "&mut",
];

/// The escape marker for the alloc rule.
const ALLOW_ALLOC: &str = "uotlint: allow(alloc)";

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Per-file result.
#[derive(Debug, Default)]
pub struct FileReport {
    pub violations: Vec<Violation>,
    /// `unsafe` sites (blocks, fns, impls) seen.
    pub unsafe_sites: usize,
    /// Granted `allow(alloc)` exemption markers.
    pub alloc_allows: usize,
}

/// Run every rule over one file. `rel` is the path relative to the lint
/// root (`rust/src`), with `/` separators.
pub fn check_file(rel: &str, source: &str) -> FileReport {
    let lines = lex(source);
    let mut report = FileReport::default();
    let spawn_allowed = SPAWN_ALLOWED.iter().any(|(f, _)| *f == rel);
    let intrin_allowed = INTRIN_ALLOWED.contains(&rel);
    let hot_file = HOT_FILES.contains(&rel);

    let mut depth = 0usize;
    let mut in_test = false;
    // Stack of (fn name, brace depth at entry, exempt) for hot fns whose
    // body the alloc rule scans.
    let mut hot_fns: Vec<(String, usize, bool)> = Vec::new();
    // A hot fn header seen but its `{` not yet (multi-line signatures).
    let mut pending_fn: Option<(String, bool)> = None;

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = line.code.as_str();
        let trimmed = code.trim();

        if !in_test && depth == 0 && trimmed.starts_with("#[cfg(test)]") {
            in_test = true;
        }
        if line.comment.contains(ALLOW_ALLOC) {
            report.alloc_allows += 1;
        }

        check_unsafe_sites(&lines, idx, code, &mut report);

        // --- encapsulation: spawns --------------------------------------
        if !in_test && !spawn_allowed {
            for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
                if code.contains(pat) {
                    report.violations.push(Violation {
                        line: lineno,
                        rule: "encapsulation",
                        msg: format!(
                            "`{pat}` outside the threading allowlist (pool, scope engine, \
                             service lifecycle) — route compute through `algo::pool`"
                        ),
                    });
                }
            }
        }

        // --- encapsulation: intrinsics ----------------------------------
        if !intrin_allowed && has_intrinsic(code) {
            report.violations.push(Violation {
                line: lineno,
                rule: "encapsulation",
                msg: "raw SIMD intrinsics outside algo/kernels.rs / util/simd.rs".into(),
            });
        }

        // --- allocation contract ----------------------------------------
        if hot_file && !in_test {
            track_hot_fn(&lines, idx, code, depth, &mut hot_fns, &mut pending_fn);
            if let Some((name, _, exempt)) = hot_fns.last() {
                if !*exempt {
                    for pat in ALLOC_PATTERNS {
                        if contains_word(code, pat) && !line.comment.contains(ALLOW_ALLOC) {
                            report.violations.push(Violation {
                                line: lineno,
                                rule: "alloc",
                                msg: format!(
                                    "`{pat}` inside hot-path fn `{name}` — use workspace \
                                     scratch (or justify with `// {ALLOW_ALLOC} — reason`)"
                                ),
                            });
                        }
                    }
                }
            }
        }

        // --- brace depth / fn frame upkeep ------------------------------
        for ch in code.chars() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth = depth.saturating_sub(1);
                    if let Some((_, entry, _)) = hot_fns.last() {
                        if depth == *entry {
                            hot_fns.pop();
                        }
                    }
                }
                _ => {}
            }
        }
    }
    report
}

/// The safety + sendsync rules for one line.
fn check_unsafe_sites(lines: &[Line], idx: usize, code: &str, report: &mut FileReport) {
    let lineno = idx + 1;
    let mut blocks_on_line = 0usize;
    for off in find_words(code, "unsafe") {
        report.unsafe_sites += 1;
        let rest = code[off + "unsafe".len()..].trim_start();
        let above = comment_run_above(lines, idx);
        if rest.starts_with("impl") {
            if !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe impl without an immediately-preceding // SAFETY: comment".into(),
                });
            } else if let Some(auto_trait) = send_or_sync(rest) {
                let lower = above.to_lowercase();
                if !SENDSYNC_KEYWORDS.iter().any(|k| lower.contains(k)) {
                    report.violations.push(Violation {
                        line: lineno,
                        rule: "sendsync",
                        msg: format!(
                            "unsafe impl {auto_trait}: the SAFETY comment must name the \
                             disjointness/ownership argument (e.g. which accesses are \
                             disjoint, what is exclusively owned, or what serializes them)"
                        ),
                    });
                }
            }
        } else if rest.starts_with("fn") || rest.starts_with("extern") {
            // `unsafe fn` declaration: a `# Safety` doc section (or a
            // SAFETY comment, for private helpers) must sit above.
            if !above.contains("# Safety") && !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe fn without a `# Safety` doc section".into(),
                });
            }
            // Public unsafe fns specifically need the doc section (the
            // rendered contract), not just an internal comment.
            let head = &code[..off];
            if (head.trim_end().ends_with("pub") || head.contains("pub("))
                && !above.contains("# Safety")
            {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "pub unsafe fn without a `# Safety` doc section".into(),
                });
            }
        } else {
            blocks_on_line += 1;
            if !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe block without an immediately-preceding // SAFETY: comment"
                        .into(),
                });
            }
        }
    }
    if blocks_on_line > 1 {
        report.violations.push(Violation {
            line: lineno,
            rule: "safety",
            msg: format!(
                "{blocks_on_line} unsafe blocks on one line — split them so each carries \
                 its own SAFETY comment (1:1)"
            ),
        });
    }
}

/// True if the line's code uses a raw SIMD intrinsic or the arch modules:
/// an `_mm…_` identifier prefix at an identifier boundary, or a
/// `core::arch` / `std::arch` path.
fn has_intrinsic(code: &str) -> bool {
    if code.contains("core::arch") || code.contains("std::arch") {
        return true;
    }
    let bytes = code.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    code.match_indices("_mm").any(|(i, _)| {
        let before_ok = i == 0 || !is_ident(bytes[i - 1]);
        // `_mm_sfence`, `_mm256_add_ps`, `_mm512_…` — next byte is an
        // underscore or a width digit. Plain `__m256` type names don't
        // match (and shouldn't: types travel with the intrinsics anyway).
        before_ok && matches!(bytes.get(i + 3), Some(b'_') | Some(b'0'..=b'9'))
    })
}

/// Which auto trait an `impl ...` header implements, if Send/Sync.
fn send_or_sync(rest: &str) -> Option<&'static str> {
    let after_impl = rest.strip_prefix("impl")?.trim_start();
    ["Send", "Sync"].into_iter().find(|t| after_impl.starts_with(t))
}

/// Comment text of the run of comment-only / attribute-only lines
/// immediately above `idx` (no blank lines allowed in between).
fn comment_run_above(lines: &[Line], idx: usize) -> String {
    let mut texts: Vec<&str> = Vec::new();
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        if code.is_empty() && !l.comment.trim().is_empty() {
            texts.push(&l.comment);
        } else if code.starts_with("#[") || code.starts_with("#![") {
            continue;
        } else {
            break;
        }
    }
    texts.join("\n")
}

/// Track entry into hot-named fns for the alloc rule. Handles multi-line
/// signatures: the header line names the fn, a later line opens the body
/// (or a `;` ends a trait declaration without one).
fn track_hot_fn(
    lines: &[Line],
    idx: usize,
    code: &str,
    depth: usize,
    hot_fns: &mut Vec<(String, usize, bool)>,
    pending_fn: &mut Option<(String, bool)>,
) {
    if let Some(off) = find_words(code, "fn").next() {
        let rest = code[off + 2..].trim_start();
        let name: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            let exempt = comment_run_above(lines, idx).contains(ALLOW_ALLOC);
            let after = &code[off..];
            if after.contains('{') {
                if is_hot_name(&name) {
                    hot_fns.push((name, depth, exempt));
                }
                *pending_fn = None;
            } else if after.contains(';') {
                *pending_fn = None; // trait declaration, no body
            } else {
                *pending_fn = Some((name, exempt));
            }
            return;
        }
    }
    if pending_fn.is_some() {
        if code.contains('{') {
            if let Some((name, exempt)) = pending_fn.take() {
                if is_hot_name(&name) {
                    hot_fns.push((name, depth, exempt));
                }
            }
        } else if code.contains(';') {
            *pending_fn = None;
        }
    }
}

/// The hot-path name globs: `iterate*`, `fused_*`, `*_pool*`, `pool_*`.
fn is_hot_name(name: &str) -> bool {
    name.starts_with("iterate")
        || name.starts_with("fused_")
        || name.contains("_pool")
        || name.starts_with("pool_")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violations(rel: &str, src: &str) -> Vec<Violation> {
        check_file(rel, src).violations
    }

    fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
        violations(rel, src).into_iter().map(|v| v.rule).collect()
    }

    // --- safety: unsafe blocks ------------------------------------------

    #[test]
    fn unsafe_block_without_comment_is_flagged() {
        let src = "fn f(p: *mut f32) {\n    let v = unsafe { *p };\n}\n";
        let v = violations("algo/session.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "safety");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_block_with_comment_passes() {
        let src = "fn f(p: *mut f32) {\n    // SAFETY: p is valid.\n    let v = unsafe { *p };\n}\n";
        assert!(violations("algo/session.rs", src).is_empty());
    }

    #[test]
    fn attributes_between_comment_and_site_are_ok() {
        let src = "// SAFETY: sound because reasons.\n#[allow(clippy::mut_from_ref)]\nunsafe impl Send for X {}\n";
        // Send impl also needs a keyword — "sound because reasons" has none.
        assert_eq!(rules_of("algo/pool.rs", src), vec!["sendsync"]);
        let src = "// SAFETY: rows are disjoint.\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        assert!(violations("algo/pool.rs", src).is_empty());
    }

    #[test]
    fn blank_line_breaks_the_comment_run() {
        let src = "// SAFETY: p is valid.\n\nfn f(p: *mut f32) { let v = unsafe { *p }; }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["safety"]);
    }

    #[test]
    fn two_unsafe_blocks_on_one_line_are_flagged() {
        let src = "// SAFETY: both fine.\nlet (a, b) = (unsafe { *p }, unsafe { *q });\n";
        let v = violations("algo/session.rs", src);
        assert!(v.iter().any(|v| v.msg.contains("2 unsafe blocks")), "{v:?}");
    }

    #[test]
    fn unsafe_in_comments_and_strings_is_ignored() {
        let src = "// this mentions unsafe code\nlet s = \"unsafe { }\";\n";
        assert!(violations("algo/session.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_may_span_lines() {
        let src = "// SAFETY: the partition is disjoint\n// across every part.\nlet v = unsafe { x.get(0) };\n";
        assert!(violations("algo/pool.rs", src).is_empty());
    }

    // --- safety: unsafe fns ---------------------------------------------

    #[test]
    fn pub_unsafe_fn_needs_safety_doc() {
        let src = "/// Does things.\npub unsafe fn f() {}\n";
        let v = violations("algo/pool.rs", src);
        assert!(v.iter().any(|v| v.msg.contains("# Safety")), "{v:?}");
        let ok = "/// Does things.\n///\n/// # Safety\n/// Caller must hold the lock.\npub unsafe fn f() {}\n";
        assert!(violations("algo/pool.rs", ok).is_empty());
    }

    // --- sendsync -------------------------------------------------------

    #[test]
    fn send_sync_impls_need_their_own_argument() {
        // One comment above a *pair* of impls only covers the first; the
        // second hits the code line above it and fails the safety rule.
        let src = "// SAFETY: rows are disjoint.\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let v = violations("algo/pool.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        assert_eq!(v[0].rule, "safety");
    }

    #[test]
    fn sendsync_comment_must_use_the_vocabulary() {
        let src = "// SAFETY: this is probably fine.\nunsafe impl Sync for X {}\n";
        assert_eq!(rules_of("algo/pool.rs", src), vec!["sendsync"]);
        let ok = "// SAFETY: each worker writes a distinct slot.\nunsafe impl Sync for X {}\n";
        assert!(violations("algo/pool.rs", ok).is_empty());
    }

    // --- alloc ----------------------------------------------------------

    #[test]
    fn alloc_in_hot_fn_is_flagged() {
        let src = "fn iterate_into(n: usize) {\n    let v = vec![0f32; n];\n}\n";
        let v = violations("algo/mapuot.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "alloc");
        assert!(v[0].msg.contains("vec!"));
    }

    #[test]
    fn alloc_outside_hot_fns_or_hot_files_passes() {
        // Non-hot fn name in a hot file: allowed (setup/constructor code).
        let src = "fn with_engine(n: usize) {\n    let v = vec![0f32; n];\n}\n";
        assert!(violations("algo/mapuot.rs", src).is_empty());
        // Hot name in a non-hot file: allowed (the contract is scoped).
        let src = "fn iterate(n: usize) {\n    let v = vec![0f32; n];\n}\n";
        assert!(violations("apps/color.rs", src).is_empty());
    }

    #[test]
    fn multiline_signature_is_tracked() {
        let src = "fn fused_rows(\n    n: usize,\n) -> f32 {\n    let v: Vec<f32> = (0..n).map(|x| x as f32).collect();\n    v[0]\n}\n";
        let v = violations("algo/kernels.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains(".collect()"));
    }

    #[test]
    fn trait_declaration_does_not_open_a_frame() {
        let src = "trait K {\n    fn fused_rows(\n        &self,\n        n: usize,\n    ) -> f32;\n}\nfn setup(n: usize) {\n    let v = vec![0f32; n];\n}\n";
        assert!(violations("algo/kernels.rs", src).is_empty());
    }

    #[test]
    fn allow_marker_exempts_and_is_counted() {
        let src = "// uotlint: allow(alloc) — legacy wrapper.\nfn iterate(n: usize) {\n    let v = vec![0f32; n];\n}\n";
        let r = check_file("algo/mapuot.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.alloc_allows, 1);
        let src = "fn iterate(n: usize) {\n    let v = vec![0f32; n]; // uotlint: allow(alloc): bootstrap\n}\n";
        let r = check_file("algo/mapuot.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.alloc_allows, 1);
    }

    #[test]
    fn test_module_is_exempt_from_alloc_and_spawn() {
        let src = "#[cfg(test)]\nmod tests {\n    fn iterate() { let v = vec![1]; }\n    fn t() { std::thread::spawn(|| {}); }\n}\n";
        assert!(violations("algo/mapuot.rs", src).is_empty());
    }

    // --- encapsulation --------------------------------------------------

    #[test]
    fn spawn_outside_allowlist_is_flagged() {
        let src = "fn go() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["encapsulation"]);
        assert!(violations("algo/pool.rs", src).is_empty());
        assert!(violations("coordinator/service.rs", src).is_empty());
    }

    #[test]
    fn intrinsics_outside_kernels_are_flagged() {
        let src = "fn go(a: __m256) { let b = _mm256_add_ps(a, a); }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["encapsulation"]);
        assert!(violations("algo/kernels.rs", src).is_empty());
        assert!(violations("util/simd.rs", src).is_empty());
        let sfence = "fn go() { _mm_sfence(); }\n";
        assert_eq!(rules_of("algo/session.rs", sfence), vec!["encapsulation"]);
        // Doc comments mentioning intrinsics are not code.
        let doc = "/// uses _mm256_stream_ps under the hood\nfn f() {}\n";
        assert!(violations("algo/session.rs", doc).is_empty());
    }

    #[test]
    fn unsafe_sites_are_counted() {
        let src = "// SAFETY: fine, p outlives the call.\nlet v = unsafe { *p };\n";
        let r = check_file("algo/session.rs", src);
        assert_eq!(r.unsafe_sites, 1);
        assert!(r.violations.is_empty());
    }
}
