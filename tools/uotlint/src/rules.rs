//! The per-file contract rules. (The interprocedural allocation rule
//! lives in [`crate::callgraph`], built on [`crate::parse`].)
//!
//! * **safety** — every `unsafe` block / fn / impl is immediately preceded
//!   by a `// SAFETY:` comment (attributes and further comment lines may
//!   sit between), every `pub unsafe fn` carries a `# Safety` doc section,
//!   and at most one unsafe block sits on a line (1:1 site-to-comment by
//!   construction).
//! * **sendsync** — every `unsafe impl Send`/`Sync` names its
//!   disjointness/ownership argument in the SAFETY comment.
//! * **panic** — no `unwrap()` / `expect(...)` / direct indexing in
//!   service-facing library code (`coordinator/`, `config/`, `runtime/`):
//!   these layers return the typed `Error`, they do not abort a worker. A
//!   `// uotlint: allow(panic) — reason` marker above the site (or on its
//!   line) grants a counted exemption for provably-infallible sites.
//! * **lock** — tree-wide: every `.lock()` must recover from poisoning
//!   via `PoisonError::into_inner` (or a `recover(...)` helper) within
//!   the statement, so one panicked holder cannot cascade into every
//!   later solve.
//! * **encapsulation** — thread spawns only in the pool / engine /
//!   service-lifecycle files; `core::arch` intrinsics only in the kernel
//!   modules.
//! * **telemetry** — the hot solver files ([`crate::callgraph::HOT_FILES`])
//!   may only use the alloc-free recorder API (`now_ns` / `record_span` /
//!   `span` / `enabled` / `Phase`): exporters, snapshots and registry
//!   management allocate and belong in the cold layers.
//!
//! `#[cfg(test)]` at brace depth 0 cuts the rest of the file from the
//! spawn, panic, lock and telemetry rules (tests may take shortcuts
//! freely); the safety rules apply everywhere, tests included.

use crate::callgraph::HOT_FILES;
use crate::lexer::{comment_run_above, find_words, Line};
use crate::parse::KEYWORDS;

/// Directories under the panic-path contract (service-facing library
/// layers that must return typed errors).
const PANIC_DIRS: [&str; 3] = ["coordinator/", "config/", "runtime/"];

/// Files allowed to touch `std::thread` spawn/scope/Builder, with the
/// reason each is on the list.
const SPAWN_ALLOWED: [(&str, &str); 5] = [
    ("algo/pool.rs", "the persistent worker pool itself"),
    ("algo/parallel.rs", "the legacy thread::scope dispatch engine"),
    ("coordinator/service.rs", "coordinator worker lifecycle (spawn-once, not per-solve)"),
    ("coordinator/pjrt_exec.rs", "the single-threaded PJRT executor thread"),
    ("bench/figures.rs", "bench harness parallel figure generation (not solver code)"),
];

/// Files allowed to use raw SIMD intrinsics / `core::arch`.
const INTRIN_ALLOWED: [&str; 2] = ["algo/kernels.rs", "util/simd.rs"];

/// Vocabulary an `unsafe impl Send`/`Sync` SAFETY comment must draw from
/// to count as naming its disjointness/ownership argument.
const SENDSYNC_KEYWORDS: [&str; 13] = [
    "disjoint",
    "distinct",
    "exclusive",
    "owns",
    "owner",
    "sole",
    "lock",
    "serialized",
    "immutable",
    "atomic",
    "aliasing",
    "outlive",
    "&mut",
];

/// The escape marker for the panic rule.
pub const ALLOW_PANIC: &str = "uotlint: allow(panic)";

/// The only `telemetry::` items a hot solver file may touch: the
/// alloc-free record path. Everything else (snapshots, exporters, the
/// registry) allocates and is cold-layer API.
const TELEMETRY_HOT_API: [&str; 5] = ["now_ns", "record_span", "span", "enabled", "Phase"];

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Per-file result.
#[derive(Debug, Default)]
pub struct FileReport {
    pub violations: Vec<Violation>,
    /// `unsafe` sites (blocks, fns, impls) seen.
    pub unsafe_sites: usize,
    /// Granted `allow(panic)` exemption markers.
    pub panic_allows: usize,
    /// `.lock()` call sites seen (all must carry poison recovery).
    pub lock_sites: usize,
}

/// Run every per-file rule over one lexed file. `rel` is the path
/// relative to the lint root (`rust/src`), with `/` separators.
pub fn check_file(rel: &str, lines: &[Line]) -> FileReport {
    let mut report = FileReport::default();
    let spawn_allowed = SPAWN_ALLOWED.iter().any(|(f, _)| *f == rel);
    let intrin_allowed = INTRIN_ALLOWED.contains(&rel);
    let panic_dir = PANIC_DIRS.iter().any(|d| rel.starts_with(d));
    let hot_file = HOT_FILES.contains(&rel);

    let mut depth = 0usize;
    let mut in_test = false;

    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = line.code.as_str();
        let trimmed = code.trim();

        if !in_test && depth == 0 && trimmed.starts_with("#[cfg(test)]") {
            in_test = true;
        }

        check_unsafe_sites(lines, idx, code, &mut report);

        // --- encapsulation: spawns --------------------------------------
        if !in_test && !spawn_allowed {
            for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
                if code.contains(pat) {
                    report.violations.push(Violation {
                        line: lineno,
                        rule: "encapsulation",
                        msg: format!(
                            "`{pat}` outside the threading allowlist (pool, scope engine, \
                             service lifecycle) — route compute through `algo::pool`"
                        ),
                    });
                }
            }
        }

        // --- encapsulation: intrinsics ----------------------------------
        if !intrin_allowed && has_intrinsic(code) {
            report.violations.push(Violation {
                line: lineno,
                rule: "encapsulation",
                msg: "raw SIMD intrinsics outside algo/kernels.rs / util/simd.rs".into(),
            });
        }

        // --- panic paths ------------------------------------------------
        if panic_dir && !in_test {
            let sites = panic_sites(code, trimmed);
            if !sites.is_empty() {
                let allowed = line.comment.contains(ALLOW_PANIC)
                    || comment_run_above(lines, idx).contains(ALLOW_PANIC);
                for what in sites {
                    if allowed {
                        report.panic_allows += 1;
                    } else {
                        report.violations.push(Violation {
                            line: lineno,
                            rule: "panic",
                            msg: format!(
                                "{what} in service-facing code — return a typed Error \
                                 (or justify with `// {ALLOW_PANIC} — reason`)"
                            ),
                        });
                    }
                }
            }
        }

        // --- telemetry: hot files use only the record path --------------
        if hot_file && !in_test {
            for (i, _) in code.match_indices("telemetry::") {
                let rest = &code[i + "telemetry::".len()..];
                let ident: String = rest
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !TELEMETRY_HOT_API.contains(&ident.as_str()) {
                    report.violations.push(Violation {
                        line: lineno,
                        rule: "telemetry",
                        msg: format!(
                            "`telemetry::{ident}` in a hot solver file — hot loops may only \
                             use the alloc-free record path ({})",
                            TELEMETRY_HOT_API.join(" / ")
                        ),
                    });
                }
            }
        }

        // --- lock discipline --------------------------------------------
        if !in_test && code.contains(".lock()") {
            report.lock_sites += 1;
            let stmt: String = lines[idx..lines.len().min(idx + 4)]
                .iter()
                .map(|l| l.code.as_str())
                .collect::<Vec<_>>()
                .join(" ");
            if !stmt.contains("into_inner") && !stmt.contains("recover(") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "lock",
                    msg: "`.lock()` without the PoisonError::into_inner recovery pattern \
                          (see coordinator::batcher::recover)"
                        .into(),
                });
            }
        }

        // --- brace depth upkeep -----------------------------------------
        for ch in code.chars() {
            match ch {
                '{' => depth += 1,
                '}' => depth = depth.saturating_sub(1),
                _ => {}
            }
        }
    }
    report
}

/// Panic-capable constructs on one line of code: `unwrap()`, `expect(`,
/// and direct indexing. Indexing is a `[` whose preceding non-space byte
/// ends an expression (identifier, `)`, `]`, `?`) — but not when that
/// identifier is a keyword or a lifetime, which puts the `[` in type or
/// iterator position (`&mut [f32]`, `for x in [..]`, `&'b [T]`).
fn panic_sites(code: &str, trimmed: &str) -> Vec<&'static str> {
    let mut out = Vec::new();
    if code.contains(".unwrap()") {
        out.push("`unwrap()`");
    }
    if code.contains(".expect(") {
        out.push("`expect(...)`");
    }
    // Attribute lines (`#[derive(..)]`, `#[cfg(..)]`) are full of brackets
    // that are not indexing.
    if !trimmed.starts_with('#') {
        let bytes = code.as_bytes();
        for (i, &ch) in bytes.iter().enumerate() {
            if ch != b'[' {
                continue;
            }
            let mut back = i as isize - 1;
            while back >= 0 && bytes[back as usize] == b' ' {
                back -= 1;
            }
            if back < 0 {
                continue;
            }
            let b = bytes[back as usize];
            let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
            if !(is_ident(b) || b == b')' || b == b']' || b == b'?') {
                continue;
            }
            if is_ident(b) {
                let end = back as usize + 1;
                while back >= 0 && is_ident(bytes[back as usize]) {
                    back -= 1;
                }
                let word = &code[(back + 1) as usize..end];
                if KEYWORDS.contains(&word) || (back >= 0 && bytes[back as usize] == b'\'') {
                    continue;
                }
            }
            out.push("direct indexing");
            break;
        }
    }
    out
}

/// The safety + sendsync rules for one line.
fn check_unsafe_sites(lines: &[Line], idx: usize, code: &str, report: &mut FileReport) {
    let lineno = idx + 1;
    let mut blocks_on_line = 0usize;
    for off in find_words(code, "unsafe") {
        report.unsafe_sites += 1;
        let rest = code[off + "unsafe".len()..].trim_start();
        let above = comment_run_above(lines, idx);
        if rest.starts_with("impl") {
            if !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe impl without an immediately-preceding // SAFETY: comment".into(),
                });
            } else if let Some(auto_trait) = send_or_sync(rest) {
                let lower = above.to_lowercase();
                if !SENDSYNC_KEYWORDS.iter().any(|k| lower.contains(k)) {
                    report.violations.push(Violation {
                        line: lineno,
                        rule: "sendsync",
                        msg: format!(
                            "unsafe impl {auto_trait}: the SAFETY comment must name the \
                             disjointness/ownership argument (e.g. which accesses are \
                             disjoint, what is exclusively owned, or what serializes them)"
                        ),
                    });
                }
            }
        } else if rest.starts_with("fn") || rest.starts_with("extern") {
            // `unsafe fn` declaration: a `# Safety` doc section (or a
            // SAFETY comment, for private helpers) must sit above.
            if !above.contains("# Safety") && !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe fn without a `# Safety` doc section".into(),
                });
            }
            // Public unsafe fns specifically need the doc section (the
            // rendered contract), not just an internal comment.
            let head = &code[..off];
            if (head.trim_end().ends_with("pub") || head.contains("pub("))
                && !above.contains("# Safety")
            {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "pub unsafe fn without a `# Safety` doc section".into(),
                });
            }
        } else {
            blocks_on_line += 1;
            if !above.contains("SAFETY:") {
                report.violations.push(Violation {
                    line: lineno,
                    rule: "safety",
                    msg: "unsafe block without an immediately-preceding // SAFETY: comment"
                        .into(),
                });
            }
        }
    }
    if blocks_on_line > 1 {
        report.violations.push(Violation {
            line: lineno,
            rule: "safety",
            msg: format!(
                "{blocks_on_line} unsafe blocks on one line — split them so each carries \
                 its own SAFETY comment (1:1)"
            ),
        });
    }
}

/// True if the line's code uses a raw SIMD intrinsic or the arch modules:
/// an `_mm…_` identifier prefix at an identifier boundary, or a
/// `core::arch` / `std::arch` path.
fn has_intrinsic(code: &str) -> bool {
    if code.contains("core::arch") || code.contains("std::arch") {
        return true;
    }
    let bytes = code.as_bytes();
    let is_ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    code.match_indices("_mm").any(|(i, _)| {
        let before_ok = i == 0 || !is_ident(bytes[i - 1]);
        // `_mm_sfence`, `_mm256_add_ps`, `_mm512_…` — next byte is an
        // underscore or a width digit. Plain `__m256` type names don't
        // match (and shouldn't: types travel with the intrinsics anyway).
        before_ok && matches!(bytes.get(i + 3), Some(b'_') | Some(b'0'..=b'9'))
    })
}

/// Which auto trait an `impl ...` header implements, if Send/Sync.
fn send_or_sync(rest: &str) -> Option<&'static str> {
    let after_impl = rest.strip_prefix("impl")?.trim_start();
    ["Send", "Sync"].into_iter().find(|t| after_impl.starts_with(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn check(rel: &str, src: &str) -> FileReport {
        check_file(rel, &lex(src))
    }

    fn violations(rel: &str, src: &str) -> Vec<Violation> {
        check(rel, src).violations
    }

    fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
        violations(rel, src).into_iter().map(|v| v.rule).collect()
    }

    // --- safety: unsafe blocks ------------------------------------------

    #[test]
    fn unsafe_block_without_comment_is_flagged() {
        let src = "fn f(p: *mut f32) {\n    let v = unsafe { *p };\n}\n";
        let v = violations("algo/session.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "safety");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_block_with_comment_passes() {
        let src = "fn f(p: *mut f32) {\n    // SAFETY: p is valid.\n    let v = unsafe { *p };\n}\n";
        assert!(violations("algo/session.rs", src).is_empty());
    }

    #[test]
    fn attributes_between_comment_and_site_are_ok() {
        let src = "// SAFETY: sound because reasons.\n#[allow(clippy::mut_from_ref)]\nunsafe impl Send for X {}\n";
        // Send impl also needs a keyword — "sound because reasons" has none.
        assert_eq!(rules_of("algo/pool.rs", src), vec!["sendsync"]);
        let src = "// SAFETY: rows are disjoint.\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        assert!(violations("algo/pool.rs", src).is_empty());
    }

    #[test]
    fn blank_line_breaks_the_comment_run() {
        let src = "// SAFETY: p is valid.\n\nfn f(p: *mut f32) { let v = unsafe { *p }; }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["safety"]);
    }

    #[test]
    fn two_unsafe_blocks_on_one_line_are_flagged() {
        let src = "// SAFETY: both fine.\nlet (a, b) = (unsafe { *p }, unsafe { *q });\n";
        let v = violations("algo/session.rs", src);
        assert!(v.iter().any(|v| v.msg.contains("2 unsafe blocks")), "{v:?}");
    }

    #[test]
    fn unsafe_in_comments_and_strings_is_ignored() {
        let src = "// this mentions unsafe code\nlet s = \"unsafe { }\";\n";
        assert!(violations("algo/session.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_may_span_lines() {
        let src = "// SAFETY: the partition is disjoint\n// across every part.\nlet v = unsafe { x.get(0) };\n";
        assert!(violations("algo/pool.rs", src).is_empty());
    }

    // --- safety: unsafe fns ---------------------------------------------

    #[test]
    fn pub_unsafe_fn_needs_safety_doc() {
        let src = "/// Does things.\npub unsafe fn f() {}\n";
        let v = violations("algo/pool.rs", src);
        assert!(v.iter().any(|v| v.msg.contains("# Safety")), "{v:?}");
        let ok = "/// Does things.\n///\n/// # Safety\n/// Caller must hold the lock.\npub unsafe fn f() {}\n";
        assert!(violations("algo/pool.rs", ok).is_empty());
    }

    // --- sendsync -------------------------------------------------------

    #[test]
    fn send_sync_impls_need_their_own_argument() {
        // One comment above a *pair* of impls only covers the first; the
        // second hits the code line above it and fails the safety rule.
        let src = "// SAFETY: rows are disjoint.\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let v = violations("algo/pool.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        assert_eq!(v[0].rule, "safety");
    }

    #[test]
    fn sendsync_comment_must_use_the_vocabulary() {
        let src = "// SAFETY: this is probably fine.\nunsafe impl Sync for X {}\n";
        assert_eq!(rules_of("algo/pool.rs", src), vec!["sendsync"]);
        let ok = "// SAFETY: each worker writes a distinct slot.\nunsafe impl Sync for X {}\n";
        assert!(violations("algo/pool.rs", ok).is_empty());
    }

    // --- panic ----------------------------------------------------------

    #[test]
    fn unwrap_in_service_code_is_flagged() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let v = violations("coordinator/service.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "panic");
        assert!(v[0].msg.contains("unwrap"));
        // Same code outside the panic dirs passes.
        assert!(violations("algo/session.rs", src).is_empty());
    }

    #[test]
    fn expect_and_indexing_are_flagged() {
        let src = "fn f(v: &[u32]) -> u32 {\n    let x = v.first().expect(\"nonempty\");\n    v[3]\n}\n";
        let rules = rules_of("runtime/mod.rs", src);
        assert_eq!(rules, vec!["panic", "panic"], "{rules:?}");
    }

    #[test]
    fn type_position_brackets_are_not_indexing() {
        // `&mut [f32]`, `for _ in [..]`, `&'b [T]`: type/iterator position.
        let src = "fn f(s: &mut [f32], t: &'b [u32]) {\n    for _p in [1, 2] {}\n    let a: [f32; 4] = [0.0; 4];\n}\n";
        assert!(violations("config/mod.rs", src).is_empty());
    }

    #[test]
    fn attribute_lines_are_not_indexing() {
        let src = "#[derive(Clone, Debug)]\nstruct S;\n";
        assert!(violations("config/mod.rs", src).is_empty());
    }

    #[test]
    fn allow_panic_marker_exempts_and_is_counted() {
        let src = "// uotlint: allow(panic) — idx is in-range by construction.\nfn f(v: &[u32], i: usize) -> u32 {\n    v[i]\n}\n";
        let r = check("coordinator/metrics.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.panic_allows, 1);
    }

    #[test]
    fn tests_may_unwrap() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        assert!(violations("coordinator/service.rs", src).is_empty());
    }

    // --- lock -----------------------------------------------------------

    #[test]
    fn bare_lock_is_flagged_tree_wide() {
        let src = "fn f(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
        let v = violations("algo/session.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock");
    }

    #[test]
    fn poison_recovery_within_the_statement_passes() {
        let src = "fn f(m: &Mutex<u32>) -> u32 {\n    let g = match m.lock() {\n        Ok(g) => g,\n        Err(poisoned) => poisoned.into_inner(),\n    };\n    *g\n}\n";
        let r = check("algo/session.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.lock_sites, 1);
    }

    #[test]
    fn recover_helper_passes_and_tests_are_exempt() {
        let src = "fn f(m: &Mutex<u32>) -> u32 {\n    *recover(m.lock())\n}\n";
        assert!(violations("coordinator/batcher.rs", src).is_empty());
        let test = "#[cfg(test)]\nmod tests {\n    fn t(m: &Mutex<u32>) { m.lock().unwrap(); }\n}\n";
        assert!(violations("coordinator/batcher.rs", test).is_empty());
    }

    // --- telemetry ------------------------------------------------------

    #[test]
    fn hot_files_may_use_only_the_record_path() {
        let ok = "fn iterate_x() {\n    let s = telemetry::span(Phase::FusedSweep);\n    drop(s);\n    telemetry::record_span(Phase::Reduction, telemetry::now_ns(), telemetry::now_ns());\n}\n";
        assert!(violations("algo/parallel.rs", ok).is_empty());
        let bad = "fn iterate_x() {\n    let e = telemetry::snapshot_spans();\n}\n";
        assert_eq!(rules_of("algo/parallel.rs", bad), vec!["telemetry"]);
        // Non-hot files may use the full API (session/export layers).
        assert!(violations("algo/session.rs", bad).is_empty());
    }

    #[test]
    fn telemetry_brace_imports_in_hot_files_are_flagged() {
        // A brace import smuggles arbitrary items past the following-ident
        // check, so it is itself a violation in hot files.
        let src = "use crate::util::telemetry::{self, Phase};\n";
        assert_eq!(rules_of("algo/kernels.rs", src), vec!["telemetry"]);
        let ok = "use crate::util::telemetry;\nuse crate::util::telemetry::Phase;\n";
        assert!(violations("algo/kernels.rs", ok).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    fn t() { telemetry::reset(); }\n}\n";
        assert!(violations("algo/oned.rs", test_src).is_empty());
    }

    // --- encapsulation --------------------------------------------------

    #[test]
    fn spawn_outside_allowlist_is_flagged() {
        let src = "fn go() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["encapsulation"]);
        assert!(violations("algo/pool.rs", src).is_empty());
        assert!(violations("coordinator/service.rs", src).is_empty());
    }

    #[test]
    fn intrinsics_outside_kernels_are_flagged() {
        let src = "fn go(a: __m256) { let b = _mm256_add_ps(a, a); }\n";
        assert_eq!(rules_of("algo/session.rs", src), vec!["encapsulation"]);
        assert!(violations("algo/kernels.rs", src).is_empty());
        assert!(violations("util/simd.rs", src).is_empty());
        let sfence = "fn go() { _mm_sfence(); }\n";
        assert_eq!(rules_of("algo/session.rs", sfence), vec!["encapsulation"]);
        // Doc comments mentioning intrinsics are not code.
        let doc = "/// uses _mm256_stream_ps under the hood\nfn f() {}\n";
        assert!(violations("algo/session.rs", doc).is_empty());
    }

    #[test]
    fn unsafe_sites_are_counted() {
        let src = "// SAFETY: fine, p outlives the call.\nlet v = unsafe { *p };\n";
        let r = check("algo/session.rs", src);
        assert_eq!(r.unsafe_sites, 1);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn spawns_in_tests_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { std::thread::spawn(|| {}); }\n}\n";
        assert!(violations("algo/mapuot.rs", src).is_empty());
    }
}
