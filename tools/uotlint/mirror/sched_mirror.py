#!/usr/bin/env python3
"""Design-validation mirror of uotlint's pool interleaving checker
(tools/uotlint/src/sched.rs + rust/src/algo/pool_model.rs).

Models algo::pool's epoch-barrier protocol as explicit state machines
(one shared-memory op per step, sequential consistency, std-park token
semantics WITHOUT spurious wakeups) and exhaustively enumerates thread
interleavings by DFS with visited-state pruning.

Checked properties:
  - no deadlock (a non-done thread exists but nothing is runnable)
  - job-slot validity: a participating worker always reads the job
    belonging to the epoch it observed
  - exact-once execution of every part of every epoch
  - barrier-drain-on-panic: a panicking part still drains the barrier,
    and the dispatcher observes `poisoned` iff a worker part panicked
  - termination: every maximal run ends with all threads done

Seedable bugs (mutation tests -- the checker must catch each):
  drop_worker_unpark, drop_caller_unpark, clear_job_before_barrier,
  publish_before_job_write, skip_remaining_store
"""
import sys
from collections import namedtuple

PARTS_BITS = 16

# Caller program counters.
C_WRITE_JOB, C_STORE_REM, C_PUBLISH, C_UNPARK, C_RUN_OWN, C_BARRIER_READ, \
    C_BARRIER_PARKED, C_CLEAR_JOB, C_SWAP_POISON, C_SHUT_STORE, \
    C_SHUT_PUBLISH, C_SHUT_UNPARK, C_JOIN, C_DONE = range(14)

# Worker program counters.
W_LOAD_EPOCH, W_CHECK_SHUT_SPIN, W_PARK, W_CHECK_SHUT_NEW, W_READ_JOB, \
    W_EXEC, W_FETCH_SUB, W_UNPARK_CALLER, W_DONE = range(9)

BUGS = (
    "drop_worker_unpark", "drop_caller_unpark", "clear_job_before_barrier",
    "publish_before_job_write", "skip_remaining_store",
)

# caller: (pc, epoch_idx, unpark_k, observed_poison tuple)
# workers: tuple of (pc, seen, last_packed, decremented_to_zero)
# shared: (epoch, remaining, job, shutdown, poisoned)
# tokens: (caller_token, worker_tokens tuple)
# executed: tuple over epochs of tuple over parts of count
State = namedtuple(
    "State", "caller workers shared tokens executed")


class Violation(Exception):
    def __init__(self, msg, trace):
        super().__init__(msg)
        self.trace = trace


def initial(cfg):
    return State(
        caller=(C_WRITE_JOB, 0, 0, ()),
        workers=tuple((W_LOAD_EPOCH, 0, 0, False) for _ in range(cfg["workers"])),
        shared=(0, 0, None, False, False),
        tokens=(False, tuple(False for _ in range(cfg["workers"]))),
        executed=tuple(tuple(0 for _ in range(cfg["parts"]))
                       for _ in range(cfg["epochs"])),
    )


def runnable(st, cfg):
    out = []
    pc = st.caller[0]
    if pc != C_DONE:
        if pc == C_BARRIER_PARKED:
            if st.tokens[0]:
                out.append(0)
        elif pc == C_JOIN:
            if all(w[0] == W_DONE for w in st.workers):
                out.append(0)
        else:
            out.append(0)
    for i, w in enumerate(st.workers):
        if w[0] == W_DONE:
            continue
        if w[0] == W_PARK and not st.tokens[1][i]:
            continue
        out.append(i + 1)
    return out


def set_worker_token(tokens, i, val):
    wt = list(tokens[1])
    wt[i] = val
    return (tokens[0], tuple(wt))


def step(st, tid, cfg, trace):
    """One shared-memory op of thread `tid`. Returns (new_state, label)."""
    epoch, remaining, job, shutdown, poisoned = st.shared
    bug = cfg.get("bug")
    parts = cfg["parts"]
    if tid == 0:
        pc, e, k, obs = st.caller
        if pc == C_WRITE_JOB:
            if bug == "publish_before_job_write":
                # Mutation: bump the epoch first; the job write happens
                # on the next step, racing the woken workers.
                gen = epoch >> PARTS_BITS
                sh = ((gen + 1) << PARTS_BITS | parts, remaining, job,
                      shutdown, poisoned)
                return st._replace(caller=(C_STORE_REM, e, k, obs), shared=sh), \
                    f"caller: publish epoch {e} BEFORE job write (bug)"
            sh = (epoch, remaining, e, shutdown, poisoned)
            return st._replace(caller=(C_STORE_REM, e, k, obs), shared=sh), \
                f"caller: job = epoch {e}"
        if pc == C_STORE_REM:
            if bug == "publish_before_job_write":
                # The delayed job write from the mutation above.
                sh = (epoch, parts - 1, e, shutdown, poisoned)
                return st._replace(caller=(C_UNPARK, e, 0, obs), shared=sh), \
                    f"caller: late job write + remaining = {parts - 1} (bug)"
            rem = remaining if bug == "skip_remaining_store" else parts - 1
            sh = (epoch, rem, job, shutdown, poisoned)
            return st._replace(caller=(C_PUBLISH, e, k, obs), shared=sh), \
                f"caller: remaining = {rem}"
        if pc == C_PUBLISH:
            gen = epoch >> PARTS_BITS
            sh = ((gen + 1) << PARTS_BITS | parts, remaining, job, shutdown,
                  poisoned)
            return st._replace(caller=(C_UNPARK, e, 0, obs), shared=sh), \
                f"caller: publish epoch {e} (gen {gen + 1}, parts {parts})"
        if pc == C_UNPARK:
            if k >= parts - 1:
                return st._replace(caller=(C_RUN_OWN, e, k, obs)), \
                    "caller: all participants unparked"
            tokens = st.tokens if bug == "drop_caller_unpark" \
                else set_worker_token(st.tokens, k, True)
            lbl = f"caller: unpark worker {k + 1}" + \
                (" DROPPED (bug)" if bug == "drop_caller_unpark" else "")
            return st._replace(caller=(C_UNPARK, e, k + 1, obs), tokens=tokens), lbl
        if pc == C_RUN_OWN:
            ex = bump_exec(st.executed, e, 0, trace)
            panicked = cfg.get("panic") == (e, 0)
            nxt = C_CLEAR_JOB if bug == "clear_job_before_barrier" else C_BARRIER_READ
            return st._replace(caller=(nxt, e, k, obs), executed=ex), \
                f"caller: run part 0 of epoch {e}" + \
                (" (panics, contained)" if panicked else "")
        if pc == C_BARRIER_READ:
            if remaining == 0:
                nxt = C_SWAP_POISON if bug == "clear_job_before_barrier" else C_CLEAR_JOB
                return st._replace(caller=(nxt, e, k, obs)), \
                    "caller: remaining == 0, barrier drained"
            return st._replace(caller=(C_BARRIER_PARKED, e, k, obs)), \
                f"caller: remaining == {remaining}, parking"
        if pc == C_BARRIER_PARKED:
            # Runnable only with a token (no spurious wakeups -- the
            # protocol must not rely on them).
            return st._replace(caller=(C_BARRIER_READ, e, k, obs),
                               tokens=(False, st.tokens[1])), \
                "caller: unparked, re-checking barrier"
        if pc == C_CLEAR_JOB:
            sh = (epoch, remaining, None, shutdown, poisoned)
            nxt = C_BARRIER_READ if bug == "clear_job_before_barrier" else C_SWAP_POISON
            return st._replace(caller=(nxt, e, k, obs), shared=sh), \
                f"caller: clear job" + \
                (" BEFORE barrier (bug)" if bug == "clear_job_before_barrier" else "")
        if pc == C_SWAP_POISON:
            sh = (epoch, remaining, job, shutdown, False)
            obs = obs + (poisoned,)
            if e + 1 < cfg["epochs"]:
                return st._replace(caller=(C_WRITE_JOB, e + 1, 0, obs), shared=sh), \
                    f"caller: observed poisoned = {poisoned}, next epoch"
            return st._replace(caller=(C_SHUT_STORE, e, 0, obs), shared=sh), \
                f"caller: observed poisoned = {poisoned}, shutting down"
        if pc == C_SHUT_STORE:
            sh = (epoch, remaining, job, True, poisoned)
            return st._replace(caller=(C_SHUT_PUBLISH, e, 0, obs), shared=sh), \
                "caller: shutdown = true"
        if pc == C_SHUT_PUBLISH:
            gen = epoch >> PARTS_BITS
            sh = ((gen + 1) << PARTS_BITS, remaining, job, shutdown, poisoned)
            return st._replace(caller=(C_SHUT_UNPARK, e, 0, obs), shared=sh), \
                "caller: publish shutdown epoch (parts 0)"
        if pc == C_SHUT_UNPARK:
            if k >= len(st.workers):
                return st._replace(caller=(C_JOIN, e, k, obs)), \
                    "caller: all workers unparked for shutdown"
            tokens = set_worker_token(st.tokens, k, True)
            return st._replace(caller=(C_SHUT_UNPARK, e, k + 1, obs),
                               tokens=tokens), f"caller: unpark worker {k + 1}"
        if pc == C_JOIN:
            return st._replace(caller=(C_DONE, e, k, obs)), "caller: joined all"
        raise AssertionError(pc)

    i = tid - 1
    idx = tid  # worker_loop idx: workers are 1-based parts
    pc, seen, last, deced = st.workers[i]

    def upd(w):
        ws = list(st.workers)
        ws[i] = w
        return tuple(ws)

    if pc == W_LOAD_EPOCH:
        if epoch != seen:
            return st._replace(workers=upd((W_CHECK_SHUT_NEW, epoch, epoch, deced))), \
                f"worker {idx}: epoch load -> new packed {epoch >> PARTS_BITS}|{epoch & (2**PARTS_BITS - 1)}"
        return st._replace(workers=upd((W_CHECK_SHUT_SPIN, seen, last, deced))), \
            f"worker {idx}: epoch load -> unchanged"
    if pc == W_CHECK_SHUT_SPIN:
        if shutdown:
            return st._replace(workers=upd((W_DONE, seen, last, deced))), \
                f"worker {idx}: shutdown observed, exiting"
        return st._replace(workers=upd((W_PARK, seen, last, deced))), \
            f"worker {idx}: no new epoch, parking"
    if pc == W_PARK:
        return st._replace(workers=upd((W_LOAD_EPOCH, seen, last, deced)),
                           tokens=set_worker_token(st.tokens, i, False)), \
            f"worker {idx}: unparked"
    if pc == W_CHECK_SHUT_NEW:
        if shutdown:
            return st._replace(workers=upd((W_DONE, seen, last, deced))), \
                f"worker {idx}: shutdown observed, exiting"
        if idx >= (last & (2**PARTS_BITS - 1)):
            return st._replace(workers=upd((W_LOAD_EPOCH, seen, last, deced))), \
                f"worker {idx}: non-participant, back to waiting"
        return st._replace(workers=upd((W_READ_JOB, seen, last, deced))), \
            f"worker {idx}: participating"
    if pc == W_READ_JOB:
        gen = last >> PARTS_BITS
        if job != gen - 1:
            raise Violation(
                f"worker {idx} read job slot {job!r} for epoch generation "
                f"{gen} (expected job {gen - 1})", trace)
        return st._replace(workers=upd((W_EXEC, seen, last, deced))), \
            f"worker {idx}: job read ok (epoch {job})"
    if pc == W_EXEC:
        e = last >> PARTS_BITS
        ex = bump_exec(st.executed, e - 1, idx, trace)
        pois = poisoned
        lbl = f"worker {idx}: run part {idx} of epoch {e - 1}"
        if cfg.get("panic") == (e - 1, idx):
            pois = True
            lbl += " (panics -> poisoned = true)"
        sh = (epoch, remaining, job, shutdown, pois)
        return st._replace(workers=upd((W_FETCH_SUB, seen, last, deced)),
                           shared=sh, executed=ex), lbl
    if pc == W_FETCH_SUB:
        if remaining == 0:
            raise Violation(
                f"worker {idx}: remaining underflow (fetch_sub at 0)", trace)
        sh = (epoch, remaining - 1, job, shutdown, poisoned)
        was_last = remaining == 1
        return st._replace(workers=upd((W_UNPARK_CALLER, seen, last, was_last)),
                           shared=sh), \
            f"worker {idx}: remaining {remaining} -> {remaining - 1}"
    if pc == W_UNPARK_CALLER:
        tokens = st.tokens
        lbl = f"worker {idx}: not last, no unpark"
        if deced:
            if cfg.get("bug") == "drop_worker_unpark":
                lbl = f"worker {idx}: last out -- unpark caller DROPPED (bug)"
            else:
                tokens = (True, st.tokens[1])
                lbl = f"worker {idx}: last out, unpark caller"
        return st._replace(workers=upd((W_LOAD_EPOCH, seen, last, False)),
                           tokens=tokens), lbl
    raise AssertionError(pc)


def bump_exec(executed, e, part, trace):
    ex = [list(row) for row in executed]
    ex[e][part] += 1
    if ex[e][part] > 1:
        raise Violation(f"part {part} of epoch {e} executed twice", trace)
    return tuple(tuple(row) for row in ex)


def check_final(st, cfg, trace):
    if st.caller[0] != C_DONE or any(w[0] != W_DONE for w in st.workers):
        raise Violation("maximal run ended with live threads", trace)
    for e, row in enumerate(st.executed):
        for p, count in enumerate(row):
            if count != 1:
                raise Violation(
                    f"part {p} of epoch {e} executed {count} times", trace)
    obs = st.caller[3]
    for e in range(cfg["epochs"]):
        want = cfg.get("panic") is not None and cfg["panic"][0] == e \
            and cfg["panic"][1] >= 1
        if obs[e] != want:
            raise Violation(
                f"epoch {e}: dispatcher observed poisoned = {obs[e]}, "
                f"expected {want}", trace)


def explore(cfg, max_states=2_000_000):
    """DFS over schedule choices; returns (states, maximal_runs)."""
    init = initial(cfg)
    visited = set()
    finals = 0
    stack = [(init, ())]
    while stack:
        st, trace = stack.pop()
        if st in visited:
            continue
        visited.add(st)
        if len(visited) > max_states:
            raise RuntimeError("state-space explosion")
        threads = runnable(st, cfg)
        if not threads:
            if st.caller[0] == C_DONE and all(w[0] == W_DONE for w in st.workers):
                check_final(st, cfg, trace)
                finals += 1
                continue
            raise Violation(
                "deadlock: live threads but nothing runnable "
                f"(caller pc {st.caller[0]}, workers "
                f"{[w[0] for w in st.workers]})", trace)
        for tid in threads:
            nxt, lbl = step(st, tid, cfg, trace)
            stack.append((nxt, trace + (lbl,)))
    return len(visited), finals


def sweep(full=False):
    """The checker's fast (CI) or full (nightly) configuration sweep."""
    worker_counts = (1, 2, 3) if full else (1, 2)
    cases = []
    for w in worker_counts:
        for parts in range(2, w + 2):
            cases.append({"workers": w, "parts": parts, "epochs": 2})
            # Panic containment: dispatcher part and one worker part.
            cases.append({"workers": w, "parts": parts, "epochs": 2,
                          "panic": (0, 0)})
            cases.append({"workers": w, "parts": parts, "epochs": 2,
                          "panic": (1, parts - 1)})
    return cases


def main():
    full = "--full" in sys.argv
    total_states = 0
    for cfg in sweep(full):
        try:
            states, finals = explore(cfg)
        except Violation as v:
            print(f"FAIL {cfg}: {v}")
            for line in v.trace[-20:]:
                print(f"    {line}")
            return 1
        total_states += states
        print(f"ok   {cfg}: {states} states, {finals} maximal runs")

    # Mutation matrix: every seeded bug must be caught.
    caught = 0
    for bug in BUGS:
        hit = None
        for base in sweep(full):
            cfg = dict(base, bug=bug)
            try:
                explore(cfg)
            except Violation as v:
                hit = (cfg, v)
                break
        if hit is None:
            print(f"MUTATION ESCAPED: {bug}")
            return 1
        cfg, v = hit
        print(f"ok   mutation {bug} caught in {cfg['workers']}w/"
              f"{cfg['parts']}p: {v}")
        caught += 1
    print(f"sched mirror: {total_states} states explored, "
          f"{caught}/{len(BUGS)} mutations caught")
    return 0


if __name__ == "__main__":
    sys.exit(main())
