#!/usr/bin/env python3
"""Faithful Python mirror of uotlint v2 (tools/uotlint/src/{lexer,parse,callgraph,rules}.rs).

The container building this repo has no Rust toolchain, so the lint's logic
is validated here: the mirror implements the same line-oriented lexer, the
same two-pass symbol-table/call-graph construction, and the same rules, and
is run over rust/src to prove the tree is clean (and over seeded violations
to prove each rule fires). The Rust implementation is the source of truth;
keep the two in sync when rules change.

Usage: python3 lint_mirror.py [root]   (default: rust/src relative to repo)
"""
import os
import re
import sys
from collections import defaultdict

# --- lexer (mirror of lexer.rs) ---------------------------------------------

def lex(source):
    """Return list of (code, comment) per line; strings blanked, comments split."""
    out = []
    block_depth = 0
    for raw in source.split("\n"):
        code, comment, block_depth = lex_line(raw, block_depth)
        out.append((code, comment))
    return out


def lex_line(raw, block_depth):
    code, comment = [], []
    i, n = 0, len(raw)
    while i < n:
        if block_depth > 0:
            if raw.startswith("*/", i):
                block_depth -= 1
                i += 2
            elif raw.startswith("/*", i):
                block_depth += 1
                i += 2
            else:
                comment.append(raw[i])
                i += 1
            continue
        if raw.startswith("//", i):
            comment.append(raw[i:])
            break
        if raw.startswith("/*", i):
            block_depth += 1
            i += 2
            continue
        c = raw[i]
        if c == '"':
            code.append('""')
            i += 1
            while i < n:
                if raw[i] == "\\":
                    i += 2
                elif raw[i] == '"':
                    i += 1
                    break
                else:
                    i += 1
        elif c == "r" and (raw.startswith('r"', i) or raw.startswith('r#"', i)):
            code.append('""')
            hashed = raw[i + 1] == "#"
            close = '"#' if hashed else '"'
            i += 3 if hashed else 2
            j = raw.find(close, i)
            i = n if j < 0 else j + len(close)
        elif c == "'":
            rest = raw[i + 1 :]
            if len(rest) >= 3 and rest[0] == "\\" and rest[2] == "'":
                code.append("' '")
                i += 4
            elif len(rest) >= 2 and rest[1] == "'" and rest[0] != "'":
                code.append("' '")
                i += 3
            else:
                code.append("'")
                i += 1
        else:
            code.append(c)
            i += 1
    return "".join(code), "".join(comment), block_depth


IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")
KEYWORDS = {
    "if", "else", "while", "match", "for", "loop", "return", "in", "as",
    "let", "move", "ref", "mut", "pub", "fn", "impl", "use", "mod",
    "struct", "enum", "trait", "type", "where", "unsafe", "dyn", "box",
    "break", "continue", "crate", "self", "Self", "super", "static",
    "const", "extern", "async", "await",
}

# --- parse (mirror of parse.rs) ---------------------------------------------

ALLOW_ALLOC = "uotlint: allow(alloc)"
ALLOW_PANIC = "uotlint: allow(panic)"

ALLOC_PATTERNS = [
    "Vec::new", "Vec::with_capacity", "vec!", ".to_vec()", ".collect()",
    "Box::new", "String::new", ".to_string()", "format!",
]


class FnDef:
    __slots__ = (
        "name", "file", "line", "in_impl", "impl_type", "is_test",
        "allow_alloc", "calls", "allocs",
    )

    def __init__(self, name, file, line, in_impl, is_test, allow_alloc, impl_type=None):
        self.name = name
        self.file = file
        self.line = line
        self.in_impl = in_impl
        self.impl_type = impl_type
        self.is_test = is_test
        self.allow_alloc = allow_alloc
        self.calls = []   # (name, line, is_method)
        self.allocs = []  # (pattern, line, allowed)


def contains_word(hay, needle):
    return find_words(hay, needle) != []


def find_words(hay, needle):
    out = []
    needs_before = needle[:1].isalnum() or needle[:1] == "_"
    needs_after = (needle[-1:].isalnum()) or needle[-1:] == "_"
    start = 0
    while True:
        i = hay.find(needle, start)
        if i < 0:
            return out
        before_ok = (not needs_before) or i == 0 or not (hay[i - 1].isalnum() or hay[i - 1] == "_")
        end = i + len(needle)
        after_ok = (not needs_after) or end >= len(hay) or not (hay[end].isalnum() or hay[end] == "_")
        if before_ok and after_ok:
            out.append(i)
        start = i + 1


def comment_run_above(lines, idx):
    texts = []
    j = idx
    while j > 0:
        j -= 1
        code, comment = lines[j]
        c = code.strip()
        if c == "" and comment.strip() != "":
            texts.append(comment)
        elif c.startswith("#[") or c.startswith("#!["):
            continue
        else:
            break
    return "\n".join(texts)


def parse_file(rel, source):
    """Pass 1 over one file: fn defs with their call and alloc sites."""
    lines = lex(source)
    fns = []
    depth = 0
    in_test = False
    impl_stack = []        # (entry_depth, self_type) of impl/trait blocks
    pending_impl = None
    fn_stack = []          # (fn_index, entry_depth)
    pending_fn = None      # FnDef awaiting its `{`
    for idx, (code, comment) in enumerate(lines):
        lineno = idx + 1
        trimmed = code.strip()
        if not in_test and depth == 0 and trimmed.startswith("#[cfg(test)]"):
            in_test = True

        # impl/trait block entry (method-call resolution targets).
        starts_item = any(
            find_words(code, kw) and _item_at_depth(code, kw, depth, impl_stack)
            for kw in ("impl", "trait")
        )
        if starts_item:
            ty = impl_self_type(code)
            if "{" in code:
                impl_stack.append((depth, ty))
            elif ";" not in code:
                pending_impl = ty
        elif pending_impl is not None:
            if "{" in code:
                impl_stack.append((depth, pending_impl))
                pending_impl = None
            elif ";" in code:
                pending_impl = None

        # fn definition tracking (multi-line signatures).
        fn_def_col = None
        offs = find_words(code, "fn")
        if offs:
            off = offs[0]
            rest = code[off + 2 :].lstrip()
            m = IDENT.match(rest)
            if m:
                name = m.group(0)
                fn_def_col = off + 2 + (len(code[off + 2 :]) - len(rest)) + m.end()
                allow = ALLOW_ALLOC in comment_run_above(lines, idx) or ALLOW_ALLOC in comment
                d = FnDef(
                    name, rel, lineno, bool(impl_stack), in_test, allow,
                    impl_stack[-1][1] if impl_stack else None,
                )
                after = code[off:]
                if "{" in after:
                    fns.append(d)
                    fn_stack.append((len(fns) - 1, depth))
                    pending_fn = None
                elif ";" in after:
                    pending_fn = None
                else:
                    pending_fn = d
        if pending_fn is not None and fn_def_col is None:
            if "{" in code:
                fns.append(pending_fn)
                fn_stack.append((len(fns) - 1, depth))
                pending_fn = None
            elif ";" in code:
                pending_fn = None

        # call + alloc sites attributed to the innermost open fn.
        if fn_stack:
            fi, _ = fn_stack[-1]
            cur = fns[fi]
            for name, is_method, qual in call_sites(code, fn_def_col):
                cur.calls.append((name, lineno, is_method, qual))
            for pat in ALLOC_PATTERNS:
                if contains_word(code, pat):
                    allowed = ALLOW_ALLOC in comment
                    cur.allocs.append((pat, lineno, allowed))

        # brace upkeep.
        for ch in code:
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth = max(0, depth - 1)
                if fn_stack and depth == fn_stack[-1][1]:
                    fn_stack.pop()
                if impl_stack and depth == impl_stack[-1][0]:
                    impl_stack.pop()
    return fns, lines, in_test


def _item_at_depth(code, kw, depth, impl_stack):
    # `impl`/`trait` keyword introducing an item (not e.g. `impl Trait` in
    # a return type). Heuristic: the line's trimmed code starts with the
    # keyword or with pub/unsafe + keyword, at module or impl-free depth.
    t = code.strip()
    for prefix in (kw + " ", kw + "<"):
        if t.startswith(prefix) or t.startswith("pub " + prefix) or t.startswith("unsafe " + prefix) or t.startswith("pub unsafe " + prefix):
            return True
    return False


def impl_self_type(code):
    """Self-type name of an `impl`/`trait` header line: the last path
    segment (generics stripped) after `for`, or the first type after the
    keyword. `impl<T> fmt::Debug for Foo<T>` -> `Foo`."""
    t = code.strip()
    for kw in ("impl", "trait"):
        offs = find_words(t, kw)
        if offs:
            rest = t[offs[0] + len(kw):]
            break
    else:
        return None
    # skip generic params on the keyword itself
    rest = rest.lstrip()
    if rest.startswith("<"):
        angle, k = 1, 1
        while k < len(rest) and angle > 0:
            if rest[k] == "<":
                angle += 1
            elif rest[k] == ">":
                angle -= 1
            k += 1
        rest = rest[k:]
    if " for " in rest:
        rest = rest.split(" for ", 1)[1]
    rest = rest.strip()
    # last path segment before generics/brace
    rest = rest.split("{", 1)[0].split("<", 1)[0].strip()
    seg = rest.rsplit("::", 1)[-1].strip()
    m = IDENT.match(seg)
    return m.group(0) if m else None


def call_sites(code, fn_def_col):
    """Identifier-followed-by-( occurrences: (name, is_method, qualifier)."""
    out = []
    for m in IDENT.finditer(code):
        name = m.group(0)
        if name in KEYWORDS:
            continue
        if fn_def_col is not None and m.end() == fn_def_col:
            continue  # the fn's own name in its definition
        j = m.end()
        # optional turbofish ::<...>
        if code.startswith("::<", j):
            k, angle = j + 3, 1
            while k < len(code) and angle > 0:
                if code[k] == "<":
                    angle += 1
                elif code[k] == ">":
                    angle -= 1
                k += 1
            j = k
        if j < len(code) and code[j] == "(":
            if j == m.end() and code[m.end():m.end()+1] == "!":
                continue  # macro (unreachable: '(' != '!')
            # macro? ident immediately followed by ! was excluded by '(' check
            back = m.start() - 1
            while back >= 0 and code[back] == " ":
                back -= 1
            is_method = back >= 0 and code[back] == "."
            qual = None
            if back >= 1 and code[back] == ":" and code[back - 1] == ":":
                qm = [q for q in IDENT.finditer(code, 0, back - 1) if q.end() == back - 1]
                if qm:
                    qual = qm[0].group(0)
            out.append((name, is_method, qual))
        elif j < len(code) and code[j] == "!":
            continue  # macro call
    return out


# --- callgraph + rules (mirror of callgraph.rs / rules.rs) ------------------

HOT_FILES = [
    "algo/mapuot.rs", "algo/pot.rs", "algo/coffee.rs", "algo/sparse.rs",
    "algo/matfree.rs", "algo/parallel.rs", "algo/kernels.rs", "algo/oned.rs",
]

PANIC_DIRS = ("coordinator/", "config/", "runtime/")

# The only `telemetry::` items a hot solver file may touch (mirror of
# rules.rs TELEMETRY_HOT_API): the alloc-free record path. Everything
# else (snapshots, exporters, the registry) is cold-layer API.
TELEMETRY_HOT_API = ("now_ns", "record_span", "span", "enabled", "Phase")

# The transitive-allocation universe: the hot core and the helper layer it
# is allowed to call. Calls resolving outside (coordinator, config, sim,
# apps, bench, CLI) are dispatch/setup layers that call INTO the core, not
# hot-path callees - resolving into them by bare name only manufactures
# phantom chains.
ALLOC_UNIVERSE = ("algo/", "util/")


def is_hot_name(name):
    # `with_pool`-style builders share the _pool suffix but are
    # constructors, not sweep kernels.
    if name.startswith("with_"):
        return False
    return (
        name.startswith("iterate") or name.startswith("fused_")
        or "_pool" in name or name.startswith("pool_")
    )


def analyze(files):
    """files: dict rel -> source. Returns (violations, stats)."""
    all_fns = []
    lexed = {}
    for rel in sorted(files):
        fns, lines, _ = parse_file(rel, files[rel])
        lexed[rel] = lines
        all_fns.extend(
            f for f in fns
            if not f.is_test and f.file.startswith(ALLOC_UNIVERSE)
        )

    by_name = defaultdict(list)
    for i, f in enumerate(all_fns):
        by_name[f.name].append(i)

    # Edges: method calls resolve to impl/trait fns only; path/bare calls to
    # any fn of that name.
    edges = defaultdict(set)
    for i, f in enumerate(all_fns):
        if f.allow_alloc:
            continue  # an allowed-to-allocate fn's callees are its own business
        for name, _line, is_method, qual in f.calls:
            cands = by_name.get(name, ())
            if qual is not None:
                typed = [j for j in cands if all_fns[j].impl_type == qual]
                if typed:
                    edges[i].update(typed)
                    continue
            for j in cands:
                if is_method and not all_fns[j].in_impl:
                    continue
                edges[i].add(j)

    roots = [
        i for i, f in enumerate(all_fns)
        if f.file in HOT_FILES and is_hot_name(f.name)
    ]
    # BFS with parent pointers for chain reporting.
    parent = {}
    order = list(roots)
    seen = set(roots)
    qi = 0
    while qi < len(order):
        u = order[qi]
        qi += 1
        for v in sorted(edges[u]):
            if v not in seen:
                seen.add(v)
                parent[v] = u
                order.append(v)

    violations = []
    allow_allocs = 0
    for i in seen:
        f = all_fns[i]
        if f.allow_alloc:
            allow_allocs += 1
            continue
        for pat, line, allowed in f.allocs:
            if allowed:
                allow_allocs += 1
                continue
            chain = [f.name]
            k = i
            while k in parent:
                k = parent[k]
                chain.append(all_fns[k].name)
            chain.reverse()
            violations.append(
                (f.file, line, "alloc",
                 f"`{pat}` in `{f.name}`, reachable from hot root via {' -> '.join(chain)}")
            )

    # panic-path + lock rules are line-oriented over the lexed files.
    allow_panics = 0
    lock_sites = 0
    for rel in sorted(files):
        lines = lexed[rel]
        depth = 0
        in_test = False
        for idx, (code, comment) in enumerate(lines):
            lineno = idx + 1
            trimmed = code.strip()
            if not in_test and depth == 0 and trimmed.startswith("#[cfg(test)]"):
                in_test = True
            if not in_test:
                if rel.startswith(PANIC_DIRS):
                    allowed = ALLOW_PANIC in comment or ALLOW_PANIC in comment_run_above(lines, idx)
                    sites = panic_sites(code, trimmed)
                    for what in sites:
                        if allowed:
                            allow_panics += 1
                        else:
                            violations.append(
                                (rel, lineno, "panic",
                                 f"{what} in service-facing code - return a typed Error "
                                 f"(or justify with `// {ALLOW_PANIC} - reason`)")
                            )
                if ".lock()" in code:
                    lock_sites += 1
                    stmt = " ".join(c for c, _ in lines[idx: idx + 4])
                    if "into_inner" not in stmt and "recover(" not in stmt:
                        violations.append(
                            (rel, lineno, "lock",
                             "`.lock()` without the PoisonError::into_inner recovery "
                             "pattern (see coordinator::batcher::recover)")
                        )
                if rel in HOT_FILES:
                    for m in re.finditer(r"telemetry::", code):
                        im = IDENT.match(code[m.end():])
                        ident = im.group(0) if im else ""
                        if ident not in TELEMETRY_HOT_API:
                            violations.append(
                                (rel, lineno, "telemetry",
                                 f"`telemetry::{ident}` in a hot solver file - hot loops "
                                 "may only use the alloc-free record path")
                            )
            for ch in code:
                if ch == "{":
                    depth += 1
                elif ch == "}":
                    depth = max(0, depth - 1)

    stats = {
        "fns": len(all_fns),
        "roots": len(roots),
        "reachable": len(seen),
        "allow_allocs": allow_allocs,
        "allow_panics": allow_panics,
        "lock_sites": lock_sites,
    }
    return violations, stats


def panic_sites(code, trimmed):
    out = []
    if ".unwrap()" in code:
        out.append("`unwrap()`")
    if ".expect(" in code:
        out.append("`expect(...)`")
    if not trimmed.startswith("#"):
        for i, ch in enumerate(code):
            if ch != "[":
                continue
            back = i - 1
            while back >= 0 and code[back] == " ":
                back -= 1
            if back < 0 or not (code[back].isalnum() or code[back] in "_)]?"):
                continue
            # `mut [f32]`, `in [..]`, `&'b [..]`: type/iterator position,
            # not indexing — the preceding token is a keyword or lifetime.
            if code[back].isalnum() or code[back] == "_":
                end = back + 1
                while back >= 0 and (code[back].isalnum() or code[back] == "_"):
                    back -= 1
                word = code[back + 1:end]
                if word in KEYWORDS or (back >= 0 and code[back] == "'"):
                    continue
            out.append("direct indexing")
            break
    return out


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.join(here, "../../../rust/src")
    files = {}
    for dirpath, _dirs, names in os.walk(root):
        for n in sorted(names):
            if n.endswith(".rs"):
                p = os.path.join(dirpath, n)
                rel = os.path.relpath(p, root).replace(os.sep, "/")
                files[rel] = open(p).read()
    violations, stats = analyze(files)
    for rel, line, rule, msg in sorted(violations):
        print(f"{rel}:{line}: [{rule}] {msg}")
    print(
        f"mirror: {len(files)} files, {stats['fns']} fns, {stats['roots']} hot roots, "
        f"{stats['reachable']} reachable, {stats['allow_allocs']} allow(alloc), "
        f"{stats['allow_panics']} allow(panic), {stats['lock_sites']} lock sites, "
        f"{len(violations)} violations"
    )
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
