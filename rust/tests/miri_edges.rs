//! Miri-sized edge-case coverage for the partition/pool/tiling machinery.
//!
//! The `prop_*` suites sweep shapes and thread ladders far too large for
//! Miri's interpreter; this file re-exercises exactly the *edges* whose
//! unsafe disjoint-split arguments are easiest to get wrong — more
//! threads than rows, empty CSR rows, tile panels wider than the matrix —
//! on shapes tiny enough that Miri finishes in minutes. CI runs it as
//!
//! ```text
//! MIRIFLAGS="-Zmiri-disable-isolation" \
//! MAP_UOT_KERNEL=scalar MAP_UOT_TILE=off cargo miri test --test miri_edges
//! ```
//!
//! (isolation off because the cache-topology probe reads sysfs; kernel
//! forced scalar because Miri has no AVX2 shims — every test below also
//! pins its policy explicitly, so the env is belt-and-braces). The file
//! is an ordinary test under `cargo test` too, so the native suite keeps
//! the same edges covered with the SIMD paths live.

use map_uot::algo::pool::{AffinityHint, Partition};
use map_uot::algo::{
    solver_for, KernelKind, KernelPolicy, NnzPartition, ParallelBackend, Problem, SolverKind,
    SolverSession, SparseProblem, StopRule, Workspace,
};
use map_uot::util::Matrix;

/// Scalar, untiled, no streaming stores: the one policy every interpreter
/// and sanitizer can execute.
fn scalar_policy() -> KernelPolicy {
    KernelPolicy::explicit(KernelKind::Scalar, 0, None)
}

/// `Partition` must tile `0..rows` with disjoint, in-order, non-empty
/// blocks for every degenerate (rows, threads, cap) combination —
/// including zero rows, one row, and threads ≫ rows. The pool kernels'
/// `SliceRef`/`ArenaRef` SAFETY arguments are all phrased in terms of
/// this property.
#[test]
fn partition_tiles_all_degenerate_shapes() {
    for rows in [0usize, 1, 2, 3, 5, 9] {
        for threads in [1usize, 2, 3, 4, 8, 16] {
            for cap in [1usize, 2, 8] {
                let part = Partition::new(rows, threads, cap);
                assert!(part.blocks() >= 1, "rows={rows} t={threads} cap={cap}");
                assert!(
                    part.blocks() <= threads.max(1) && part.blocks() <= rows.max(1),
                    "rows={rows} t={threads} cap={cap}: {} blocks",
                    part.blocks()
                );
                let mut next = 0usize;
                for b in 0..part.blocks() {
                    let r = part.range(b);
                    assert_eq!(r.start, next, "rows={rows} t={threads} cap={cap} b={b}");
                    assert!(rows == 0 || !r.is_empty(), "empty block {b} for rows={rows}");
                    next = r.end;
                }
                assert_eq!(next, rows, "rows={rows} t={threads} cap={cap}: blocks must tile");
            }
        }
    }
}

/// Same tiling contract for the nnz-balanced CSR partition, on skewed
/// structures with empty rows — including m < threads, an all-empty
/// matrix, and a single dense row holding every nonzero.
#[test]
fn nnz_partition_tiles_skewed_and_empty_structures() {
    let cases: &[&[usize]] = &[
        &[0, 0, 3, 3, 5, 5],  // empty rows interleaved
        &[0, 0, 0, 0],        // all rows empty
        &[0, 7],              // one row owns every nonzero
        &[0],                 // zero rows
        &[0, 1, 2, 3, 4, 5],  // uniform
    ];
    for row_ptr in cases {
        let m = row_ptr.len() - 1;
        for threads in [1usize, 2, 4, 16] {
            let part = NnzPartition::new(row_ptr, threads, threads);
            assert_eq!(part.rows(), m, "{row_ptr:?} t={threads}");
            assert!(part.blocks() >= 1);
            let mut next = 0usize;
            for b in 0..part.blocks() {
                let r = part.range(b);
                assert_eq!(r.start, next, "{row_ptr:?} t={threads} b={b}");
                assert!(m == 0 || r.end > r.start, "{row_ptr:?} t={threads}: empty block {b}");
                next = r.end;
            }
            assert_eq!(next, m, "{row_ptr:?} t={threads}: blocks must tile");
        }
    }
}

/// Pool engine vs. spawn engine on shapes where threads outnumber rows,
/// forced scalar so the comparison runs under Miri. Two iterations of
/// every solver cover the one-phase (MAP-UOT/POT) and two-phase (COFFEE)
/// pool dispatch paths plus the parked-worker handshake.
#[test]
fn pool_bitmatches_spawn_on_tiny_oversubscribed_shapes() {
    for kind in SolverKind::ALL {
        for &(m, n) in &[(1usize, 1usize), (2, 3), (3, 5)] {
            let t = 3; // > m for the first two shapes
            let p = Problem::random(m, n, 0.7, (m * 13 + n) as u64);
            let solver = solver_for(kind);
            let mut ws_spawn = Workspace::with_backend_policy(
                m,
                n,
                t,
                ParallelBackend::SpawnPerIter,
                AffinityHint::None,
                scalar_policy(),
            );
            let mut ws_pool = Workspace::with_backend_policy(
                m,
                n,
                t,
                ParallelBackend::Pool,
                AffinityHint::None,
                scalar_policy(),
            );
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            for it in 0..2 {
                let da = solver.iterate_tracked(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_spawn);
                let db = solver.iterate_tracked(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_pool);
                assert_eq!(a.as_slice(), b.as_slice(), "{kind:?} {m}x{n} iter={it}");
                assert_eq!(da.to_bits(), db.to_bits(), "{kind:?} {m}x{n} iter={it}: deltas");
            }
            assert_eq!(cs_a, cs_b, "{kind:?} {m}x{n}: colsums");
        }
    }
}

/// Sparse pool solve with empty rows *and* columns in the support, with
/// more threads than rows: the nnz-partitioned arena/slice splits must
/// stay in bounds and bit-match the spawn engine.
#[test]
fn sparse_pool_handles_empty_rows_when_oversubscribed() {
    // Row 1 and column 2 are structurally empty.
    let plan = Matrix::from_fn(3, 4, |i, j| {
        if i == 1 || j == 2 { 0.0 } else { (1 + i * 4 + j) as f32 * 0.25 }
    });
    let dense = Problem {
        plan,
        rpd: vec![0.9, 0.4, 1.3],
        cpd: vec![0.6, 1.1, 0.8, 1.0],
        fi: 0.7,
    };
    let sp = SparseProblem::from_problem(&dense, 0.0).unwrap();
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 3 };
    let mut sessions = [ParallelBackend::SpawnPerIter, ParallelBackend::Pool].map(|backend| {
        SolverSession::builder(SolverKind::MapUot)
            .threads(5) // > m = 3
            .backend(backend)
            .kernel(KernelKind::Scalar)
            .stop(stop)
            .build_sparse(&sp)
    });
    let reports = sessions.each_mut().map(|s| s.solve_sparse(&sp).unwrap());
    assert_eq!(reports[0].iters, reports[1].iters);
    let [spawn, pool] = &sessions;
    let (a, b) = (spawn.sparse_plan().unwrap(), pool.sparse_plan().unwrap());
    assert_eq!(a.values, b.values, "sparse pool diverged from spawn");
    assert!(a.values.iter().all(|v| v.is_finite()));
}

/// A tile panel wider than the matrix must degrade to the untiled sweep
/// **bit-for-bit** (`tile_for(n)` rejects the panel, so no out-of-bounds
/// access is even reachable), while a narrow panel that does not divide
/// `n` clamps its last panel and agrees within the usual tiled tolerance
/// (the two-phase tiled sweep reorders the colsum math, so bit equality
/// is not expected there — see `prop_kernels.rs`).
#[test]
fn tile_wider_than_matrix_matches_untiled() {
    let (m, n) = (4usize, 5usize);
    let p = Problem::random(m, n, 0.6, 99);
    let solver = solver_for(SolverKind::MapUot);
    // tile_cols: untiled reference, wider-than-n, non-dividing narrow.
    let mut results = Vec::new();
    for tile_cols in [0usize, 64, 2] {
        let policy = KernelPolicy::explicit(KernelKind::Scalar, tile_cols, None);
        let mut ws = Workspace::with_backend_policy(
            m,
            n,
            1,
            ParallelBackend::SpawnPerIter,
            AffinityHint::None,
            policy,
        );
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        for _ in 0..3 {
            solver.iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
        results.push((tile_cols, a, cs));
    }
    let (_, ref_plan, ref_cs) = &results[0];
    let (_, wide_plan, wide_cs) = &results[1];
    assert_eq!(
        wide_plan.as_slice(),
        ref_plan.as_slice(),
        "tile wider than n must take the untiled path bit-for-bit"
    );
    assert_eq!(wide_cs, ref_cs, "tile wider than n: colsums diverged");
    let (_, narrow_plan, narrow_cs) = &results[2];
    let diff = narrow_plan.max_rel_diff(ref_plan, 1e-6);
    assert!(diff < 1e-5, "clamped last panel: plan rel diff {diff}");
    for (a, b) in narrow_cs.iter().zip(ref_cs) {
        let denom = b.abs().max(1e-6);
        assert!(((a - b).abs() / denom) < 1e-5, "clamped last panel: colsum {a} vs {b}");
    }
}
