//! Property tests for the sparse CSR backend:
//!
//! * **Agreement** — on identical support, the fused CSR sweep matches the
//!   dense MAP-UOT kernel (tolerance: the colsum grouping differs) on the
//!   serial, `thread::scope` and pool engines across thread counts.
//! * **Bit-exactness** — for any fixed nnz partition, the scope and pool
//!   engines are bit-identical to the partitioned serial reference
//!   (`parallel::sparse_mapuot_iterate_partitioned_tracked`): same values,
//!   same carried column sums, same tracked deltas. A full
//!   `SolverSession::solve_sparse` on the pool engine bit-matches the
//!   spawn engine for every thread count.
//! * **Hardening** — malformed CSR (bad `row_ptr`, out-of-range or
//!   unsorted `col_idx`, NaN/negative values) is rejected with
//!   `Error::InvalidProblem`, never a panic; empty rows/columns solve
//!   safely; zero structure is preserved.
//!
//! CI runs this file under the same thread-oversubscription matrix as
//! `prop_pool.rs`: set `MAP_UOT_POOL_THREADS=t` to restrict the sweep.

use map_uot::algo::pool::{AccArena, AffinityHint, PaddedSlots, ParallelBackend, ThreadPool};
use map_uot::algo::sparse::{self, CsrMatrix, NnzPartition, SparseProblem, SparseWorkspace};
use map_uot::algo::{mapuot, parallel, Problem, SolverKind, SolverSession, StopRule};
use map_uot::error::Error;
use map_uot::util::{Matrix, XorShift};

/// Thread counts to sweep: the full ladder by default, or the single value
/// from `MAP_UOT_POOL_THREADS` (the CI oversubscription matrix).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 3, 4, 8, 16],
    }
}

/// Random sparse problem on a Bernoulli support.
fn sparse_problem(m: usize, n: usize, density: f32, seed: u64) -> SparseProblem {
    let mut rng = XorShift::new(seed);
    let plan = Matrix::from_fn(m, n, |_, _| {
        if rng.next_f32() < density { rng.uniform(0.1, 2.0) } else { 0.0 }
    });
    let rpd = rng.uniform_vec(m, 0.3, 1.7);
    let cpd = rng.uniform_vec(n, 0.3, 1.7);
    let dense = Problem { plan, rpd, cpd, fi: 0.7 };
    SparseProblem::from_problem(&dense, 0.0).expect("generator produces valid problems")
}

/// Shapes crossing the interesting edges: single row/col blocks, more
/// threads than rows, wide (past the parallel-reduction column cutoff is
/// covered by prop_pool; sparse colsums reduce identically).
const SHAPES: &[(usize, usize, f32)] = &[
    (1, 1, 1.0),
    (2, 3, 0.8),
    (9, 8, 0.5),
    (23, 17, 0.4),
    (64, 48, 0.15),
    (7, 300, 0.3),
];

#[test]
fn sparse_matches_dense_on_same_support_all_engines() {
    for &(m, n, density) in SHAPES {
        for &t in &thread_counts() {
            let sp = sparse_problem(m, n, density, (m * 31 + n) as u64);
            let mut dense = sp.plan.to_dense();
            let mut cs_dense = dense.col_sums();

            let mut engines = [
                SparseWorkspace::with_backend(m, n, t, ParallelBackend::Pool, AffinityHint::None),
                SparseWorkspace::with_backend(
                    m,
                    n,
                    t,
                    ParallelBackend::SpawnPerIter,
                    AffinityHint::None,
                ),
                SparseWorkspace::new(m, n, 1),
            ];
            let mut plans: Vec<CsrMatrix> = (0..engines.len()).map(|_| sp.plan.clone()).collect();
            let mut colsums: Vec<Vec<f32>> = plans.iter().map(|p| p.col_sums()).collect();
            for ws in engines.iter_mut() {
                ws.prepare(&sp.plan);
            }
            for _ in 0..6 {
                mapuot::iterate(&mut dense, &mut cs_dense, &sp.rpd, &sp.cpd, sp.fi);
                for ((ws, plan), cs) in
                    engines.iter_mut().zip(plans.iter_mut()).zip(colsums.iter_mut())
                {
                    ws.iterate(plan, cs, &sp.rpd, &sp.cpd, sp.fi);
                }
            }
            for (which, plan) in plans.iter().enumerate() {
                assert!(
                    plan.to_dense().max_rel_diff(&dense, 1e-6) < 1e-3,
                    "{m}x{n} d={density} t={t} engine {which} diverged from dense"
                );
            }
            // Pool and scope engines bit-match (same partition, same
            // reduction order).
            assert_eq!(plans[0].values, plans[1].values, "{m}x{n} t={t}");
            assert_eq!(colsums[0], colsums[1], "{m}x{n} t={t}");
        }
    }
}

/// For any fixed partition, both threaded engines are bit-identical to the
/// partitioned serial reference — values, colsums, and tracked deltas.
#[test]
fn engines_bitmatch_partitioned_serial_reference() {
    for &(m, n, density) in SHAPES {
        for &t in &thread_counts() {
            let sp = sparse_problem(m, n, density, (m * 7 + n * 3) as u64);
            let part = NnzPartition::new(&sp.plan.row_ptr, t, t);
            let pool = ThreadPool::new(t);
            let mut a = sp.plan.clone(); // scope
            let mut b = sp.plan.clone(); // pool
            let mut c = sp.plan.clone(); // partitioned serial reference
            let mut cs_a = a.col_sums();
            let mut cs_b = b.col_sums();
            let mut cs_c = c.col_sums();
            let mut fcol = vec![0f32; n];
            let mut inv = vec![0f32; n];
            let mut acc_a = AccArena::padded(t, n);
            let mut acc_b = AccArena::padded(t, n);
            let mut acc_c = AccArena::padded(t, n);
            let mut deltas = PaddedSlots::new(t);
            for it in 0..4 {
                let da = parallel::sparse_mapuot_iterate_tracked(
                    &mut a, &mut cs_a, &sp.rpd, &sp.cpd, sp.fi, &mut fcol, &mut inv, &mut acc_a,
                    &part,
                );
                let db = parallel::sparse_mapuot_iterate_pool_tracked(
                    &mut b, &mut cs_b, &sp.rpd, &sp.cpd, sp.fi, &pool, &mut fcol, &mut inv,
                    &mut acc_b, &mut deltas, &part,
                );
                let dc = parallel::sparse_mapuot_iterate_partitioned_tracked(
                    &mut c, &mut cs_c, &sp.rpd, &sp.cpd, sp.fi, &mut fcol, &mut inv, &mut acc_c,
                    &part,
                );
                assert_eq!(
                    da.to_bits(),
                    dc.to_bits(),
                    "{m}x{n} t={t} iter={it}: scope delta diverged from reference"
                );
                assert_eq!(
                    db.to_bits(),
                    dc.to_bits(),
                    "{m}x{n} t={t} iter={it}: pool delta diverged from reference"
                );
            }
            assert_eq!(a.values, c.values, "{m}x{n} t={t}: scope values");
            assert_eq!(b.values, c.values, "{m}x{n} t={t}: pool values");
            assert_eq!(cs_a, cs_c, "{m}x{n} t={t}: scope colsums");
            assert_eq!(cs_b, cs_c, "{m}x{n} t={t}: pool colsums");
        }
    }
}

/// Full sparse session solves agree across engines: bit-identical CSR
/// plans, same iteration counts — and a single-block pool run matches the
/// plain serial reference.
#[test]
fn full_sparse_solve_agrees_across_backends() {
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    for &t in &thread_counts() {
        let sp = sparse_problem(32, 24, 0.4, 21);
        let mut spawn = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::SpawnPerIter)
            .stop(stop)
            .build_sparse(&sp);
        let mut pool = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::Pool)
            .stop(stop)
            .build_sparse(&sp);
        let rs = spawn.solve_sparse(&sp).unwrap();
        let rp = pool.solve_sparse(&sp).unwrap();
        assert_eq!(rs.iters, rp.iters, "t={t}");
        assert_eq!(
            spawn.sparse_plan().unwrap().values,
            pool.sparse_plan().unwrap().values,
            "t={t}"
        );
    }
}

/// Malformed CSR input is a typed error, never a panic. The non-monotonic
/// and offset `row_ptr` cases used to pass construction and blow up later
/// inside `row_sums`/the fused sweep.
#[test]
fn malformed_csr_is_rejected_with_typed_errors() {
    let cases: Vec<(&str, map_uot::error::Result<CsrMatrix>)> = vec![
        ("row_ptr too short", CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0])),
        ("row_ptr too long", CsrMatrix::new(1, 2, vec![0, 1, 1], vec![0], vec![1.0])),
        ("row_ptr not starting at 0", CsrMatrix::new(2, 2, vec![1, 1, 1], vec![0], vec![1.0])),
        (
            "row_ptr non-monotonic",
            CsrMatrix::new(3, 3, vec![0, 2, 1, 3], vec![0, 1, 2], vec![1.0, 1.0, 1.0]),
        ),
        ("row_ptr end != nnz", CsrMatrix::new(2, 2, vec![0, 1, 2], vec![0], vec![1.0])),
        ("col/val length mismatch", CsrMatrix::new(1, 2, vec![0, 1], vec![0, 1], vec![1.0])),
        ("col out of range", CsrMatrix::new(2, 2, vec![0, 1, 1], vec![5], vec![1.0])),
        (
            "cols not ascending",
            CsrMatrix::new(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]),
        ),
        (
            "duplicate col in a row",
            CsrMatrix::new(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 1.0]),
        ),
        ("negative value", CsrMatrix::new(2, 2, vec![0, 1, 1], vec![0], vec![-1.0])),
        ("NaN value", CsrMatrix::new(1, 1, vec![0, 1], vec![0], vec![f32::NAN])),
        ("infinite value", CsrMatrix::new(1, 1, vec![0, 1], vec![0], vec![f32::INFINITY])),
    ];
    for (what, outcome) in cases {
        match outcome {
            Err(Error::InvalidProblem(_)) => {}
            other => panic!("{what}: expected InvalidProblem, got {other:?}"),
        }
    }
    // from_dense shares the contract: NaN is rejected (not silently
    // dropped) and a negative threshold cannot admit negative values.
    let nan = Matrix::from_fn(2, 2, |i, j| if i + j == 1 { f32::NAN } else { 1.0 });
    assert!(matches!(CsrMatrix::from_dense(&nan, 0.0), Err(Error::InvalidProblem(_))));
    let neg = Matrix::from_fn(2, 2, |i, _| if i == 0 { -0.5 } else { 1.0 });
    assert!(matches!(CsrMatrix::from_dense(&neg, -1.0), Err(Error::InvalidProblem(_))));
}

/// Empty rows and columns are handled on every engine: factors guard to
/// zero, values stay finite, and the zero structure never changes.
#[test]
fn empty_rows_and_columns_solve_safely() {
    let dense = Matrix::from_fn(6, 6, |i, j| {
        if i == 1 || i == 4 || j == 2 { 0.0 } else { 1.0 }
    });
    let plan = CsrMatrix::from_dense(&dense, 0.0).unwrap();
    let sp = SparseProblem::new(plan, vec![1.0; 6], vec![1.0; 6], 0.5).unwrap();
    for &t in &thread_counts() {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 100 })
            .build_sparse(&sp);
        session.solve_sparse(&sp).unwrap();
        let out = session.sparse_plan().unwrap();
        assert_eq!(out.nnz(), sp.nnz(), "t={t}: structure changed");
        assert_eq!(out.col_idx, sp.plan.col_idx, "t={t}");
        assert!(out.values.iter().all(|v| v.is_finite() && *v >= 0.0), "t={t}");
    }
}

/// An all-zero support (nnz = 0) is degenerate but must terminate cleanly.
#[test]
fn empty_support_terminates() {
    let plan = CsrMatrix::new(3, 3, vec![0, 0, 0, 0], vec![], vec![]).unwrap();
    let sp = SparseProblem::new(plan, vec![1.0; 3], vec![1.0; 3], 0.5).unwrap();
    let mut session = SolverSession::builder(SolverKind::MapUot)
        .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 16 })
        .build_sparse(&sp);
    let report = session.solve_sparse(&sp).unwrap();
    // Nothing can move: the marginal error is stuck at the full target
    // mass and the plan delta at zero, so the delta rule fires.
    assert!(report.iters <= 16);
    assert_eq!(session.sparse_plan().unwrap().nnz(), 0);
}

/// The workspace accepts skewed structures: one dominant row must not
/// starve the other blocks, and iteration stays correct under
/// oversubscription (threads > rows).
#[test]
fn skewed_structure_is_balanced_and_correct() {
    let mut rng = XorShift::new(3);
    let dense = Matrix::from_fn(16, 64, |i, _| {
        let p = if i == 0 { 0.9 } else { 0.05 };
        if rng.next_f32() < p { rng.uniform(0.1, 2.0) } else { 0.0 }
    });
    let plan = CsrMatrix::from_dense(&dense, 0.0).unwrap();
    let rpd = rng.uniform_vec(16, 0.3, 1.7);
    let cpd = rng.uniform_vec(64, 0.3, 1.7);
    let sp = SparseProblem::new(plan, rpd, cpd, 0.7).unwrap();
    for &t in &thread_counts() {
        let part = NnzPartition::new(&sp.plan.row_ptr, t, t);
        let max_row = (0..sp.rows())
            .map(|i| sp.plan.row_ptr[i + 1] - sp.plan.row_ptr[i])
            .max()
            .unwrap();
        for b in 0..part.blocks() {
            let r = part.range(b);
            let block_nnz = sp.plan.row_ptr[r.end] - sp.plan.row_ptr[r.start];
            assert!(
                block_nnz <= sp.nnz() / part.blocks() + max_row,
                "t={t} block {b}: {block_nnz} nnz of {} total",
                sp.nnz()
            );
        }
        // Oversubscribed solve still matches the serial result bit-wise
        // through the session (single solve, fixed iters comparison).
        let mut ws = SparseWorkspace::new(16, 64, t);
        ws.prepare(&sp.plan);
        let mut a = sp.plan.clone();
        let mut cs = a.col_sums();
        for _ in 0..4 {
            ws.iterate(&mut a, &mut cs, &sp.rpd, &sp.cpd, sp.fi);
        }
        assert!(a.values.iter().all(|v| v.is_finite()));
    }
}

/// Sparse and dense solves on a fully dense support agree end to end —
/// the degenerate case where CSR is pure overhead but must stay correct.
#[test]
fn fully_dense_support_matches_dense_solver() {
    let p = Problem::random(20, 14, 0.7, 11);
    let sp = SparseProblem::from_problem(&p, 0.0).unwrap();
    assert_eq!(sp.nnz(), 20 * 14);
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    let mut sparse_session = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .build_sparse(&sp);
    let mut dense_session = SolverSession::builder(SolverKind::MapUot).stop(stop).build(&p);
    sparse_session.solve_sparse(&sp).unwrap();
    dense_session.solve(&p).unwrap();
    let sparse_out = sparse_session.sparse_plan().unwrap().to_dense();
    assert!(
        sparse_out.max_rel_diff(dense_session.plan(), 1e-6) < 1e-3,
        "sparse-on-dense-support diverged from the dense solver"
    );
}

/// `sparse::iterate` (compat wrapper), `iterate_into` and the tracked form
/// advance the plan identically.
#[test]
fn serial_entry_points_are_bit_identical() {
    let sp = sparse_problem(19, 13, 0.4, 5);
    let n = sp.cols();
    let mut a = sp.plan.clone();
    let mut b = sp.plan.clone();
    let mut c = sp.plan.clone();
    let mut cs_a = a.col_sums();
    let mut cs_b = b.col_sums();
    let mut cs_c = c.col_sums();
    let mut fcol = vec![0f32; n];
    let mut fcol2 = vec![0f32; n];
    let mut inv = vec![0f32; n];
    for _ in 0..5 {
        sparse::iterate(&mut a, &mut cs_a, &sp.rpd, &sp.cpd, sp.fi);
        sparse::iterate_into(&mut b, &mut cs_b, &sp.rpd, &sp.cpd, sp.fi, &mut fcol);
        sparse::iterate_tracked_into(
            &mut c, &mut cs_c, &sp.rpd, &sp.cpd, sp.fi, &mut fcol2, &mut inv,
        );
    }
    assert_eq!(a.values, b.values);
    assert_eq!(a.values, c.values);
    assert_eq!(cs_a, cs_b);
    assert_eq!(cs_a, cs_c);
}
