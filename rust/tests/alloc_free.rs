//! Counting-allocator proof of the session allocation contract: after the
//! first (warmup) solve, `SolverSession::solve` on same-shape problems must
//! perform **zero heap allocations** on the serial path — no `plan.clone()`
//! for delta tracking, no per-iteration scratch, no per-check buffers.
//!
//! This file holds exactly one test so no concurrent test in the same
//! binary can pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use map_uot::algo::{Problem, SolverKind, SolverSession, StopRule};

struct CountingAllocator;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

#[test]
fn hot_loop_allocates_nothing_after_warmup() {
    // Problems are constructed (and allocate) before counting starts.
    let problems: Vec<Problem> = (0..3).map(|s| Problem::random(48, 40, 0.7, s)).collect();
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 200 };

    for kind in SolverKind::ALL {
        let mut session = SolverSession::builder(kind)
            .threads(1)
            .stop(stop)
            .check_every(8)
            .build(&problems[0]);
        // Warmup: first solve may allocate (it sizes nothing extra today,
        // but the contract only starts after it).
        session.solve(&problems[0]).expect("warmup solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for p in &problems {
            session.solve(p).expect("steady-state solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count,
            0,
            "{}: {count} heap allocations in the post-warmup hot loop",
            kind.name()
        );
    }
}
