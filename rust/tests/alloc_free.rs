//! Counting-allocator proof of the session allocation contract: after the
//! first (warmup) solve, `SolverSession::solve` on same-shape problems must
//! perform **zero heap allocations** — no `plan.clone()` for delta
//! tracking, no per-iteration scratch, no per-check buffers. The contract
//! covers the serial path **and** the threaded pool backend: the pool's
//! workers are spawned at build time, parked between epoch dispatches, and
//! the job is published as a borrowed `&dyn Fn` — so the counter (which
//! sees every thread's allocations) must stay at zero there too. The
//! legacy spawn-per-iteration backend is exempt: `thread::scope` allocates
//! per spawned thread, which is exactly why it is no longer the default.
//!
//! This file holds exactly one test so no concurrent test in the same
//! binary can pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use map_uot::algo::{
    CostKind, GeomProblem, Problem, SolverKind, SolverSession, SparseProblem, StopRule,
};

struct CountingAllocator;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);
/// Largest single allocation observed while counting — the O(m·n)
/// tripwire for the matfree leg (a materialized plan would show up here
/// as one giant allocation regardless of how many small ones happen).
static MAX_ALLOC_BYTES: AtomicUsize = AtomicUsize::new(0);

fn record(size: usize) {
    if COUNTING.load(Ordering::Relaxed) {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        MAX_ALLOC_BYTES.fetch_max(size, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        record(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

#[test]
fn hot_loop_allocates_nothing_after_warmup() {
    // Problems are constructed (and allocate) before counting starts.
    let problems: Vec<Problem> = (0..3).map(|s| Problem::random(48, 40, 0.7, s)).collect();
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 200 };

    // Serial and pooled-threaded paths share one contract: zero heap
    // allocations after warmup. (threads = 4 exercises the pool's epoch
    // dispatch, the padded arena and the column-parallel reduction.)
    for threads in [1usize, 4] {
        for kind in SolverKind::ALL {
            let mut session = SolverSession::builder(kind)
                .threads(threads)
                .stop(stop)
                .check_every(8)
                .build(&problems[0]);
            // Warmup: the build spawned the pool workers; the first solve
            // may allocate (it sizes nothing extra today, but the contract
            // only starts after it).
            session.solve(&problems[0]).expect("warmup solve");

            ALLOCATIONS.store(0, Ordering::SeqCst);
            COUNTING.store(true, Ordering::SeqCst);
            for p in &problems {
                session.solve(p).expect("steady-state solve");
            }
            COUNTING.store(false, Ordering::SeqCst);

            let count = ALLOCATIONS.load(Ordering::SeqCst);
            assert_eq!(
                count,
                0,
                "{} (threads={threads}): {count} heap allocations in the post-warmup hot loop",
                kind.name()
            );
        }
    }

    // Sparse path, same contract: after the first solve on a structure,
    // same-structure `solve_sparse` calls refresh the CSR plan in place,
    // rebuild the nnz partition into retained capacity, and iterate out of
    // the SparseWorkspace scratch — zero heap allocations, serial and
    // pooled. The variant problems share the support but carry different
    // values, so every solve does real work.
    let base = Problem::random(48, 40, 0.7, 11);
    let sp0 = SparseProblem::from_problem(&base, 1.0).expect("valid sparse problem");
    assert!(sp0.nnz() > 0, "threshold left an empty support");
    let variants: Vec<SparseProblem> = (0..3)
        .map(|k| {
            let mut v = sp0.clone();
            for x in v.plan.values.iter_mut() {
                *x *= 1.0 + 0.1 * (k as f32 + 1.0);
            }
            v
        })
        .collect();
    for threads in [1usize, 4] {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .build_sparse(&sp0);
        session.solve_sparse(&sp0).expect("sparse warmup solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for p in &variants {
            session.solve_sparse(p).expect("steady-state sparse solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "sparse (threads={threads}): {count} heap allocations in the post-warmup hot loop"
        );
    }

    // Matfree path, same zero-alloc contract: after the first solve on a
    // shape, same-shape `solve_matfree` calls reset the scaling vectors,
    // re-seed the carried column sums out of the panel buffer, and
    // iterate — zero heap allocations, serial and pooled. The variants
    // share the clouds but scale the marginals, so every solve does real
    // work.
    let base_geom = GeomProblem::random(48, 40, 3, CostKind::SqEuclidean, 0.25, 0.7, 13);
    let geom_variants: Vec<GeomProblem> = (0..3)
        .map(|k| {
            let mut g = base_geom.clone();
            for t in g.rpd.iter_mut().chain(g.cpd.iter_mut()) {
                *t *= 1.0 + 0.1 * (k as f32 + 1.0);
            }
            g
        })
        .collect();
    for threads in [1usize, 4] {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .build_matfree(&base_geom);
        session.solve_matfree(&base_geom).expect("matfree warmup solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for g in &geom_variants {
            session.solve_matfree(g).expect("steady-state matfree solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "matfree (threads={threads}): {count} heap allocations in the post-warmup hot loop"
        );
    }

    // Warm-start steady state, same contract on all three paths: once the
    // warmup solve has stored its entry, a counted re-solve of the same
    // problem (a) fingerprints the marginals into a stack sketch, (b)
    // borrows the cached scaling slices out of the hit, (c) seeds the
    // plan / carried sums in place, and (d) overwrites the same-sketch
    // entry's buffers on convergence (`resize` to the same length plus
    // `copy_from_slice` / the derive kernels) — zero heap allocations.
    for threads in [1usize, 4] {
        let mut warm_dense = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .warm(4)
            .build(&problems[0]);
        warm_dense.solve(&problems[0]).expect("warm dense warmup");
        let mut warm_sparse = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .warm(4)
            .build_sparse(&sp0);
        warm_sparse.solve_sparse(&sp0).expect("warm sparse warmup");
        let mut warm_matfree = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .warm(4)
            .build_matfree(&base_geom);
        warm_matfree.solve_matfree(&base_geom).expect("warm matfree warmup");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for _ in 0..3 {
            warm_dense.solve(&problems[0]).expect("steady-state warm dense solve");
            warm_sparse.solve_sparse(&sp0).expect("steady-state warm sparse solve");
            warm_matfree.solve_matfree(&base_geom).expect("steady-state warm matfree solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "warm seeding (threads={threads}): {count} heap allocations in the post-warmup \
             hot loop"
        );
        // Every counted solve was a cache hit; the warmup was the one miss.
        for (which, stats) in [
            ("dense", warm_dense.warm_stats()),
            ("sparse", warm_sparse.warm_stats()),
            ("matfree", warm_matfree.warm_stats()),
        ] {
            assert_eq!(stats, Some((3, 1)), "{which} (threads={threads}) hit/miss counts");
        }
    }

    // Exact 1D path, same contract: after the first solve on a shape,
    // same-shape `solve_oned` calls re-gather the sorted supports into the
    // retained workspace (`sort_unstable_by` is in-place), run the
    // prefix/suffix sweeps out of the O(m + n) buffers, and extract the
    // monotone coupling into the reserved m + n entry capacity — zero
    // heap allocations end to end.
    let base_oned = GeomProblem::random(48, 40, 1, CostKind::Euclidean, 0.25, 0.7, 17);
    let oned_variants: Vec<GeomProblem> = (0..3)
        .map(|k| {
            let mut g = base_oned.clone();
            for t in g.rpd.iter_mut().chain(g.cpd.iter_mut()) {
                *t *= 1.0 + 0.1 * (k as f32 + 1.0);
            }
            g
        })
        .collect();
    for threads in [1usize, 4] {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .build_oned(&base_oned);
        session.solve_oned(&base_oned).expect("oned warmup solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for g in &oned_variants {
            session.solve_oned(g).expect("steady-state oned solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "oned (threads={threads}): {count} heap allocations in the post-warmup hot loop"
        );
    }

    // The headline acceptance: an m = n = 16384 matfree solve — a shape
    // whose dense plan would be a single 1 GiB allocation — never
    // allocates anything O(m·n). Counting covers problem construction,
    // session build AND the solve; the tripwire is the largest single
    // allocation observed (a materialized plan cannot hide among small
    // ones). Budget: m·n·4 / 64 = 16 MiB, generous against the actual
    // maximum (one ~196 KiB point cloud / ~64 KiB panel rows) yet 64×
    // below the plan. One iteration suffices — the allocation behavior of
    // iteration k equals iteration 1.
    {
        const BIG: usize = 16384;
        ALLOCATIONS.store(0, Ordering::SeqCst);
        MAX_ALLOC_BYTES.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        let g = GeomProblem::random(BIG, BIG, 3, CostKind::SqEuclidean, 0.25, 0.7, 29);
        // Build against a placeholder and let solve_matfree size the
        // matfree state itself — build_matfree would only perform the same
        // O(m+n) sizing allocations a step earlier; the proof is identical
        // either way.
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(4)
            .stop(StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 1 })
            .check_every(1)
            .build(&Problem::random(1, 1, 0.7, 0));
        session.solve_matfree(&g).expect("16384 matfree solve");
        COUNTING.store(false, Ordering::SeqCst);

        let max_single = MAX_ALLOC_BYTES.load(Ordering::SeqCst);
        assert!(
            max_single < BIG * BIG * 4 / 64,
            "matfree 16384: a {max_single}-byte allocation appeared on the solve path \
             (O(m*n) would be {})",
            BIG * BIG * 4
        );
        assert!(max_single > 0, "counting was not engaged");
    }

    // The 1D headline acceptance: an m = n = 1_000_000 exact oned solve —
    // a shape whose dense plan would be a 4 TB allocation — stays O(m + n)
    // resident. Counting covers problem construction, session build AND
    // the solve; the tripwire is the largest single allocation, capped at
    // 48 bytes per support point (the actual maximum is the reserved
    // m + n transport entry capacity at 12 bytes each) — five orders of
    // magnitude below anything O(m·n).
    {
        const BIG1D: usize = 1_000_000;
        ALLOCATIONS.store(0, Ordering::SeqCst);
        MAX_ALLOC_BYTES.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        let g = GeomProblem::random(BIG1D, BIG1D, 1, CostKind::Euclidean, 0.25, 0.7, 31);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .stop(StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 2 })
            .check_every(1)
            .build_oned(&g);
        session.solve_oned(&g).expect("1M oned solve");
        COUNTING.store(false, Ordering::SeqCst);

        let max_single = MAX_ALLOC_BYTES.load(Ordering::SeqCst);
        assert!(
            max_single <= 48 * (BIG1D + BIG1D),
            "oned 1M: a {max_single}-byte allocation appeared — not O(m + n)"
        );
        assert!(max_single > 0, "counting was not engaged");
        let transport = session.oned_transport().expect("coupling extracted");
        assert!(
            !transport.entries.is_empty() && transport.entries.len() <= 2 * BIG1D,
            "coupling has {} entries",
            transport.entries.len()
        );
    }
}
