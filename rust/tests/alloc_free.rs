//! Counting-allocator proof of the session allocation contract: after the
//! first (warmup) solve, `SolverSession::solve` on same-shape problems must
//! perform **zero heap allocations** — no `plan.clone()` for delta
//! tracking, no per-iteration scratch, no per-check buffers. The contract
//! covers the serial path **and** the threaded pool backend: the pool's
//! workers are spawned at build time, parked between epoch dispatches, and
//! the job is published as a borrowed `&dyn Fn` — so the counter (which
//! sees every thread's allocations) must stay at zero there too. The
//! legacy spawn-per-iteration backend is exempt: `thread::scope` allocates
//! per spawned thread, which is exactly why it is no longer the default.
//!
//! This file holds exactly one test so no concurrent test in the same
//! binary can pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use map_uot::algo::{Problem, SolverKind, SolverSession, SparseProblem, StopRule};

struct CountingAllocator;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

#[test]
fn hot_loop_allocates_nothing_after_warmup() {
    // Problems are constructed (and allocate) before counting starts.
    let problems: Vec<Problem> = (0..3).map(|s| Problem::random(48, 40, 0.7, s)).collect();
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 200 };

    // Serial and pooled-threaded paths share one contract: zero heap
    // allocations after warmup. (threads = 4 exercises the pool's epoch
    // dispatch, the padded arena and the column-parallel reduction.)
    for threads in [1usize, 4] {
        for kind in SolverKind::ALL {
            let mut session = SolverSession::builder(kind)
                .threads(threads)
                .stop(stop)
                .check_every(8)
                .build(&problems[0]);
            // Warmup: the build spawned the pool workers; the first solve
            // may allocate (it sizes nothing extra today, but the contract
            // only starts after it).
            session.solve(&problems[0]).expect("warmup solve");

            ALLOCATIONS.store(0, Ordering::SeqCst);
            COUNTING.store(true, Ordering::SeqCst);
            for p in &problems {
                session.solve(p).expect("steady-state solve");
            }
            COUNTING.store(false, Ordering::SeqCst);

            let count = ALLOCATIONS.load(Ordering::SeqCst);
            assert_eq!(
                count,
                0,
                "{} (threads={threads}): {count} heap allocations in the post-warmup hot loop",
                kind.name()
            );
        }
    }

    // Sparse path, same contract: after the first solve on a structure,
    // same-structure `solve_sparse` calls refresh the CSR plan in place,
    // rebuild the nnz partition into retained capacity, and iterate out of
    // the SparseWorkspace scratch — zero heap allocations, serial and
    // pooled. The variant problems share the support but carry different
    // values, so every solve does real work.
    let base = Problem::random(48, 40, 0.7, 11);
    let sp0 = SparseProblem::from_problem(&base, 1.0).expect("valid sparse problem");
    assert!(sp0.nnz() > 0, "threshold left an empty support");
    let variants: Vec<SparseProblem> = (0..3)
        .map(|k| {
            let mut v = sp0.clone();
            for x in v.plan.values.iter_mut() {
                *x *= 1.0 + 0.1 * (k as f32 + 1.0);
            }
            v
        })
        .collect();
    for threads in [1usize, 4] {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .build_sparse(&sp0);
        session.solve_sparse(&sp0).expect("sparse warmup solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for p in &variants {
            session.solve_sparse(p).expect("steady-state sparse solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "sparse (threads={threads}): {count} heap allocations in the post-warmup hot loop"
        );
    }
}
