//! Property tests for the iteration-count accelerators:
//!
//! * **Warm-start exactness** — a warm-seeded re-solve converges to the
//!   same plan as a cold solve within 1e-5 relative, on every path
//!   (dense fused, sparse CSR, matfree) and every engine (serial, scope,
//!   pool) across thread counts. Warm seeding only moves the *starting
//!   point* inside the diag-scaling family the iteration preserves, so
//!   the fixed point cannot move.
//! * **TI exactness** — translation-invariant sweeps share the plain
//!   fixed point: the pre-sweep colsum rescale is exactly 1 at
//!   stationarity, so the converged plan matches within 1e-5 on all
//!   three paths.
//! * **Seed-engine bit-identity** — for any fixed row partition, the
//!   scope and pool warm-seed engines produce bit-identical column sums
//!   to the partitioned serial reference.
//! * **ε-schedule** — the ladder lands on the plain answer at the target
//!   bandwidth; misuse (non-matfree path, `from <= ε`, zero steps) is a
//!   typed error, never a panic. `Deadline` cancels with `Canceled`.
//!
//! CI runs this file under the same thread-oversubscription matrix as
//! `prop_pool.rs`/`prop_sparse.rs`/`prop_matfree.rs`: set
//! `MAP_UOT_POOL_THREADS=t` to restrict the sweep.

use std::time::Duration;

use map_uot::algo::matfree::{CostKind, GeomProblem};
use map_uot::algo::pool::{AccArena, Partition, ThreadPool};
use map_uot::algo::sparse::SparseProblem;
use map_uot::algo::{
    parallel, Deadline, KernelKind, KernelPolicy, Problem, SolverKind, SolverSession, StopRule,
    TileSpec,
};
use map_uot::error::Error;
use map_uot::util::XorShift;

/// Thread counts to sweep: the full ladder by default, or the single value
/// from `MAP_UOT_POOL_THREADS` (the CI oversubscription matrix).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 3, 4, 8, 16],
    }
}

/// Shapes crossing the engine edges: single cell, more threads than rows,
/// wide rows, odd dims.
const SHAPES: &[(usize, usize)] = &[(1, 1), (2, 3), (9, 8), (23, 17), (7, 64)];

/// Tight stop so both the cold and the warm trajectory land well inside
/// the 1e-5 agreement band (convergence is geometric in (1-fi), so the
/// final error sits far below the threshold that stopped the solve).
const STOP: StopRule = StopRule { tol: 1e-6, delta_tol: 1e-9, max_iter: 5_000 };

fn geom(m: usize, n: usize, seed: u64) -> GeomProblem {
    GeomProblem::random(m, n, 3, CostKind::SqEuclidean, 0.25, 0.7, seed)
}

/// Warm-seeded dense re-solves converge to the cold plan within 1e-5 on
/// every thread count, and the second solve is a cache hit.
#[test]
fn warm_dense_resolve_matches_cold_plan() {
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        for &t in &thread_counts() {
            let p = Problem::random(m, n, 0.7, 100 + seed as u64);
            let mut cold = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .build(&p);
            let rc = cold.solve(&p).unwrap();
            assert!(rc.converged, "{m}x{n} t={t}: cold must converge");

            let mut warm = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .warm(4)
                .build(&p);
            let first = warm.solve(&p).unwrap();
            assert!(first.converged);
            assert_eq!(warm.warm_stats(), Some((0, 1)), "{m}x{n} t={t}: first solve misses");
            let second = warm.solve(&p).unwrap();
            assert!(second.converged);
            assert_eq!(warm.warm_stats(), Some((1, 1)), "{m}x{n} t={t}: re-solve hits");
            assert!(
                second.iters <= first.iters,
                "{m}x{n} t={t}: warm {} vs cold {} iters",
                second.iters,
                first.iters
            );
            let rel = warm.plan().max_rel_diff(cold.plan(), 1e-6);
            assert!(rel < 1e-5, "{m}x{n} t={t}: warm plan off by {rel}");
        }
    }
}

/// Same property on the sparse CSR path: warm re-solve hits the cache and
/// lands on the cold plan, support preserved exactly.
#[test]
fn warm_sparse_resolve_matches_cold_plan() {
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        for &t in &thread_counts() {
            let p = Problem::random(m, n, 0.7, 200 + seed as u64);
            let sp = SparseProblem::from_problem(&p, 0.0).unwrap();
            let mut cold = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .build_sparse(&sp);
            let rc = cold.solve_sparse(&sp).unwrap();
            assert!(rc.converged, "{m}x{n} t={t}: cold must converge");
            let cold_plan = cold.sparse_plan().unwrap().clone();

            let mut warm = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .warm(4)
                .build_sparse(&sp);
            warm.solve_sparse(&sp).unwrap();
            assert_eq!(warm.warm_stats(), Some((0, 1)), "{m}x{n} t={t}: first solve misses");
            let second = warm.solve_sparse(&sp).unwrap();
            assert!(second.converged);
            assert_eq!(warm.warm_stats(), Some((1, 1)), "{m}x{n} t={t}: re-solve hits");
            let warm_plan = warm.sparse_plan().unwrap();
            assert_eq!(warm_plan.col_idx, cold_plan.col_idx, "{m}x{n} t={t}: support moved");
            for (k, (a, b)) in warm_plan.values.iter().zip(&cold_plan.values).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5 * b.abs().max(1e-6),
                    "{m}x{n} t={t} nnz {k}: {a} vs {b}"
                );
            }
        }
    }
}

/// Same property on the matfree path: the warm hit copies the cached
/// scaling vectors and re-seeds the carried colsum through the engine
/// dispatch, then converges to the cold plan.
#[test]
fn warm_matfree_resolve_matches_cold_plan() {
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        for &t in &thread_counts() {
            let gp = geom(m, n, 300 + seed as u64);
            let mut cold = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .build_matfree(&gp);
            let rc = cold.solve_matfree(&gp).unwrap();
            assert!(rc.converged, "{m}x{n} t={t}: cold must converge");
            let cold_plan = cold.matfree_materialize(&gp).unwrap();

            let mut warm = SolverSession::builder(SolverKind::MapUot)
                .threads(t)
                .stop(STOP)
                .check_every(1)
                .warm(4)
                .build_matfree(&gp);
            warm.solve_matfree(&gp).unwrap();
            assert_eq!(warm.warm_stats(), Some((0, 1)), "{m}x{n} t={t}: first solve misses");
            let second = warm.solve_matfree(&gp).unwrap();
            assert!(second.converged);
            assert_eq!(warm.warm_stats(), Some((1, 1)), "{m}x{n} t={t}: re-solve hits");
            let warm_plan = warm.matfree_materialize(&gp).unwrap();
            let rel = warm_plan.max_rel_diff(&cold_plan, 1e-6);
            assert!(rel < 1e-5, "{m}x{n} t={t}: warm plan off by {rel}");
        }
    }
}

/// TI sweeps share the plain fixed point on all three paths: the mass
/// rescale is exactly 1 at stationarity, so the converged plans agree.
#[test]
fn ti_solves_share_the_plain_fixed_point_on_all_paths() {
    for &t in &thread_counts() {
        // fi = 0.5: slow (1-fi) mass contraction, the regime TI targets.
        let p = Problem::random(18, 14, 0.5, 77);
        let mut plain = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .build(&p);
        plain.solve(&p).unwrap();
        let mut ti = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .ti(true)
            .build(&p);
        let rt = ti.solve(&p).unwrap();
        assert!(rt.converged, "t={t}");
        let rel = ti.plan().max_rel_diff(plain.plan(), 1e-6);
        assert!(rel < 1e-5, "t={t}: dense TI plan off by {rel}");

        let sp = SparseProblem::from_problem(&p, 0.0).unwrap();
        let mut plain_s = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .build_sparse(&sp);
        plain_s.solve_sparse(&sp).unwrap();
        let mut ti_s = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .ti(true)
            .build_sparse(&sp);
        ti_s.solve_sparse(&sp).unwrap();
        for (k, (a, b)) in ti_s
            .sparse_plan()
            .unwrap()
            .values
            .iter()
            .zip(&plain_s.sparse_plan().unwrap().values)
            .enumerate()
        {
            assert!(
                (a - b).abs() <= 1e-5 * b.abs().max(1e-6),
                "t={t} nnz {k}: sparse TI {a} vs plain {b}"
            );
        }

        let gp = GeomProblem::random(16, 12, 3, CostKind::SqEuclidean, 0.25, 0.5, 77);
        let mut plain_g = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .build_matfree(&gp);
        plain_g.solve_matfree(&gp).unwrap();
        let mut ti_g = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .ti(true)
            .build_matfree(&gp);
        ti_g.solve_matfree(&gp).unwrap();
        let rel = ti_g
            .matfree_materialize(&gp)
            .unwrap()
            .max_rel_diff(&plain_g.matfree_materialize(&gp).unwrap(), 1e-6);
        assert!(rel < 1e-5, "t={t}: matfree TI plan off by {rel}");
    }
}

/// For any fixed row partition, the scope and pool warm-seed engines are
/// bit-identical to the partitioned serial reference — the same contract
/// the iterate engines honor, extended to warm seeding.
#[test]
fn seed_engines_bitmatch_partitioned_serial_reference() {
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        for &t in &thread_counts() {
            let gp = geom(m, n, 400 + seed as u64);
            let policy = KernelPolicy::for_shape(KernelKind::Auto, TileSpec::Auto, m, n);
            let part = Partition::new(m, t, t);
            let pool = ThreadPool::new(t);
            let mut rng = XorShift::new(4000 + seed as u64);
            // Non-trivial scalings: warm seeding never runs at u = v = 1.
            let u = rng.uniform_vec(m, 0.5, 1.5);
            let v = rng.uniform_vec(n, 0.5, 1.5);
            let mut c_serial = vec![0f32; n];
            let mut c_scope = vec![0f32; n];
            let mut c_pool = vec![0f32; n];
            let (mut pan_a, mut acc_a) = (AccArena::padded(t, n), AccArena::padded(t, n));
            let (mut pan_b, mut acc_b) = (AccArena::padded(t, n), AccArena::padded(t, n));
            let (mut pan_c, mut acc_c) = (AccArena::padded(t, n), AccArena::padded(t, n));
            parallel::matfree_seed_partitioned(
                &gp, &u, &v, &mut c_serial, &mut pan_a, &mut acc_a, &part, &policy,
            );
            parallel::matfree_seed_scope(
                &gp, &u, &v, &mut c_scope, &mut pan_b, &mut acc_b, &part, &policy,
            );
            parallel::matfree_seed_pool(
                &gp, &u, &v, &mut c_pool, &pool, &mut pan_c, &mut acc_c, &part, &policy,
            );
            for j in 0..n {
                assert_eq!(
                    c_scope[j].to_bits(),
                    c_serial[j].to_bits(),
                    "{m}x{n} t={t} col {j}: scope seed"
                );
                assert_eq!(
                    c_pool[j].to_bits(),
                    c_serial[j].to_bits(),
                    "{m}x{n} t={t} col {j}: pool seed"
                );
            }
        }
    }
}

/// The ε ladder lands on the plain answer at the target bandwidth, and
/// rung iterations are visible in the report.
#[test]
fn eps_schedule_lands_on_the_plain_answer() {
    for &t in &thread_counts() {
        let gp = GeomProblem::random(16, 12, 3, CostKind::SqEuclidean, 0.3, 0.7, 55);
        let mut plain = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .build_matfree(&gp);
        plain.solve_matfree(&gp).unwrap();
        let mut laddered = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(STOP)
            .check_every(1)
            .eps_schedule(1.2, 3)
            .build_matfree(&gp);
        let rl = laddered.solve_matfree(&gp).unwrap();
        assert!(rl.converged, "t={t}");
        assert!(rl.iters >= 3, "t={t}: rung iterations must be counted, got {}", rl.iters);
        let rel = laddered
            .matfree_materialize(&gp)
            .unwrap()
            .max_rel_diff(&plain.matfree_materialize(&gp).unwrap(), 1e-6);
        // The ladder changes the trajectory, not the fixed point; the
        // landing solve still runs the plain stop rule at the target ε.
        assert!(rel < 1e-4, "t={t}: laddered plan off by {rel}");
    }
}

/// Accelerator misuse is a typed error, never a panic or a silent no-op.
#[test]
fn accelerator_misuse_is_rejected_with_typed_errors() {
    let p = Problem::random(6, 5, 0.7, 9);
    let gp = geom(6, 5, 9);
    let sp = SparseProblem::from_problem(&p, 0.0).unwrap();

    // ε-schedule is matfree-only.
    let mut dense = SolverSession::builder(SolverKind::MapUot).eps_schedule(2.0, 2).build(&p);
    assert!(matches!(dense.solve(&p), Err(Error::InvalidProblem(_))));
    let mut sparse =
        SolverSession::builder(SolverKind::MapUot).eps_schedule(2.0, 2).build_sparse(&sp);
    assert!(matches!(sparse.solve_sparse(&sp), Err(Error::InvalidProblem(_))));

    // The ladder must start above the target bandwidth, with >= 1 rung.
    let mut low =
        SolverSession::builder(SolverKind::MapUot).eps_schedule(0.1, 2).build_matfree(&gp);
    assert!(matches!(low.solve_matfree(&gp), Err(Error::InvalidProblem(_))));
    let mut zero =
        SolverSession::builder(SolverKind::MapUot).eps_schedule(2.0, 0).build_matfree(&gp);
    assert!(matches!(zero.solve_matfree(&gp), Err(Error::InvalidProblem(_))));

    // TI is a MAP-UOT iteration identity; other solvers reject it.
    for kind in [SolverKind::Pot, SolverKind::Coffee] {
        let mut s = SolverSession::builder(kind).ti(true).build(&p);
        assert!(matches!(s.solve(&p), Err(Error::InvalidProblem(_))), "{kind:?}");
    }
}

/// A `Deadline` in the past cancels at the first check boundary with the
/// typed `Canceled` error on every path.
#[test]
fn expired_deadline_cancels_with_typed_error() {
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 1_000_000 };
    let p = Problem::random(12, 10, 0.7, 3);
    let mut dense = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(2)
        .observer(Deadline::within(Duration::from_millis(0)))
        .build(&p);
    match dense.solve(&p) {
        Err(Error::Canceled { iters }) => assert!(iters <= 2, "canceled after {iters}"),
        other => panic!("expected Canceled, got {other:?}"),
    }
    let gp = geom(12, 10, 3);
    let mut mf = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(2)
        .observer(Deadline::within(Duration::from_millis(0)))
        .build_matfree(&gp);
    match mf.solve_matfree(&gp) {
        Err(Error::Canceled { iters }) => assert!(iters <= 2, "canceled after {iters}"),
        other => panic!("expected Canceled, got {other:?}"),
    }
}
