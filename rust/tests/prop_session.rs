//! Property-based tests of the workspace-centric session API: workspace
//! reuse must be invisible (bit-identical to fresh one-shot solves), the
//! observer must fire on every check boundary, and cancellation must take
//! effect within one check interval.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use map_uot::algo::{
    CheckEvent, ObserverAction, Problem, SolverKind, SolverSession, StopRule,
};
use map_uot::error::Error;
use map_uot::testing::check;
use map_uot::util::XorShift;

const STOP: StopRule = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 256 };

/// N consecutive solves through one reused session (same shape, different
/// problems) bit-match fresh one-shot sessions, for every solver kind.
#[test]
fn prop_workspace_reuse_bit_matches_fresh_solves() {
    check(71, |rng: &mut XorShift| {
        let m = 2 + rng.below(14);
        let n = 2 + rng.below(14);
        let fi = rng.uniform(0.2, 1.0);
        let n_solves = 2 + rng.below(4);
        let seeds: Vec<u64> = (0..n_solves).map(|_| rng.next_u64()).collect();
        (m, n, fi, seeds)
    }, |(m, n, fi, seeds)| {
        for kind in SolverKind::ALL {
            let problems: Vec<Problem> = seeds
                .iter()
                .map(|&s| Problem::random(*m, *n, *fi, s))
                .collect();
            let mut reused = SolverSession::builder(kind)
                .stop(STOP)
                .check_every(4)
                .build(&problems[0]);
            for (i, p) in problems.iter().enumerate() {
                let report = reused
                    .solve(p)
                    .map_err(|e| format!("reused solve failed: {e}"))?;
                let mut fresh = SolverSession::builder(kind)
                    .stop(STOP)
                    .check_every(4)
                    .build(p);
                let fresh_report = fresh
                    .solve(p)
                    .map_err(|e| format!("fresh solve failed: {e}"))?;
                if reused.plan().as_slice() != fresh.plan().as_slice() {
                    return Err(format!(
                        "{} solve {i}: reused workspace diverged from fresh solve",
                        kind.name()
                    ));
                }
                if report.iters != fresh_report.iters
                    || report.err != fresh_report.err
                    || report.delta != fresh_report.delta
                {
                    return Err(format!(
                        "{} solve {i}: reports differ ({} vs {} iters)",
                        kind.name(),
                        report.iters,
                        fresh_report.iters
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Threaded sessions reuse per-thread accumulators; results must still
/// bit-match a fresh threaded session.
#[test]
fn threaded_workspace_reuse_bit_matches_fresh() {
    let problems: Vec<Problem> = (0..3).map(|s| Problem::random(21, 13, 0.7, s)).collect();
    let mut reused = SolverSession::builder(SolverKind::MapUot)
        .threads(3)
        .stop(STOP)
        .build(&problems[0]);
    for p in &problems {
        reused.solve(p).unwrap();
        let mut fresh = SolverSession::builder(SolverKind::MapUot)
            .threads(3)
            .stop(STOP)
            .build(p);
        fresh.solve(p).unwrap();
        assert_eq!(reused.plan().as_slice(), fresh.plan().as_slice());
    }
}

/// The observer fires exactly once per check boundary: iters/check_every
/// times, with iters strictly increasing by check_every.
#[test]
fn observer_fires_on_every_check_boundary() {
    let p = Problem::random(24, 24, 0.7, 5);
    let check_every = 4;
    let calls = Arc::new(AtomicUsize::new(0));
    let last_iters = Arc::new(AtomicUsize::new(0));
    let calls_obs = Arc::clone(&calls);
    let last_obs = Arc::clone(&last_iters);
    let mut session = SolverSession::builder(SolverKind::MapUot)
        .stop(STOP)
        .check_every(check_every)
        .observer(move |ev: CheckEvent| {
            calls_obs.fetch_add(1, Ordering::Relaxed);
            let prev = last_obs.swap(ev.iters, Ordering::Relaxed);
            assert_eq!(ev.iters, prev + check_every, "non-contiguous check boundary");
            assert!(ev.err.is_finite() && ev.delta.is_finite());
            ObserverAction::Continue
        })
        .build(&p);
    let report = session.solve(&p).unwrap();
    assert_eq!(
        calls.load(Ordering::Relaxed),
        report.iters / check_every,
        "observer calls != check boundaries (iters={})",
        report.iters
    );
    assert_eq!(last_iters.load(Ordering::Relaxed), report.iters);
}

/// Cancellation stops the solve within `check_every` iterations of the
/// boundary that requested it, and surfaces as the typed error.
#[test]
fn cancellation_stops_within_check_every() {
    let p = Problem::random(32, 32, 0.6, 7);
    for cancel_at_call in [1usize, 3] {
        let check_every = 8;
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_obs = Arc::clone(&calls);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .stop(StopRule { tol: 0.0, delta_tol: 0.0, max_iter: 10_000 })
            .check_every(check_every)
            .observer(move |_: CheckEvent| {
                if calls_obs.fetch_add(1, Ordering::Relaxed) + 1 == cancel_at_call {
                    ObserverAction::Cancel
                } else {
                    ObserverAction::Continue
                }
            })
            .build(&p);
        match session.solve(&p) {
            Err(Error::Canceled { iters }) => {
                assert_eq!(iters, cancel_at_call * check_every);
            }
            other => panic!("expected Canceled, got {other:?}"),
        }
        // A canceled session stays usable: the observer's one-shot cancel
        // has fired, so the next solve runs until the budget — or until the
        // f32 iterate hits an exact fixed point (tracked delta == 0.0).
        let report = session.solve(&p).expect("session reusable after cancel");
        assert!(report.iters >= check_every, "iters={}", report.iters);
    }
}

/// Batch solving through one session matches per-problem fresh sessions.
#[test]
fn solve_batch_matches_fresh_sessions() {
    let problems: Vec<Problem> = (0..5).map(|s| Problem::random(18, 12, 0.8, 100 + s)).collect();
    let mut session = SolverSession::builder(SolverKind::Coffee)
        .stop(STOP)
        .build(&problems[0]);
    let outcomes = session.solve_batch(&problems);
    assert_eq!(outcomes.len(), problems.len());
    for (p, outcome) in problems.iter().zip(outcomes) {
        let (plan, report) = outcome.unwrap();
        let mut fresh = SolverSession::builder(SolverKind::Coffee).stop(STOP).build(p);
        let fresh_report = fresh.solve(p).unwrap();
        assert_eq!(plan.as_slice(), fresh.plan().as_slice());
        assert_eq!(report.iters, fresh_report.iters);
    }
}
