//! Property-based tests on solver invariants, via the in-repo harness.

use map_uot::algo::{convergence, solver_for, Problem, SolverKind, SolverSession, StopRule, Workspace};
use map_uot::testing::check;
use map_uot::util::XorShift;

fn gen_problem(rng: &mut XorShift) -> (Problem, usize) {
    let m = 2 + rng.below(20);
    let n = 2 + rng.below(20);
    let fi = rng.uniform(0.1, 1.0);
    let iters = 1 + rng.below(6);
    (Problem::random(m, n, fi, rng.next_u64()), iters)
}

/// All three solvers produce the same iterate, for any problem/iterations.
#[test]
fn prop_solver_equivalence() {
    check(41, gen_problem, |(p, iters)| {
        let mut plans = Vec::new();
        for kind in SolverKind::ALL {
            let solver = solver_for(kind);
            let mut ws = Workspace::new(p.rows(), p.cols(), 1);
            let mut plan = p.plan.clone();
            let mut cs = plan.col_sums();
            for _ in 0..*iters {
                solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
            }
            plans.push(plan);
        }
        let d1 = plans[0].max_rel_diff(&plans[2], 1e-6);
        let d2 = plans[1].max_rel_diff(&plans[2], 1e-6);
        if d1 > 1e-3 || d2 > 1e-3 {
            return Err(format!("solvers diverged: pot {d1}, coffee {d2}"));
        }
        Ok(())
    });
}

/// Mass positivity and finiteness are preserved by every iteration.
#[test]
fn prop_positivity_preserved() {
    check(43, gen_problem, |(p, iters)| {
        let solver = solver_for(SolverKind::MapUot);
        let mut ws = Workspace::new(p.rows(), p.cols(), 1);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        for _ in 0..*iters {
            solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
        if plan.as_slice().iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err("negative or non-finite mass".into());
        }
        Ok(())
    });
}

/// Carried column sums always equal fresh column sums of the plan.
#[test]
fn prop_carried_colsum_consistent() {
    check(47, gen_problem, |(p, iters)| {
        let solver = solver_for(SolverKind::MapUot);
        let mut ws = Workspace::new(p.rows(), p.cols(), 1);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        for _ in 0..*iters {
            solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
        for (carried, fresh) in cs.iter().zip(plan.col_sums()) {
            if (carried - fresh).abs() > 1e-3 * fresh.abs().max(1e-3) {
                return Err(format!("colsum drift: {carried} vs {fresh}"));
            }
        }
        Ok(())
    });
}

/// With fi = 1, row marginals are exactly satisfied after every iteration
/// (the rescaling ends on rows), regardless of the problem.
#[test]
fn prop_balanced_row_feasibility() {
    check(53, gen_problem, |(p, iters)| {
        let solver = solver_for(SolverKind::MapUot);
        let mut ws = Workspace::new(p.rows(), p.cols(), 1);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        for _ in 0..*iters {
            solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, 1.0, &mut ws);
        }
        for (rs, &t) in plan.row_sums().iter().zip(&p.rpd) {
            if (rs - t).abs() > 1e-3 * t {
                return Err(format!("row marginal violated: {rs} vs {t}"));
            }
        }
        Ok(())
    });
}

/// Scale-equivariance: multiplying the initial plan by a constant is
/// cancelled by the first full iteration when fi = 1 (factors renormalize
/// both dimensions), and never amplified for fi < 1.
#[test]
fn prop_scale_perturbation_contracts() {
    check(59, gen_problem, |(p, iters)| {
        let solver = solver_for(SolverKind::MapUot);
        let mut ws = Workspace::new(p.rows(), p.cols(), 1);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        let mut scaled = map_uot::util::Matrix::from_fn(p.rows(), p.cols(), |i, j| {
            2.0 * p.plan.get(i, j)
        });
        let mut cs2 = scaled.col_sums();
        for _ in 0..*iters {
            solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
            solver.iterate(&mut scaled, &mut cs2, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
        let diff = scaled.max_rel_diff(&plan, 1e-6);
        if p.fi > 0.999 && diff > 1e-3 {
            return Err(format!("fi=1 scale not cancelled: {diff}"));
        }
        if diff > 1.0 + 1e-3 {
            return Err(format!("2x scale perturbation amplified: {diff}"));
        }
        Ok(())
    });
}

/// Marginal error is non-increasing across iterations for fi = 1 with
/// balanced total mass (classic Sinkhorn convergence).
#[test]
fn prop_error_monotone_balanced() {
    check(61, |rng: &mut XorShift| {
        let m = 3 + rng.below(14);
        let n = 3 + rng.below(14);
        let mut p = Problem::random(m, n, 1.0, rng.next_u64());
        let tr: f32 = p.rpd.iter().sum();
        let tc: f32 = p.cpd.iter().sum();
        for v in &mut p.cpd {
            *v *= tr / tc;
        }
        p
    }, |p| {
        let solver = solver_for(SolverKind::MapUot);
        let mut ws = Workspace::new(p.rows(), p.cols(), 1);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        let mut prev = f32::INFINITY;
        for it in 0..12 {
            solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, 1.0, &mut ws);
            let err = convergence::marginal_error(&plan, &p.rpd, &p.cpd);
            if err > prev * 1.001 + 1e-5 {
                return Err(format!("error rose at iter {it}: {prev} -> {err}"));
            }
            prev = err;
        }
        Ok(())
    });
}

/// A session solve respects its iteration budget and reports consistently.
#[test]
fn prop_solve_report_consistent() {
    check(67, gen_problem, |(p, _)| {
        let check_every = 8;
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 64 })
            .check_every(check_every)
            .build(p);
        let report = session
            .solve(p)
            .map_err(|e| format!("unexpected solve error: {e}"))?;
        if report.iters > 64 + check_every {
            return Err(format!("budget exceeded: {}", report.iters));
        }
        let err = convergence::marginal_error(session.plan(), &p.rpd, &p.cpd);
        if (err - report.err).abs() > 1e-3 * err.abs().max(1.0) {
            return Err(format!("reported err {} vs actual {err}", report.err));
        }
        Ok(())
    });
}
