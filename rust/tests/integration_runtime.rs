//! Cross-layer integration: the AOT artifacts (L1 Pallas kernel inside the
//! L2 chunk graph) executed through the Rust PJRT runtime must match the
//! native Rust solvers — the end-to-end correctness contract of the stack.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use map_uot::algo::{self, solver_for, Problem, SolverKind, Workspace};
use map_uot::runtime::{ArtifactKind, Runtime};
use map_uot::util::Matrix;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("MAP_UOT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

#[test]
fn chunk_matches_native_mapuot() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let meta = rt.manifest().chunk_exact(256, 256).expect("256x256 bucket").clone();

    let p = Problem::random(256, 256, 0.7, 42);
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    let out = rt
        .run_uot_chunk(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi)
        .unwrap();
    assert_eq!(out.steps, meta.steps);

    // Native reference: the same number of fused iterations.
    let solver = solver_for(SolverKind::MapUot);
    let mut ws = Workspace::new(256, 256, 1);
    let mut native = p.plan.clone();
    let mut native_cs = native.col_sums();
    for _ in 0..meta.steps {
        solver.iterate(&mut native, &mut native_cs, &p.rpd, &p.cpd, p.fi, &mut ws);
    }
    let diff = plan.max_rel_diff(&native, 1e-5);
    assert!(diff < 5e-3, "PJRT vs native diff = {diff}");

    // The device-side error must agree with the host-side metric.
    let host_err = algo::convergence::marginal_error(&plan, &p.rpd, &p.cpd);
    assert!(
        (out.err - host_err).abs() <= 1e-3 * host_err.abs().max(1.0),
        "device err {} vs host err {}",
        out.err,
        host_err
    );
}

#[test]
fn repeated_chunks_converge() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let p = Problem::random(256, 256, 1.0, 7);
    // Balance total masses so fi=1 converges to feasibility.
    let mut p = p;
    let tr: f32 = p.rpd.iter().sum();
    let tc: f32 = p.cpd.iter().sum();
    for v in &mut p.cpd {
        *v *= tr / tc;
    }
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    let mut last_err = f32::INFINITY;
    for _ in 0..6 {
        let out = rt
            .run_uot_chunk(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi)
            .unwrap();
        assert!(out.err <= last_err * 1.05, "error rose: {last_err} -> {}", out.err);
        last_err = out.err;
    }
    assert!(last_err < 1e-2, "did not converge: {last_err}");
}

#[test]
fn gibbs_and_barycentric_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let (m, n, d) = (256usize, 256usize, 3usize);

    let mut rng = map_uot::util::XorShift::new(9);
    let xs: Vec<f32> = (0..m * d).map(|_| rng.uniform(0.0, 1.0)).collect();
    let ys: Vec<f32> = (0..n * d).map(|_| rng.uniform(0.0, 1.0)).collect();
    let eps = 0.25f32;

    let (plan, colsum) = rt.run_gibbs_init(&xs, &ys, m, n, d, eps).unwrap();
    // Native reference.
    let native = Matrix::from_fn(m, n, |i, j| {
        let d2: f32 = (0..d).map(|k| (xs[i * d + k] - ys[j * d + k]).powi(2)).sum();
        (-d2 / eps).exp()
    });
    assert!(plan.max_rel_diff(&native, 1e-5) < 1e-3);
    for (a, b) in colsum.iter().zip(native.col_sums()) {
        assert!((a - b).abs() < 1e-2 * b.abs().max(1.0));
    }

    // Barycentric projection vs native.
    let mapped = rt.run_barycentric(&plan, &ys, d).unwrap();
    assert_eq!(mapped.len(), m * d);
    for i in (0..m).step_by(37) {
        let row = plan.row(i);
        let rs: f32 = row.iter().sum();
        for k in 0..d {
            let expect: f32 =
                row.iter().enumerate().map(|(j, &w)| w * ys[j * d + k]).sum::<f32>() / rs;
            let got = mapped[i * d + k];
            assert!((got - expect).abs() < 1e-3, "({i},{k}): {got} vs {expect}");
        }
    }
}

#[test]
fn warmup_compiles_all_chunks() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let n = rt.warmup(ArtifactKind::UotChunk).unwrap();
    assert!(n >= 1, "no chunk artifacts found");
}

#[test]
fn missing_bucket_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let p = Problem::random(7000, 7000, 0.5, 1);
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    let err = rt
        .run_uot_chunk(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi)
        .unwrap_err();
    assert!(err.to_string().contains("no uot_chunk"), "{err}");
}
