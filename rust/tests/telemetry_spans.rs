//! Integration tests for the span recorder across engines (PR 10):
//! per-pool-thread span attribution at widths 1 / 2 / 16, tracing purity
//! (a traced solve is bit-identical to an untraced one), and the golden
//! Perfetto-JSON schema the exporters promise.
//!
//! Telemetry state (the enable flag, the lane registry) is
//! process-global, so every test here serializes on one mutex.

use std::collections::BTreeSet;
use std::sync::{Mutex, MutexGuard, PoisonError};

use map_uot::algo::{Problem, SolverKind, SolverSession, StopRule};
use map_uot::util::telemetry::{self, Phase, SpanEvent};

static GUARD: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(PoisonError::into_inner)
}

const STOP: StopRule = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 120 };

/// Thread widths to sweep: serial, minimal pool, oversubscribed — or the
/// single value from `MAP_UOT_POOL_THREADS` (the CI matrix, same
/// convention as `prop_pool.rs`).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 16],
    }
}

/// A traced solve attributes sweep work to the threads that did it: one
/// lane serial, several lanes (session thread plus pool workers) once the
/// pool engine dispatches parts.
#[test]
fn span_attribution_follows_pool_width() {
    let _g = serialize();
    let p = Problem::random(192, 160, 0.7, 3);
    for threads in thread_counts() {
        telemetry::set_enabled(true);
        telemetry::reset();
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(STOP)
            .check_every(4)
            .build(&p);
        session.solve(&p).expect("traced solve");
        telemetry::set_enabled(false);
        let events = telemetry::snapshot_spans();
        assert!(!events.is_empty(), "threads={threads}: no spans recorded");

        let all_lanes: BTreeSet<u32> = events.iter().map(|e| e.lane).collect();
        let sweep_lanes: BTreeSet<u32> =
            events.iter().filter(|e| e.phase == Phase::FusedSweep).map(|e| e.lane).collect();
        if threads == 1 {
            assert_eq!(all_lanes.len(), 1, "serial run recorded on lanes {all_lanes:?}");
        } else {
            assert!(
                sweep_lanes.len() >= 2,
                "threads={threads}: sweep spans on lanes {sweep_lanes:?}, expected the \
                 session thread plus at least one pool worker"
            );
        }

        // Well-formed on every lane: non-negative durations, per-lane seq
        // strictly increasing (snapshot drains each ring in order).
        assert!(events.iter().all(|e| e.end_ns >= e.start_ns));
        for lane in &all_lanes {
            let s: Vec<u64> = events.iter().filter(|e| e.lane == *lane).map(|e| e.seq).collect();
            assert!(s.windows(2).all(|w| w[0] < w[1]), "lane {lane}: seq out of order");
        }
    }
    telemetry::reset();
}

/// Tracing is observation only: at every pool width, a traced solve
/// returns the bit-identical plan and iteration count of an untraced one.
#[test]
fn traced_solves_are_bit_identical_to_untraced() {
    let _g = serialize();
    telemetry::set_enabled(false);
    let p = Problem::random(96, 80, 0.7, 5);
    for threads in thread_counts() {
        let solve = |traced: bool| {
            let mut b = SolverSession::builder(SolverKind::MapUot)
                .threads(threads)
                .stop(STOP)
                .check_every(4);
            if traced {
                b = b.trace("unused-never-exported.json");
            }
            let mut s = b.build(&p);
            let report = s.solve(&p).expect("solve");
            (s.into_plan(), report.iters)
        };
        let (plain, plain_iters) = solve(false);
        let (traced, traced_iters) = solve(true);
        telemetry::set_enabled(false);
        assert_eq!(plain_iters, traced_iters, "threads={threads}: iteration count drifted");
        assert_eq!(
            plain.as_slice(),
            traced.as_slice(),
            "threads={threads}: tracing changed the plan"
        );
    }
    telemetry::reset();
}

/// The Perfetto exporter's schema, pinned byte-for-byte on fixed events,
/// plus a live traced solve whose export passes the same validator the CI
/// traced-solve leg runs.
#[test]
fn perfetto_export_matches_golden_schema() {
    let _g = serialize();
    let events = [
        SpanEvent { lane: 0, seq: 0, phase: Phase::KernelGenerate, start_ns: 1_000, end_ns: 2_500 },
        SpanEvent { lane: 3, seq: 7, phase: Phase::Reduction, start_ns: 2_500, end_ns: 2_750 },
    ];
    let golden = concat!(
        "[\n",
        "{\"name\":\"kernel_generate\",\"cat\":\"mapuot\",\"ph\":\"X\",",
        "\"ts\":1.000,\"dur\":1.500,\"pid\":1,\"tid\":0},\n",
        "{\"name\":\"reduction\",\"cat\":\"mapuot\",\"ph\":\"X\",",
        "\"ts\":2.500,\"dur\":0.250,\"pid\":1,\"tid\":3}\n",
        "]\n"
    );
    assert_eq!(telemetry::render_perfetto(&events), golden);
    assert_eq!(telemetry::validate_perfetto(golden), Ok(2));

    // Live half: a traced pool solve, exported through the session, passes
    // the same schema check with every drained span present.
    telemetry::set_enabled(true);
    telemetry::reset();
    let path = std::env::temp_dir().join("map_uot_golden_trace.json");
    let path = path.to_str().expect("utf-8 temp path").to_string();
    let p = Problem::random(64, 48, 0.7, 9);
    let mut session = SolverSession::builder(SolverKind::MapUot)
        .threads(2)
        .stop(STOP)
        .check_every(4)
        .trace(path.clone())
        .build(&p);
    session.solve(&p).expect("traced solve");
    let exported = session.export_trace().expect("trace export");
    telemetry::set_enabled(false);
    assert!(exported > 0, "traced solve drained no spans");
    let raw = std::fs::read_to_string(&path).expect("trace file written");
    assert_eq!(telemetry::validate_perfetto(&raw), Ok(exported));
    let _ = std::fs::remove_file(&path);
    telemetry::reset();
}
