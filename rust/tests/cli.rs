//! CLI smoke tests: the `map-uot` binary's subcommands run and print what
//! they promise. Uses the cargo-provided binary path.

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_map-uot"))
        .args(args)
        .env("MAP_UOT_BENCH_FAST", "1")
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["solve", "serve", "app", "fig", "info", "stats"] {
        assert!(stdout.contains(cmd), "missing {cmd} in help:\n{stdout}");
    }
}

#[test]
fn solve_reports_convergence() {
    let (stdout, _, ok) = run(&[
        "solve", "--m", "64", "--n", "48", "--solver", "mapuot", "--max-iter", "200",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("MAP-UOT solve 64x48"), "{stdout}");
    assert!(stdout.contains("converged=true"), "{stdout}");
}

#[test]
fn solve_all_solver_names_parse() {
    for s in ["pot", "coffee", "map-uot"] {
        let (stdout, _, ok) = run(&["solve", "--m", "16", "--n", "16", "--solver", s]);
        assert!(ok, "solver {s}: {stdout}");
    }
}

#[test]
fn solve_threaded_on_both_parallel_backends() {
    for par in ["pool", "spawn"] {
        let (stdout, _, ok) = run(&[
            "solve", "--m", "48", "--n", "32", "--threads", "3", "--par", par, "--pin",
            "--max-iter", "200",
        ]);
        assert!(ok, "par={par}: {stdout}");
        assert!(stdout.contains("converged=true"), "par={par}: {stdout}");
    }
}

#[test]
fn solve_rejects_unknown_parallel_backend() {
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--par", "sapwn"]);
    assert!(!ok, "typoed --par must not silently fall back");
    assert!(stderr.contains("unknown --par backend"), "{stderr}");
}

#[test]
fn solve_rejects_unknown_kernel_and_tile() {
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--kernel", "sse9"]);
    assert!(!ok, "typoed --kernel must not silently fall back");
    assert!(stderr.contains("unknown --kernel backend"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--tile", "wide"]);
    assert!(!ok, "typoed --tile must not silently fall back");
    assert!(stderr.contains("unknown --tile policy"), "{stderr}");
}

#[test]
fn solve_kernel_and_tile_combinations_converge() {
    // `avx2` must work (via runtime fallback) even on hosts without AVX2,
    // and the report line names the *resolved* kernel and tile.
    for kernel in ["auto", "scalar", "unrolled", "avx2"] {
        let (stdout, _, ok) = run(&[
            "solve", "--m", "48", "--n", "300", "--kernel", kernel, "--tile", "64",
            "--max-iter", "300",
        ]);
        assert!(ok, "kernel={kernel}: {stdout}");
        assert!(stdout.contains("converged=true"), "kernel={kernel}: {stdout}");
        assert!(stdout.contains("tile=64"), "kernel={kernel}: {stdout}");
        assert!(stdout.contains("kernel="), "kernel={kernel}: {stdout}");
    }
    let (stdout, _, ok) = run(&[
        "solve", "--m", "32", "--n", "32", "--tile", "off", "--max-iter", "300",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("tile=off"), "{stdout}");
}

#[test]
fn solve_sparse_reports_density_and_convergence() {
    let (stdout, _, ok) = run(&[
        "solve", "--m", "48", "--n", "40", "--sparse", "1.0", "--max-iter", "400",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("MAP-UOT sparse solve 48x40"), "{stdout}");
    assert!(stdout.contains("nnz="), "{stdout}");
    assert!(stdout.contains("density="), "{stdout}");
}

#[test]
fn solve_sparse_threaded_on_both_parallel_backends() {
    for par in ["pool", "spawn"] {
        let (stdout, _, ok) = run(&[
            "solve", "--m", "48", "--n", "32", "--sparse", "1.0", "--threads", "3", "--par", par,
            "--max-iter", "400",
        ]);
        assert!(ok, "par={par}: {stdout}");
        assert!(stdout.contains("sparse solve"), "par={par}: {stdout}");
    }
}

#[test]
fn solve_sparse_rejects_bad_threshold_and_solver() {
    // A bare or typoed --sparse must fail loudly, not fall back to dense.
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--sparse", "wide"]);
    assert!(!ok, "typoed --sparse must not silently fall back");
    assert!(stderr.contains("--sparse"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--sparse"]);
    assert!(!ok, "bare --sparse must not silently fall back");
    assert!(stderr.contains("--sparse"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--sparse", "0.5", "--solver", "pot",
    ]);
    assert!(!ok, "sparse + POT must be rejected");
    assert!(stderr.contains("mapuot"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--sparse", "-0.5"]);
    assert!(!ok, "negative threshold must be rejected");
    assert!(stderr.contains("threshold"), "{stderr}");
    // The dense kernel/tile knobs do not apply to the CSR sweep — pairing
    // them with --sparse must fail loudly, not silently measure nothing.
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--sparse", "0.5", "--kernel", "avx2",
    ]);
    assert!(!ok, "--kernel with --sparse must be rejected");
    assert!(stderr.contains("do not apply"), "{stderr}");
}

#[test]
fn solve_matfree_reports_state_and_convergence() {
    let (stdout, _, ok) = run(&[
        "solve", "--m", "48", "--n", "40", "--matfree", "0.25", "--max-iter", "400",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("MAP-UOT matfree solve 48x40"), "{stdout}");
    assert!(stdout.contains("d=3"), "{stdout}");
    assert!(stdout.contains("cost=sqeuclid"), "{stdout}");
    assert!(stdout.contains("resident ~"), "{stdout}");
    // Explicit dim/cost flags flow through to the report line.
    let (stdout, _, ok) = run(&[
        "solve", "--m", "32", "--n", "32", "--matfree", "0.5", "--dim", "2", "--cost", "euclid",
        "--max-iter", "400",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("d=2"), "{stdout}");
    assert!(stdout.contains("cost=euclid"), "{stdout}");
}

#[test]
fn solve_matfree_threaded_on_both_parallel_backends() {
    for par in ["pool", "spawn"] {
        let (stdout, _, ok) = run(&[
            "solve", "--m", "48", "--n", "32", "--matfree", "0.25", "--threads", "3", "--par", par,
            "--max-iter", "400",
        ]);
        assert!(ok, "par={par}: {stdout}");
        assert!(stdout.contains("matfree solve"), "par={par}: {stdout}");
    }
}

#[test]
fn solve_matfree_rejects_inapplicable_flags() {
    // A bare or typoed --matfree must fail loudly, not fall back to dense.
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--matfree", "wide"]);
    assert!(!ok, "typoed --matfree must not silently fall back");
    assert!(stderr.contains("--matfree"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--matfree"]);
    assert!(!ok, "bare --matfree must not silently fall back");
    assert!(stderr.contains("--matfree"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--matfree", "-0.5"]);
    assert!(!ok, "nonpositive epsilon must be rejected");
    assert!(stderr.contains("epsilon"), "{stderr}");
    // Wrong solver, conflicting backends, and pjrt are all loud errors.
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--matfree", "0.5", "--solver", "coffee",
    ]);
    assert!(!ok, "matfree + COFFEE must be rejected");
    assert!(stderr.contains("mapuot"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--matfree", "0.5", "--sparse", "0.5",
    ]);
    assert!(!ok, "matfree + sparse must be rejected");
    assert!(stderr.contains("pick one"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--matfree", "0.5", "--backend", "pjrt",
    ]);
    assert!(!ok, "matfree + pjrt must be rejected");
    assert!(stderr.contains("native"), "{stderr}");
    // The geometry flags are inapplicable without --matfree, and a typoed
    // cost kind is rejected.
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--dim", "2"]);
    assert!(!ok, "--dim without --matfree must be rejected");
    assert!(stderr.contains("--matfree"), "{stderr}");
    let (_, stderr, ok) = run(&["solve", "--m", "16", "--n", "16", "--cost", "euclid"]);
    assert!(!ok, "--cost without --matfree must be rejected");
    assert!(stderr.contains("--matfree"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--matfree", "0.5", "--cost", "manhattan",
    ]);
    assert!(!ok, "unknown cost kind must be rejected");
    assert!(stderr.contains("--cost"), "{stderr}");
    let (_, stderr, ok) = run(&[
        "solve", "--m", "16", "--n", "16", "--matfree", "0.5", "--dim", "0",
    ]);
    assert!(!ok, "--dim 0 must be rejected");
    assert!(stderr.contains("--dim"), "{stderr}");
}

#[test]
fn solve_matfree_accepts_kernel_and_tile() {
    // Unlike --sparse, the kernel/tile knobs apply to matfree generation.
    let (stdout, _, ok) = run(&[
        "solve", "--m", "32", "--n", "300", "--matfree", "0.25", "--kernel", "scalar", "--tile",
        "64", "--max-iter", "300",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("kernel=scalar"), "{stdout}");
    assert!(stdout.contains("tile=64"), "{stdout}");
}

#[test]
fn fig_roofline_prints_eq1() {
    let (stdout, _, ok) = run(&["fig", "3"]);
    assert!(ok);
    assert!(stdout.contains("0.250"), "Eq. 1 intensity missing:\n{stdout}");
    assert!(stdout.contains("39.7"), "GPU ridge point missing:\n{stdout}");
}

#[test]
fn fig_16_prints_cluster_scaling() {
    let (stdout, _, ok) = run(&["fig", "16"]);
    assert!(ok);
    assert!(stdout.contains("768"), "{stdout}");
}

#[test]
fn unknown_figure_fails_cleanly() {
    let (_, stderr, ok) = run(&["fig", "99"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"), "{stderr}");
}

#[test]
fn unknown_app_fails_cleanly() {
    let (_, stderr, ok) = run(&["app", "nonsense"]);
    assert!(!ok);
    assert!(stderr.contains("unknown app"), "{stderr}");
}

#[test]
fn serve_native_completes_workload() {
    let (stdout, _, ok) = run(&[
        "serve", "--requests", "6", "--workers", "2", "--size", "32", "--max-iter", "64",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("6/6 ok"), "{stdout}");
    // Latency decomposes into queue wait + solve (PR 10).
    assert!(stdout.contains("+ wait"), "{stdout}");
}

#[test]
fn solve_trace_exports_and_stats_validates() {
    // Both exporter formats: `.jsonl` event log and chrome://tracing JSON.
    let dir = std::env::temp_dir();
    for name in ["map_uot_cli_trace.jsonl", "map_uot_cli_trace.json"] {
        let path = dir.join(name);
        let path = path.to_str().expect("utf-8 temp path").to_string();
        let (stdout, _, ok) = run(&[
            "solve", "--m", "48", "--n", "40", "--threads", "2", "--max-iter", "200", "--trace",
            path.as_str(),
        ]);
        assert!(ok, "{stdout}");
        assert!(stdout.contains("roofline:"), "{stdout}");
        assert!(stdout.contains("spans ->"), "{stdout}");
        let (stdout, _, ok) = run(&["stats", "--check-trace", path.as_str()]);
        assert!(ok, "{stdout}");
        assert!(stdout.contains("trace ok:"), "{stdout}");
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn stats_rejects_invalid_trace() {
    let path = std::env::temp_dir().join("map_uot_cli_bad_trace.json");
    std::fs::write(&path, "not json").expect("temp write");
    let (_, stderr, ok) = run(&["stats", "--check-trace", path.to_str().expect("utf-8")]);
    assert!(!ok, "invalid trace must fail the gate");
    assert!(stderr.contains("invalid trace"), "{stderr}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stats_prints_versioned_snapshot_json() {
    let (stdout, _, ok) = run(&["stats", "--requests", "6", "--size", "32", "--max-iter", "64"]);
    assert!(ok, "{stdout}");
    let json = stdout.lines().find(|l| l.starts_with('{')).expect("stats JSON line");
    assert!(json.starts_with("{\"schema_version\":1,"), "{json}");
    for key in ["\"counters\":", "\"solve_ms\":", "\"wait_ms\":"] {
        assert!(json.contains(key), "missing {key}: {json}");
    }
    for key in ["\"gauges\":", "\"warm\":", "\"backends\":"] {
        assert!(json.contains(key), "missing {key}: {json}");
    }
}

#[test]
fn info_reports_platform_or_missing_artifacts() {
    let (stdout, _, ok) = run(&["info"]);
    assert!(ok);
    assert!(
        stdout.contains("pjrt platform") || stdout.contains("no artifacts"),
        "{stdout}"
    );
}
