//! Property tests for the exact near-linear 1D fast path (`algo::oned`):
//!
//! * **Equivalence** — a oned solve tracks the dense MAP-UOT session on
//!   the materialized Laplace-kernel problem: same iteration counts under
//!   a fixed budget, materialized plans within tolerance everywhere, and
//!   within 1e-5 on the golden-seeded small-shape pin (the acceptance
//!   criterion).
//! * **Robustness** — unsorted and duplicate support positions need no
//!   pre-processing; degenerate m = 1 / n = 1 shapes solve cleanly.
//! * **Typed rejection** — d > 1, the squared-Euclidean (Gaussian)
//!   kernel, non-MapUot sessions, and a configured ε ladder are typed
//!   `InvalidProblem` errors, never panics.
//! * **Transport contract** — the extracted coupling is monotone in
//!   sorted support order, strictly positive, at most m + n entries, and
//!   its destroyed/created slacks balance against the problem marginals.
//!   The quantile fixture is golden-pinned against
//!   `data/golden_oned_quantile.txt`.
//! * **Interop** — the warm cache fingerprint is shared with matfree on
//!   purpose: a converged 1D solve seeds a later matfree solve of the
//!   same geometry (and vice versa), and the sweep is thread-count
//!   invariant (bit-identical scaling vectors for every pool size).
//!
//! CI runs this file under the same `MAP_UOT_POOL_THREADS` matrix as
//! `prop_matfree.rs`, and the small sweep-index tests under Miri.

use map_uot::algo::matfree::{CostKind, GeomProblem};
use map_uot::algo::oned::{fused_monotone_coupling, TransportList};
use map_uot::algo::{KernelKind, SolverKind, SolverSession, StopRule};
use map_uot::error::Error;

/// Thread counts to sweep: the full ladder by default, or the single value
/// from `MAP_UOT_POOL_THREADS` (the CI oversubscription matrix).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 3, 4, 8, 16],
    }
}

/// 1D shapes crossing the interesting edges: scalar, single row/col,
/// skewed, odd dims.
const SHAPES: &[(usize, usize)] = &[(1, 1), (1, 9), (9, 1), (2, 3), (23, 17), (7, 120)];

fn problem(m: usize, n: usize, seed: u64) -> GeomProblem {
    GeomProblem::random(m, n, 1, CostKind::Euclidean, 0.25, 0.7, seed)
}

/// Rank of each original index in sorted position order (ties broken by
/// index, matching the stable outcome of the workspace gather).
fn ranks(pos: &[f32]) -> Vec<usize> {
    let mut ord: Vec<usize> = (0..pos.len()).collect();
    ord.sort_by(|&a, &b| pos[a].total_cmp(&pos[b]).then(a.cmp(&b)));
    let mut rank = vec![0usize; pos.len()];
    for (r, &i) in ord.iter().enumerate() {
        rank[i] = r;
    }
    rank
}

/// Full session solves on the exact 1D sweep and the dense kernel agree:
/// a fixed iteration budget (negative tolerances never fire) makes the
/// iteration counts trivially deterministic, and the materialized plans
/// must match within tolerance (the sweeps accumulate in f64, the dense
/// path mutates a stored f32 plan — relative, not bitwise).
#[test]
#[cfg_attr(miri, ignore)] // dense comparator is O(m·n·iters) under the interpreter
fn oned_solve_matches_dense_session() {
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 48 };
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        let gp = problem(m, n, 91 + seed as u64);
        let dense = gp.dense_problem();

        let mut od = SolverSession::builder(SolverKind::MapUot)
            .stop(stop)
            .check_every(4)
            .build_oned(&gp);
        let od_report = od.solve_oned(&gp).unwrap();

        let mut ds = SolverSession::builder(SolverKind::MapUot)
            .stop(stop)
            .check_every(4)
            .build(&dense);
        let ds_report = ds.solve(&dense).unwrap();

        assert_eq!(od_report.iters, ds_report.iters, "{m}x{n}");
        let materialized = od.oned_materialize(&gp).unwrap();
        let rel = materialized.max_rel_diff(ds.plan(), 1e-4);
        assert!(rel < 1e-3, "{m}x{n}: materialized oned plan off by {rel}");
    }
}

/// The golden-seeded equivalence pin (the acceptance criterion): a small
/// fixed shape over a fixed iteration budget, forced-scalar dense kernel
/// so both sides evaluate libm exp — the exact sweep must land within
/// 1e-5 relative of the dense MAP-UOT plan.
#[test]
#[cfg_attr(miri, ignore)]
fn oned_matches_dense_golden_seeded() {
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 64 };
    let gp = GeomProblem::random(16, 12, 1, CostKind::Euclidean, 0.25, 0.7, 1234);
    let dense = gp.dense_problem();
    let mut od = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(4)
        .build_oned(&gp);
    let mut ds = SolverSession::builder(SolverKind::MapUot)
        .kernel(KernelKind::Scalar)
        .stop(stop)
        .check_every(4)
        .build(&dense);
    let ro = od.solve_oned(&gp).unwrap();
    let rd = ds.solve(&dense).unwrap();
    assert_eq!(ro.iters, rd.iters);
    let materialized = od.oned_materialize(&gp).unwrap();
    let rel = materialized.max_rel_diff(ds.plan(), 1e-3);
    assert!(rel < 1e-5, "golden 1D shape off by {rel}");
    assert!((ro.err - rd.err).abs() <= 1e-3 * rd.err.max(1e-2), "{} vs {}", ro.err, rd.err);
}

/// Unsorted, interleaved, and duplicated support positions are handled by
/// the in-workspace sort + tie rules with no pre-deduplication — still
/// equivalent to the dense solve on the same geometry.
#[test]
#[cfg_attr(miri, ignore)]
fn unsorted_and_duplicate_supports_match_dense() {
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 48 };
    // Descending x with duplicates; y interleaved, coincident with two of
    // the x positions (tie between a source and a target event).
    let x = vec![2.0f32, 0.5, 2.0, -1.0, 0.5, 3.25];
    let y = vec![0.5f32, -1.0, 1.75, 0.5, 2.0];
    let rpd = vec![0.9f32, 1.1, 0.6, 1.4, 0.8, 1.0];
    let cpd = vec![1.2f32, 0.7, 1.0, 0.9, 1.3];
    let gp =
        GeomProblem::new(x, y, 1, CostKind::Euclidean, 0.3, rpd, cpd, 0.7).unwrap();
    let dense = gp.dense_problem();
    let mut od = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(4)
        .build_oned(&gp);
    let mut ds = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .check_every(4)
        .build(&dense);
    od.solve_oned(&gp).unwrap();
    ds.solve(&dense).unwrap();
    let rel = od.oned_materialize(&gp).unwrap().max_rel_diff(ds.plan(), 1e-4);
    assert!(rel < 1e-3, "duplicate-support plan off by {rel}");
}

/// Degenerate single-row / single-column / scalar shapes solve cleanly to
/// convergence and produce finite scaling vectors plus a coupling of at
/// most m + n entries.
#[test]
fn degenerate_shapes_terminate_cleanly() {
    for &(m, n) in &[(1usize, 1usize), (1, 7), (7, 1)] {
        let gp = problem(m, n, (m * 31 + n) as u64);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 200 })
            .build_oned(&gp);
        let report = session.solve_oned(&gp).unwrap();
        assert!(report.iters <= 200, "{m}x{n}");
        let (u, v) = session.oned_scaling().unwrap();
        assert!(u.iter().chain(v.iter()).all(|x| x.is_finite()), "{m}x{n}");
        let transport = session.oned_transport().unwrap();
        assert!(transport.entries.len() <= m + n, "{m}x{n}");
        assert!(transport.entries.iter().all(|t| t.mass > 0.0), "{m}x{n}");
    }
}

/// Every ineligible request is a typed `InvalidProblem` carrying enough
/// text to route the caller to the right backend — never a panic.
#[test]
fn ineligible_requests_are_typed_errors() {
    // d > 1 geometry.
    let d2 = GeomProblem::random(6, 5, 2, CostKind::Euclidean, 0.5, 0.7, 3);
    let mut s = SolverSession::builder(SolverKind::MapUot).build_oned(&d2);
    match s.solve_oned(&d2) {
        Err(Error::InvalidProblem(msg)) => assert!(msg.contains("d == 1"), "{msg}"),
        other => panic!("d=2: expected InvalidProblem, got {other:?}"),
    }
    // Squared-Euclidean (Gaussian) kernel does not factor.
    let gauss = GeomProblem::random(6, 5, 1, CostKind::SqEuclidean, 0.5, 0.7, 3);
    let mut s = SolverSession::builder(SolverKind::MapUot).build_oned(&gauss);
    match s.solve_oned(&gauss) {
        Err(Error::InvalidProblem(msg)) => assert!(msg.contains("euclid"), "{msg}"),
        other => panic!("gaussian: expected InvalidProblem, got {other:?}"),
    }
    // Non-MapUot sessions have no scaling-form sweep.
    let gp = problem(6, 5, 3);
    let mut s = SolverSession::builder(SolverKind::Pot).build_oned(&gp);
    match s.solve_oned(&gp) {
        Err(Error::InvalidProblem(msg)) => assert!(msg.contains("MapUot"), "{msg}"),
        other => panic!("pot: expected InvalidProblem, got {other:?}"),
    }
    // A configured ε ladder has nothing to amortize on the exact sweep.
    let mut s = SolverSession::builder(SolverKind::MapUot)
        .eps_schedule(2.0, 3)
        .build_oned(&gp);
    match s.solve_oned(&gp) {
        Err(Error::InvalidProblem(msg)) => assert!(msg.contains("eps_schedule"), "{msg}"),
        other => panic!("ladder: expected InvalidProblem, got {other:?}"),
    }
    // Materializing before any solve is typed, too.
    let s2 = SolverSession::builder(SolverKind::MapUot).build_oned(&gp);
    assert!(matches!(s2.oned_materialize(&gp), Err(Error::InvalidProblem(_))));
}

/// The extracted coupling is monotone in *sorted* support order (entries
/// never cross), strictly positive, bounded by m + n entries, and its
/// slacks balance: `transported + destroyed = Σrpd` and
/// `transported + created = Σcpd`.
#[test]
fn transport_list_is_monotone_and_balances() {
    for (seed, &(m, n)) in SHAPES.iter().enumerate() {
        let gp = problem(m, n, 700 + seed as u64);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 })
            .build_oned(&gp);
        session.solve_oned(&gp).unwrap();
        let t = session.oned_transport().unwrap();
        assert!(t.entries.len() <= m + n, "{m}x{n}");
        assert!(t.entries.iter().all(|e| e.mass > 0.0), "{m}x{n}");
        let rx = ranks(&gp.x);
        let ry = ranks(&gp.y);
        for w in t.entries.windows(2) {
            assert!(
                rx[w[0].from as usize] <= rx[w[1].from as usize]
                    && ry[w[0].to as usize] <= ry[w[1].to as usize],
                "{m}x{n}: coupling entries cross in sorted order"
            );
        }
        let tr = t.transported();
        let sum_rpd: f32 = gp.rpd.iter().sum();
        let sum_cpd: f32 = gp.cpd.iter().sum();
        assert!(
            (tr + t.destroyed - sum_rpd).abs() <= 1e-3 * sum_rpd.max(1.0),
            "{m}x{n}: row slack"
        );
        assert!(
            (tr + t.created - sum_cpd).abs() <= 1e-3 * sum_cpd.max(1.0),
            "{m}x{n}: col slack"
        );
    }
}

/// The quantile coupling pins against `data/golden_oned_quantile.txt`
/// (hand-derived: two marginal vectors and the six entries of their
/// monotone pairing). Skips with a notice if the data directory is not
/// checked out.
#[test]
fn golden_oned_quantile_coupling() {
    let Some(text) = ["../data/golden_oned_quantile.txt", "data/golden_oned_quantile.txt"]
        .iter()
        .find_map(|p| std::fs::read_to_string(p).ok())
    else {
        eprintln!("skipping: data/golden_oned_quantile.txt not found");
        return;
    };
    let mut lines = text.lines().filter(|l| !l.trim_start().starts_with('#'));
    let parse_row = |l: &str| -> Vec<f32> {
        l.split_whitespace().map(|t| t.parse().expect("golden float")).collect()
    };
    let rowsum = parse_row(lines.next().expect("rowsum line"));
    let colsum = parse_row(lines.next().expect("colsum line"));
    let expected: Vec<(u32, u32, f32)> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let f = parse_row(l);
            assert_eq!(f.len(), 3, "entry line");
            (f[0] as u32, f[1] as u32, f[2])
        })
        .collect();

    // Identity orders: the golden marginals are already in sorted support
    // order, and the targets (rpd/cpd) equal the transported masses so
    // both slacks must vanish.
    let sx: Vec<u32> = (0..rowsum.len() as u32).collect();
    let sy: Vec<u32> = (0..colsum.len() as u32).collect();
    let mut out = TransportList::default();
    out.reserve_for(rowsum.len(), colsum.len());
    fused_monotone_coupling(&sx, &sy, &rowsum, &colsum, &rowsum, &colsum, &mut out);
    assert_eq!(out.entries.len(), expected.len());
    for (got, want) in out.entries.iter().zip(&expected) {
        assert_eq!((got.from, got.to), (want.0, want.1));
        assert!((got.mass - want.2).abs() <= 1e-6, "{} vs {}", got.mass, want.2);
    }
    assert!(out.destroyed.abs() <= 1e-6 && out.created.abs() <= 1e-6);
}

/// Warm interop: the oned path hashes a problem with the *matfree*
/// fingerprint on purpose, so a converged 1D solve seeds a later matfree
/// solve of the same geometry on the same session — observable as a cache
/// hit and an iteration count no worse than the cold run.
#[test]
#[cfg_attr(miri, ignore)] // matfree leg is O(m·n·iters) under the interpreter
fn warm_cache_interops_between_oned_and_matfree() {
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    let gp = problem(24, 18, 77);

    let mut cold = SolverSession::builder(SolverKind::MapUot).stop(stop).build_matfree(&gp);
    let cold_iters = cold.solve_matfree(&gp).unwrap().iters;

    let mut warm = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .warm(4)
        .build_oned(&gp);
    let ro = warm.solve_oned(&gp).unwrap();
    assert!(ro.converged, "1D solve must converge to store its scaling");
    assert_eq!(warm.warm_stats(), Some((0, 1)), "first solve is a miss + store");
    let rm = warm.solve_matfree(&gp).unwrap();
    let (hits, _) = warm.warm_stats().unwrap();
    assert!(hits >= 1, "matfree solve must hit the 1D-seeded entry");
    assert!(
        rm.iters <= cold_iters,
        "seeded matfree took {} iters, cold took {cold_iters}",
        rm.iters
    );

    // And the reverse direction: a matfree solve seeds a later oned solve.
    let mut warm2 = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .warm(4)
        .build_matfree(&gp);
    let r1 = warm2.solve_matfree(&gp).unwrap();
    assert!(r1.converged);
    let r2 = warm2.solve_oned(&gp).unwrap();
    let (hits2, _) = warm2.warm_stats().unwrap();
    assert!(hits2 >= 1, "oned solve must hit the matfree-seeded entry");
    assert!(r2.iters <= r1.iters, "seeded oned took {} vs {}", r2.iters, r1.iters);
}

/// The exact sweep is serial by construction: solves are bit-identical
/// for every session thread count (the pool only exists for the other
/// backends). This is what the CI pool matrix pins.
#[test]
#[cfg_attr(miri, ignore)] // spins real thread pools; nothing here touches raw memory
fn oned_solves_are_thread_count_invariant() {
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    let gp = problem(32, 24, 55);
    let mut reference = SolverSession::builder(SolverKind::MapUot).stop(stop).build_oned(&gp);
    reference.solve_oned(&gp).unwrap();
    let (ru, rv) = reference.oned_scaling().unwrap();
    for &t in &thread_counts() {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(stop)
            .build_oned(&gp);
        session.solve_oned(&gp).unwrap();
        let (u, v) = session.oned_scaling().unwrap();
        assert_eq!(u, ru, "t={t}: u diverged");
        assert_eq!(v, rv, "t={t}: v diverged");
    }
}
