//! Counting-allocator proof of the telemetry overhead contract (PR 10):
//! with span tracing **enabled**, the post-warmup hot loop still performs
//! **zero heap allocations**. The recorder's only allocation is the
//! one-time per-thread lane registration, which the warmup solve absorbs
//! (session thread and every pool worker record at least one span there);
//! after that each span is a clock read plus three relaxed stores into the
//! thread's fixed-capacity ring — overflow wraps and counts, it never
//! grows.
//!
//! This file holds exactly one test so no concurrent test in the same
//! binary can pollute the global allocation counter (same discipline as
//! `alloc_free.rs`, which proves the untraced contract).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use map_uot::algo::{Problem, SolverKind, SolverSession, StopRule};
use map_uot::util::telemetry::{self, Phase};

struct CountingAllocator;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

fn record(_size: usize) {
    if COUNTING.load(Ordering::Relaxed) {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        record(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

#[test]
fn traced_hot_loop_allocates_nothing_after_warmup() {
    let trace_path = std::env::temp_dir().join("map_uot_alloc_free_trace.jsonl");
    let trace_path = trace_path.to_str().expect("utf-8 temp path").to_string();

    // Problems are constructed (and allocate) before counting starts.
    let problems: Vec<Problem> = (0..3).map(|s| Problem::random(48, 40, 0.7, s)).collect();
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 200 };

    // Serial and pooled engines share the contract; threads = 4 makes the
    // pool workers and the column-parallel reduction record spans too, so
    // the counter (which sees every thread) covers their lanes.
    for threads in [1usize, 4] {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(threads)
            .stop(stop)
            .check_every(8)
            .trace(trace_path.clone())
            .build(&problems[0]);
        assert!(telemetry::enabled(), "trace() arms span recording at build");
        // Warmup: lane registration for the session thread and each pool
        // worker happens on the first recorded span.
        session.solve(&problems[0]).expect("warmup traced solve");

        ALLOCATIONS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        for p in &problems {
            session.solve(p).expect("steady-state traced solve");
        }
        COUNTING.store(false, Ordering::SeqCst);

        let count = ALLOCATIONS.load(Ordering::SeqCst);
        assert_eq!(
            count, 0,
            "traced (threads={threads}): {count} heap allocations in the post-warmup hot loop"
        );
    }

    // The zero-alloc proof must not be vacuous: the counted solves really
    // recorded — the full phase vocabulary is present, and the pooled run
    // put worker lanes (lane > 0) on the record.
    let events = telemetry::snapshot_spans();
    assert!(!events.is_empty(), "tracing was armed but nothing recorded");
    for phase in [Phase::FusedSweep, Phase::Reduction, Phase::ConvergenceCheck, Phase::Solve] {
        assert!(events.iter().any(|e| e.phase == phase), "no {phase:?} span recorded");
    }
    assert!(events.iter().any(|e| e.lane > 0), "pool workers recorded no spans");

    // Export is a cold path (allowed to allocate) and must round-trip: the
    // `.jsonl` file has one well-formed object per drained span.
    let stop_session = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .trace(trace_path.clone())
        .build(&problems[0]);
    let exported = stop_session.export_trace().expect("trace export");
    assert_eq!(exported, telemetry::snapshot_spans().len());
    let body = std::fs::read_to_string(&trace_path).expect("trace file written");
    assert_eq!(body.lines().count(), exported);
    assert!(body.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
    let _ = std::fs::remove_file(&trace_path);
}
