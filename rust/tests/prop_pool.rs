//! Property tests for the persistent-pool execution engine: for every
//! solver, shape and thread count — including m < threads and heavy
//! oversubscription — the pool backend must produce **bit-identical**
//! plans, carried column sums and tracked deltas to the legacy
//! `thread::scope` backend. Both backends share the balanced `Partition`,
//! the block kernels and the block-ascending reduction order, so equality
//! is exact, not approximate.
//!
//! CI runs this file under a thread-oversubscription matrix: set
//! `MAP_UOT_POOL_THREADS=t` to restrict the sweep to one thread count
//! (e.g. 16 on a 2-core runner).

use std::sync::Arc;

use map_uot::algo::pool::{AccArena, AffinityHint, PaddedSlots, ParallelBackend, ThreadPool};
use map_uot::algo::{parallel, solver_for, Problem, SolverKind, SolverSession, Workspace};

/// Thread counts to sweep: the full ladder by default, or the single value
/// from `MAP_UOT_POOL_THREADS` (the CI oversubscription matrix).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 3, 4, 8, 16],
    }
}

// (8, 1200) crosses PAR_REDUCE_MIN_COLS so the column-parallel
// `reduce_acc_pool` branch is exercised, not just the serial reduction.
const SHAPES: &[(usize, usize)] = &[(1, 1), (2, 3), (9, 8), (23, 17), (64, 48), (8, 1200)];

/// Pool-backed `Solver::iterate` bit-matches the scope backend for all
/// three solvers across shapes and thread counts.
#[test]
fn pool_iterate_bitmatches_scope() {
    for kind in SolverKind::ALL {
        for &(m, n) in SHAPES {
            for &t in &thread_counts() {
                let p = Problem::random(m, n, 0.7, (m * 31 + n) as u64);
                let solver = solver_for(kind);
                let mut ws_spawn = Workspace::with_backend(
                    m,
                    n,
                    t,
                    ParallelBackend::SpawnPerIter,
                    AffinityHint::None,
                );
                let mut ws_pool =
                    Workspace::with_backend(m, n, t, ParallelBackend::Pool, AffinityHint::None);
                let mut a = p.plan.clone();
                let mut cs_a = a.col_sums();
                let mut b = p.plan.clone();
                let mut cs_b = b.col_sums();
                for it in 0..4 {
                    solver.iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_spawn);
                    solver.iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_pool);
                    assert_eq!(
                        a.as_slice(),
                        b.as_slice(),
                        "{} {m}x{n} t={t} iter={it}: plans diverged",
                        kind.name()
                    );
                }
                assert_eq!(cs_a, cs_b, "{} {m}x{n} t={t}: colsums diverged", kind.name());
            }
        }
    }
}

/// Same contract for the tracked variants, including the returned delta.
#[test]
fn pool_tracked_bitmatches_scope_tracked() {
    for kind in SolverKind::ALL {
        for &(m, n) in SHAPES {
            for &t in &thread_counts() {
                let p = Problem::random(m, n, 0.6, (m * 7 + n * 3) as u64);
                let solver = solver_for(kind);
                let mut ws_spawn = Workspace::with_backend(
                    m,
                    n,
                    t,
                    ParallelBackend::SpawnPerIter,
                    AffinityHint::None,
                );
                let mut ws_pool =
                    Workspace::with_backend(m, n, t, ParallelBackend::Pool, AffinityHint::None);
                let mut a = p.plan.clone();
                let mut cs_a = a.col_sums();
                let mut b = p.plan.clone();
                let mut cs_b = b.col_sums();
                for it in 0..4 {
                    let da =
                        solver.iterate_tracked(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_spawn);
                    let db =
                        solver.iterate_tracked(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_pool);
                    assert_eq!(
                        da.to_bits(),
                        db.to_bits(),
                        "{} {m}x{n} t={t} iter={it}: deltas diverged ({da} vs {db})",
                        kind.name()
                    );
                }
                assert_eq!(a.as_slice(), b.as_slice(), "{} {m}x{n} t={t}", kind.name());
                assert_eq!(cs_a, cs_b, "{} {m}x{n} t={t}", kind.name());
            }
        }
    }
}

/// Direct kernel-level check of the MAP-UOT pool path (no session in the
/// loop), with fewer rows than pool threads.
#[test]
fn direct_mapuot_pool_matches_scope_with_few_rows() {
    for &t in &thread_counts() {
        let (m, n) = (3usize, 29usize);
        let p = Problem::random(m, n, 0.8, 11);
        let pool = ThreadPool::new(t);
        let mut fcol_a = vec![0f32; n];
        let mut fcol_b = vec![0f32; n];
        let mut inv_a = vec![0f32; n];
        let mut inv_b = vec![0f32; n];
        let mut acc_a = AccArena::padded(t, n);
        let mut acc_b = AccArena::padded(t, n);
        let mut deltas = PaddedSlots::new(t);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..3 {
            let da = parallel::mapuot_iterate_tracked(
                &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, t, &mut fcol_a, &mut inv_a, &mut acc_a,
            );
            let db = parallel::mapuot_iterate_pool_tracked(
                &mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &pool, &mut fcol_b, &mut inv_b,
                &mut acc_b, &mut deltas,
            );
            assert_eq!(da.to_bits(), db.to_bits(), "t={t}");
        }
        assert_eq!(a.as_slice(), b.as_slice(), "t={t}");
        assert_eq!(cs_a, cs_b, "t={t}");
    }
}

/// Full solves agree across backends: same plans (bit-exact), same
/// iteration counts.
#[test]
fn full_solve_agrees_across_backends() {
    for &t in &thread_counts() {
        let p = Problem::random(32, 24, 0.7, 21);
        let mut spawn = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::SpawnPerIter)
            .build(&p);
        let mut pool = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::Pool)
            .build(&p);
        let rs = spawn.solve(&p).unwrap();
        let rp = pool.solve(&p).unwrap();
        assert_eq!(rs.iters, rp.iters, "t={t}");
        assert_eq!(spawn.plan().as_slice(), pool.plan().as_slice(), "t={t}");
    }
}

/// A shared pool serving two sessions produces the same bits as private
/// pools (dispatches serialize; arithmetic is unchanged).
#[test]
fn shared_pool_bitmatches_private_pool() {
    let t = *thread_counts().first().unwrap();
    let p = Problem::random(24, 16, 0.7, 5);
    let shared = Arc::new(ThreadPool::new(t));
    let mut a = SolverSession::builder(SolverKind::Coffee)
        .pool(Arc::clone(&shared))
        .build(&p);
    let mut b = SolverSession::builder(SolverKind::Coffee).threads(t).build(&p);
    a.solve(&p).unwrap();
    b.solve(&p).unwrap();
    assert_eq!(a.plan().as_slice(), b.plan().as_slice());
}
