//! End-to-end service integration: coordinator + PJRT backend.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use map_uot::algo::{Problem, SolverKind, SolverSession, StopRule};
use map_uot::config::{Backend, ServiceConfig};
use map_uot::coordinator::Service;

/// Native one-shot reference solve through the session API.
fn native_solve(p: &Problem, stop: StopRule) -> map_uot::util::Matrix {
    let mut session = SolverSession::builder(SolverKind::MapUot).stop(stop).build(p);
    session.solve(p).expect("native reference solve");
    session.into_plan()
}

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn pjrt_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        backend: Backend::Pjrt,
        stop: StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 },
        ..ServiceConfig::default()
    }
}

#[test]
fn pjrt_service_solves_exact_bucket() {
    if !artifacts_ready() {
        return;
    }
    let svc = Service::start(pjrt_cfg()).unwrap();
    let p = Problem::random(256, 256, 0.8, 3);
    let solved = svc.solve_blocking(p.clone()).unwrap();
    assert_eq!(solved.backend, Backend::Pjrt);
    assert!(solved.report.converged, "err={}", solved.report.err);

    // Same answer as the native solver.
    let native = native_solve(&p, pjrt_cfg().stop);
    let plan = solved.response.plan().expect("dense requests return a plan");
    let diff = plan.max_rel_diff(&native, 1e-5);
    assert!(diff < 2e-2, "pjrt vs native diff={diff}");
    svc.shutdown();
}

#[test]
fn pjrt_service_pads_odd_shapes() {
    if !artifacts_ready() {
        return;
    }
    let svc = Service::start(pjrt_cfg()).unwrap();
    // 200x180 pads into the 256x256 bucket.
    let p = Problem::random(200, 180, 0.7, 11);
    let solved = svc.solve_blocking(p.clone()).unwrap();
    let plan = solved.response.plan().expect("dense requests return a plan");
    assert_eq!(plan.rows(), 200);
    assert_eq!(plan.cols(), 180);
    let native = native_solve(&p, pjrt_cfg().stop);
    let diff = plan.max_rel_diff(&native, 1e-5);
    assert!(diff < 2e-2, "padded pjrt vs native diff={diff}");
    svc.shutdown();
}

#[test]
fn mixed_burst_all_complete_with_metrics() {
    if !artifacts_ready() {
        return;
    }
    let svc = Service::start(pjrt_cfg()).unwrap();
    let mut rxs = Vec::new();
    for seed in 0..12u64 {
        let (m, n) = match seed % 3 {
            0 => (256, 256),
            1 => (128, 128),
            _ => (200, 140),
        };
        rxs.push(svc.submit(Problem::random(m, n, 0.8, seed)).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        let solved = resp.result.expect("solve failed");
        assert_eq!(solved.backend, Backend::Pjrt);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    assert!(m.iterations > 0);
    assert!(m.mean_latency_ms > 0.0);
    svc.shutdown();
}

#[test]
fn oversized_request_fails_cleanly_not_fatally() {
    if !artifacts_ready() {
        return;
    }
    let svc = Service::start(pjrt_cfg()).unwrap();
    // Bigger than every bucket: the request must fail, the service must
    // keep serving.
    let big = Problem::random(4000, 4000, 0.5, 1);
    assert!(svc.solve_blocking(big).is_err());
    let ok = svc.solve_blocking(Problem::random(64, 64, 0.8, 2));
    assert!(ok.is_ok());
    let m = svc.metrics();
    assert_eq!(m.failed, 1);
    assert_eq!(m.completed, 1);
    svc.shutdown();
}
