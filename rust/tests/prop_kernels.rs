//! Property tests for the kernel-backend subsystem (`algo::kernels`):
//! every available backend (scalar reference, 16-lane unrolled, AVX2+FMA
//! where the host supports it) × tile width (including `n_tile ∤ n`,
//! `n_tile > n`, `n = 1`) × execution engine (serial, persistent pool)
//! must agree with the scalar untiled reference within 1e-5 relative on
//! random problems — and pool must stay **bit-identical** to the scope
//! backend under any fixed policy, because both share the partition, the
//! kernel and the reduction order.
//!
//! CI runs the whole test binary twice: once plain and once under
//! `MAP_UOT_KERNEL=scalar MAP_UOT_TILE=off` (the dispatch-fallback leg) —
//! these tests pin policies explicitly, so they exercise the same matrix
//! either way.

use map_uot::algo::pool::{AccArena, PaddedSlots, ThreadPool};
use map_uot::algo::{
    parallel, solver_for, KernelKind, KernelPolicy, Problem, SolverKind, SolverSession, TileSpec,
    Workspace,
};

/// ≥ 6 shapes: single cell, single row, m < threads, tiny, tall, wide.
const SHAPES: &[(usize, usize)] = &[(1, 1), (1, 37), (3, 8), (16, 16), (33, 257), (5, 1000)];

/// Tile widths: off, pathological small (never divides 257/1000 evenly),
/// lane-width, mid, and wider than every shape's n.
const TILES: &[usize] = &[0, 3, 7, 16, 64, 2000];

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-3)
}

/// Serial: every backend × tile width reproduces the scalar untiled
/// reference within 1e-5 relative (plans, carried colsums, tracked delta)
/// over several iterations.
#[test]
fn kernels_by_tiles_match_scalar_reference() {
    for &(m, n) in SHAPES {
        let p = Problem::random(m, n, 0.7, (m * 131 + n) as u64);
        let solver = solver_for(SolverKind::MapUot);

        // Reference: scalar kernel, untiled, cached stores.
        let mut ws_ref = Workspace::new(m, n, 1);
        ws_ref.set_policy(KernelPolicy::explicit(KernelKind::Scalar, 0, None));
        let mut plan_ref = p.plan.clone();
        let mut cs_ref = plan_ref.col_sums();
        let mut deltas_ref = Vec::new();
        for _ in 0..3 {
            deltas_ref.push(solver.iterate_tracked(
                &mut plan_ref, &mut cs_ref, &p.rpd, &p.cpd, p.fi, &mut ws_ref,
            ));
        }

        for kind in KernelKind::available() {
            for &tile in TILES {
                let mut ws = Workspace::new(m, n, 1);
                ws.set_policy(KernelPolicy::explicit(kind, tile, None));
                let mut plan = p.plan.clone();
                let mut cs = plan.col_sums();
                for (it, dref) in deltas_ref.iter().enumerate() {
                    let d = solver.iterate_tracked(
                        &mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws,
                    );
                    assert!(
                        rel_close(d, *dref, 1e-4),
                        "{} tile={tile} {m}x{n} iter={it}: delta {d} vs {dref}"
                    );
                }
                let diff = plan.max_rel_diff(&plan_ref, 1e-6);
                assert!(
                    diff < 1e-5,
                    "{} tile={tile} {m}x{n}: plan rel diff {diff}",
                    kind.name()
                );
                for (a, b) in cs.iter().zip(&cs_ref) {
                    assert!(
                        rel_close(*a, *b, 1e-5),
                        "{} tile={tile} {m}x{n}: colsum {a} vs {b}",
                        kind.name()
                    );
                }
            }
        }
    }
}

/// Forced streaming stores change the cache protocol, never the bits:
/// NT-on must bit-match NT-off for every backend × tile (the AVX2 path is
/// the one actually exercising `_mm256_stream_ps`).
#[test]
fn nt_stores_are_bit_identical() {
    for kind in KernelKind::available() {
        for &(m, n) in SHAPES {
            for &tile in &[0usize, 7, 64] {
                let p = Problem::random(m, n, 0.6, (m + n * 13) as u64);
                let solver = solver_for(SolverKind::MapUot);
                let mut ws_a = Workspace::new(m, n, 1);
                ws_a.set_policy(KernelPolicy::explicit(kind, tile, None));
                let mut ws_b = Workspace::new(m, n, 1);
                // nt threshold 0 bytes: every sweep streams.
                ws_b.set_policy(KernelPolicy::explicit(kind, tile, Some(0)));
                let mut a = p.plan.clone();
                let mut cs_a = a.col_sums();
                let mut b = p.plan.clone();
                let mut cs_b = b.col_sums();
                for _ in 0..3 {
                    let da =
                        solver.iterate_tracked(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_a);
                    let db =
                        solver.iterate_tracked(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_b);
                    assert_eq!(da.to_bits(), db.to_bits(), "{} tile={tile} {m}x{n}", kind.name());
                }
                assert_eq!(a.as_slice(), b.as_slice(), "{} tile={tile} {m}x{n}", kind.name());
                assert_eq!(cs_a, cs_b, "{} tile={tile} {m}x{n}", kind.name());
            }
        }
    }
}

/// Pool and scope engines stay bit-identical under any fixed kernel/tile
/// policy (tiling composes with the row partition identically in both).
#[test]
fn pool_bitmatches_scope_under_policy() {
    for kind in KernelKind::available() {
        for &(m, n) in SHAPES {
            for &t in &[2usize, 4, 8] {
                let tile = 7; // never divides the sweep shapes' n evenly
                let policy = KernelPolicy::explicit(kind, tile, None);
                let p = Problem::random(m, n, 0.7, (m * 7 + n + t) as u64);
                let pool = ThreadPool::new(t);
                let mut fcol_a = vec![0f32; n];
                let mut fcol_b = vec![0f32; n];
                let mut inv_a = vec![0f32; n];
                let mut inv_b = vec![0f32; n];
                let mut rs_a = vec![0f32; m];
                let mut rs_b = vec![0f32; m];
                let mut acc_a = AccArena::padded(t, n);
                let mut acc_b = AccArena::padded(t, n);
                let mut slots = PaddedSlots::new(t);
                let mut a = p.plan.clone();
                let mut cs_a = a.col_sums();
                let mut b = p.plan.clone();
                let mut cs_b = b.col_sums();
                for _ in 0..3 {
                    let da = parallel::mapuot_iterate_tracked_policy(
                        &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, t, &mut fcol_a, &mut inv_a,
                        &mut rs_a, &mut acc_a, &policy,
                    );
                    let db = parallel::mapuot_iterate_pool_tracked_policy(
                        &mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &pool, &mut fcol_b, &mut inv_b,
                        &mut rs_b, &mut acc_b, &mut slots, &policy,
                    );
                    assert_eq!(
                        da.to_bits(),
                        db.to_bits(),
                        "{} {m}x{n} t={t}: deltas diverged",
                        kind.name()
                    );
                }
                assert_eq!(a.as_slice(), b.as_slice(), "{} {m}x{n} t={t}", kind.name());
                assert_eq!(cs_a, cs_b, "{} {m}x{n} t={t}", kind.name());
            }
        }
    }
}

/// Full solves: a tiled, pooled session lands on the same plan as an
/// untiled, serial one for every available backend — including shapes
/// with fewer rows than threads.
#[test]
fn tiled_pooled_full_solve_matches_untiled_serial() {
    for kind in KernelKind::available() {
        for &(m, n) in &[(32usize, 24usize), (3, 40), (24, 257)] {
            let p = Problem::random(m, n, 0.7, (m + n) as u64);
            let mut serial = SolverSession::builder(SolverKind::MapUot)
                .kernel(kind)
                .tile(TileSpec::Off)
                .build(&p);
            let mut pooled = SolverSession::builder(SolverKind::MapUot)
                .threads(4)
                .kernel(kind)
                .tile(TileSpec::Cols(16))
                .build(&p);
            let rs = serial.solve(&p).unwrap();
            let rp = pooled.solve(&p).unwrap();
            assert!(rs.converged && rp.converged, "{} {m}x{n}", kind.name());
            let diff = serial.plan().max_rel_diff(pooled.plan(), 1e-6);
            assert!(diff < 1e-3, "{} {m}x{n}: {diff}", kind.name());
        }
    }
}

/// The one-shot auto-tuner and the topology-derived auto width both
/// produce sessions that agree with the reference (whatever width they
/// pick on this host).
#[test]
fn auto_and_tuned_tiles_solve_correctly() {
    let p = Problem::random(24, 600, 0.7, 9);
    let mut reference = SolverSession::builder(SolverKind::MapUot)
        .kernel(KernelKind::Scalar)
        .tile(TileSpec::Off)
        .build(&p);
    reference.solve(&p).unwrap();
    for tile in [TileSpec::Auto, TileSpec::Tune] {
        let mut s = SolverSession::builder(SolverKind::MapUot)
            .kernel(KernelKind::Auto)
            .tile(tile)
            .build(&p);
        s.solve(&p).unwrap();
        let diff = s.plan().max_rel_diff(reference.plan(), 1e-6);
        assert!(diff < 1e-3, "{tile:?}: {diff}");
    }
}

/// The fast-exp satellite: every kernel backend's generation primitive
/// (`Kernel::exp_scale_and_sum`) agrees with scalar libm `f32::exp`
/// within 1e-6 relative across magnitude sweeps — including the
/// subnormal/underflow band, where the denominator clamps at the smallest
/// normal (deep subnormals have percent-scale ulp spacing, so a pure
/// relative bound is unsatisfiable by *any* rounding scheme; the clamp
/// holds the tail to an equivalent absolute bound instead).
#[test]
fn fast_exp_matches_libm_reference() {
    use map_uot::algo::{kernel_for, Kernel};
    let mut rng = map_uot::util::XorShift::new(17);
    // Cost magnitudes spanning ~1e-6 .. ~1e2 per decade, plus exact zero
    // and the deep-underflow band (with inv_eps = 2 these reach exponents
    // of -240, far past where exp flushes to zero).
    let mut costs: Vec<f32> = vec![0.0];
    for decade in -6..=2 {
        for _ in 0..48 {
            costs.push(10f32.powi(decade) * rng.uniform(1.0, 10.0));
        }
    }
    for band in [43.5, 44.0, 47.5, 50.0, 51.9, 60.0, 120.0] {
        costs.push(band); // x = -2·band crosses normal → subnormal → zero
    }
    let inv_eps = 2.0f32;
    let scale = 0.75f32;
    let v: Vec<f32> = (0..costs.len()).map(|_| rng.uniform(0.5, 1.5)).collect();

    // Reference: elementwise libm.
    let want: Vec<f32> = costs
        .iter()
        .zip(&v)
        .map(|(&c, &vj)| (-c * inv_eps).exp() * (scale * vj))
        .collect();

    for kind in KernelKind::available() {
        let k = kernel_for(kind);
        let mut buf = costs.clone();
        let s = k.exp_scale_and_sum(&mut buf, inv_eps, scale, &v);
        let mut want_sum = 0f64;
        for (j, (&got, &w)) in buf.iter().zip(&want).enumerate() {
            assert!(
                (got - w).abs() <= 1e-6 * w.abs().max(f32::MIN_POSITIVE),
                "{} elem {j} (cost {}): {got:e} vs libm {w:e}",
                kind.name(),
                costs[j]
            );
            want_sum += w as f64;
        }
        assert!(
            (s as f64 - want_sum).abs() <= 1e-4 * want_sum.abs().max(1.0),
            "{}: sum {s} vs {want_sum}",
            kind.name()
        );
    }
}

/// Awkward lengths for the generation primitive: every backend handles
/// head/tail splits (8/16-lane bodies + scalar tails) identically to the
/// scalar reference within tolerance, and the scalar backend is exactly
/// elementwise libm.
#[test]
fn exp_scale_and_sum_handles_awkward_lengths() {
    use map_uot::algo::kernels::ScalarKernel;
    use map_uot::algo::{kernel_for, Kernel};
    let mut rng = map_uot::util::XorShift::new(23);
    for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 257] {
        let costs: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 8.0)).collect();
        let v: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let mut buf_ref = costs.clone();
        let s_ref = ScalarKernel.exp_scale_and_sum(&mut buf_ref, 1.5, 0.8, &v);
        for kind in KernelKind::available() {
            let k = kernel_for(kind);
            let mut buf = costs.clone();
            let s = k.exp_scale_and_sum(&mut buf, 1.5, 0.8, &v);
            assert!(
                (s - s_ref).abs() <= 1e-5 * s_ref.abs().max(1.0),
                "{} n={n}: sum {s} vs {s_ref}",
                kind.name()
            );
            for (j, (a, b)) in buf.iter().zip(&buf_ref).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6 * b.abs().max(1e-9),
                    "{} n={n} elem {j}: {a} vs {b}",
                    kind.name()
                );
            }
        }
    }
}
