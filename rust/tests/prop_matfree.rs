//! Property tests for the materialization-free backend:
//!
//! * **Equivalence** — a matfree solve tracks the dense MAP-UOT session on
//!   the materialized Gibbs problem: same iteration counts under the same
//!   stop rule, and the materialized matfree plan within tolerance of the
//!   dense plan on small golden-seeded shapes.
//! * **Bit-exactness** — for any fixed row partition, the scope and pool
//!   engines are bit-identical to the partitioned serial reference
//!   (`parallel::matfree_iterate_partitioned_tracked`): same scaling
//!   vectors, same carried sums, same tracked deltas. A full
//!   `SolverSession::solve_matfree` on the pool engine bit-matches the
//!   spawn engine for every thread count.
//! * **Hardening** — malformed geometry is a typed error, never a panic;
//!   a bandwidth small enough to underflow every kernel entry terminates
//!   cleanly with dead rows, exactly like the dense zero-row guard.
//!
//! CI runs this file under the same thread-oversubscription matrix as
//! `prop_pool.rs`/`prop_sparse.rs`: set `MAP_UOT_POOL_THREADS=t` to
//! restrict the sweep.

use map_uot::algo::matfree::{CostKind, GeomProblem, MatfreeWorkspace};
use map_uot::algo::pool::{
    AccArena, AffinityHint, PaddedSlots, ParallelBackend, Partition, ThreadPool,
};
use map_uot::algo::{parallel, KernelKind, KernelPolicy, SolverKind, SolverSession, StopRule};
use map_uot::error::Error;

/// Thread counts to sweep: the full ladder by default, or the single value
/// from `MAP_UOT_POOL_THREADS` (the CI oversubscription matrix).
fn thread_counts() -> Vec<usize> {
    match std::env::var("MAP_UOT_POOL_THREADS") {
        Ok(v) => vec![v.parse().expect("MAP_UOT_POOL_THREADS must be a thread count")],
        Err(_) => vec![1, 2, 3, 4, 8, 16],
    }
}

/// Shapes crossing the interesting edges: single row/col, more threads
/// than rows, wide rows (panel tiling), odd dims.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 3, 2),
    (9, 8, 3),
    (23, 17, 3),
    (7, 300, 2),
    (64, 48, 4),
];

fn problem(m: usize, n: usize, d: usize, cost: CostKind, seed: u64) -> GeomProblem {
    GeomProblem::random(m, n, d, cost, 0.25, 0.7, seed)
}

/// Full session solves on matfree and dense agree: same iteration counts
/// under the same stop rule, materialized plans within tolerance (both
/// paths round differently — dense mutates a stored plan, matfree
/// re-derives entries from the scaling vectors — so the comparison is
/// relative, not bitwise).
#[test]
fn matfree_solve_matches_dense_session() {
    // A fixed iteration budget (negative tolerances never fire) keeps the
    // comparison deterministic: both sessions run exactly max_iter sweeps,
    // so a threshold crossing inside one path's rounding can never skew
    // the iteration counts.
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 48 };
    for (seed, &(m, n, d)) in SHAPES.iter().enumerate() {
        for cost in [CostKind::SqEuclidean, CostKind::Euclidean] {
            let gp = problem(m, n, d, cost, 41 + seed as u64);
            let dense = gp.dense_problem();

            let mut mf = SolverSession::builder(SolverKind::MapUot)
                .stop(stop)
                .check_every(4)
                .build_matfree(&gp);
            let mf_report = mf.solve_matfree(&gp).unwrap();

            let mut ds = SolverSession::builder(SolverKind::MapUot)
                .stop(stop)
                .check_every(4)
                .build(&dense);
            let ds_report = ds.solve(&dense).unwrap();

            assert_eq!(mf_report.iters, ds_report.iters, "{m}x{n} d={d} {cost:?}");
            let materialized = mf.matfree_materialize(&gp).unwrap();
            let rel = materialized.max_rel_diff(ds.plan(), 1e-4);
            assert!(
                rel < 1e-3,
                "{m}x{n} d={d} {:?}: materialized matfree plan off by {rel}",
                cost
            );
        }
    }
}

/// The golden-seeded equivalence pin (the satellite's headline case): a
/// small forced-scalar shape where both backends evaluate libm exp over a
/// fixed iteration budget, so the only differences are rounding order —
/// within 1e-5 relative.
#[test]
fn matfree_matches_dense_golden_seeded_scalar() {
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 64 };
    let gp = GeomProblem::random(16, 12, 3, CostKind::SqEuclidean, 0.25, 0.7, 1234);
    let dense = gp.dense_problem();
    let mut mf = SolverSession::builder(SolverKind::MapUot)
        .kernel(KernelKind::Scalar)
        .stop(stop)
        .check_every(4)
        .build_matfree(&gp);
    let mut ds = SolverSession::builder(SolverKind::MapUot)
        .kernel(KernelKind::Scalar)
        .stop(stop)
        .check_every(4)
        .build(&dense);
    let rm = mf.solve_matfree(&gp).unwrap();
    let rd = ds.solve(&dense).unwrap();
    assert_eq!(rm.iters, rd.iters);
    let materialized = mf.matfree_materialize(&gp).unwrap();
    let rel = materialized.max_rel_diff(ds.plan(), 1e-3);
    assert!(rel < 1e-5, "golden shape off by {rel}");
    assert!((rm.err - rd.err).abs() <= 1e-3 * rd.err.max(1e-2), "{} vs {}", rm.err, rd.err);
}

/// For any fixed partition, both threaded engines are bit-identical to the
/// partitioned serial reference — scaling vectors, carried sums, tracked
/// deltas.
#[test]
fn engines_bitmatch_partitioned_serial_reference() {
    for &(m, n, d) in SHAPES {
        for &t in &thread_counts() {
            let gp = problem(m, n, d, CostKind::SqEuclidean, (m * 7 + n * 3 + d) as u64);
            let policy = KernelPolicy::for_shape(
                KernelKind::Auto,
                map_uot::algo::TileSpec::Auto,
                m,
                n,
            );
            let part = Partition::new(m, t, t);
            let pool = ThreadPool::new(t);
            let mut fcol = vec![0f32; n];
            let mut inv = vec![0f32; n];
            let mut deltas = PaddedSlots::new(t);
            // Three engines, three state sets, one partition. Seed every
            // engine's colsum identically (serial pass).
            let mut seed_ws = MatfreeWorkspace::new(m, n, 1);
            seed_ws.prepare(m, n);
            let ones_m = vec![1f32; m];
            let ones = vec![1f32; n];
            let mut seeded = vec![0f32; n];
            seed_ws.seed_col_sums(&gp, &ones_m, &ones, &mut seeded);
            let fresh = || (vec![1f32; m], vec![1f32; n], seeded.clone(), vec![0f32; m]);
            let (mut u_a, mut v_a, mut c_a, mut r_a) = fresh(); // scope
            let (mut u_b, mut v_b, mut c_b, mut r_b) = fresh(); // pool
            let (mut u_c, mut v_c, mut c_c, mut r_c) = fresh(); // serial reference
            let (mut pan_a, mut acc_a) = (AccArena::padded(t, n), AccArena::padded(t, n));
            let (mut pan_b, mut acc_b) = (AccArena::padded(t, n), AccArena::padded(t, n));
            let (mut pan_c, mut acc_c) = (AccArena::padded(t, n), AccArena::padded(t, n));
            for it in 0..4 {
                let da = parallel::matfree_iterate_tracked(
                    &gp, &mut u_a, &mut v_a, &mut c_a, &mut r_a, &mut fcol, &mut inv, &mut pan_a,
                    &mut acc_a, &part, &policy,
                );
                let db = parallel::matfree_iterate_pool_tracked(
                    &gp, &mut u_b, &mut v_b, &mut c_b, &mut r_b, &pool, &mut fcol, &mut inv,
                    &mut pan_b, &mut acc_b, &mut deltas, &part, &policy,
                );
                let dc = parallel::matfree_iterate_partitioned_tracked(
                    &gp, &mut u_c, &mut v_c, &mut c_c, &mut r_c, &mut fcol, &mut inv, &mut pan_c,
                    &mut acc_c, &part, &policy,
                );
                assert_eq!(da.to_bits(), dc.to_bits(), "{m}x{n} t={t} it={it}: scope delta");
                assert_eq!(db.to_bits(), dc.to_bits(), "{m}x{n} t={t} it={it}: pool delta");
            }
            assert_eq!(u_a, u_c, "{m}x{n} t={t}: scope u");
            assert_eq!(u_b, u_c, "{m}x{n} t={t}: pool u");
            assert_eq!(v_a, v_c, "{m}x{n} t={t}: scope v");
            assert_eq!(v_b, v_c, "{m}x{n} t={t}: pool v");
            assert_eq!(c_a, c_c, "{m}x{n} t={t}: scope colsum");
            assert_eq!(c_b, c_c, "{m}x{n} t={t}: pool colsum");
            assert_eq!(r_a, r_c, "{m}x{n} t={t}: scope rowsum");
            assert_eq!(r_b, r_c, "{m}x{n} t={t}: pool rowsum");
        }
    }
}

/// Full matfree session solves agree across backends: bit-identical
/// scaling vectors, same iteration counts — pool vs spawn for every
/// thread count, and any thread count vs the serial session (the session
/// partition at `t` blocks is fixed per engine, so serial-vs-threaded is
/// compared through the *same* session thread count on both engines).
#[test]
fn full_matfree_solve_agrees_across_backends() {
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    for &t in &thread_counts() {
        let gp = problem(32, 24, 3, CostKind::SqEuclidean, 21);
        let mut spawn = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::SpawnPerIter)
            .stop(stop)
            .build_matfree(&gp);
        let mut pool = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .backend(ParallelBackend::Pool)
            .stop(stop)
            .build_matfree(&gp);
        let rs = spawn.solve_matfree(&gp).unwrap();
        let rp = pool.solve_matfree(&gp).unwrap();
        assert_eq!(rs.iters, rp.iters, "t={t}");
        assert_eq!(spawn.matfree_scaling().unwrap().0, pool.matfree_scaling().unwrap().0, "t={t} u");
        assert_eq!(spawn.matfree_scaling().unwrap().1, pool.matfree_scaling().unwrap().1, "t={t} v");
    }
}

/// Threaded solves match the serial solve within tolerance (different
/// partitions regroup the colsum reduction, so this is a tolerance check,
/// not bitwise — the bitwise contract is per-partition, above).
#[test]
fn threaded_solves_track_serial_within_tolerance() {
    let stop = StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 400 };
    let gp = problem(32, 24, 3, CostKind::Euclidean, 33);
    let mut serial = SolverSession::builder(SolverKind::MapUot)
        .stop(stop)
        .build_matfree(&gp);
    serial.solve_matfree(&gp).unwrap();
    let (su, sv) = serial.matfree_scaling().unwrap();
    for &t in &thread_counts() {
        let mut threaded = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(stop)
            .build_matfree(&gp);
        threaded.solve_matfree(&gp).unwrap();
        let (tu, tv) = threaded.matfree_scaling().unwrap();
        for (a, b) in tu.iter().zip(su).chain(tv.iter().zip(sv)) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1e-4), "t={t}: {a} vs {b}");
        }
    }
}

/// Workspace engine dispatch (serial / scope / pool through
/// `MatfreeWorkspace`) matches the dense kernel on the same problem for
/// every thread count.
#[test]
fn workspace_engines_track_dense_for_all_thread_counts() {
    use map_uot::algo::mapuot;
    for &t in &thread_counts() {
        let (m, n) = (23, 17);
        let gp = problem(m, n, 3, CostKind::SqEuclidean, 5);
        let dense = gp.dense_problem();
        let mut plan = dense.plan.clone();
        let mut cs_dense = plan.col_sums();

        let mut engines = [
            MatfreeWorkspace::with_backend(m, n, t, ParallelBackend::Pool, AffinityHint::None),
            MatfreeWorkspace::with_backend(m, n, t, ParallelBackend::SpawnPerIter, AffinityHint::None),
            MatfreeWorkspace::new(m, n, 1),
        ];
        let mut states: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> = (0..engines.len())
            .map(|_| (vec![1f32; m], vec![1f32; n], vec![0f32; n], vec![0f32; m]))
            .collect();
        for (ws, st) in engines.iter_mut().zip(states.iter_mut()) {
            ws.prepare(m, n);
            let ones_m = vec![1f32; m];
            let ones = vec![1f32; n];
            ws.seed_col_sums(&gp, &ones_m, &ones, &mut st.2);
        }
        for _ in 0..6 {
            mapuot::iterate(&mut plan, &mut cs_dense, &gp.rpd, &gp.cpd, gp.fi);
            for (ws, st) in engines.iter_mut().zip(states.iter_mut()) {
                let (u, v, c, r) = st;
                ws.iterate(&gp, u, v, c, r);
            }
        }
        for (which, st) in states.iter().enumerate() {
            for (j, (a, b)) in st.2.iter().zip(&cs_dense).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-3 * b.abs().max(1e-3),
                    "t={t} engine {which} col {j}: {a} vs {b}"
                );
            }
        }
        // Pool and scope engines bit-match (same partition, same order).
        assert_eq!(states[0].0, states[1].0, "t={t} u");
        assert_eq!(states[0].2, states[1].2, "t={t} colsum");
    }
}

/// Malformed geometry is a typed error, never a panic.
#[test]
fn malformed_geometry_is_rejected_with_typed_errors() {
    let sq = CostKind::SqEuclidean;
    let ones = || vec![1.0f32; 3];
    let cases: Vec<(&str, map_uot::error::Result<GeomProblem>)> = vec![
        (
            "x length mismatch",
            GeomProblem::new(vec![0.0; 5], vec![0.0; 6], 2, sq, 0.5, ones(), ones(), 0.7),
        ),
        (
            "y length mismatch",
            GeomProblem::new(vec![0.0; 6], vec![0.0; 5], 2, sq, 0.5, ones(), ones(), 0.7),
        ),
        (
            "zero dimension",
            GeomProblem::new(vec![], vec![], 0, sq, 0.5, ones(), ones(), 0.7),
        ),
        (
            "NaN coordinate",
            GeomProblem::new(vec![f32::NAN; 6], vec![0.0; 6], 2, sq, 0.5, ones(), ones(), 0.7),
        ),
        (
            "zero epsilon",
            GeomProblem::new(vec![0.0; 6], vec![0.0; 6], 2, sq, 0.0, ones(), ones(), 0.7),
        ),
        (
            "infinite epsilon",
            GeomProblem::new(vec![0.0; 6], vec![0.0; 6], 2, sq, f32::INFINITY, ones(), ones(), 0.7),
        ),
        (
            "nonpositive marginal",
            GeomProblem::new(vec![0.0; 6], vec![0.0; 6], 2, sq, 0.5, vec![1.0, 0.0, 1.0], ones(), 0.7),
        ),
        (
            "fi out of range",
            GeomProblem::new(vec![0.0; 6], vec![0.0; 6], 2, sq, 0.5, ones(), ones(), 1.5),
        ),
    ];
    for (what, outcome) in cases {
        match outcome {
            Err(Error::InvalidProblem(_)) => {}
            other => panic!("{what}: expected InvalidProblem, got {other:?}"),
        }
    }
}

/// A bandwidth so small every kernel entry underflows produces dead rows
/// (factor-0 guard), terminates cleanly, and stays finite — the matfree
/// analogue of the dense zero-column test.
#[test]
fn underflowing_bandwidth_terminates_cleanly() {
    // Distant clouds + tiny epsilon: exp(-d²/ε) underflows to 0 for every
    // pair, so u dies on the first iteration and the delta rule fires.
    let x = vec![0.0; 8 * 2];
    let y = vec![100.0; 6 * 2];
    let gp = GeomProblem::new(x, y, 2, CostKind::SqEuclidean, 1e-3, vec![1.0; 8], vec![1.0; 6], 0.7)
        .unwrap();
    for &t in &thread_counts() {
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(t)
            .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 32 })
            .build_matfree(&gp);
        let report = session.solve_matfree(&gp).unwrap();
        assert!(report.iters <= 32);
        let (u, v) = session.matfree_scaling().unwrap();
        assert!(u.iter().chain(v.iter()).all(|x| x.is_finite()), "t={t}");
    }
}
