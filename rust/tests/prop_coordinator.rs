//! Property-based tests on coordinator invariants (routing, batching,
//! padding) using the in-repo `testing` harness (proptest is unavailable
//! offline — see DESIGN.md).

use std::collections::BTreeSet;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use map_uot::algo::{solver_for, Problem, SolverKind, Workspace};
use map_uot::coordinator::batcher::{Batcher, FullPolicy};
use map_uot::coordinator::request::{Payload, SolveRequest};
use map_uot::coordinator::router;
use map_uot::runtime::Manifest;
use map_uot::testing::{check, int_range, Gen};
use map_uot::util::XorShift;

fn mk_req(id: u64, m: usize, n: usize) -> SolveRequest {
    let (tx, rx) = channel();
    std::mem::forget(rx);
    SolveRequest {
        id,
        payload: Payload::Dense(Problem::random(m, n, 0.5, id + 1)),
        reply: tx,
        submitted_at: std::time::Instant::now(),
    }
}

/// Batching conserves requests: no loss, no duplication, batch bounds hold,
/// every batch is shape-homogeneous.
#[test]
fn prop_batcher_conserves_requests() {
    check(11, |rng: &mut XorShift| {
        let n_req = 1 + rng.below(40);
        let batch_max = 1 + rng.below(8);
        let shapes = [(8usize, 8usize), (16, 16), (8, 16)];
        let reqs: Vec<(u64, (usize, usize))> = (0..n_req as u64)
            .map(|i| (i, shapes[rng.below(shapes.len())]))
            .collect();
        (reqs, batch_max)
    }, |(reqs, batch_max)| {
        let b = Batcher::new(1024, *batch_max, Duration::from_micros(100));
        for (id, (m, n)) in reqs {
            b.push(mk_req(*id, *m, *n), FullPolicy::Reject)
                .map_err(|_| "push rejected".to_string())?;
        }
        b.close();
        let mut seen = BTreeSet::new();
        while let Some(batch) = b.pop_batch() {
            if batch.is_empty() || batch.len() > *batch_max {
                return Err(format!("batch size {} out of bounds", batch.len()));
            }
            let shape = batch[0].shape();
            for r in batch {
                if r.shape() != shape {
                    return Err("mixed shapes in batch".into());
                }
                if !seen.insert(r.id) {
                    return Err(format!("duplicate id {}", r.id));
                }
            }
        }
        if seen.len() != reqs.len() {
            return Err(format!("lost requests: {} of {}", seen.len(), reqs.len()));
        }
        Ok(())
    });
}

/// Concurrent producers + consumers: conservation still holds.
#[test]
fn prop_batcher_concurrent_conservation() {
    for trial in 0..8u64 {
        let b = Arc::new(Batcher::new(16, 4, Duration::from_micros(50)));
        let n_producers = 4;
        let per_producer = 25u64;

        let mut handles = Vec::new();
        for p in 0..n_producers {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    let id = p * 1000 + i + trial * 100_000;
                    let mut req = mk_req(id, 8, 8);
                    loop {
                        match b.push(req, FullPolicy::Block) {
                            Ok(()) => break,
                            Err(r) => req = r, // closed would loop forever; not closed here
                        }
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some(batch) = b.pop_batch() {
                    ids.extend(batch.iter().map(|r| r.id));
                }
                ids
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let ids = consumer.join().unwrap();
        let set: BTreeSet<u64> = ids.iter().copied().collect();
        assert_eq!(ids.len() as u64, n_producers * per_producer, "trial {trial}");
        assert_eq!(set.len(), ids.len(), "duplicates in trial {trial}");
    }
}

/// Padding into any admissible bucket preserves solver semantics on the
/// real support and keeps padding identically zero.
#[test]
fn prop_padding_preserves_semantics() {
    check(23, |rng: &mut XorShift| {
        let m = 2 + rng.below(12);
        let n = 2 + rng.below(12);
        let bm = m + rng.below(8);
        let bn = n + rng.below(8);
        let iters = 1 + rng.below(4);
        let seed = rng.next_u64();
        (m, n, bm, bn, iters, seed)
    }, |&(m, n, bm, bn, iters, seed)| {
        let p = Problem::random(m, n, 0.7, seed);
        let mut padded = router::pad(&p, bm, bn);
        let solver = solver_for(SolverKind::MapUot);
        let mut ws_plain = Workspace::new(m, n, 1);
        let mut ws_padded = Workspace::new(bm, bn, 1);
        let mut plain = p.plan.clone();
        let mut plain_cs = plain.col_sums();
        for _ in 0..iters {
            solver.iterate(&mut plain, &mut plain_cs, &p.rpd, &p.cpd, p.fi, &mut ws_plain);
            solver.iterate(
                &mut padded.plan,
                &mut padded.colsum,
                &padded.rpd,
                &padded.cpd,
                padded.fi,
                &mut ws_padded,
            );
        }
        let diff = padded.unpad().max_rel_diff(&plain, 1e-6);
        if diff > 1e-3 {
            return Err(format!("support diverged: {diff}"));
        }
        for i in 0..bm {
            for j in 0..bn {
                if (i >= m || j >= n) && padded.plan.get(i, j) != 0.0 {
                    return Err(format!("padding non-zero at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

/// The router always picks the *smallest* fitting bucket.
#[test]
fn prop_router_bucket_minimality() {
    let manifest = Manifest::parse(
        "a file=a kind=uot_chunk m=64 n=64 steps=8 block_m=32\n\
         b file=b kind=uot_chunk m=128 n=128 steps=8 block_m=32\n\
         c file=c kind=uot_chunk m=256 n=128 steps=8 block_m=32\n\
         d file=d kind=uot_chunk m=512 n=512 steps=8 block_m=32\n",
    )
    .unwrap();
    let gen = |rng: &mut XorShift| (1 + rng.below(600), 1 + rng.below(600));
    check(31, gen, |&(m, n)| {
        let picked = manifest.chunk_for(m, n);
        let fitting: Vec<_> = manifest
            .iter()
            .filter(|a| a.m >= m && a.n >= n)
            .collect();
        match picked {
            None => {
                if !fitting.is_empty() {
                    return Err(format!("router found nothing but {} fit", fitting.len()));
                }
            }
            Some(p) => {
                for f in fitting {
                    if f.m * f.n < p.m * p.n {
                        return Err(format!(
                            "picked {}x{} but {}x{} is smaller",
                            p.m, p.n, f.m, f.n
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Generator sanity for the harness itself (meta-property).
#[test]
fn prop_int_range_bounds() {
    check(1, |rng: &mut XorShift| int_range(5, 9).generate(rng), |&v| {
        if (5..=9).contains(&v) { Ok(()) } else { Err(format!("{v} out of range")) }
    });
}
