//! Ablation: coordinator batching policy — throughput/latency as a
//! function of `batch_max` and worker count under a synthetic burst.
//! (The design-choice study DESIGN.md calls out for the L3 batcher.)

use std::sync::Arc;

use map_uot::algo::{Problem, SolverKind, StopRule};
use map_uot::bench::{fast_mode, Table};
use map_uot::config::ServiceConfig;
use map_uot::coordinator::Service;
use map_uot::util::Timer;

fn run_once(workers: usize, batch_max: usize, requests: usize, size: usize) -> (f64, f64) {
    let cfg = ServiceConfig {
        workers,
        batch_max,
        solver: SolverKind::MapUot,
        stop: StopRule { tol: 0.0, delta_tol: 0.0, max_iter: 32 },
        ..ServiceConfig::default()
    };
    let svc = Arc::new(Service::start(cfg).expect("start"));
    let timer = Timer::start();
    let rxs: Vec<_> = (0..requests)
        .map(|i| svc.submit(Problem::random(size, size, 0.8, i as u64)).expect("submit"))
        .collect();
    for rx in rxs {
        let _ = rx.recv().expect("reply");
    }
    let wall = timer.elapsed().as_secs_f64();
    let m = svc.metrics();
    Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
    (requests as f64 / wall, m.mean_latency_ms)
}

fn main() {
    let (requests, size) = if fast_mode() { (16, 64) } else { (64, 192) };
    let mut t = Table::new(
        format!("Ablation: batching policy ({requests} requests of {size}x{size}, 32 iters each)"),
        &["workers", "batch_max", "req/s", "mean latency ms"],
    );
    for &workers in &[1usize, 2, 4] {
        for &batch_max in &[1usize, 4, 16] {
            let (rps, lat) = run_once(workers, batch_max, requests, size);
            t.row(&[
                format!("{workers}"),
                format!("{batch_max}"),
                format!("{rps:.1}"),
                format!("{lat:.1}"),
            ]);
        }
    }
    t.print();
    println!("\n(single-core host: worker-count rows mainly measure scheduling overhead;");
    println!(" batch_max rows show the batcher amortizing queue wakeups)");
}
