//! Ablation: §6 future work — P/E-core-aware scheduling headroom on the
//! 12900K hybrid model (uniform vs proportional vs work-stealing splits).

use map_uot::algo::SolverKind;
use map_uot::bench::Table;
use map_uot::sim::hetero::{self, Schedule};

fn main() {
    let cpu = hetero::i9_12900k_hybrid();
    let mut t = Table::new(
        "Ablation: hybrid P/E scheduling (12900K model, ms/iter + speedup vs uniform)",
        &["size", "uniform", "proportional", "stealing(8)", "stealing(32)", "best speedup"],
    );
    for &s in &[1024usize, 4096, 10240] {
        let ms = |sched| hetero::iter_time_s(&cpu, SolverKind::MapUot, s, s, sched) * 1e3;
        let uni = ms(Schedule::Uniform);
        let prop = ms(Schedule::Proportional);
        let ws8 = ms(Schedule::WorkStealing { chunks_per_core: 8 });
        let ws32 = ms(Schedule::WorkStealing { chunks_per_core: 32 });
        t.row(&[
            format!("{s}x{s}"),
            format!("{uni:.3}"),
            format!("{prop:.3}"),
            format!("{ws8:.3}"),
            format!("{ws32:.3}"),
            format!("{:.2}x", uni / prop),
        ]);
    }
    t.print();
    println!("\n(§6 headroom: the fused loop's even row split leaves P-cores idle on a");
    println!(" hybrid part; rate-proportional splitting recovers ~(p/e+1)/2 of it)");
}
