//! `cargo bench` harness regenerating paper Figure 17.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    let (t, s) = map_uot::bench::figures::fig17();
    t.print();
    println!("summary (paper: 2.77x/1.79x at 1920x1280 on CPU): {s}");
}
