//! §Perf harness: achieved memory bandwidth of the solver inner loops vs
//! this machine's practical streaming peak (a memcpy-like roofline), plus
//! per-primitive timings. This is the measurement loop behind
//! EXPERIMENTS.md §Perf — run directly with
//! `cargo bench --bench perf_kernel`.

use map_uot::algo::{self, mapuot, SolverKind};
use map_uot::bench::{measure, Policy, Table};
use map_uot::util::Matrix;

// 420 MB plan: beyond even this host's 260 MB LLC, so the sweeps hit DRAM
// and the paper's traffic argument applies. (At LLC-resident sizes the
// fused and phase-fused variants tie — recorded in EXPERIMENTS.md §Perf.)
const S: usize = 10240;

fn streaming_peak_gbs() -> f64 {
    // Practical peak: a scale-by-constant sweep (1 read + 1 write, fully
    // vectorizable, no dependencies) over the same footprint.
    let mut m = Matrix::from_fn(S, S, |i, j| (i + j) as f32 * 1e-6 + 0.5);
    let sec = measure(Policy { warmup: 1, reps: 5 }, || {
        for v in m.as_mut_slice() {
            *v *= 1.000001;
        }
    });
    2.0 * (S * S * 4) as f64 / sec / 1e9
}

fn solver_gbs(kind: SolverKind) -> (f64, f64) {
    let p = algo::Problem::random(S, S, 0.7, 1);
    let solver = algo::solver_for(kind);
    let mut ws = algo::Workspace::new(S, S, 1);
    let mut plan = p.plan.clone();
    let mut cs = plan.col_sums();
    let sec = measure(Policy { warmup: 1, reps: 5 }, || {
        solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws);
    });
    let bytes = kind.accesses_per_element() as f64 * (S * S * 4) as f64;
    (bytes / sec / 1e9, sec * 1e3)
}

fn primitive_gbs() -> (f64, f64) {
    // The two fused row primitives in isolation.
    let n = S;
    let mut row = vec![1.0f32; n * 16];
    let fcol = vec![1.0000001f32; n];
    let mut ncs = vec![0f32; n];
    let t1 = measure(Policy { warmup: 1, reps: 5 }, || {
        let mut acc = 0f32;
        for r in row.chunks_exact_mut(n) {
            acc += mapuot::scale_by_vec_and_sum(r, &fcol);
        }
        std::hint::black_box(acc)
    });
    let t2 = measure(Policy { warmup: 1, reps: 5 }, || {
        for r in row.chunks_exact_mut(n) {
            mapuot::scale_by_scalar_and_accumulate(r, 0.9999999, &mut ncs);
        }
    });
    let bytes = (row.len() * 4) as f64 * 2.0; // read+write per element
    (bytes / t1 / 1e9, bytes / t2 / 1e9)
}

/// Relative cost of span tracing on a steady-state session solve: same
/// problem, fixed iteration budget, traced vs untraced. The PR 10
/// contract is <= 5%; the recorder's enabled path is two clock reads plus
/// three relaxed stores per span, a handful of spans per check burst.
fn trace_overhead_pct() -> f64 {
    use map_uot::algo::{Problem, SolverSession, StopRule};
    use map_uot::util::telemetry;
    let p = Problem::random(2048, 2048, 0.7, 1);
    let stop = StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 8 };
    let time = |traced: bool| {
        let mut b = SolverSession::builder(SolverKind::MapUot).stop(stop).check_every(4);
        if traced {
            // Path is never written: export_trace is not called here.
            b = b.trace("trace-overhead-unused.jsonl");
        }
        let mut s = b.build(&p);
        s.solve(&p).expect("warmup solve");
        let sec = measure(Policy { warmup: 1, reps: 5 }, || {
            s.solve(&p).expect("steady-state solve");
        });
        telemetry::set_enabled(false);
        sec
    };
    let base = time(false);
    let traced = time(true);
    (traced / base - 1.0) * 100.0
}

fn lazy_ms() -> f64 {
    let p = algo::Problem::random(S, S, 0.7, 1);
    let mut solver =
        algo::lazy::LazySolver::new(p.plan.clone(), p.rpd.clone(), p.cpd.clone(), p.fi);
    measure(Policy { warmup: 1, reps: 5 }, || solver.iterate()) * 1e3
}

fn main() {
    let peak = streaming_peak_gbs();
    let (p1, p2) = primitive_gbs();
    let mut t = Table::new(
        format!("Perf: achieved bandwidth at {S}x{S} (streaming peak {peak:.1} GB/s)"),
        &["what", "GB/s", "ms/iter", "% of streaming peak"],
    );
    for kind in SolverKind::ALL {
        let (gbs, ms) = solver_gbs(kind);
        t.row(&[
            kind.name().into(),
            format!("{gbs:.1}"),
            format!("{ms:.2}"),
            format!("{:.0}%", gbs / peak * 100.0),
        ]);
    }
    t.row(&["primitive: scale+rowsum".into(), format!("{p1:.1}"), "-".into(), format!("{:.0}%", p1 / peak * 100.0)]);
    t.row(&["primitive: scale+colacc".into(), format!("{p2:.1}"), "-".into(), format!("{:.0}%", p2 / peak * 100.0)]);
    let lz = lazy_ms();
    let lazy_gbs = 2.0 * (S * S * 4) as f64 / (lz * 1e-3) / 1e9;
    t.row(&[
        "MAP-UOT lazy (§Perf)".into(),
        format!("{lazy_gbs:.1}"),
        format!("{lz:.2}"),
        format!("{:.0}%", lazy_gbs / peak * 100.0),
    ]);
    t.print();
    let pct = trace_overhead_pct();
    println!("\nsession span tracing overhead: {pct:+.1}% (contract: <= 5%)");
    println!(
        "\ninterpretation: MAP-UOT moves 2 element-accesses/cell/iter; at the\n\
         streaming peak its ms/iter is the practical roofline on this host."
    );
}
