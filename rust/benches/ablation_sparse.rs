//! Ablation: fused vs unfused sparse UOT (paper §6 future work), and the
//! interweaving benefit as a function of density.

use map_uot::algo::sparse::{self, CsrMatrix};
use map_uot::bench::{fast_mode, measure, Policy, Table};
use map_uot::util::{Matrix, XorShift};

fn main() {
    let n = if fast_mode() { 512 } else { 4096 };
    let mut t = Table::new(
        format!("Ablation: sparse MAP-UOT at {n}x{n} (ms/iter)"),
        &["density", "nnz", "unfused 4-pass", "fused 1-pass", "speedup"],
    );
    for &density in &[0.01f32, 0.05, 0.2, 0.5] {
        let mut rng = XorShift::new(7);
        let dense = Matrix::from_fn(n, n, |_, _| {
            if rng.next_f32() < density { rng.uniform(0.1, 2.0) } else { 0.0 }
        });
        let a0 = CsrMatrix::from_dense(&dense, 0.0);
        let rpd = rng.uniform_vec(n, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);

        let mut a = a0.clone();
        let mut cs = a.col_sums();
        let policy = Policy { warmup: 1, reps: 5 };
        let unfused = measure(policy, || {
            sparse::iterate_baseline(&mut a, &mut cs, &rpd, &cpd, 0.7)
        }) * 1e3;
        let mut b = a0.clone();
        let mut cs2 = b.col_sums();
        let fused = measure(policy, || {
            sparse::iterate(&mut b, &mut cs2, &rpd, &cpd, 0.7)
        }) * 1e3;
        t.row(&[
            format!("{density}"),
            format!("{}", a0.nnz()),
            format!("{unfused:.3}"),
            format!("{fused:.3}"),
            format!("{:.2}x", unfused / fused),
        ]);
    }
    t.print();
}
