//! Ablation: fused vs unfused sparse UOT (paper §6 future work) vs the
//! dense fused kernel, across a density sweep.
//!
//! The interweaving benefit *grows* for sparse data — the unfused 4-pass
//! baseline streams `values`+`col_idx` four times per iteration, the
//! fused pass once — and the sweep locates the density below which the
//! fused CSR pass beats the dense fused kernel outright (the dense kernel
//! touches every M·N cell; CSR touches nnz cells plus an 8 B/nnz index
//! tax and gather/scatter latency, so the crossover is well below 50%).
//!
//! Emits `BENCH_sparse.json` (committed at the repo root) for the perf
//! trajectory, regardless of the invocation cwd — own env var
//! `MAP_UOT_SPARSE_JSON`, so running alongside the other benches clobbers
//! nothing. Set MAP_UOT_BENCH_FAST=1 for a quick pass.

use map_uot::algo::mapuot;
use map_uot::algo::sparse::{self, CsrMatrix};
use map_uot::bench::{fast_mode, measure, Policy, Table};
use map_uot::util::{Matrix, XorShift};

fn main() {
    let n = if fast_mode() { 256 } else { 4096 };
    let densities: &[f32] = if fast_mode() {
        &[0.05, 0.5]
    } else {
        &[0.01, 0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 0.75]
    };
    let fi = 0.7f32;
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let mut t = Table::new(
        format!("Ablation: sparse MAP-UOT at {n}x{n} (ms/iter)"),
        &["density", "nnz", "unfused 4-pass", "fused CSR", "dense fused", "vs 4-pass", "vs dense"],
    );
    let mut json_rows = String::new();
    // Crossover = the largest density below which fused CSR won at *every*
    // measured point (the first dense win truncates it), so a noisy
    // non-monotone sweep cannot overstate the break-even density.
    let mut crossover: Option<f32> = None;
    let mut dense_won = false;
    for &density in densities {
        let mut rng = XorShift::new(7);
        let dense_plan = Matrix::from_fn(n, n, |_, _| {
            if rng.next_f32() < density { rng.uniform(0.1, 2.0) } else { 0.0 }
        });
        let rpd = rng.uniform_vec(n, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);
        let a0 = CsrMatrix::from_dense(&dense_plan, 0.0).expect("finite nonnegative source");
        let nnz = a0.nnz();

        let mut a = a0.clone();
        let mut cs_a = a.col_sums();
        let unfused =
            measure(policy, || sparse::iterate_baseline(&mut a, &mut cs_a, &rpd, &cpd, fi)) * 1e3;

        let mut b = a0.clone();
        let mut cs_b = b.col_sums();
        let mut fcol = vec![0f32; n];
        let fused = measure(policy, || {
            sparse::iterate_into(&mut b, &mut cs_b, &rpd, &cpd, fi, &mut fcol)
        }) * 1e3;

        let mut d = dense_plan.clone();
        let mut cs_d = d.col_sums();
        let mut dfcol = vec![0f32; n];
        let dense_ms = measure(policy, || {
            mapuot::iterate_into(&mut d, &mut cs_d, &rpd, &cpd, fi, &mut dfcol)
        }) * 1e3;

        if fused >= dense_ms {
            dense_won = true;
        } else if !dense_won {
            crossover = Some(density);
        }
        for (variant, ms) in
            [("csr-4pass", unfused), ("csr-fused", fused), ("dense-fused", dense_ms)]
        {
            if !json_rows.is_empty() {
                json_rows.push(',');
            }
            json_rows.push_str(&format!(
                "\n    {{\"n\": {n}, \"density\": {density}, \"nnz\": {nnz}, \
                 \"variant\": \"{variant}\", \"ms_per_iter\": {ms:.4}}}"
            ));
        }
        t.row(&[
            format!("{density}"),
            format!("{nnz}"),
            format!("{unfused:.3}"),
            format!("{fused:.3}"),
            format!("{dense_ms:.3}"),
            format!("{:.2}x", unfused / fused),
            format!("{:.2}x", dense_ms / fused),
        ]);
    }
    t.print();
    match crossover {
        Some(d) => println!(
            "crossover: fused CSR beats the dense fused kernel up to density ~{d} on this host"
        ),
        None => println!("crossover: dense fused kernel won at every measured density"),
    }

    let json = format!(
        "{{\n  \"bench\": \"ablation_sparse\",\n  \"unit\": \"ms_per_iter\",\n  \"n\": {n},\n  \
         \"schema\": {{\"rows\": \"[{{n, density, nnz, variant, ms_per_iter}}]\", \
         \"variant\": \"csr-4pass | csr-fused | dense-fused\"}},\n  \"rows\": [{json_rows}\n  ]\n}}\n"
    );
    let path = std::env::var("MAP_UOT_SPARSE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sparse.json").to_string()
    });
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[ablation_sparse] wrote {path}"),
        Err(e) => eprintln!("[ablation_sparse] could not write {path}: {e}"),
    }
}
