//! `cargo bench` harness regenerating paper Figure 14.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    map_uot::bench::figures::fig14().print();
}
