//! Ablation: materialization-free scaling-form MAP-UOT vs the dense fused
//! kernel — time per iteration AND resident solver state, across shapes.
//!
//! The regenerate-vs-reload argument: a dense iteration re-streams the
//! stored plan (8 B/cell/iter at DRAM speed); matfree stores nothing and
//! spends one SIMD exp per cell instead. At overlapping shapes the sweep
//! measures where the exp ALU cost crosses the DRAM roofline; past the
//! shapes where the dense plan cannot be allocated at all (16384² is
//! already 1 GiB), matfree is the only row — which is the point: the
//! interesting column there is `resident_bytes`, not the speedup.
//!
//! Emits `BENCH_matfree.json` (committed at the repo root) regardless of
//! the invocation cwd — own env var `MAP_UOT_MATFREE_JSON`, so running
//! alongside the other benches clobbers nothing. Set MAP_UOT_BENCH_FAST=1
//! for a quick pass (CI runs that mode so the series is produced end to
//! end on every push).

use map_uot::algo::matfree::{CostKind, GeomProblem, MatfreeWorkspace};
use map_uot::algo::mapuot;
use map_uot::bench::{fast_mode, measure, Policy, Table};

fn main() {
    // (m = n, dense measured too?) — the tail rows are dense-impossible
    // (or at least dense-irresponsible) shapes where only matfree runs.
    let shapes: &[(usize, bool)] = if fast_mode() {
        &[(192, true), (384, true), (1024, false)]
    } else {
        &[(1024, true), (2048, true), (4096, true), (8192, false), (16384, false)]
    };
    let eps = 0.25f32;
    let fi = 0.7f32;
    let d = 3usize;
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let mut t = Table::new(
        "Ablation: matfree vs dense MAP-UOT (ms/iter, resident KiB)".into(),
        &["n", "variant", "ms/iter", "resident KiB", "vs dense"],
    );
    let mut json_rows = String::new();
    let mut push_row = |n: usize, variant: &str, ms: f64, bytes: usize| {
        if !json_rows.is_empty() {
            json_rows.push(',');
        }
        json_rows.push_str(&format!(
            "\n    {{\"n\": {n}, \"d\": {d}, \"variant\": \"{variant}\", \
             \"ms_per_iter\": {ms:.4}, \"resident_bytes\": {bytes}}}"
        ));
    };

    for &(n, run_dense) in shapes {
        let gp = GeomProblem::random(n, n, d, CostKind::SqEuclidean, eps, fi, 7);

        // Matfree: O(m + n) state — the scaling vectors + carried sums +
        // workspace scratch (exact bytes from the workspace itself).
        let mut ws = MatfreeWorkspace::new(n, n, 1);
        ws.prepare(n, n);
        let mut u = vec![1f32; n];
        let mut v = vec![1f32; n];
        let mut colsum = vec![0f32; n];
        let mut rowsum = vec![0f32; n];
        ws.seed_col_sums(&gp, &u, &v, &mut colsum);
        let mf_ms =
            measure(policy, || ws.iterate(&gp, &mut u, &mut v, &mut colsum, &mut rowsum)) * 1e3;
        let mf_bytes = ws.resident_bytes() + 4 * (u.len() + v.len() + colsum.len() + rowsum.len());

        let dense_cell = if run_dense {
            // Dense fused kernel on the materialized problem.
            let p = gp.dense_problem();
            let mut plan = p.plan.clone();
            let mut cs = plan.col_sums();
            let mut fcol = vec![0f32; n];
            let dense_ms = measure(policy, || {
                mapuot::iterate_into(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut fcol)
            }) * 1e3;
            let dense_bytes = n * n * 4;
            push_row(n, "dense-fused", dense_ms, dense_bytes);
            t.row(&[
                format!("{n}"),
                "dense-fused".into(),
                format!("{dense_ms:.3}"),
                format!("{:.0}", dense_bytes as f64 / 1024.0),
                "1.00x".into(),
            ]);
            Some(dense_ms)
        } else {
            t.row(&[
                format!("{n}"),
                "dense-fused".into(),
                "—".into(),
                format!("{:.0} (unallocatable here)", (n * n * 4) as f64 / 1024.0),
                "—".into(),
            ]);
            None
        };

        push_row(n, "matfree", mf_ms, mf_bytes);
        t.row(&[
            format!("{n}"),
            "matfree".into(),
            format!("{mf_ms:.3}"),
            format!("{:.0}", mf_bytes as f64 / 1024.0),
            match dense_cell {
                Some(dm) => format!("{:.2}x", dm / mf_ms),
                None => "matfree-only".into(),
            },
        ]);
    }
    t.print();
    println!(
        "\n(read-off: resident bytes are O(n) for matfree vs O(n^2) dense; time/iter trades the\n\
         dense path's 8 B/cell DRAM re-stream for one SIMD exp per cell — crossover sits near\n\
         the host's DRAM roofline, and past dense-allocatable shapes matfree is the only row)"
    );

    let json = format!(
        "{{\n  \"bench\": \"ablation_matfree\",\n  \"unit\": \"ms_per_iter\",\n  \"d\": {d},\n  \
         \"epsilon\": {eps},\n  \
         \"schema\": {{\"rows\": \"[{{n, d, variant, ms_per_iter, resident_bytes}}]\", \
         \"variant\": \"matfree | dense-fused\"}},\n  \"rows\": [{json_rows}\n  ]\n}}\n"
    );
    let path = std::env::var("MAP_UOT_MATFREE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_matfree.json").to_string()
    });
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[ablation_matfree] wrote {path}"),
        Err(e) => eprintln!("[ablation_matfree] could not write {path}: {e}"),
    }
}
