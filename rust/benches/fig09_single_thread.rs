//! `cargo bench` harness regenerating paper Figure 9.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    let (t, s) = map_uot::bench::figures::fig09();
    t.print();
    println!("summary (paper claims up to 2.9x/2.4x, avg 1.9x/1.6x): {s}");
}
