//! Ablation: the exact near-linear 1D sweep (`algo::oned`) vs the
//! materialization-free O(m·n) sweep vs the dense fused kernel — time per
//! iteration AND resident solver state, m = n from 1k into the millions.
//!
//! The asymptotic argument: one oned iteration is two prefix/suffix decay
//! recursions over the sorted supports — O(m + n) work, O(m + n) state —
//! where matfree spends one exp per *cell* (O(m·n), no state) and dense
//! re-streams a stored plan (O(m·n) work and state). The crossover is
//! therefore not a roofline question but a complexity-class one: oned
//! wins by ~n/const at every shape where it is admissible, and the tail
//! rows (dense unallocatable, matfree unaffordable) are oned-only — which
//! is the point of the fast path.
//!
//! Emits `BENCH_oned.json` (committed at the repo root) regardless of the
//! invocation cwd — own env var `MAP_UOT_ONED_JSON`, so running alongside
//! the other benches clobbers nothing. Set MAP_UOT_BENCH_FAST=1 for a
//! quick pass (CI runs that mode so the series is produced end to end on
//! every push).

use map_uot::algo::mapuot;
use map_uot::algo::matfree::{CostKind, GeomProblem, MatfreeWorkspace};
use map_uot::algo::oned::{OnedWorkspace, TransportList};
use map_uot::bench::{fast_mode, measure, Policy, Table};

fn main() {
    // (m = n, dense measured?, matfree measured?) — the tail rows are the
    // shapes where only the exact 1D sweep is affordable at all.
    let shapes: &[(usize, bool, bool)] = if fast_mode() {
        &[(512, true, true), (4_096, false, true), (65_536, false, false)]
    } else {
        &[
            (1_024, true, true),
            (4_096, true, true),
            (16_384, false, true),
            (262_144, false, false),
            (1_048_576, false, false),
            (4_194_304, false, false),
        ]
    };
    let eps = 0.25f32;
    let fi = 0.7f32;
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let mut t = Table::new(
        "Ablation: exact 1D sweep vs matfree vs dense (ms/iter, resident KiB)".into(),
        &["n", "variant", "ms/iter", "resident KiB", "vs oned"],
    );
    let mut json_rows = String::new();
    let mut push_row = |n: usize, variant: &str, ms: f64, bytes: usize| {
        if !json_rows.is_empty() {
            json_rows.push(',');
        }
        json_rows.push_str(&format!(
            "\n    {{\"n\": {n}, \"variant\": \"{variant}\", \
             \"ms_per_iter\": {ms:.5}, \"resident_bytes\": {bytes}}}"
        ));
    };

    for &(n, run_dense, run_matfree) in shapes {
        let gp = GeomProblem::random(n, n, 1, CostKind::Euclidean, eps, fi, 7);

        // Exact 1D sweep: O(m + n) state (sorted positions/orders, f64
        // accumulators, carried sums) and O(m + n) work per iteration.
        let mut ws = OnedWorkspace::new(n, n);
        ws.prepare(&gp).expect("1D Euclidean geometry is eligible");
        let mut u = vec![1f32; n];
        let mut v = vec![1f32; n];
        let mut colsum = vec![0f32; n];
        let mut rowsum = vec![0f32; n];
        ws.seed_col_sums(&gp, &u, &v, &mut colsum);
        let oned_ms =
            measure(policy, || ws.iterate(&gp, &mut u, &mut v, &mut colsum, &mut rowsum)) * 1e3;
        let mut transport = TransportList::default();
        transport.reserve_for(n, n);
        let oned_bytes = ws.resident_bytes()
            + 4 * (u.len() + v.len() + colsum.len() + rowsum.len())
            + 12 * (n + n);
        push_row(n, "oned", oned_ms, oned_bytes);
        t.row(&[
            format!("{n}"),
            "oned".into(),
            format!("{oned_ms:.4}"),
            format!("{:.0}", oned_bytes as f64 / 1024.0),
            "1.00x".into(),
        ]);

        if run_matfree {
            let mut mws = MatfreeWorkspace::new(n, n, 1);
            mws.prepare(n, n);
            let mut mu = vec![1f32; n];
            let mut mv = vec![1f32; n];
            let mut mcol = vec![0f32; n];
            let mut mrow = vec![0f32; n];
            mws.seed_col_sums(&gp, &mu, &mv, &mut mcol);
            let mf_ms = measure(policy, || {
                mws.iterate(&gp, &mut mu, &mut mv, &mut mcol, &mut mrow)
            }) * 1e3;
            let mf_bytes = mws.resident_bytes() + 4 * (4 * n);
            push_row(n, "matfree", mf_ms, mf_bytes);
            t.row(&[
                format!("{n}"),
                "matfree".into(),
                format!("{mf_ms:.3}"),
                format!("{:.0}", mf_bytes as f64 / 1024.0),
                format!("{:.0}x", mf_ms / oned_ms),
            ]);
        } else {
            t.row(&[
                format!("{n}"),
                "matfree".into(),
                "— (O(n^2) sweep unaffordable here)".into(),
                "—".into(),
                "—".into(),
            ]);
        }

        if run_dense {
            let p = gp.dense_problem();
            let mut plan = p.plan.clone();
            let mut cs = plan.col_sums();
            let mut fcol = vec![0f32; n];
            let dense_ms = measure(policy, || {
                mapuot::iterate_into(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut fcol)
            }) * 1e3;
            let dense_bytes = n * n * 4;
            push_row(n, "dense-fused", dense_ms, dense_bytes);
            t.row(&[
                format!("{n}"),
                "dense-fused".into(),
                format!("{dense_ms:.3}"),
                format!("{:.0}", dense_bytes as f64 / 1024.0),
                format!("{:.0}x", dense_ms / oned_ms),
            ]);
        } else {
            t.row(&[
                format!("{n}"),
                "dense-fused".into(),
                "—".into(),
                format!("{:.0} (unallocatable here)", (n as f64) * (n as f64) * 4.0 / 1024.0),
                "—".into(),
            ]);
        }
    }
    t.print();
    println!(
        "\n(read-off: the gap is a complexity class, not a roofline — oned does O(n) work per\n\
         iteration against O(n^2) for both dense and matfree, so the speedup itself grows ~n\n\
         and the exact-vs-iterative crossover sits at the smallest measured shape; the tail\n\
         rows are oned-only because nothing else fits in time or memory at m = n in the millions)"
    );

    let json = format!(
        "{{\n  \"bench\": \"ablation_oned\",\n  \"unit\": \"ms_per_iter\",\n  \"d\": 1,\n  \
         \"epsilon\": {eps},\n  \
         \"schema\": {{\"rows\": \"[{{n, variant, ms_per_iter, resident_bytes}}]\", \
         \"variant\": \"oned | matfree | dense-fused\"}},\n  \"rows\": [{json_rows}\n  ]\n}}\n"
    );
    let path = std::env::var("MAP_UOT_ONED_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_oned.json").to_string()
    });
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[ablation_oned] wrote {path}"),
        Err(e) => eprintln!("[ablation_oned] could not write {path}: {e}"),
    }
}
