//! Ablation: double precision (paper §5.1 — "similar performance
//! improvement when using double-precision"). The MAP-UOT/POT ratio must
//! survive the f32→f64 switch; absolute times grow with the byte traffic.

use map_uot::algo::{self, fp64, SolverKind};
use map_uot::bench::{fast_mode, measure, Policy, Table};

fn main() {
    let s = if fast_mode() { 512 } else { 4096 };
    let policy = Policy { warmup: 1, reps: 5 };
    let mut t = Table::new(
        format!("Ablation: FP64 at {s}x{s} (ms/iter)"),
        &["precision", "POT", "MAP-UOT", "speedup"],
    );

    // f32 row.
    let p = algo::Problem::random(s, s, 0.7, 1);
    let mut ws = algo::Workspace::new(s, s, 1);
    let mut plan = p.plan.clone();
    let mut cs = plan.col_sums();
    let pot_solver = algo::solver_for(SolverKind::Pot);
    let pot32 = measure(policy, || {
        pot_solver.iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, p.fi, &mut ws)
    }) * 1e3;
    let mut plan2 = p.plan.clone();
    let mut cs2 = plan2.col_sums();
    let map_solver = algo::solver_for(SolverKind::MapUot);
    let map32 = measure(policy, || {
        map_solver.iterate(&mut plan2, &mut cs2, &p.rpd, &p.cpd, p.fi, &mut ws)
    }) * 1e3;
    t.row(&["f32".into(), format!("{pot32:.2}"), format!("{map32:.2}"), format!("{:.2}x", pot32 / map32)]);

    // f64 row.
    let (plan0, rpd, cpd) = fp64::random_problem(s, s, 1);
    let colsums = |pl: &[f64]| {
        let mut out = vec![0f64; s];
        for row in pl.chunks_exact(s) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        out
    };
    let mut a = plan0.clone();
    let mut csa = colsums(&a);
    let pot64 = measure(policy, || fp64::pot_iterate(&mut a, s, &mut csa, &rpd, &cpd, 0.7)) * 1e3;
    let mut b = plan0;
    let mut csb = colsums(&b);
    // Hoisted column-factor scratch (PR 1 allocation contract): the loop
    // times the fused sweep, not a per-iteration Vec allocation. POT keeps
    // its allocating 4-pass body by design — it models the unfused
    // baseline's execution, allocations included.
    let mut fcol64 = vec![0f64; s];
    let map64 = measure(policy, || {
        fp64::mapuot_iterate_into(&mut b, s, &mut csb, &rpd, &cpd, 0.7, &mut fcol64)
    }) * 1e3;
    t.row(&["f64".into(), format!("{pot64:.2}"), format!("{map64:.2}"), format!("{:.2}x", pot64 / map64)]);

    t.print();
    println!("\n(paper §5.1: the improvement ratio is precision-independent — traffic scales");
    println!(" by 2x for every solver alike)");
}
