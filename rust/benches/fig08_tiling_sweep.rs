//! `cargo bench` harness regenerating paper Figure 8.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    let (a, b) = map_uot::bench::figures::fig08();
    a.print();
    b.print();
}
