//! `cargo bench` harness for the tiling sweep: the paper's Figure 8 GPU
//! tables (3090 Ti model) plus the **measured CPU tiled kernel** — MAP-UOT
//! ms/iter across shapes × tile widths × kernel backends on this host.
//! Emits `BENCH_tiling.json` for the perf trajectory. Thin wrapper over
//! `map_uot::bench::figures` (criterion is unavailable offline; see
//! DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    // The bench harness (unlike the side-effect-free CLI) emits the
    // machine-readable series by default — into the committed repo-root
    // snapshot, regardless of the invocation cwd (CI runs from rust/).
    // Own env var, distinct from fig12's MAP_UOT_BENCH_JSON, so running
    // both benches in one process clobbers neither series.
    if std::env::var("MAP_UOT_TILING_JSON").is_err() {
        std::env::set_var(
            "MAP_UOT_TILING_JSON",
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_tiling.json"),
        );
    }
    let (a, b) = map_uot::bench::figures::fig08();
    a.print();
    b.print();
    map_uot::bench::figures::fig08_cpu().print();
}
