//! Ablation: iteration-count accelerators under a drifting request stream.
//!
//! The fused sweep already runs at the Roofline minimum of 2 accesses per
//! element, so the remaining lever is the *number* of sweeps. This bench
//! replays the service scenario those accelerators target: a stream of
//! geometric requests whose marginals drift slowly (tracking filters,
//! frame-to-frame color transfer, minibatched domain adaptation), served
//! by a matfree-enabled `Service` in four configurations —
//!
//!   cold           every request solved from u = v = 1
//!   warm           per-worker warm-start cache seeds from the previous
//!                  converged scaling (`[solver] warm`)
//!   warm+ti        plus translation-invariant sweeps (`[solver] ti`)
//!   warm+ti+sched  plus the ε ladder for cache misses
//!                  (`[solver] eps_schedule`)
//!
//! Reported per variant: mean iterations-to-tolerance (from the
//! coordinator's per-request iteration histogram) and p99 latency. Emits
//! `BENCH_warmstart.json` at the repo root regardless of cwd — env
//! override `MAP_UOT_WARMSTART_JSON`; set MAP_UOT_BENCH_FAST=1 for the
//! quick CI pass.

use map_uot::algo::{CostKind, GeomProblem, SolverKind};
use map_uot::bench::{fast_mode, Table};
use map_uot::config::ServiceConfig;
use map_uot::coordinator::Service;

/// The drifting stream: one base geometry, marginals modulated smoothly
/// per request (total mass drifts too — the mode TI corrects).
fn stream(n: usize, requests: usize) -> Vec<GeomProblem> {
    let base = GeomProblem::random(n, n, 3, CostKind::SqEuclidean, 0.25, 0.5, 7);
    (0..requests)
        .map(|k| {
            let mut p = base.clone();
            let phase = k as f32 / requests as f32 * std::f32::consts::TAU;
            let row_scale = 1.0 + 0.20 * phase.sin();
            let col_scale = 1.0 + 0.15 * (phase * 1.7).cos();
            for r in p.rpd.iter_mut() {
                *r *= row_scale;
            }
            for c in p.cpd.iter_mut() {
                *c *= col_scale;
            }
            p
        })
        .collect()
}

struct VariantResult {
    name: &'static str,
    mean_iters: f64,
    total_iters: u64,
    p99_ms: f64,
}

fn run_variant(
    name: &'static str,
    warm: usize,
    ti: bool,
    eps_schedule: Option<(f32, usize)>,
    problems: &[GeomProblem],
) -> VariantResult {
    // One worker so one session (and its warm cache) serves the whole
    // stream — the steady state of a pinned shard.
    let cfg = ServiceConfig {
        workers: 1,
        solver: SolverKind::MapUot,
        matfree: true,
        warm,
        ti,
        eps_schedule,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg).expect("bench service config is valid");
    for p in problems {
        // Sequential blocking submits: iteration counts must reflect the
        // drift order, not batching luck.
        let solved = svc.solve_geom_blocking(p.clone()).expect("bench problems solve");
        assert!(solved.report.converged, "{name}: stream request failed to converge");
    }
    let m = svc.metrics();
    let out = VariantResult {
        name,
        mean_iters: m.mean_iters(),
        total_iters: m.iterations,
        p99_ms: m.latency_percentile_ms(99.0),
    };
    svc.shutdown();
    out
}

fn main() {
    let (n, requests) = if fast_mode() { (48, 12) } else { (256, 64) };
    let problems = stream(n, requests);
    // The ladder starts 4x above the target bandwidth; two rungs.
    let sched = Some((1.0f32, 2usize));

    let variants = [
        run_variant("cold", 0, false, None, &problems),
        run_variant("warm", 8, false, None, &problems),
        run_variant("warm+ti", 8, true, None, &problems),
        run_variant("warm+ti+sched", 8, true, sched, &problems),
    ];

    let cold_mean = variants[0].mean_iters;
    let mut t = Table::new(
        format!("Ablation: warm-start / TI / ε-schedule ({n}x{n}, {requests} drifting requests)"),
        &["variant", "mean iters", "total iters", "p99 ms", "iters vs cold"],
    );
    let mut json_rows = String::new();
    for v in &variants {
        let speedup = if v.mean_iters > 0.0 { cold_mean / v.mean_iters } else { 0.0 };
        t.row(&[
            v.name.into(),
            format!("{:.1}", v.mean_iters),
            format!("{}", v.total_iters),
            format!("{:.2}", v.p99_ms),
            format!("{speedup:.2}x"),
        ]);
        if !json_rows.is_empty() {
            json_rows.push(',');
        }
        json_rows.push_str(&format!(
            "\n    {{\"variant\": \"{}\", \"mean_iters\": {:.3}, \"total_iters\": {}, \
             \"p99_ms\": {:.4}, \"iters_speedup_vs_cold\": {:.3}}}",
            v.name, v.mean_iters, v.total_iters, v.p99_ms, speedup
        ));
    }
    t.print();
    println!(
        "\n(read-off: cold pays the full transient on every request; warm re-enters near the\n\
         previous fixed point, TI removes the global-mass mode the marginal drift excites on\n\
         top of it, and the ladder only helps the cache-miss requests — so the headline\n\
         number is the warm+ti row's iters-vs-cold, expected >= 2x on this stream)"
    );

    let json = format!(
        "{{\n  \"bench\": \"ablation_warmstart\",\n  \"unit\": \"mean_iters_to_tolerance\",\n  \
         \"n\": {n},\n  \"requests\": {requests},\n  \
         \"schema\": {{\"rows\": \"[{{variant, mean_iters, total_iters, p99_ms, \
         iters_speedup_vs_cold}}]\", \
         \"variant\": \"cold | warm | warm+ti | warm+ti+sched\"}},\n  \"rows\": [{json_rows}\n  ]\n}}\n"
    );
    let path = std::env::var("MAP_UOT_WARMSTART_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_warmstart.json").to_string()
    });
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[ablation_warmstart] wrote {path}"),
        Err(e) => eprintln!("[ablation_warmstart] could not write {path}: {e}"),
    }
}
