//! `cargo bench` harness regenerating paper Figure 13.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    let (t, s) = map_uot::bench::figures::fig13();
    t.print();
    println!("summary (paper claims up to 3.5x, avg 1.6x): {s}");
}
