//! `cargo bench` harness regenerating paper Figure 12, plus the measured
//! Fig. 12b companion: spawn-per-iteration vs persistent-pool backends ×
//! thread counts, and the accumulator ablation (padded arena vs packed
//! arena vs `Vec<Vec<f32>>`). Emits `BENCH_pool.json` (iters/sec per
//! backend × thread count) for the perf trajectory.
//! Thin wrapper over `map_uot::bench::figures` (criterion is unavailable
//! offline; see DESIGN.md). Set MAP_UOT_BENCH_FAST=1 for a quick pass.

fn main() {
    // The bench harness (unlike the side-effect-free CLI) emits the
    // machine-readable series by default.
    if std::env::var("MAP_UOT_BENCH_JSON").is_err() {
        std::env::set_var("MAP_UOT_BENCH_JSON", "BENCH_pool.json");
    }
    map_uot::bench::figures::fig12().print();
    map_uot::bench::figures::fig12_pool().print();
}
