//! Minimal key-value config parser (TOML subset).
//!
//! No serde/toml crates are available offline, so the service config file
//! format is a deliberately small TOML subset: `[section]` headers,
//! `key = value` lines (string / integer / float / bool), `#` comments.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed config: section -> key -> raw value string.
#[derive(Debug, Default, Clone)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut out = RawConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`: {raw:?}", lineno + 1))
            })?;
            let value = v.trim().trim_matches('"').to_string();
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(out)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key}: cannot parse {s:?}"))
            }),
        }
    }

    /// Section names present.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# service config
[coordinator]
workers = 4
batch_max = 8           # requests per batch
backend = "native"

[solver]
fi = 0.7
tol = 1e-4
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.get_or("coordinator", "workers", 1usize).unwrap(), 4);
        assert_eq!(c.get_or("coordinator", "batch_max", 1usize).unwrap(), 8);
        assert_eq!(c.get("coordinator", "backend"), Some("native"));
        assert!((c.get_or("solver", "fi", 0.0f32).unwrap() - 0.7).abs() < 1e-6);
        assert!((c.get_or("solver", "tol", 0.0f64).unwrap() - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.get_or("coordinator", "absent", 9usize).unwrap(), 9);
        assert_eq!(c.get_or("absent", "absent", 3i32).unwrap(), 3);
    }

    #[test]
    fn bad_lines_error() {
        assert!(RawConfig::parse("[x]\nnot a kv line").is_err());
        assert!(RawConfig::parse("[s]\nk = notanum")
            .unwrap()
            .get_or("s", "k", 0i64)
            .is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = RawConfig::parse("# only comments\n\n   \n").unwrap();
        assert_eq!(c.sections().count(), 0);
    }
}
