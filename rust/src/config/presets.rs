//! Hardware presets — paper Table 1.

use crate::sim::cache::{CacheConfig, HierarchyConfig};
use crate::sim::cluster::ClusterConfig;
use crate::sim::gpu::GpuConfig;
use crate::sim::roofline::Machine;

/// 12th Gen Intel Core i9-12900K (Table 1, top): 793.6 GFLOPS FP32 peak,
/// 76.8 GB/s DRAM bandwidth.
pub fn i9_12900k_roofline() -> Machine {
    Machine { name: "i9-12900K", peak_gflops: 793.6, peak_bw_gbs: 76.8 }
}

/// NVIDIA GeForce RTX 3090 Ti (Table 1, middle): 40 TFLOPS FP32,
/// 1008 GB/s GDDR6X.
pub fn rtx_3090ti_roofline() -> Machine {
    Machine { name: "RTX 3090 Ti", peak_gflops: 40_000.0, peak_bw_gbs: 1008.0 }
}

/// Golden Cove P-core cache hierarchy of the 12900K:
/// L1D 48 KiB / 12-way, L2 1.25 MiB / 10-way, 64-byte lines.
pub fn i9_12900k_caches() -> HierarchyConfig {
    HierarchyConfig {
        l1: CacheConfig { size_bytes: 48 * 1024, line_bytes: 64, assoc: 12 },
        l2: CacheConfig { size_bytes: 1280 * 1024, line_bytes: 64, assoc: 10 },
        // Degree-16 miss-triggered L2 streamer (≈ the measured single-digit
        // L2 miss rates of sequential sweeps in paper Fig. 4).
        l2_prefetch: 16,
    }
}

/// RTX 3090 Ti execution parameters (Table 1 + GA102 whitepaper values).
pub fn rtx_3090ti_gpu() -> GpuConfig {
    GpuConfig {
        name: "RTX 3090 Ti",
        peak_bw_gbs: 1008.0,
        peak_gflops: 40_000.0,
        sm_count: 84,
        max_threads_per_sm: 1536,
        warp_size: 32,
        // Calibrated micro-costs (DESIGN.md §Substitutions): per-kernel
        // launch, per-block scheduling slot, per-conflicting-atomic
        // serialization, per-warp shuffle-reduce step.
        kernel_launch_us: 4.0,
        block_sched_ns: 150.0,
        atomic_conflict_ns: 12.0,
        smem_reduce_ns_per_step: 6.0,
        // Framework (CuPy / driver) baseline device-memory overhead, MB.
        context_mb: 120.0,
    }
}

/// Tianhe-1 node/network model (Table 1, bottom): 12-core Intel Xeon
/// Westmere nodes, 32 GB RAM, Infiniband QDR.
pub fn tianhe1_cluster(procs_per_node: usize) -> ClusterConfig {
    ClusterConfig {
        procs_per_node,
        // Westmere 3-channel DDR3-1066: ~25.6 GB/s per node, shared.
        node_bw_gbs: 25.6,
        // Per-process sustained compute-side throughput cap (elements/s of
        // matrix traffic it can issue when bandwidth-unconstrained).
        proc_gelems_per_s: 1.0,
        // Infiniband QDR 4x: 4 GB/s raw, ~1 GB/s effective through the
        // mpi4py + pickle path the paper uses (Smith, PyHPC'16).
        link_bw_gbs: 1.0,
        // MPI small-message latency (alpha in the Thakur model), inflated
        // by the mpi4py dispatch path.
        alpha_us: 20.0,
        // Per-iteration serial overhead of the mpi4py driver loop (µs).
        py_overhead_us: 1500.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let c = i9_12900k_roofline();
        assert_eq!(c.peak_gflops, 793.6);
        assert_eq!(c.peak_bw_gbs, 76.8);
        let g = rtx_3090ti_roofline();
        assert_eq!(g.peak_bw_gbs, 1008.0);
        let h = i9_12900k_caches();
        assert_eq!(h.l1.size_bytes, 48 * 1024);
        assert!(h.l1.size_bytes < h.l2.size_bytes);
    }

    #[test]
    fn cache_geometry_is_consistent() {
        let h = i9_12900k_caches();
        for c in [h.l1, h.l2] {
            assert_eq!(c.size_bytes % (c.line_bytes * c.assoc), 0);
        }
    }
}
