//! Configuration: hardware presets (Table 1), service config file parsing.

pub mod parser;
pub mod presets;

use crate::algo::{AffinityHint, KernelKind, ParallelBackend, SolverKind, StopRule, TileSpec};
use crate::error::Result;
use parser::RawConfig;

/// Which execution backend the coordinator routes a request to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust solvers (`algo/`).
    Native,
    /// AOT-compiled HLO artifacts through PJRT (`runtime/`).
    Pjrt,
}

/// Routing policy for the exact near-linear 1D fast path (config key
/// `[solver] oned = auto|on|off`, CLI `solve --oned auto|on|off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnedMode {
    /// Classify each geometric request (`coordinator::router::classify_geom`)
    /// and take the exact 1D sweep when eligible, silently falling back to
    /// the iterative matfree path otherwise. The default: eligible requests
    /// get the near-linear solve for free, nothing is ever rejected.
    Auto,
    /// Require the 1D path: an ineligible request (d > 1 with more than one
    /// varying axis, or a non-factoring cost) fails with a typed
    /// per-request error instead of falling back.
    On,
    /// Never route to the 1D path, even for eligible requests.
    Off,
}

impl OnedMode {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(OnedMode::Auto),
            "on" | "true" | "1" => Some(OnedMode::On),
            "off" | "false" | "0" | "none" => Some(OnedMode::Off),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OnedMode::Auto => "auto",
            OnedMode::On => "on",
            OnedMode::Off => "off",
        }
    }
}

/// Full service configuration (coordinator + solver defaults).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Max requests drained into one batch.
    pub batch_max: usize,
    /// Max time the batcher waits to fill a batch (microseconds).
    pub batch_wait_us: u64,
    /// Bounded queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Execution backend.
    pub backend: Backend,
    /// Default solver for native execution.
    pub solver: SolverKind,
    /// Threads per native solve.
    pub solver_threads: usize,
    /// Parallel engine for threaded native solves (each coordinator worker
    /// keeps one persistent pool for its whole life under `Pool`).
    pub parallel: ParallelBackend,
    /// Core-affinity hint for pool workers.
    pub affinity: AffinityHint,
    /// Kernel backend for the MAP-UOT hot path (config key
    /// `[solver] kernel = auto|scalar|unrolled|avx2`).
    pub kernel: KernelKind,
    /// Column-tiling policy for the fused sweep (config key
    /// `[solver] tile = auto|off|tune|<cols>`).
    pub tile: TileSpec,
    /// Sparse-solve threshold (config key `[solver] sparse = <threshold>`,
    /// or `off`). When set, native workers convert each request's plan to
    /// CSR (dropping entries `<= threshold`) and solve through the fused
    /// CSR backend; requires `kind = mapuot` (validated at service start).
    pub sparse: Option<f32>,
    /// Materialization-free backend (config key `[solver] matfree =
    /// on|off`). When on, the service accepts geometric point-cloud
    /// requests (`Service::submit_geom`) and solves them on the
    /// scaling-form backend — O(m+n) solver state, densified responses at
    /// the boundary. Requires `kind = mapuot`, the native backend, and no
    /// `sparse` threshold (validated at `Service::start`).
    pub matfree: bool,
    /// Exact 1D fast-path routing policy (config key `[solver] oned =
    /// auto|on|off`). `auto` (default) classifies each geometric request
    /// and takes the near-linear exact sweep when the geometry is 1D
    /// (`d == 1`, or effectively 1D) under the Euclidean cost, falling
    /// back to matfree otherwise; `on` makes ineligibility a typed
    /// per-request error; `off` disables the path. `on` requires
    /// `matfree = on` — geometric requests enter through the matfree
    /// protocol (validated at `Service::start`).
    pub oned: OnedMode,
    /// Warm-start cache capacity per worker session (config key
    /// `[solver] warm = <entries>` or `off`). `0` disables warm starting;
    /// `cap > 0` seeds each solve from the nearest cached converged
    /// scaling (see `algo::warmstart`).
    pub warm: usize,
    /// Translation-invariant sweeps (config key `[solver] ti = on|off`).
    /// Requires `kind = mapuot` (validated at `Service::start`).
    pub ti: bool,
    /// ε-schedule for matfree solves (config key
    /// `[solver] eps_schedule = <from>:<steps>`, or `off`): a geometric
    /// coarse-to-fine bandwidth ladder from `from` down to each problem's
    /// ε. Requires `matfree = on` (validated at `Service::start`).
    pub eps_schedule: Option<(f32, usize)>,
    /// Stopping criteria.
    pub stop: StopRule,
    /// Span-trace export path (config key `[solver] trace = <path>`, or
    /// `off`; CLI `serve`/`solve --trace <path>`). When set the service
    /// enables in-band telemetry (`util::telemetry`) at start and exports
    /// the recorded spans on shutdown — chrome://tracing JSON, or JSONL
    /// events when the path ends in `.jsonl`.
    pub trace: Option<String>,
    /// Artifact directory for the PJRT backend.
    pub artifacts_dir: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            batch_max: 8,
            batch_wait_us: 200,
            queue_cap: 1024,
            backend: Backend::Native,
            solver: SolverKind::MapUot,
            solver_threads: 1,
            parallel: ParallelBackend::Pool,
            affinity: AffinityHint::None,
            kernel: KernelKind::Auto,
            tile: TileSpec::Auto,
            sparse: None,
            matfree: false,
            oned: OnedMode::Auto,
            warm: 0,
            ti: false,
            eps_schedule: None,
            stop: StopRule::default(),
            trace: None,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ServiceConfig {
    /// Load from the TOML-subset config format (see [`parser`]).
    pub fn from_raw(c: &RawConfig) -> Result<Self> {
        let d = ServiceConfig::default();
        let backend = match c.get("coordinator", "backend") {
            Some("pjrt") => Backend::Pjrt,
            Some("native") | None => Backend::Native,
            Some(other) => {
                return Err(crate::error::Error::Config(format!("unknown backend {other:?}")))
            }
        };
        let solver = match c.get("solver", "kind") {
            None => d.solver,
            Some(s) => SolverKind::parse(s)
                .ok_or_else(|| crate::error::Error::Config(format!("unknown solver {s:?}")))?,
        };
        let parallel = match c.get("solver", "parallel") {
            None => d.parallel,
            Some(s) => ParallelBackend::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("unknown parallel backend {s:?}"))
            })?,
        };
        let affinity = if c.get_or("solver", "pin", false)? {
            AffinityHint::Pinned
        } else {
            AffinityHint::None
        };
        let kernel = match c.get("solver", "kernel") {
            None => d.kernel,
            Some(s) => KernelKind::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("unknown kernel backend {s:?}"))
            })?,
        };
        let tile = match c.get("solver", "tile") {
            None => d.tile,
            Some(s) => TileSpec::parse(s)
                .ok_or_else(|| crate::error::Error::Config(format!("unknown tile policy {s:?}")))?,
        };
        let matfree = match c.get("solver", "matfree") {
            None => d.matfree,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" | "none" => false,
                _ => {
                    return Err(crate::error::Error::Config(format!(
                        "invalid matfree setting {s:?} (expected on|off)"
                    )))
                }
            },
        };
        let oned = match c.get("solver", "oned") {
            None => d.oned,
            Some(s) => OnedMode::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!(
                    "invalid oned setting {s:?} (expected auto|on|off)"
                ))
            })?,
        };
        let sparse = match c.get("solver", "sparse") {
            None => d.sparse,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "off" | "none" => None,
                raw => {
                    let t = raw.parse::<f32>().map_err(|_| {
                        crate::error::Error::Config(format!("invalid sparse threshold {s:?}"))
                    })?;
                    if !t.is_finite() || t < 0.0 {
                        return Err(crate::error::Error::Config(format!(
                            "sparse threshold {s:?} must be finite and >= 0"
                        )));
                    }
                    Some(t)
                }
            },
        };
        let warm = match c.get("solver", "warm") {
            None => d.warm,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "off" | "none" => 0,
                raw => raw.parse::<usize>().map_err(|_| {
                    crate::error::Error::Config(format!(
                        "invalid warm cache capacity {s:?} (expected a count or off)"
                    ))
                })?,
            },
        };
        let ti = match c.get("solver", "ti") {
            None => d.ti,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" | "none" => false,
                _ => {
                    return Err(crate::error::Error::Config(format!(
                        "invalid ti setting {s:?} (expected on|off)"
                    )))
                }
            },
        };
        let eps_schedule = match c.get("solver", "eps_schedule") {
            None => d.eps_schedule,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "off" | "none" => None,
                raw => {
                    let (from_s, steps_s) = raw.split_once(':').ok_or_else(|| {
                        crate::error::Error::Config(format!(
                            "invalid eps_schedule {s:?} (expected <from>:<steps>, e.g. 1.0:3)"
                        ))
                    })?;
                    let from = from_s.parse::<f32>().map_err(|_| {
                        crate::error::Error::Config(format!(
                            "invalid eps_schedule start bandwidth {from_s:?}"
                        ))
                    })?;
                    let steps = steps_s.parse::<usize>().map_err(|_| {
                        crate::error::Error::Config(format!(
                            "invalid eps_schedule rung count {steps_s:?}"
                        ))
                    })?;
                    if !(from.is_finite() && from > 0.0) {
                        return Err(crate::error::Error::Config(format!(
                            "eps_schedule start bandwidth {from_s:?} must be finite and > 0"
                        )));
                    }
                    if steps == 0 {
                        return Err(crate::error::Error::Config(
                            "eps_schedule needs at least one coarse rung (steps >= 1)".into(),
                        ));
                    }
                    Some((from, steps))
                }
            },
        };
        let trace = match c.get("solver", "trace") {
            None => d.trace,
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "off" | "none" => None,
                _ => Some(s.to_string()),
            },
        };
        Ok(Self {
            workers: c.get_or("coordinator", "workers", d.workers)?,
            batch_max: c.get_or("coordinator", "batch_max", d.batch_max)?,
            batch_wait_us: c.get_or("coordinator", "batch_wait_us", d.batch_wait_us)?,
            queue_cap: c.get_or("coordinator", "queue_cap", d.queue_cap)?,
            backend,
            solver,
            solver_threads: c.get_or("solver", "threads", d.solver_threads)?,
            parallel,
            affinity,
            kernel,
            tile,
            sparse,
            matfree,
            oned,
            warm,
            ti,
            eps_schedule,
            trace,
            stop: StopRule {
                tol: c.get_or("solver", "tol", d.stop.tol)?,
                delta_tol: c.get_or("solver", "delta_tol", d.stop.delta_tol)?,
                max_iter: c.get_or("solver", "max_iter", d.stop.max_iter)?,
            },
            artifacts_dir: c
                .get("runtime", "artifacts_dir")
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
        })
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_raw(&RawConfig::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_raw_full() {
        let raw = parser::RawConfig::parse(
            "[coordinator]\nworkers=3\nbackend=pjrt\n\
             [solver]\nkind=coffee\nthreads=2\nmax_iter=50\nparallel=spawn\npin=true\n\
             kernel=scalar\ntile=512\n",
        )
        .unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.backend, Backend::Pjrt);
        assert_eq!(c.solver, SolverKind::Coffee);
        assert_eq!(c.solver_threads, 2);
        assert_eq!(c.parallel, ParallelBackend::SpawnPerIter);
        assert_eq!(c.affinity, AffinityHint::Pinned);
        assert_eq!(c.kernel, KernelKind::Scalar);
        assert_eq!(c.tile, TileSpec::Cols(512));
        assert_eq!(c.stop.max_iter, 50);
    }

    #[test]
    fn kernel_and_tile_default_and_reject() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.kernel, KernelKind::Auto);
        assert_eq!(c.tile, TileSpec::Auto);
        let raw = parser::RawConfig::parse("[solver]\nkernel=sse9\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = parser::RawConfig::parse("[solver]\ntile=wide\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = parser::RawConfig::parse("[solver]\nkernel=avx2\ntile=off\n").unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.kernel, KernelKind::Avx2);
        assert_eq!(c.tile, TileSpec::Off);
    }

    #[test]
    fn sparse_threshold_parses_and_rejects() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.sparse, None, "sparse path is opt-in");
        let raw = parser::RawConfig::parse("[solver]\nsparse=0.25\n").unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.sparse, Some(0.25));
        let raw = parser::RawConfig::parse("[solver]\nsparse=off\n").unwrap();
        assert_eq!(ServiceConfig::from_raw(&raw).unwrap().sparse, None);
        for bad in ["wide", "-0.5", "nan", "inf"] {
            let raw = parser::RawConfig::parse(&format!("[solver]\nsparse={bad}\n")).unwrap();
            assert!(ServiceConfig::from_raw(&raw).is_err(), "sparse={bad} must be rejected");
        }
    }

    #[test]
    fn matfree_parses_and_rejects() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert!(!c.matfree, "matfree is opt-in");
        for on in ["on", "true", "1"] {
            let raw = parser::RawConfig::parse(&format!("[solver]\nmatfree={on}\n")).unwrap();
            assert!(ServiceConfig::from_raw(&raw).unwrap().matfree, "matfree={on}");
        }
        for off in ["off", "false", "0", "none"] {
            let raw = parser::RawConfig::parse(&format!("[solver]\nmatfree={off}\n")).unwrap();
            assert!(!ServiceConfig::from_raw(&raw).unwrap().matfree, "matfree={off}");
        }
        let raw = parser::RawConfig::parse("[solver]\nmatfree=0.5\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err(), "matfree takes on|off, not a number");
    }

    #[test]
    fn oned_parses_and_rejects() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.oned, OnedMode::Auto, "auto-routing is the default");
        for (s, want) in [
            ("auto", OnedMode::Auto),
            ("on", OnedMode::On),
            ("true", OnedMode::On),
            ("off", OnedMode::Off),
            ("none", OnedMode::Off),
        ] {
            let raw = parser::RawConfig::parse(&format!("[solver]\noned={s}\n")).unwrap();
            assert_eq!(ServiceConfig::from_raw(&raw).unwrap().oned, want, "oned={s}");
        }
        let raw = parser::RawConfig::parse("[solver]\noned=maybe\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err(), "oned takes auto|on|off");
    }

    #[test]
    fn warm_ti_and_eps_schedule_parse_and_reject() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.warm, 0, "warm starting is opt-in");
        assert!(!c.ti, "TI is opt-in");
        assert_eq!(c.eps_schedule, None, "eps scheduling is opt-in");

        let raw =
            parser::RawConfig::parse("[solver]\nwarm=8\nti=on\neps_schedule=1.5:3\n").unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.warm, 8);
        assert!(c.ti);
        assert_eq!(c.eps_schedule, Some((1.5, 3)));

        let raw = parser::RawConfig::parse("[solver]\nwarm=off\neps_schedule=off\n").unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.warm, 0);
        assert_eq!(c.eps_schedule, None);

        for bad in ["warm=-3", "warm=big", "ti=0.5", "eps_schedule=1.5",
                    "eps_schedule=x:3", "eps_schedule=1.5:x", "eps_schedule=nan:3",
                    "eps_schedule=-1:3", "eps_schedule=1.5:0"] {
            let raw = parser::RawConfig::parse(&format!("[solver]\n{bad}\n")).unwrap();
            assert!(ServiceConfig::from_raw(&raw).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn trace_path_parses_and_defaults_off() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.trace, None, "tracing is opt-in");
        let raw = parser::RawConfig::parse("[solver]\ntrace=out/solve.trace.json\n").unwrap();
        let c = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(c.trace.as_deref(), Some("out/solve.trace.json"));
        let raw = parser::RawConfig::parse("[solver]\ntrace=off\n").unwrap();
        assert_eq!(ServiceConfig::from_raw(&raw).unwrap().trace, None);
    }

    #[test]
    fn parallel_backend_defaults_to_pool() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.parallel, ParallelBackend::Pool);
        assert_eq!(c.affinity, AffinityHint::None);
        let raw = parser::RawConfig::parse("[solver]\nparallel=forkbomb\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn defaults_for_empty_config() {
        let c = ServiceConfig::from_raw(&parser::RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(c.workers, ServiceConfig::default().workers);
        assert_eq!(c.backend, Backend::Native);
    }

    #[test]
    fn rejects_unknown_backend_and_solver() {
        let raw = parser::RawConfig::parse("[coordinator]\nbackend=cuda\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = parser::RawConfig::parse("[solver]\nkind=quantum\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }
}
