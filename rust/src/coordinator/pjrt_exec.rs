//! PJRT executor actor.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so the runtime lives on a
//! dedicated thread that owns it for its whole life; workers talk to it
//! over a channel. One executor serializes device work — fine on the CPU
//! plugin, which parallelizes internally across the XLA thread pool.
//!
//! Threading model: this executor is the PJRT counterpart of the native
//! path's persistent solver pool (`algo::pool`) — in both cases the
//! expensive resource (XLA client here, parked OS workers there) is
//! created once and owned by a long-lived thread, and the per-request
//! cost is a channel round-trip, never a spawn/join. The executor thread
//! itself never runs the native pool; `Backend::Pjrt` and the native
//! `ParallelBackend` are orthogonal knobs.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::algo::{Problem, SolveReport, StopRule};
use crate::coordinator::router;
use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::util::{Matrix, Timer};

/// Job sent to the executor thread.
pub enum PjrtJob {
    Solve {
        problem: Problem,
        stop: StopRule,
        reply: Sender<Result<(Matrix, SolveReport)>>,
    },
    Shutdown,
}

/// Cloneable handle to the executor.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<PjrtJob>,
}

impl PjrtHandle {
    /// Solve a problem on the PJRT backend (blocking).
    pub fn solve(&self, problem: Problem, stop: StopRule) -> Result<(Matrix, SolveReport)> {
        let (reply, rx) = channel();
        self.tx
            .send(PjrtJob::Solve { problem, stop, reply })
            .map_err(|_| Error::Service("pjrt executor gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Service("pjrt executor dropped reply".into()))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(PjrtJob::Shutdown);
    }
}

/// Spawn the executor thread over `artifacts_dir`. Fails fast (before
/// returning) if the runtime cannot open the artifact directory.
pub fn spawn(artifacts_dir: String) -> Result<(PjrtHandle, JoinHandle<()>)> {
    let (tx, rx) = channel::<PjrtJob>();
    let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
    let join = std::thread::Builder::new()
        .name("pjrt-exec".into())
        .spawn(move || {
            let mut rt = match Runtime::open(&artifacts_dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            run_loop(&mut rt, rx);
        })
        .map_err(|e| Error::Service(format!("spawn pjrt-exec: {e}")))?;
    ready_rx
        .recv()
        .map_err(|_| Error::Service("pjrt executor died during startup".into()))?
        .map_err(Error::Runtime)?;
    Ok((PjrtHandle { tx }, join))
}

fn run_loop(rt: &mut Runtime, rx: Receiver<PjrtJob>) {
    while let Ok(job) = rx.recv() {
        match job {
            PjrtJob::Shutdown => break,
            PjrtJob::Solve { problem, stop, reply } => {
                let _ = reply.send(solve_on(rt, &problem, stop));
            }
        }
    }
}

/// Chunked solve: route to a bucket, pad, run chunks until the stop rule.
///
/// Convergence control lives here at L3: the artifact returns the marginal
/// error as a device-side scalar, and the plan-motion criterion for the
/// relaxed (fi < 1) fixed point is evaluated on the carried column sums —
/// O(N) host work per chunk, never the full matrix.
fn solve_on(rt: &mut Runtime, problem: &Problem, stop: StopRule) -> Result<(Matrix, SolveReport)> {
    let timer = Timer::start();
    let (m, n) = (problem.rows(), problem.cols());
    let meta = rt
        .manifest()
        .chunk_for(m, n)
        .ok_or_else(|| Error::Artifact(format!("no uot_chunk bucket fits {m}x{n}")))?;
    let (bm, bn) = (meta.m, meta.n);
    let mut padded = router::pad(problem, bm, bn);

    let mut iters = 0usize;
    let mut err = f32::INFINITY;
    let mut delta = f32::INFINITY;
    let mut prev_colsum = padded.colsum.clone();
    while !stop.is_done(err, delta, iters) {
        let out = rt.run_uot_chunk(
            &mut padded.plan,
            &mut padded.colsum,
            &padded.rpd,
            &padded.cpd,
            padded.fi,
        )?;
        iters += out.steps;
        err = out.err;
        delta = prev_colsum
            .iter()
            .zip(&padded.colsum)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        prev_colsum.copy_from_slice(&padded.colsum);
    }

    let plan = padded.unpad();
    let converged = err <= stop.tol || delta <= stop.delta_tol;
    Ok((
        plan,
        SolveReport { iters, err, delta, converged, seconds: timer.elapsed().as_secs_f64() },
    ))
}
