//! Routing: backend choice and shape-bucket padding.
//!
//! The PJRT backend executes shape-specialized artifacts, so a request is
//! routed to the smallest chunk bucket that fits and zero-padded into it.
//! Padding is sound because a zero row/column has zero mass: the factor
//! guard `(target/sum)^fi with sum=0 → 0` keeps it identically zero, the
//! real support evolves exactly as unpadded, and the padded rows contribute
//! 0 to the device-side marginal error (their target is also 0).

use crate::algo::Problem;
use crate::runtime::Manifest;
use crate::util::Matrix;

/// Where a request will execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Native solver on the worker thread.
    Native,
    /// PJRT artifact with this bucket shape.
    Pjrt { bucket_m: usize, bucket_n: usize },
}

/// Pick a route: PJRT when enabled and a bucket fits, else native.
pub fn route(manifest: Option<&Manifest>, m: usize, n: usize) -> Route {
    match manifest.and_then(|mf| mf.chunk_for(m, n)) {
        Some(meta) => Route::Pjrt { bucket_m: meta.m, bucket_n: meta.n },
        None => Route::Native,
    }
}

/// A problem padded into a bucket, remembering its true shape.
#[derive(Debug)]
pub struct Padded {
    pub plan: Matrix,
    pub colsum: Vec<f32>,
    pub rpd: Vec<f32>,
    pub cpd: Vec<f32>,
    pub fi: f32,
    pub orig_m: usize,
    pub orig_n: usize,
}

/// Zero-pad `problem` into a `bm × bn` bucket.
pub fn pad(problem: &Problem, bm: usize, bn: usize) -> Padded {
    let (m, n) = (problem.rows(), problem.cols());
    assert!(bm >= m && bn >= n, "bucket {bm}x{bn} smaller than problem {m}x{n}");
    let mut plan = Matrix::zeros(bm, bn);
    for i in 0..m {
        plan.row_mut(i)[..n].copy_from_slice(problem.plan.row(i));
    }
    let mut rpd = vec![0f32; bm];
    rpd[..m].copy_from_slice(&problem.rpd);
    let mut cpd = vec![0f32; bn];
    cpd[..n].copy_from_slice(&problem.cpd);
    let colsum = plan.col_sums();
    Padded { plan, colsum, rpd, cpd, fi: problem.fi, orig_m: m, orig_n: n }
}

impl Padded {
    /// Extract the unpadded plan.
    pub fn unpad(&self) -> Matrix {
        let mut out = Matrix::zeros(self.orig_m, self.orig_n);
        for i in 0..self.orig_m {
            out.row_mut(i)
                .copy_from_slice(&self.plan.row(i)[..self.orig_n]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{solver_for, SolverKind, Workspace};
    use crate::runtime::Manifest;

    const MANIFEST: &str = "\
c256 file=a kind=uot_chunk m=256 n=256 steps=8 block_m=128
c512 file=b kind=uot_chunk m=512 n=512 steps=8 block_m=64
";

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let mf = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(route(Some(&mf), 100, 100), Route::Pjrt { bucket_m: 256, bucket_n: 256 });
        assert_eq!(route(Some(&mf), 400, 100), Route::Pjrt { bucket_m: 512, bucket_n: 512 });
        assert_eq!(route(Some(&mf), 4096, 4096), Route::Native);
        assert_eq!(route(None, 8, 8), Route::Native);
    }

    #[test]
    fn padding_preserves_solver_semantics() {
        // Iterating the padded problem must evolve the real support exactly
        // as iterating the original problem.
        let p = Problem::random(10, 7, 0.6, 3);
        let mut padded = pad(&p, 16, 12);

        let solver = solver_for(SolverKind::MapUot);
        let mut ws_plain = Workspace::new(10, 7, 1);
        let mut ws_padded = Workspace::new(16, 12, 1);
        let mut plain = p.plan.clone();
        let mut plain_cs = plain.col_sums();
        for _ in 0..4 {
            solver.iterate(&mut plain, &mut plain_cs, &p.rpd, &p.cpd, p.fi, &mut ws_plain);
            solver.iterate(
                &mut padded.plan,
                &mut padded.colsum,
                &padded.rpd,
                &padded.cpd,
                padded.fi,
                &mut ws_padded,
            );
        }
        let unpadded = padded.unpad();
        assert!(unpadded.max_rel_diff(&plain, 1e-6) < 1e-4);
        // padding stayed exactly zero
        for i in 0..16 {
            for j in 0..12 {
                if i >= 10 || j >= 7 {
                    assert_eq!(padded.plan.get(i, j), 0.0, "({i},{j})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "smaller than problem")]
    fn pad_rejects_too_small_bucket() {
        let p = Problem::random(10, 10, 0.5, 1);
        let _ = pad(&p, 8, 16);
    }
}
