//! Routing: backend choice, problem-class detection and shape-bucket
//! padding.
//!
//! The PJRT backend executes shape-specialized artifacts, so a request is
//! routed to the smallest chunk bucket that fits and zero-padded into it.
//! Padding is sound because a zero row/column has zero mass: the factor
//! guard `(target/sum)^fi with sum=0 → 0` keeps it identically zero, the
//! real support evolves exactly as unpadded, and the padded rows contribute
//! 0 to the device-side marginal error (their target is also 0).
//!
//! Geometric requests additionally get a **problem-class** decision:
//! [`classify_geom`] detects problems the exact near-linear 1D sweep
//! ([`crate::algo::oned`]) can solve — explicit `d == 1` Euclidean
//! problems, plus higher-`d` problems whose points only actually vary
//! along one coordinate axis (within a tolerance) and therefore carry a
//! 1D geometry in disguise. The service consults this classifier under
//! `oned = auto|on` and falls back to the O(m·n)-per-sweep matfree path
//! with the classifier's stated reason otherwise.

use crate::algo::matfree::{CostKind, GeomProblem};
use crate::algo::Problem;
use crate::error::{Error, Result};
use crate::runtime::Manifest;
use crate::util::Matrix;

/// Where a request will execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Native solver on the worker thread.
    Native,
    /// PJRT artifact with this bucket shape.
    Pjrt { bucket_m: usize, bucket_n: usize },
}

/// Pick a route: PJRT when enabled and a bucket fits, else native.
pub fn route(manifest: Option<&Manifest>, m: usize, n: usize) -> Route {
    match manifest.and_then(|mf| mf.chunk_for(m, n)) {
        Some(meta) => Route::Pjrt { bucket_m: meta.m, bucket_n: meta.n },
        None => Route::Native,
    }
}

/// Default coordinate-agreement tolerance for the effectively-1D test:
/// an axis whose coordinates (over the union of both supports) span no
/// more than this is treated as constant. Tight enough that dropping the
/// axis perturbs each pairwise Euclidean cost by at most
/// `sqrt(d) · 1e-6` — far below the f32 kernel's own rounding at any ε
/// the validated constructors accept.
pub const ONED_AXIS_TOL: f32 = 1e-6;

/// Which solver class a geometric request belongs to.
#[derive(Debug, Clone, PartialEq)]
pub enum ProblemClass {
    /// Eligible for the exact near-linear 1D sweep, reading coordinate
    /// `axis` of every point (always 0 for genuinely 1D problems).
    Oned { axis: usize },
    /// Needs an iterative 2D backend; `reason` says why, verbatim usable
    /// in typed errors and fallback logs.
    General { reason: String },
}

/// Classify a geometric problem for routing: [`ProblemClass::Oned`] when
/// the exact 1D sweep applies, [`ProblemClass::General`] with the reason
/// otherwise.
///
/// Eligibility is the conjunction of two facts:
/// - the cost is [`CostKind::Euclidean`] — the Laplace kernel
///   `exp(-|x − y|/ε)` is the one that factors into prefix/suffix sweeps
///   (the Gaussian of `SqEuclidean` does not; see `algo::oned`), and
/// - the geometry is one-dimensional: either `d == 1` outright, or at
///   most one coordinate axis actually varies across `x ∪ y` (every other
///   axis spans ≤ `tol`). A zero-varying-axes problem (all points
///   coincident within `tol`) is degenerate-1D and routes to axis 0.
///
/// The scan is a single O((m + n) · d) pass tracking per-axis min/max —
/// no allocation beyond the return value.
pub fn classify_geom(p: &GeomProblem, tol: f32) -> ProblemClass {
    if p.cost != CostKind::Euclidean {
        return ProblemClass::General {
            reason: format!(
                "cost {} does not factor into the 1D prefix/suffix sweeps (only euclid does)",
                p.cost.name()
            ),
        };
    }
    if p.d == 1 {
        return ProblemClass::Oned { axis: 0 };
    }
    // Per-axis coordinate span over the union of both supports.
    let mut varying_axis = None;
    let mut varying = 0usize;
    for axis in 0..p.d {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for pts in [&p.x, &p.y] {
            for point in pts.chunks_exact(p.d) {
                // uotlint: allow(panic) — chunks_exact(p.d) yields windows
                // of length p.d, and axis < p.d by the loop bound.
                let c = point[axis];
                lo = lo.min(c);
                hi = hi.max(c);
            }
        }
        if hi - lo > tol {
            varying += 1;
            varying_axis = Some(axis);
        }
    }
    match (varying, varying_axis) {
        (0, _) => ProblemClass::Oned { axis: 0 },
        (1, Some(axis)) => ProblemClass::Oned { axis },
        (k, _) => ProblemClass::General {
            reason: format!(
                "{k} of {} coordinate axes vary by more than {tol:e}; the exact sweep needs \
                 a one-dimensional geometry",
                p.d
            ),
        },
    }
}

/// Project an effectively-1D problem onto `axis`: a validated `d == 1`
/// [`GeomProblem`] carrying coordinate `axis` of every point with the
/// original cost, ε, marginals and fi. Combined with
/// [`classify_geom`]'s span bound, solving the projection equals solving
/// the original within the stated tolerance.
pub fn project_oned(p: &GeomProblem, axis: usize) -> Result<GeomProblem> {
    if axis >= p.d {
        return Err(Error::InvalidProblem(format!(
            "projection axis {axis} out of range for d = {}",
            p.d
        )));
    }
    let take = |pts: &[f32]| pts.iter().skip(axis).step_by(p.d).copied().collect::<Vec<f32>>();
    GeomProblem::new(
        take(&p.x),
        take(&p.y),
        1,
        p.cost,
        p.epsilon,
        p.rpd.clone(),
        p.cpd.clone(),
        p.fi,
    )
}

/// A problem padded into a bucket, remembering its true shape.
#[derive(Debug)]
pub struct Padded {
    pub plan: Matrix,
    pub colsum: Vec<f32>,
    pub rpd: Vec<f32>,
    pub cpd: Vec<f32>,
    pub fi: f32,
    pub orig_m: usize,
    pub orig_n: usize,
}

/// Zero-pad `problem` into a `bm × bn` bucket.
pub fn pad(problem: &Problem, bm: usize, bn: usize) -> Padded {
    let (m, n) = (problem.rows(), problem.cols());
    assert!(bm >= m && bn >= n, "bucket {bm}x{bn} smaller than problem {m}x{n}");
    let mut plan = Matrix::zeros(bm, bn);
    for i in 0..m {
        // uotlint: allow(panic) — bm >= m && bn >= n is asserted above, so
        // every row/prefix slice in this fn is in bounds by construction.
        plan.row_mut(i)[..n].copy_from_slice(problem.plan.row(i));
    }
    let mut rpd = vec![0f32; bm];
    // uotlint: allow(panic) — m <= bm asserted above.
    rpd[..m].copy_from_slice(&problem.rpd);
    let mut cpd = vec![0f32; bn];
    // uotlint: allow(panic) — n <= bn asserted above.
    cpd[..n].copy_from_slice(&problem.cpd);
    let colsum = plan.col_sums();
    Padded { plan, colsum, rpd, cpd, fi: problem.fi, orig_m: m, orig_n: n }
}

impl Padded {
    /// Extract the unpadded plan.
    pub fn unpad(&self) -> Matrix {
        let mut out = Matrix::zeros(self.orig_m, self.orig_n);
        for i in 0..self.orig_m {
            // uotlint: allow(panic) — orig_n <= the padded width by
            // construction in `pad`.
            out.row_mut(i).copy_from_slice(&self.plan.row(i)[..self.orig_n]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{solver_for, SolverKind, Workspace};
    use crate::runtime::Manifest;

    const MANIFEST: &str = "\
c256 file=a kind=uot_chunk m=256 n=256 steps=8 block_m=128
c512 file=b kind=uot_chunk m=512 n=512 steps=8 block_m=64
";

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let mf = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(route(Some(&mf), 100, 100), Route::Pjrt { bucket_m: 256, bucket_n: 256 });
        assert_eq!(route(Some(&mf), 400, 100), Route::Pjrt { bucket_m: 512, bucket_n: 512 });
        assert_eq!(route(Some(&mf), 4096, 4096), Route::Native);
        assert_eq!(route(None, 8, 8), Route::Native);
    }

    #[test]
    fn padding_preserves_solver_semantics() {
        // Iterating the padded problem must evolve the real support exactly
        // as iterating the original problem.
        let p = Problem::random(10, 7, 0.6, 3);
        let mut padded = pad(&p, 16, 12);

        let solver = solver_for(SolverKind::MapUot);
        let mut ws_plain = Workspace::new(10, 7, 1);
        let mut ws_padded = Workspace::new(16, 12, 1);
        let mut plain = p.plan.clone();
        let mut plain_cs = plain.col_sums();
        for _ in 0..4 {
            solver.iterate(&mut plain, &mut plain_cs, &p.rpd, &p.cpd, p.fi, &mut ws_plain);
            solver.iterate(
                &mut padded.plan,
                &mut padded.colsum,
                &padded.rpd,
                &padded.cpd,
                padded.fi,
                &mut ws_padded,
            );
        }
        let unpadded = padded.unpad();
        assert!(unpadded.max_rel_diff(&plain, 1e-6) < 1e-4);
        // padding stayed exactly zero
        for i in 0..16 {
            for j in 0..12 {
                if i >= 10 || j >= 7 {
                    assert_eq!(padded.plan.get(i, j), 0.0, "({i},{j})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "smaller than problem")]
    fn pad_rejects_too_small_bucket() {
        let p = Problem::random(10, 10, 0.5, 1);
        let _ = pad(&p, 8, 16);
    }

    #[test]
    fn classifies_explicit_1d_euclidean_as_oned() {
        let p = GeomProblem::random(9, 7, 1, CostKind::Euclidean, 0.5, 0.7, 11);
        assert_eq!(classify_geom(&p, ONED_AXIS_TOL), ProblemClass::Oned { axis: 0 });
    }

    #[test]
    fn rejects_non_factoring_cost_with_reason() {
        let p = GeomProblem::random(9, 7, 1, CostKind::SqEuclidean, 0.5, 0.7, 11);
        match classify_geom(&p, ONED_AXIS_TOL) {
            ProblemClass::General { reason } => {
                assert!(reason.contains("sqeuclid"), "reason names the cost: {reason}")
            }
            other => panic!("sqeuclid must not classify as 1D: {other:?}"),
        }
    }

    #[test]
    fn detects_effectively_1d_axis_in_higher_d() {
        // 3D points whose axes 0 and 2 are pinned to constants: only axis
        // 1 carries geometry.
        let mut p = GeomProblem::random(8, 6, 3, CostKind::Euclidean, 0.5, 0.7, 23);
        for point in p.x.chunks_exact_mut(3).chain(p.y.chunks_exact_mut(3)) {
            point[0] = 0.25;
            point[2] = -1.5;
        }
        assert_eq!(classify_geom(&p, ONED_AXIS_TOL), ProblemClass::Oned { axis: 1 });

        // Re-enable axis 2 → two varying axes → general, with the count
        // in the reason.
        for (k, point) in p.y.chunks_exact_mut(3).enumerate() {
            point[2] = -1.5 + 0.1 * (k + 1) as f32;
        }
        match classify_geom(&p, ONED_AXIS_TOL) {
            ProblemClass::General { reason } => {
                assert!(reason.contains("2 of 3"), "reason counts varying axes: {reason}")
            }
            other => panic!("two varying axes must be general: {other:?}"),
        }
    }

    #[test]
    fn coincident_points_are_degenerate_1d() {
        let mut p = GeomProblem::random(4, 5, 2, CostKind::Euclidean, 0.5, 0.7, 3);
        for c in p.x.iter_mut().chain(p.y.iter_mut()) {
            *c = 0.5;
        }
        assert_eq!(classify_geom(&p, ONED_AXIS_TOL), ProblemClass::Oned { axis: 0 });
    }

    #[test]
    fn projection_extracts_the_varying_axis() {
        let mut p = GeomProblem::random(8, 6, 3, CostKind::Euclidean, 0.5, 0.7, 23);
        for point in p.x.chunks_exact_mut(3).chain(p.y.chunks_exact_mut(3)) {
            point[0] = 0.25;
            point[2] = -1.5;
        }
        let q = project_oned(&p, 1).unwrap();
        assert_eq!(q.d, 1);
        assert_eq!(q.rows(), 8);
        assert_eq!(q.cols(), 6);
        for (i, c) in q.x.iter().enumerate() {
            assert_eq!(*c, p.x[i * 3 + 1], "row point {i}");
        }
        for (j, c) in q.y.iter().enumerate() {
            assert_eq!(*c, p.y[j * 3 + 1], "col point {j}");
        }
        assert_eq!(q.rpd, p.rpd);
        assert_eq!(q.cpd, p.cpd);
        assert_eq!(q.epsilon, p.epsilon);
        assert_eq!(q.fi, p.fi);

        // The projected cost equals the original within the span bound.
        for i in 0..8 {
            for j in 0..6 {
                let a = p.cost_entry(i, j);
                let b = q.cost_entry(i, j);
                assert!((a - b).abs() < 1e-5, "cost ({i},{j}): {a} vs {b}");
            }
        }
        assert!(project_oned(&p, 3).is_err(), "axis out of range is typed");
    }
}
