//! Bounded request queue with dynamic batching and backpressure.
//!
//! The batcher is the L3 hot-path data structure: producers `push` (bounded
//! — `Reject` gives load-shedding, `Block` gives backpressure), workers
//! `pop_batch` which drains up to `batch_max` *shape-compatible* requests,
//! waiting up to `batch_wait` after the first arrival so concurrent
//! requests of the same shape can share a worker (and, on the PJRT path,
//! an executable's warm state).
//!
//! # Poison recovery
//!
//! The queue mutex is *recovered*, never trusted to kill the service: a
//! worker that panics while holding the lock (a poisoned `Mutex`) must
//! not cascade into panicking every other producer and consumer. The
//! protected state is a plain `VecDeque` + `closed` flag — every
//! operation on it either completes or does not start, so the state is
//! valid at every observable point and `PoisonError::into_inner` is
//! sound. Requests the panicking worker had already drained die with it
//! (their reply channels drop, which submitters observe as a typed
//! `Error::Service` through `Service::await_response`); everything still
//! queued is served by the surviving workers.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::coordinator::request::SolveRequest;

/// Unwrap a lock/wait result, recovering the payload from poisoning (see
/// the module docs: the queue state is valid at every observable point,
/// so a panic elsewhere must not cascade here). Works for `lock()`
/// guards and for `wait_timeout()`'s `(guard, timeout)` pairs alike.
fn recover<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// What `push` does when the queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FullPolicy {
    /// Block the producer until space frees (backpressure).
    Block,
    /// Return the request to the caller (load shedding).
    Reject,
}

#[derive(Debug, Default)]
struct State {
    queue: VecDeque<SolveRequest>,
    closed: bool,
}

/// Bounded MPMC batching queue.
#[derive(Debug)]
pub struct Batcher {
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    batch_max: usize,
    batch_wait: Duration,
}

impl Batcher {
    pub fn new(cap: usize, batch_max: usize, batch_wait: Duration) -> Self {
        assert!(cap > 0 && batch_max > 0);
        Self {
            state: Mutex::new(State::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
            batch_max,
            batch_wait,
        }
    }

    /// Enqueue a request. Returns `Err(request)` if rejected (full under
    /// `Reject`, or queue closed).
    pub fn push(&self, req: SolveRequest, policy: FullPolicy) -> Result<(), SolveRequest> {
        let mut st = recover(self.state.lock());
        loop {
            if st.closed {
                return Err(req);
            }
            if st.queue.len() < self.cap {
                st.queue.push_back(req);
                self.not_empty.notify_one();
                return Ok(());
            }
            match policy {
                FullPolicy::Reject => return Err(req),
                FullPolicy::Block => {
                    st = recover(self.not_full.wait(st));
                }
            }
        }
    }

    /// Dequeue a batch: blocks for the first request (or close), then
    /// drains same-shape requests up to `batch_max`, waiting up to
    /// `batch_wait` to top the batch up. Returns `None` when closed+empty.
    pub fn pop_batch(&self) -> Option<Vec<SolveRequest>> {
        let mut st = recover(self.state.lock());
        // Wait for work.
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = recover(self.not_empty.wait(st));
        }

        // The wait loop above only breaks on a non-empty queue, but a typed
        // drain beats a panic while holding the queue lock.
        let first = match st.queue.pop_front() {
            Some(req) => req,
            None => return None,
        };
        let shape = first.shape();
        let mut batch = vec![first];
        let deadline = Instant::now() + self.batch_wait;

        loop {
            // Drain compatible requests (stable order for the rest).
            let mut i = 0;
            while batch.len() < self.batch_max && i < st.queue.len() {
                if st.queue.get(i).is_some_and(|r| r.shape() == shape) {
                    match st.queue.remove(i) {
                        Some(req) => batch.push(req),
                        None => break,
                    }
                } else {
                    i += 1;
                }
            }
            if batch.len() >= self.batch_max || st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (next, timeout) = recover(self.not_empty.wait_timeout(st, deadline - now));
            st = next;
            if timeout.timed_out() && st.queue.iter().all(|r| r.shape() != shape) {
                break;
            }
        }
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        recover(self.state.lock()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        recover(self.state.lock()).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Problem;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64, m: usize, n: usize) -> SolveRequest {
        let (tx, _rx) = channel();
        // leak the receiver side: these tests never reply
        std::mem::forget(_rx);
        SolveRequest {
            id,
            payload: crate::coordinator::request::Payload::Dense(Problem::random(m, n, 0.5, id)),
            reply: tx,
            submitted_at: std::time::Instant::now(),
        }
    }

    fn batcher(cap: usize, bmax: usize) -> Batcher {
        Batcher::new(cap, bmax, Duration::from_millis(5))
    }

    #[test]
    fn batches_group_same_shape() {
        let b = batcher(16, 8);
        b.push(req(1, 8, 8), FullPolicy::Reject).unwrap();
        b.push(req(2, 4, 4), FullPolicy::Reject).unwrap();
        b.push(req(3, 8, 8), FullPolicy::Reject).unwrap();
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        let batch2 = b.pop_batch().unwrap();
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn respects_batch_max() {
        let b = batcher(16, 2);
        for i in 0..5 {
            b.push(req(i, 8, 8), FullPolicy::Reject).unwrap();
        }
        assert_eq!(b.pop_batch().unwrap().len(), 2);
        assert_eq!(b.pop_batch().unwrap().len(), 2);
        assert_eq!(b.pop_batch().unwrap().len(), 1);
    }

    #[test]
    fn reject_when_full() {
        let b = batcher(2, 8);
        b.push(req(1, 4, 4), FullPolicy::Reject).unwrap();
        b.push(req(2, 4, 4), FullPolicy::Reject).unwrap();
        assert!(b.push(req(3, 4, 4), FullPolicy::Reject).is_err());
    }

    #[test]
    fn block_until_space() {
        let b = Arc::new(batcher(1, 1));
        b.push(req(1, 4, 4), FullPolicy::Reject).unwrap();
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || {
            // Blocks until the main thread pops.
            b2.push(req(2, 4, 4), FullPolicy::Block).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(b.len(), 1);
        let _ = b.pop_batch().unwrap();
        h.join().unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn close_unblocks_consumers() {
        let b = Arc::new(batcher(4, 4));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.pop_batch());
        std::thread::sleep(Duration::from_millis(10));
        b.close();
        assert!(h.join().unwrap().is_none());
        // producers now fail
        assert!(b.push(req(9, 4, 4), FullPolicy::Block).is_err());
    }

    #[test]
    fn drains_after_close() {
        let b = batcher(4, 4);
        b.push(req(1, 4, 4), FullPolicy::Reject).unwrap();
        b.close();
        assert_eq!(b.pop_batch().unwrap().len(), 1);
        assert!(b.pop_batch().is_none());
    }

    /// A thread that panics while holding the queue lock poisons the
    /// mutex; every subsequent operation must recover and keep serving —
    /// one crashed worker must not cascade into killing the service.
    #[test]
    fn survives_a_poisoned_lock() {
        let b = Arc::new(batcher(8, 4));
        b.push(req(1, 4, 4), FullPolicy::Reject).unwrap();
        let b2 = Arc::clone(&b);
        let _ = std::thread::spawn(move || {
            let _guard = b2.state.lock().unwrap();
            panic!("worker dies while holding the batcher lock");
        })
        .join();
        assert!(b.state.is_poisoned(), "the panic above must have poisoned the lock");
        // The full surface still works on the recovered state.
        b.push(req(2, 4, 4), FullPolicy::Reject).unwrap();
        assert_eq!(b.len(), 2);
        let batch = b.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        b.close();
        assert!(b.push(req(3, 4, 4), FullPolicy::Block).is_err());
        assert!(b.pop_batch().is_none());
    }
}
