//! Lock-free service metrics (counters + latency histogram).

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram bucket upper bounds (milliseconds).
pub const LATENCY_BUCKETS_MS: [f64; 8] = [0.5, 1.0, 2.0, 5.0, 10.0, 50.0, 200.0, 1000.0];

/// Per-request iteration-count histogram bucket upper bounds. Geometric,
/// because warm starting / TI / ε-scheduling move iterations-to-tolerance
/// multiplicatively — the warm-start ablation reads its speedups off this
/// histogram's percentiles.
pub const ITER_BUCKETS: [u64; 8] = [8, 16, 32, 64, 128, 256, 512, 1024];

/// Service-wide metrics, cheap to update from any thread.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Total solver iterations executed (native + PJRT chunks × steps).
    pub iterations: AtomicU64,
    latency_buckets: [AtomicU64; 9], // 8 bounded + overflow
    latency_total_us: AtomicU64,
    iter_buckets: [AtomicU64; 9], // 8 bounded + overflow
    iter_requests: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, seconds: f64) {
        let ms = seconds * 1e3;
        let idx = LATENCY_BUCKETS_MS.iter().position(|&b| ms <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_total_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one request's iterations-to-tolerance (also folds the count
    /// into the [`Metrics::iterations`] running total).
    pub fn record_iters(&self, iters: u64) {
        let idx = ITER_BUCKETS.iter().position(|&b| iters <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        self.iter_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.iter_requests.fetch_add(1, Ordering::Relaxed);
        self.iterations.fetch_add(iters, Ordering::Relaxed);
    }

    /// Point-in-time snapshot for reporting.
    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let latency_buckets = self.latency_buckets.each_ref().map(|a| a.load(Ordering::Relaxed));
        let iter_buckets = self.iter_buckets.each_ref().map(|a| a.load(Ordering::Relaxed));
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            iterations: self.iterations.load(Ordering::Relaxed),
            mean_latency_ms: if completed == 0 {
                0.0
            } else {
                self.latency_total_us.load(Ordering::Relaxed) as f64 / completed as f64 / 1e3
            },
            latency_buckets,
            iter_buckets,
            iter_requests: self.iter_requests.load(Ordering::Relaxed),
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Debug, Clone, Copy)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub iterations: u64,
    pub mean_latency_ms: f64,
    pub latency_buckets: [u64; 9],
    pub iter_buckets: [u64; 9],
    /// Requests with a recorded iteration count (histogram mass).
    pub iter_requests: u64,
}

impl Snapshot {
    /// Approximate latency percentile from the histogram (ms).
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        let total: u64 = self.latency_buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.latency_buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return LATENCY_BUCKETS_MS.get(i).copied().unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }

    /// Approximate per-request iteration-count percentile (bucket upper
    /// bound; `inf` in the overflow bucket).
    pub fn iters_percentile(&self, p: f64) -> f64 {
        let total: u64 = self.iter_buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.iter_buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return ITER_BUCKETS.get(i).map(|&b| b as f64).unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }

    /// Mean iterations-to-tolerance across recorded requests — the
    /// warm-start ablation's headline number.
    pub fn mean_iters(&self) -> f64 {
        if self.iter_requests == 0 {
            0.0
        } else {
            self.iterations as f64 / self.iter_requests as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_and_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(0.0004); // 0.4 ms -> bucket 0
        }
        for _ in 0..10 {
            m.record_latency(0.1); // 100 ms -> bucket 200
        }
        m.completed.store(100, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.latency_percentile_ms(50.0), 0.5);
        assert_eq!(s.latency_percentile_ms(99.0), 200.0);
        assert!(s.mean_latency_ms > 0.0);
    }

    #[test]
    fn batch_means() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.snapshot().mean_batch_size - 6.0).abs() < 1e-9);
    }

    #[test]
    fn iteration_histogram_and_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_iters(8); // bucket 0
        }
        for _ in 0..10 {
            m.record_iters(400); // bucket 512
        }
        let s = m.snapshot();
        assert_eq!(s.iter_requests, 100);
        assert_eq!(s.iterations, 90 * 8 + 10 * 400);
        assert_eq!(s.iters_percentile(50.0), 8.0);
        assert_eq!(s.iters_percentile(99.0), 512.0);
        assert!((s.mean_iters() - (90.0 * 8.0 + 10.0 * 400.0) / 100.0).abs() < 1e-9);
        // Overflow bucket maps to infinity.
        m.record_iters(1_000_000);
        assert!(m.snapshot().iters_percentile(100.0).is_infinite());
    }

    #[test]
    fn empty_iteration_histogram_reads_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.iters_percentile(99.0), 0.0);
        assert_eq!(s.mean_iters(), 0.0);
    }
}
