//! Lock-free service metrics (counters + latency histograms).
//!
//! Latency is decomposed at the batcher seam: **queue wait** (submission
//! to dequeue) and **solve** (dequeue to completion) are recorded into
//! separate histograms sharing [`LATENCY_BUCKETS_MS`], so a p99 regression
//! is attributable to queueing vs. compute from the snapshot alone. The
//! machine-readable labeled surface on top of this lives in
//! [`crate::coordinator::obs`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram bucket upper bounds (milliseconds).
pub const LATENCY_BUCKETS_MS: [f64; 8] = [0.5, 1.0, 2.0, 5.0, 10.0, 50.0, 200.0, 1000.0];

/// Per-request iteration-count histogram bucket upper bounds. Geometric,
/// because warm starting / TI / ε-scheduling move iterations-to-tolerance
/// multiplicatively — the warm-start ablation reads its speedups off this
/// histogram's percentiles.
pub const ITER_BUCKETS: [u64; 8] = [8, 16, 32, 64, 128, 256, 512, 1024];

/// Service-wide metrics, cheap to update from any thread.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Total solver iterations executed (native + PJRT chunks × steps).
    pub iterations: AtomicU64,
    latency_buckets: [AtomicU64; 9], // 8 bounded + overflow
    latency_total_us: AtomicU64,
    wait_buckets: [AtomicU64; 9], // 8 bounded + overflow
    wait_total_us: AtomicU64,
    wait_count: AtomicU64,
    iter_buckets: [AtomicU64; 9], // 8 bounded + overflow
    iter_requests: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's **solve** latency (dequeue to completion).
    /// Queue wait goes through [`Metrics::record_wait`] — recording the
    /// end-to-end figure here would conflate the two (the pre-PR-10 bug).
    pub fn record_latency(&self, seconds: f64) {
        let ms = seconds * 1e3;
        let idx = LATENCY_BUCKETS_MS.iter().position(|&b| ms <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_total_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    /// Record one request's **queue wait** (submission to dequeue).
    pub fn record_wait(&self, seconds: f64) {
        let ms = seconds * 1e3;
        let idx = LATENCY_BUCKETS_MS.iter().position(|&b| ms <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        self.wait_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.wait_total_us.fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.wait_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one request's iterations-to-tolerance (also folds the count
    /// into the [`Metrics::iterations`] running total).
    pub fn record_iters(&self, iters: u64) {
        let idx = ITER_BUCKETS.iter().position(|&b| iters <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        self.iter_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.iter_requests.fetch_add(1, Ordering::Relaxed);
        self.iterations.fetch_add(iters, Ordering::Relaxed);
    }

    /// Point-in-time snapshot for reporting.
    pub fn snapshot(&self) -> Snapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let latency_buckets = self.latency_buckets.each_ref().map(|a| a.load(Ordering::Relaxed));
        let wait_buckets = self.wait_buckets.each_ref().map(|a| a.load(Ordering::Relaxed));
        let wait_count = self.wait_count.load(Ordering::Relaxed);
        let iter_buckets = self.iter_buckets.each_ref().map(|a| a.load(Ordering::Relaxed));
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            iterations: self.iterations.load(Ordering::Relaxed),
            mean_latency_ms: if completed == 0 {
                0.0
            } else {
                self.latency_total_us.load(Ordering::Relaxed) as f64 / completed as f64 / 1e3
            },
            latency_buckets,
            wait_buckets,
            mean_wait_ms: if wait_count == 0 {
                0.0
            } else {
                self.wait_total_us.load(Ordering::Relaxed) as f64 / wait_count as f64 / 1e3
            },
            wait_count,
            iter_buckets,
            iter_requests: self.iter_requests.load(Ordering::Relaxed),
        }
    }
}

/// Immutable metrics snapshot.
#[derive(Debug, Clone, Copy)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub iterations: u64,
    /// Mean **solve** latency (dequeue to completion); queue wait is
    /// tracked separately in `mean_wait_ms`.
    pub mean_latency_ms: f64,
    /// Solve-latency histogram counts (bounds: [`LATENCY_BUCKETS_MS`] +
    /// overflow).
    pub latency_buckets: [u64; 9],
    /// Queue-wait histogram counts (same bounds as `latency_buckets`).
    pub wait_buckets: [u64; 9],
    /// Mean queue wait (submission to dequeue).
    pub mean_wait_ms: f64,
    /// Requests with a recorded queue wait (wait-histogram mass).
    pub wait_count: u64,
    pub iter_buckets: [u64; 9],
    /// Requests with a recorded iteration count (histogram mass).
    pub iter_requests: u64,
}

/// Shared histogram-percentile walk with total edge semantics:
/// no samples or `p` that is ≤ 0 / NaN → 0.0; otherwise the upper bound
/// of the bucket holding the ceil(p%·total)-th sample, `inf` for the
/// overflow bucket; `p` ≥ 100 reads the last occupied bucket.
fn percentile(buckets: &[u64; 9], bounds: &[f64; 8], p: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 || p.is_nan() || p <= 0.0 {
        return 0.0;
    }
    let target = (p.min(100.0) / 100.0 * total as f64).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= target {
            return bounds.get(i).copied().unwrap_or(f64::INFINITY);
        }
    }
    f64::INFINITY
}

impl Snapshot {
    /// Approximate **solve**-latency percentile from the histogram (ms):
    /// the upper bound of the bucket containing the p-th-percentile
    /// sample, `inf` when it falls in the overflow bucket. Degenerate
    /// inputs are total, not NaN: an empty histogram returns 0.0 for any
    /// `p`; `p ≤ 0` (or NaN) returns 0.0; `p ≥ 100` is clamped to the
    /// last occupied bucket.
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        percentile(&self.latency_buckets, &LATENCY_BUCKETS_MS, p)
    }

    /// Approximate **queue-wait** percentile (ms); same bucket bounds and
    /// edge semantics as [`Snapshot::latency_percentile_ms`]. Together
    /// they decompose end-to-end p99 into wait + solve.
    pub fn wait_percentile_ms(&self, p: f64) -> f64 {
        percentile(&self.wait_buckets, &LATENCY_BUCKETS_MS, p)
    }

    /// Approximate per-request iteration-count percentile (bucket upper
    /// bound; `inf` in the overflow bucket). Edge semantics as
    /// [`Snapshot::latency_percentile_ms`]: empty histogram or `p ≤ 0`
    /// (or NaN) → 0.0, `p ≥ 100` clamps.
    pub fn iters_percentile(&self, p: f64) -> f64 {
        let mut bounds = [0.0f64; 8];
        for (b, &v) in bounds.iter_mut().zip(ITER_BUCKETS.iter()) {
            *b = v as f64;
        }
        percentile(&self.iter_buckets, &bounds, p)
    }

    /// Mean iterations-to-tolerance across recorded requests — the
    /// warm-start ablation's headline number. 0.0 (not NaN) when no
    /// request has recorded an iteration count.
    pub fn mean_iters(&self) -> f64 {
        if self.iter_requests == 0 {
            0.0
        } else {
            self.iterations as f64 / self.iter_requests as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_and_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(0.0004); // 0.4 ms -> bucket 0
        }
        for _ in 0..10 {
            m.record_latency(0.1); // 100 ms -> bucket 200
        }
        m.completed.store(100, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.latency_percentile_ms(50.0), 0.5);
        assert_eq!(s.latency_percentile_ms(99.0), 200.0);
        assert!(s.mean_latency_ms > 0.0);
    }

    #[test]
    fn batch_means() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.snapshot().mean_batch_size - 6.0).abs() < 1e-9);
    }

    #[test]
    fn iteration_histogram_and_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_iters(8); // bucket 0
        }
        for _ in 0..10 {
            m.record_iters(400); // bucket 512
        }
        let s = m.snapshot();
        assert_eq!(s.iter_requests, 100);
        assert_eq!(s.iterations, 90 * 8 + 10 * 400);
        assert_eq!(s.iters_percentile(50.0), 8.0);
        assert_eq!(s.iters_percentile(99.0), 512.0);
        assert!((s.mean_iters() - (90.0 * 8.0 + 10.0 * 400.0) / 100.0).abs() < 1e-9);
        // Overflow bucket maps to infinity.
        m.record_iters(1_000_000);
        assert!(m.snapshot().iters_percentile(100.0).is_infinite());
    }

    #[test]
    fn empty_iteration_histogram_reads_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.iters_percentile(99.0), 0.0);
        assert_eq!(s.mean_iters(), 0.0);
    }

    #[test]
    fn percentile_edge_cases_are_total() {
        // No samples: every percentile reads a documented 0.0, never NaN.
        let empty = Metrics::new().snapshot();
        for p in [0.0, 50.0, 100.0, 200.0, -5.0, f64::NAN] {
            assert_eq!(empty.latency_percentile_ms(p), 0.0, "p={p}");
            assert_eq!(empty.wait_percentile_ms(p), 0.0, "p={p}");
            assert_eq!(empty.iters_percentile(p), 0.0, "p={p}");
        }
        assert_eq!(empty.mean_latency_ms, 0.0);
        assert_eq!(empty.mean_wait_ms, 0.0);

        // Single-bucket histogram: p=0 reads 0.0 (no sample demanded),
        // any positive p up to and past 100 reads that bucket's bound.
        let m = Metrics::new();
        m.record_latency(0.003); // 3 ms -> the 5 ms bucket
        m.record_iters(100); // -> the 128 bucket
        let s = m.snapshot();
        assert_eq!(s.latency_percentile_ms(0.0), 0.0);
        assert_eq!(s.latency_percentile_ms(0.1), 5.0);
        assert_eq!(s.latency_percentile_ms(100.0), 5.0);
        assert_eq!(s.latency_percentile_ms(250.0), 5.0, "p past 100 clamps");
        assert_eq!(s.iters_percentile(0.0), 0.0);
        assert_eq!(s.iters_percentile(100.0), 128.0);
        // Overflow-bucket mass still reads inf at p=100.
        m.record_latency(9.0); // 9000 ms -> overflow
        assert!(m.snapshot().latency_percentile_ms(100.0).is_infinite());
    }

    #[test]
    fn wait_and_solve_decompose() {
        let m = Metrics::new();
        // 10 requests: ~0.4 ms queue wait, 100 ms solve.
        for _ in 0..10 {
            m.record_wait(0.0004);
            m.record_latency(0.1);
        }
        m.completed.store(10, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.wait_count, 10);
        assert_eq!(s.wait_percentile_ms(99.0), 0.5, "wait stays in the fast bucket");
        assert_eq!(s.latency_percentile_ms(99.0), 200.0, "solve dominates");
        assert!((s.mean_wait_ms - 0.4).abs() < 1e-9);
        assert!((s.mean_latency_ms - 100.0).abs() < 1e-9);
    }
}
