//! L3 coordinator: the UOT solver service.
//!
//! Requests enter a bounded [`batcher`] (dynamic batching by shape, with
//! backpressure / load-shedding), a worker pool executes them on the
//! [`router`]-chosen backend — native solvers in-thread, or the PJRT
//! executor actor ([`pjrt_exec`]) running the AOT artifacts — and
//! [`metrics`] tracks throughput/latency, with the labeled
//! machine-readable surface in [`obs`]. Python never appears here.

pub mod batcher;
pub mod metrics;
pub mod obs;
pub mod pjrt_exec;
pub mod request;
pub mod router;
pub mod service;

pub use batcher::{Batcher, FullPolicy};
pub use metrics::{Metrics, Snapshot};
pub use obs::{stats_json, BackendClass, Obs, ObsSnapshot, STATS_SCHEMA_VERSION};
pub use request::{Payload, RequestId, Response, SolveRequest, SolveResponse, Solved};
pub use router::{classify_geom, project_oned, ProblemClass, Route, ONED_AXIS_TOL};
pub use service::Service;
