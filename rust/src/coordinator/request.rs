//! Request/response types of the solver service.

use std::sync::mpsc::Sender;

use crate::algo::{Problem, SolveReport, SolverKind};
use crate::config::Backend;
use crate::error::Error;
use crate::util::Matrix;

/// Monotonic request id assigned at submission.
pub type RequestId = u64;

/// A solve request travelling through the coordinator.
#[derive(Debug)]
pub struct SolveRequest {
    pub id: RequestId,
    pub problem: Problem,
    /// Reply channel back to the submitter.
    pub reply: Sender<SolveResponse>,
    /// Submission timestamp for latency accounting.
    pub submitted_at: std::time::Instant,
}

impl SolveRequest {
    /// Shape key used for batching and artifact bucketing.
    pub fn shape(&self) -> (usize, usize) {
        (self.problem.rows(), self.problem.cols())
    }
}

/// The service's answer to one request. Failures carry the crate's typed
/// [`Error`] (e.g. `Error::Canceled`, `Error::Runtime`), not a string.
#[derive(Debug)]
pub struct SolveResponse {
    pub id: RequestId,
    pub result: Result<Solved, Error>,
}

/// Successful solve payload.
#[derive(Debug)]
pub struct Solved {
    pub plan: Matrix,
    pub report: SolveReport,
    /// Which backend executed it.
    pub backend: Backend,
    /// Which solver kind ran (native) — MAP-UOT for PJRT (the artifact is
    /// the fused kernel).
    pub solver: SolverKind,
    /// End-to-end latency from submission to completion (seconds).
    pub latency_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn shape_key() {
        let (tx, _rx) = channel();
        let r = SolveRequest {
            id: 1,
            problem: Problem::random(8, 6, 0.5, 1),
            reply: tx,
            submitted_at: std::time::Instant::now(),
        };
        assert_eq!(r.shape(), (8, 6));
    }
}
