//! Request/response types of the solver service.

use std::sync::mpsc::Sender;

use crate::algo::{GeomProblem, Problem, SolveReport, SolverKind, TransportList};
use crate::config::Backend;
use crate::error::Error;
use crate::util::Matrix;

/// Monotonic request id assigned at submission.
pub type RequestId = u64;

/// What a request asks the service to solve.
#[derive(Debug)]
pub enum Payload {
    /// Dense UOT instance — the original protocol.
    Dense(Problem),
    /// Geometric point-cloud instance for the materialization-free
    /// backend (requires `ServiceConfig.matfree`; accepted through
    /// `Service::submit_geom`). O((m+n)·d) on the wire where a dense
    /// request carries O(m·n), and O(m+n) on the way back too: geometric
    /// requests answer with [`Response::Scaling`] — the scaling vectors
    /// (plus the sparse transport list when the exact 1D path ran) —
    /// never a densified m×n plan.
    Geom(GeomProblem),
}

impl Payload {
    /// Shape key used for batching and artifact bucketing.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Payload::Dense(p) => (p.rows(), p.cols()),
            Payload::Geom(g) => (g.rows(), g.cols()),
        }
    }
}

/// A solve request travelling through the coordinator.
#[derive(Debug)]
pub struct SolveRequest {
    pub id: RequestId,
    pub payload: Payload,
    /// Reply channel back to the submitter.
    pub reply: Sender<SolveResponse>,
    /// Submission timestamp for latency accounting.
    pub submitted_at: std::time::Instant,
}

impl SolveRequest {
    /// Shape key used for batching and artifact bucketing.
    pub fn shape(&self) -> (usize, usize) {
        self.payload.shape()
    }
}

/// The service's answer to one request. Failures carry the crate's typed
/// [`Error`] (e.g. `Error::Canceled`, `Error::Runtime`), not a string.
#[derive(Debug)]
pub struct SolveResponse {
    pub id: RequestId,
    pub result: Result<Solved, Error>,
}

/// The solved artifact itself, in whichever representation the executing
/// backend produces natively.
#[derive(Debug)]
pub enum Response {
    /// Dense m×n transport plan — what the dense, sparse-densified and
    /// PJRT backends hand back.
    Plan(Matrix),
    /// Scaling vectors `(u, v)` defining `plan_ij = u_i · A_ij · v_j`
    /// over the request's implicit kernel — the native answer of the
    /// geometric backends, O(m+n) instead of O(m·n). When the exact 1D
    /// path solved the request, `transport` additionally carries its
    /// sparse monotone coupling (≤ m+n entries plus the unbalanced
    /// creation/destruction slacks); the iterative matfree path leaves it
    /// `None`.
    Scaling { u: Vec<f32>, v: Vec<f32>, transport: Option<TransportList> },
}

impl Response {
    /// The dense plan, if this response carries one.
    pub fn plan(&self) -> Option<&Matrix> {
        match self {
            Response::Plan(p) => Some(p),
            Response::Scaling { .. } => None,
        }
    }

    /// The scaling vectors, if this response carries them.
    pub fn scaling(&self) -> Option<(&[f32], &[f32])> {
        match self {
            Response::Plan(_) => None,
            Response::Scaling { u, v, .. } => Some((u.as_slice(), v.as_slice())),
        }
    }

    /// The sparse 1D transport list, if the exact path produced one.
    pub fn transport(&self) -> Option<&TransportList> {
        match self {
            Response::Scaling { transport, .. } => transport.as_ref(),
            Response::Plan(_) => None,
        }
    }
}

/// Successful solve payload.
#[derive(Debug)]
pub struct Solved {
    pub response: Response,
    pub report: SolveReport,
    /// Which backend executed it.
    pub backend: Backend,
    /// Which solver kind ran (native) — MAP-UOT for PJRT (the artifact is
    /// the fused kernel).
    pub solver: SolverKind,
    /// End-to-end latency from submission to completion (seconds);
    /// `latency_s - wait_s` is the solve share.
    pub latency_s: f64,
    /// Queue wait from submission to worker dequeue (seconds) — recorded
    /// separately so tail latency decomposes into wait + solve.
    pub wait_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn shape_key() {
        let (tx, _rx) = channel();
        let r = SolveRequest {
            id: 1,
            payload: Payload::Dense(Problem::random(8, 6, 0.5, 1)),
            reply: tx,
            submitted_at: std::time::Instant::now(),
        };
        assert_eq!(r.shape(), (8, 6));
    }

    #[test]
    fn geom_shape_key() {
        use crate::algo::CostKind;
        let (tx, _rx) = channel();
        let r = SolveRequest {
            id: 2,
            payload: Payload::Geom(GeomProblem::random(9, 4, 3, CostKind::SqEuclidean, 0.5, 0.7, 1)),
            reply: tx,
            submitted_at: std::time::Instant::now(),
        };
        assert_eq!(r.shape(), (9, 4));
    }
}
