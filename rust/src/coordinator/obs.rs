//! Labeled observability surface on top of the core [`Metrics`]
//! counters: per-backend-class latency/iteration histograms, live
//! gauges (in-flight solves, queue depth), warm-cache hit counters, and
//! the versioned machine-readable `stats` JSON the CLI prints.
//!
//! Labels are the flat [`BackendClass`] vocabulary rather than the full
//! backend × problem-class product: routing makes the product sparse
//! (e.g. a PJRT service never executes the CSR path, a geometric request
//! never lands on the dense path), so one label per *executed* backend
//! keeps every bucket meaningful. All five labels always appear in the
//! JSON — zero-count labels included — so the schema is fixed and a
//! consumer can diff two snapshots field-by-field.
//!
//! The JSON is hand-rolled (the crate is zero-dependency) and versioned
//! through [`STATS_SCHEMA_VERSION`]; any key rename or semantic change
//! must bump it. Non-finite floats (an overflow-bucket percentile reads
//! `inf`) render as JSON `null` — JSON has no `Infinity`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::metrics::{Snapshot, ITER_BUCKETS, LATENCY_BUCKETS_MS};

/// Version of the `stats` JSON schema. Bump on any key rename, removal,
/// or semantic change; additions may ride on the same version.
pub const STATS_SCHEMA_VERSION: u32 = 1;

/// Which backend actually executed a request — the label vocabulary of
/// the per-backend histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendClass {
    /// Native dense fused sweep.
    Dense,
    /// Native fused CSR sweep (`[solver] sparse`).
    Sparse,
    /// Materialization-free scaling-form sweep (`[solver] matfree`).
    Matfree,
    /// Exact near-linear 1D path.
    Oned,
    /// PJRT executor running AOT artifacts.
    Pjrt,
}

impl BackendClass {
    /// Every label, in stable serialization order.
    pub const ALL: [BackendClass; 5] = [
        BackendClass::Dense,
        BackendClass::Sparse,
        BackendClass::Matfree,
        BackendClass::Oned,
        BackendClass::Pjrt,
    ];

    /// Stable label name used as the JSON key.
    pub fn name(self) -> &'static str {
        match self {
            BackendClass::Dense => "dense",
            BackendClass::Sparse => "sparse",
            BackendClass::Matfree => "matfree",
            BackendClass::Oned => "oned",
            BackendClass::Pjrt => "pjrt",
        }
    }
}

/// One label's histograms (solve latency + iterations), lock-free.
#[derive(Debug, Default)]
struct LabelHist {
    count: AtomicU64,
    solve_total_us: AtomicU64,
    latency_buckets: [AtomicU64; 9], // 8 bounded + overflow
    iterations: AtomicU64,
    iter_buckets: [AtomicU64; 9], // 8 bounded + overflow
}

/// The labeled service-observability state, cheap to update from any
/// worker thread. Lives next to (not inside) [`Metrics`]: the core
/// counters stay label-free and dependency-free, this type owns the
/// label vocabulary and the JSON surface.
#[derive(Debug, Default)]
pub struct Obs {
    hists: [LabelHist; 5],
    in_flight: AtomicU64,
    queue_depth: AtomicU64,
    warm_hits: AtomicU64,
    warm_misses: AtomicU64,
}

impl Obs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed solve under its backend-class label.
    /// `solve_s` is the solve share (dequeue to completion) — the same
    /// figure [`Metrics::record_latency`] takes, not end-to-end.
    ///
    /// [`Metrics::record_latency`]: crate::coordinator::metrics::Metrics::record_latency
    pub fn record(&self, class: BackendClass, solve_s: f64, iters: u64) {
        // uotlint: allow(panic) — the enum discriminant indexes the
        // 5-label array; `ALL` and `hists` share their length.
        let h = &self.hists[class as usize];
        let ms = solve_s * 1e3;
        let idx = LATENCY_BUCKETS_MS.iter().position(|&b| ms <= b).unwrap_or(8);
        // uotlint: allow(panic) — idx is position()'s in-range index over an
        // 8-element table or the literal 8; the bucket array has length 9.
        h.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        let iidx = ITER_BUCKETS.iter().position(|&b| iters <= b).unwrap_or(8);
        // uotlint: allow(panic) — same in-range argument as above.
        h.iter_buckets[iidx].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        h.solve_total_us.fetch_add((solve_s * 1e6) as u64, Ordering::Relaxed);
        h.iterations.fetch_add(iters, Ordering::Relaxed);
    }

    /// A worker started executing a request. Pair with [`Obs::exit`].
    pub fn enter(&self) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    /// The paired request finished (success or failure).
    pub fn exit(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Publish the batcher's current queue depth (sampled per batch).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    /// Fold a warm-cache delta in (hits/misses since the caller's last
    /// fold — workers keep per-session baselines and add differences).
    pub fn add_warm(&self, hits: u64, misses: u64) {
        self.warm_hits.fetch_add(hits, Ordering::Relaxed);
        self.warm_misses.fetch_add(misses, Ordering::Relaxed);
    }

    /// Point-in-time snapshot for reporting.
    pub fn snapshot(&self) -> ObsSnapshot {
        let labels = BackendClass::ALL.map(|class| {
            // uotlint: allow(panic) — the enum discriminant indexes the
            // 5-label array; `ALL` and `hists` share their length.
            let h = &self.hists[class as usize];
            let count = h.count.load(Ordering::Relaxed);
            LabelSnapshot {
                class,
                count,
                mean_latency_ms: if count == 0 {
                    0.0
                } else {
                    h.solve_total_us.load(Ordering::Relaxed) as f64 / count as f64 / 1e3
                },
                latency_buckets: h.latency_buckets.each_ref().map(|a| a.load(Ordering::Relaxed)),
                iterations: h.iterations.load(Ordering::Relaxed),
                iter_buckets: h.iter_buckets.each_ref().map(|a| a.load(Ordering::Relaxed)),
            }
        });
        ObsSnapshot {
            labels,
            in_flight: self.in_flight.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            warm_misses: self.warm_misses.load(Ordering::Relaxed),
        }
    }
}

/// One label's immutable snapshot.
#[derive(Debug, Clone, Copy)]
pub struct LabelSnapshot {
    pub class: BackendClass,
    /// Requests recorded under this label.
    pub count: u64,
    /// Mean solve latency (ms); 0.0 when the label is empty.
    pub mean_latency_ms: f64,
    /// Solve-latency histogram (bounds: [`LATENCY_BUCKETS_MS`] +
    /// overflow).
    pub latency_buckets: [u64; 9],
    /// Total iterations executed under this label.
    pub iterations: u64,
    /// Iteration histogram (bounds: [`ITER_BUCKETS`] + overflow).
    pub iter_buckets: [u64; 9],
}

impl LabelSnapshot {
    /// Mean iterations per request under this label; 0.0 when empty.
    pub fn mean_iters(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.iterations as f64 / self.count as f64
        }
    }
}

/// Immutable labeled-observability snapshot.
#[derive(Debug, Clone, Copy)]
pub struct ObsSnapshot {
    /// Per-label histograms, in [`BackendClass::ALL`] order.
    pub labels: [LabelSnapshot; 5],
    /// Requests currently executing on a worker.
    pub in_flight: u64,
    /// Batcher queue depth at the last batch pop.
    pub queue_depth: u64,
    pub warm_hits: u64,
    pub warm_misses: u64,
}

impl ObsSnapshot {
    /// Warm-cache hit rate in [0, 1]; 0.0 when no lookups were folded
    /// in (warm starting off, or no geometric/dense repeats yet).
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_misses;
        if total == 0 {
            0.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

/// Render an `f64` as a JSON number; non-finite values (overflow-bucket
/// percentiles read `inf`) become `null`.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

/// Render a 9-slot histogram as a JSON array of counts.
fn jarr(buckets: &[u64; 9]) -> String {
    let mut out = String::with_capacity(64);
    out.push('[');
    for (i, b) in buckets.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&b.to_string());
    }
    out.push(']');
    out
}

/// Serialize the core [`Snapshot`] plus the labeled [`ObsSnapshot`] into
/// the versioned `stats` JSON — the machine-readable surface behind the
/// `stats` CLI report mode. One line, no trailing newline; every key is
/// always present (fixed schema), floats are 6-decimal fixed-point, and
/// non-finite floats are `null`.
pub fn stats_json(core: &Snapshot, obs: &ObsSnapshot) -> String {
    use std::fmt::Write as _;
    let mut o = String::with_capacity(4096);
    let _ = write!(o, "{{\"schema_version\":{STATS_SCHEMA_VERSION}");
    let _ = write!(
        o,
        ",\"counters\":{{\"submitted\":{},\"completed\":{},\"rejected\":{},\"failed\":{},\
         \"batches\":{},\"iterations\":{}}}",
        core.submitted, core.completed, core.rejected, core.failed, core.batches, core.iterations
    );
    let _ = write!(
        o,
        ",\"solve_ms\":{{\"mean\":{},\"p50\":{},\"p99\":{},\"buckets\":{}}}",
        jnum(core.mean_latency_ms),
        jnum(core.latency_percentile_ms(50.0)),
        jnum(core.latency_percentile_ms(99.0)),
        jarr(&core.latency_buckets)
    );
    let _ = write!(
        o,
        ",\"wait_ms\":{{\"mean\":{},\"p50\":{},\"p99\":{},\"count\":{},\"buckets\":{}}}",
        jnum(core.mean_wait_ms),
        jnum(core.wait_percentile_ms(50.0)),
        jnum(core.wait_percentile_ms(99.0)),
        core.wait_count,
        jarr(&core.wait_buckets)
    );
    let _ = write!(
        o,
        ",\"iters\":{{\"mean\":{},\"p50\":{},\"p99\":{},\"requests\":{},\"buckets\":{}}}",
        jnum(core.mean_iters()),
        jnum(core.iters_percentile(50.0)),
        jnum(core.iters_percentile(99.0)),
        core.iter_requests,
        jarr(&core.iter_buckets)
    );
    let _ = write!(
        o,
        ",\"gauges\":{{\"in_flight\":{},\"queue_depth\":{}}}",
        obs.in_flight, obs.queue_depth
    );
    let _ = write!(
        o,
        ",\"warm\":{{\"hits\":{},\"misses\":{},\"hit_rate\":{}}}",
        obs.warm_hits,
        obs.warm_misses,
        jnum(obs.warm_hit_rate())
    );
    o.push_str(",\"backends\":{");
    for (i, l) in obs.labels.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        let _ = write!(
            o,
            "\"{}\":{{\"count\":{},\"mean_latency_ms\":{},\"mean_iters\":{},\
             \"latency_buckets\":{},\"iter_buckets\":{}}}",
            l.class.name(),
            l.count,
            jnum(l.mean_latency_ms),
            jnum(l.mean_iters()),
            jarr(&l.latency_buckets),
            jarr(&l.iter_buckets)
        );
    }
    o.push_str("}}");
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;

    /// Minimal structural JSON check: balanced braces/brackets outside
    /// strings, and no bare `inf`/`NaN` tokens anywhere.
    fn assert_wellformed(json: &str) {
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for c in json.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' | '[' => depth += 1,
                '}' | ']' => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0, "unbalanced close in {json}");
        }
        assert_eq!(depth, 0, "unbalanced JSON: {json}");
        assert!(!in_str, "unterminated string: {json}");
        assert!(!json.contains("inf") && !json.contains("NaN"), "non-finite leaked: {json}");
    }

    #[test]
    fn labeled_histograms_and_hit_rate() {
        let obs = Obs::new();
        obs.record(BackendClass::Dense, 0.003, 40);
        obs.record(BackendClass::Dense, 0.004, 44);
        obs.record(BackendClass::Oned, 0.0002, 1);
        obs.enter();
        obs.set_queue_depth(7);
        obs.add_warm(3, 1);
        let s = obs.snapshot();
        assert_eq!(s.in_flight, 1);
        assert_eq!(s.queue_depth, 7);
        assert!((s.warm_hit_rate() - 0.75).abs() < 1e-12);
        let dense = s.labels[0];
        assert_eq!(dense.class, BackendClass::Dense);
        assert_eq!(dense.count, 2);
        assert!((dense.mean_latency_ms - 3.5).abs() < 1e-9);
        assert!((dense.mean_iters() - 42.0).abs() < 1e-9);
        assert_eq!(dense.latency_buckets[3], 2, "3 ms and 4 ms land in the 5 ms bucket");
        let oned = s.labels[3];
        assert_eq!(oned.count, 1);
        assert_eq!(oned.latency_buckets[0], 1, "0.2 ms lands in the 0.5 ms bucket");
        // Untouched labels stay at zero with total means.
        assert_eq!(s.labels[4].count, 0);
        assert_eq!(s.labels[4].mean_latency_ms, 0.0);
        assert_eq!(s.labels[4].mean_iters(), 0.0);
        obs.exit();
        assert_eq!(obs.snapshot().in_flight, 0);
    }

    #[test]
    fn stats_json_is_versioned_wellformed_and_fixed_schema() {
        let m = Metrics::new();
        m.record_wait(0.0004);
        m.record_latency(0.003);
        m.record_iters(40);
        let obs = Obs::new();
        obs.record(BackendClass::Sparse, 0.003, 40);
        let json = stats_json(&m.snapshot(), &obs.snapshot());
        assert_wellformed(&json);
        assert!(json.starts_with("{\"schema_version\":1,"), "{json}");
        // Every label key appears even at count 0 — fixed schema.
        for key in ["\"dense\":", "\"sparse\":", "\"matfree\":", "\"oned\":", "\"pjrt\":"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        for key in ["counters", "solve_ms", "wait_ms", "iters", "gauges", "warm", "backends"] {
            assert!(json.contains(&format!("\"{key}\":")), "missing {key} in {json}");
        }
        assert!(json.contains("\"p99\":5.000000"), "solve p99 reads the 5 ms bucket: {json}");
    }

    #[test]
    fn non_finite_values_render_null() {
        let m = Metrics::new();
        m.record_latency(9.0); // 9000 ms -> overflow bucket, percentiles read inf
        let obs = Obs::new();
        let json = stats_json(&m.snapshot(), &obs.snapshot());
        assert_wellformed(&json);
        assert!(json.contains("\"p99\":null"), "overflow percentile must be null: {json}");
        assert!(jnum(f64::NAN) == "null" && jnum(f64::INFINITY) == "null");
    }
}
