//! The solver service: worker pool draining the batcher, routing each
//! request to the native solvers or the PJRT executor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::algo::{GeomProblem, Problem, SolverKind, SolverSession, SparseProblem};
use crate::config::{Backend, OnedMode, ServiceConfig};
use crate::coordinator::batcher::{Batcher, FullPolicy};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::obs::{self, BackendClass, Obs};
use crate::coordinator::pjrt_exec::{self, PjrtHandle};
use crate::coordinator::request::{Payload, Response, SolveRequest, SolveResponse, Solved};
use crate::coordinator::router::{self, ProblemClass};
use crate::error::{Error, Result};
use crate::util::telemetry;

/// A running solver service.
pub struct Service {
    cfg: ServiceConfig,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    obs: Arc<Obs>,
    workers: Vec<JoinHandle<()>>,
    pjrt: Option<(PjrtHandle, JoinHandle<()>)>,
    next_id: AtomicU64,
}

impl Service {
    /// Start workers (and the PJRT executor when configured).
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        // A sparse service is misconfigured loudly, not per-request: the
        // fused CSR sweep is the MAP-UOT algorithm, and the threshold must
        // be a usable number.
        if let Some(threshold) = cfg.sparse {
            if !threshold.is_finite() || threshold < 0.0 {
                return Err(Error::Config(format!(
                    "sparse threshold {threshold} must be finite and >= 0"
                )));
            }
            if cfg.solver != SolverKind::MapUot {
                return Err(Error::Config(
                    "[solver] sparse requires kind = mapuot (the fused CSR kernel)".into(),
                ));
            }
            if cfg.backend == Backend::Pjrt {
                return Err(Error::Config(
                    "[solver] sparse runs on the native backend only".into(),
                ));
            }
        }
        // A matfree service is likewise misconfigured loudly at start: the
        // scaling-form sweep is the MAP-UOT algorithm, PJRT executes dense
        // artifacts, and one worker session cannot default two conversion
        // backends at once.
        if cfg.matfree {
            if cfg.solver != SolverKind::MapUot {
                return Err(Error::Config(
                    "[solver] matfree requires kind = mapuot (the scaling-form sweep)".into(),
                ));
            }
            if cfg.backend == Backend::Pjrt {
                return Err(Error::Config(
                    "[solver] matfree runs on the native backend only".into(),
                ));
            }
            if cfg.sparse.is_some() {
                return Err(Error::Config(
                    "[solver] matfree and [solver] sparse are mutually exclusive".into(),
                ));
            }
        }
        // Accelerator knobs fail fast too: TI is a MAP-UOT correction, the
        // ε ladder only exists on the matfree path, and a ladder that does
        // not descend is a typo.
        if cfg.ti && cfg.solver != SolverKind::MapUot {
            return Err(Error::Config(
                "[solver] ti requires kind = mapuot (TI corrects the MAP-UOT sweep)".into(),
            ));
        }
        if let Some((from, steps)) = cfg.eps_schedule {
            if !cfg.matfree {
                return Err(Error::Config(
                    "[solver] eps_schedule requires [solver] matfree = on (the ladder \
                     schedules the kernel bandwidth)"
                        .into(),
                ));
            }
            if !(from.is_finite() && from > 0.0) {
                return Err(Error::Config(format!(
                    "[solver] eps_schedule start bandwidth {from} must be finite and > 0"
                )));
            }
            if steps == 0 {
                return Err(Error::Config(
                    "[solver] eps_schedule needs at least one coarse rung (steps >= 1)".into(),
                ));
            }
        }
        // The 1D fast-path policy fails fast too: `on` hard-requires the
        // geometric protocol (only matfree services accept geom requests,
        // so oned = on without it could never fire), and the ε ladder
        // schedules iterative matfree sweeps the exact path does not run.
        if cfg.oned == OnedMode::On {
            if !cfg.matfree {
                return Err(Error::Config(
                    "[solver] oned = on requires [solver] matfree = on (geometric \
                     requests enter through the matfree protocol)"
                        .into(),
                ));
            }
            if cfg.eps_schedule.is_some() {
                return Err(Error::Config(
                    "[solver] oned = on and [solver] eps_schedule are mutually exclusive \
                     (the ladder amortizes matfree sweeps; the exact 1D path has none)"
                        .into(),
                ));
            }
        }
        let batcher = Arc::new(Batcher::new(
            cfg.queue_cap,
            cfg.batch_max,
            Duration::from_micros(cfg.batch_wait_us),
        ));
        let metrics = Arc::new(Metrics::new());
        let obs = Arc::new(Obs::new());
        // A traced service turns the span recorder on before any worker
        // runs — the per-thread rings then register lazily on each
        // worker's first recorded span (the documented warmup
        // allocation), and `shutdown` exports whatever was captured.
        if cfg.trace.is_some() {
            telemetry::set_enabled(true);
        }

        let pjrt = match cfg.backend {
            Backend::Pjrt => Some(pjrt_exec::spawn(cfg.artifacts_dir.clone())?),
            Backend::Native => None,
        };
        let pjrt_handle = pjrt.as_ref().map(|(h, _)| h.clone());

        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers.max(1) {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let obs_w = Arc::clone(&obs);
            let cfg_w = cfg.clone();
            let pjrt_w = pjrt_handle.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("uot-worker-{w}"))
                    .spawn(move || worker_loop(&batcher, &metrics, &obs_w, &cfg_w, pjrt_w.as_ref()))
                    .map_err(|e| Error::Service(format!("spawn worker: {e}")))?,
            );
        }
        Ok(Self { cfg, batcher, metrics, obs, workers, pjrt, next_id: AtomicU64::new(1) })
    }

    /// Submit a dense problem; returns the reply channel. `Err` on
    /// queue-full (load shedding) or after shutdown.
    pub fn submit(&self, problem: Problem) -> Result<Receiver<SolveResponse>> {
        self.submit_payload(Payload::Dense(problem))
    }

    /// Submit a geometric point-cloud problem for the geometric backends.
    /// Rejected up front (typed [`Error::Config`]) unless the service was
    /// started with `ServiceConfig.matfree` — a geom request must fail at
    /// the boundary, not inside a worker. O((m+n)·d) on the wire and
    /// O(m+n) back: the worker classifies the request
    /// (`ServiceConfig.oned` policy) between the exact near-linear 1D
    /// sweep and the iterative matfree sweep, and either way answers with
    /// [`Response::Scaling`] — never a densified m×n plan.
    pub fn submit_geom(&self, problem: GeomProblem) -> Result<Receiver<SolveResponse>> {
        if !self.cfg.matfree {
            return Err(Error::Config(
                "geometric requests need [solver] matfree = on (ServiceConfig.matfree)".into(),
            ));
        }
        self.submit_payload(Payload::Geom(problem))
    }

    fn submit_payload(&self, payload: Payload) -> Result<Receiver<SolveResponse>> {
        let (tx, rx) = channel();
        let req = SolveRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            payload,
            reply: tx,
            submitted_at: std::time::Instant::now(),
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.batcher.push(req, FullPolicy::Reject) {
            Ok(()) => Ok(rx),
            Err(_) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Service("queue full".into()))
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn solve_blocking(&self, problem: Problem) -> Result<Solved> {
        Self::await_response(self.submit(problem)?)
    }

    /// Convenience: submit a geometric problem and wait.
    pub fn solve_geom_blocking(&self, problem: GeomProblem) -> Result<Solved> {
        Self::await_response(self.submit_geom(problem)?)
    }

    fn await_response(rx: Receiver<SolveResponse>) -> Result<Solved> {
        let resp = rx
            .recv()
            .map_err(|_| Error::Service("service dropped request".into()))?;
        resp.result
    }

    pub fn metrics(&self) -> crate::coordinator::metrics::Snapshot {
        self.metrics.snapshot()
    }

    /// Labeled observability snapshot (per-backend histograms, gauges,
    /// warm-cache counters).
    pub fn obs(&self) -> obs::ObsSnapshot {
        self.obs.snapshot()
    }

    /// The versioned machine-readable `stats` JSON for this service —
    /// core counters plus the labeled surface, in one line.
    pub fn stats_json(&self) -> String {
        obs::stats_json(&self.metrics.snapshot(), &self.obs.snapshot())
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    pub fn queue_len(&self) -> usize {
        self.batcher.len()
    }

    /// Drain and stop. Pending requests are completed first. When the
    /// service was started with a `trace` path, the recorded span trace
    /// is exported here, after every worker has quiesced.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some((h, j)) = self.pjrt.take() {
            h.shutdown();
            let _ = j.join();
        }
        if let Some(path) = self.cfg.trace.as_deref() {
            let events = telemetry::snapshot_spans();
            if let Err(e) = telemetry::export_trace(path, &events) {
                eprintln!("trace export failed ({path}): {e}");
            }
        }
    }
}

/// Which backend class executed a solved request. Derivable after the
/// fact from the response shape plus the service config — routing makes
/// the full backend × problem-class product sparse (see
/// [`crate::coordinator::obs`]), so no extra plumbing through `Solved`.
fn backend_class(cfg: &ServiceConfig, s: &Solved) -> BackendClass {
    if s.backend == Backend::Pjrt {
        return BackendClass::Pjrt;
    }
    match &s.response {
        Response::Scaling { transport: Some(_), .. } => BackendClass::Oned,
        Response::Scaling { .. } => BackendClass::Matfree,
        Response::Plan(_) if cfg.sparse.is_some() => BackendClass::Sparse,
        Response::Plan(_) => BackendClass::Dense,
    }
}

fn worker_loop(
    batcher: &Batcher,
    metrics: &Metrics,
    obs: &Obs,
    cfg: &ServiceConfig,
    pjrt: Option<&PjrtHandle>,
) {
    // One reusable session per worker: the service's steady state is a
    // stream of same-shape problems (the batcher groups by shape), so after
    // the first solve of each shape the native path allocates only the
    // result plan it hands back. With `solver_threads > 1` the session also
    // owns one persistent solver pool (spawned on the first request, parked
    // between iterations), so this OS thread reuses the same workers for
    // every solve it ever executes — no spawn/join on the request path.
    let mut session: Option<SolverSession> = None;
    // The session's warm-cache counters are monotonic totals; fold only
    // the delta since this worker's last batch into the shared gauge.
    let mut warm_seen = (0u64, 0u64);
    while let Some(batch) = batcher.pop_batch() {
        metrics.record_batch(batch.len());
        obs.set_queue_depth(batcher.len());
        for req in batch {
            obs.enter();
            let result = execute(cfg, pjrt, &mut session, &req);
            obs.exit();
            match &result {
                Ok(s) => {
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    // record_iters folds the count into `iterations` and
                    // the per-request histogram the ablation reads.
                    metrics.record_iters(s.report.iters as u64);
                    // Decomposed latency: queue wait vs the solve share.
                    metrics.record_wait(s.wait_s);
                    metrics.record_latency(s.latency_s - s.wait_s);
                    let class = backend_class(cfg, s);
                    obs.record(class, s.latency_s - s.wait_s, s.report.iters as u64);
                }
                Err(_) => {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Receiver may have given up; dropping the response is fine.
            let _ = req.reply.send(SolveResponse { id: req.id, result });
        }
        if let Some((hits, misses)) = session.as_ref().and_then(|s| s.warm_stats()) {
            obs.add_warm(hits.saturating_sub(warm_seen.0), misses.saturating_sub(warm_seen.1));
            warm_seen = (hits, misses);
        }
    }
}

fn execute(
    cfg: &ServiceConfig,
    pjrt: Option<&PjrtHandle>,
    session: &mut Option<SolverSession>,
    req: &SolveRequest,
) -> Result<Solved> {
    // Entering execution ends the queue-wait clock: everything from here
    // on (including conversions and routing) is the solve share.
    let wait_s = req.submitted_at.elapsed().as_secs_f64();
    let builder = || {
        let mut b = SolverSession::builder(cfg.solver)
            .threads(cfg.solver_threads)
            .backend(cfg.parallel)
            .affinity(cfg.affinity)
            .kernel(cfg.kernel)
            .tile(cfg.tile)
            .stop(cfg.stop)
            .warm(cfg.warm)
            .ti(cfg.ti);
        if let Some((from, steps)) = cfg.eps_schedule {
            b = b.eps_schedule(from, steps);
        }
        b
    };
    let (response, report, backend) = match (&req.payload, pjrt) {
        // Geometric requests run the geometric backends on this worker's
        // reusable session (defensive re-checks of the start-time
        // validation: submit_geom already gates on cfg.matfree, and a
        // matfree service can never have a PJRT executor). The response is
        // the solver's native O(m+n) representation — scaling vectors,
        // plus the sparse transport list when the exact 1D path ran.
        (Payload::Geom(g), _) => {
            if !cfg.matfree || pjrt.is_some() {
                return Err(Error::Config(
                    "geometric request on a service without [solver] matfree".into(),
                ));
            }
            // Problem-class routing (`[solver] oned` policy). An ε ladder
            // pins auto mode to matfree: the ladder amortizes iterative
            // sweeps the exact path does not run (oned = on + ladder is
            // already rejected at start).
            let class = match cfg.oned {
                OnedMode::Off => {
                    ProblemClass::General { reason: "[solver] oned = off".into() }
                }
                _ if cfg.eps_schedule.is_some() => ProblemClass::General {
                    reason: "[solver] eps_schedule pins geometric requests to the \
                             iterative matfree path"
                        .into(),
                },
                _ => router::classify_geom(g, router::ONED_AXIS_TOL),
            };
            match class {
                ProblemClass::Oned { axis } => {
                    // Effectively-1D problems (d > 1, one varying axis)
                    // solve their validated 1D projection.
                    let projected;
                    let p1 = if g.d == 1 {
                        g
                    } else {
                        projected = router::project_oned(g, axis)?;
                        &projected
                    };
                    let sess = session.get_or_insert_with(|| builder().build_oned(p1));
                    let report = sess.solve_oned(p1)?;
                    let (u, v) = sess
                        .oned_scaling()
                        .ok_or_else(|| Error::Service("solve_oned left no scalings".into()))?;
                    let response = Response::Scaling {
                        u: u.to_vec(),
                        v: v.to_vec(),
                        transport: sess.oned_transport().cloned(),
                    };
                    (response, report, Backend::Native)
                }
                ProblemClass::General { reason } => {
                    if cfg.oned == OnedMode::On {
                        return Err(Error::InvalidProblem(format!(
                            "[solver] oned = on, but the request is not 1D-eligible: {reason}"
                        )));
                    }
                    let sess = session.get_or_insert_with(|| builder().build_matfree(g));
                    let report = sess.solve_matfree(g)?;
                    let (u, v) = sess
                        .matfree_scaling()
                        .ok_or_else(|| Error::Service("solve_matfree left no scalings".into()))?;
                    let response =
                        Response::Scaling { u: u.to_vec(), v: v.to_vec(), transport: None };
                    (response, report, Backend::Native)
                }
            }
        }
        (Payload::Dense(problem), Some(handle)) => {
            let (plan, report) = handle.solve(problem.clone(), cfg.stop)?;
            (Response::Plan(plan), report, Backend::Pjrt)
        }
        (Payload::Dense(problem), None) => {
            match cfg.sparse {
                // Sparse service: convert the request's plan to CSR and
                // run the fused CSR backend; the worker's session (and its
                // pool) is reused across requests, so after the first
                // solve of each structure the hot loop is allocation-free.
                // The response is densified — the request/response types
                // stay dense at the service boundary.
                Some(threshold) => {
                    let sp = SparseProblem::from_problem(problem, threshold)?;
                    // A threshold that wipes the whole plan would "solve"
                    // to an all-zero response flagged converged (nothing
                    // can move, so the delta rule fires immediately) —
                    // surface the misconfiguration as a typed per-request
                    // error instead of silently returning garbage.
                    if sp.nnz() == 0 {
                        return Err(Error::InvalidProblem(format!(
                            "sparse threshold {threshold} dropped every plan entry \
                             (all values <= threshold)"
                        )));
                    }
                    let sess = session.get_or_insert_with(|| builder().build_sparse(&sp));
                    let report = sess.solve_sparse(&sp)?;
                    let plan = sess
                        .sparse_plan()
                        .ok_or_else(|| Error::Service("solve_sparse left no CSR plan".into()))?
                        .to_dense();
                    (Response::Plan(plan), report, Backend::Native)
                }
                None => {
                    let sess = session.get_or_insert_with(|| builder().build(problem));
                    let (plan, report) = sess.solve_cloned(problem)?;
                    (Response::Plan(plan), report, Backend::Native)
                }
            }
        }
    };
    Ok(Solved {
        response,
        report,
        backend,
        solver: cfg.solver,
        latency_s: req.submitted_at.elapsed().as_secs_f64(),
        wait_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_cfg(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            solver: SolverKind::MapUot,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn solve_blocking_roundtrip() {
        let svc = Service::start(native_cfg(2)).unwrap();
        let p = Problem::random(24, 24, 0.8, 1);
        let solved = svc.solve_blocking(p).unwrap();
        assert!(solved.report.converged);
        assert_eq!(solved.backend, Backend::Native);
        assert_eq!(solved.response.plan().expect("dense request answers dense").rows(), 24);
        let m = svc.metrics();
        assert_eq!(m.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let svc = Arc::new(Service::start(native_cfg(4)).unwrap());
        let mut rxs = Vec::new();
        for seed in 0..32u64 {
            rxs.push(svc.submit(Problem::random(16, 16, 0.7, seed)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.is_ok());
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 32);
        assert_eq!(m.submitted, 32);
        assert!(m.mean_batch_size >= 1.0);
        Arc::try_unwrap(svc).ok().unwrap().shutdown();
    }

    #[test]
    fn threaded_workers_use_persistent_pools() {
        // Two coordinator workers, each with a 2-thread solver pool: many
        // same-shape requests reuse each worker's pool and workspace.
        let mut cfg = native_cfg(2);
        cfg.solver_threads = 2;
        let svc = Arc::new(Service::start(cfg).unwrap());
        let mut rxs = Vec::new();
        for seed in 0..16u64 {
            rxs.push(svc.submit(Problem::random(24, 24, 0.7, seed)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.unwrap().report.converged);
        }
        assert_eq!(svc.metrics().completed, 16);
        Arc::try_unwrap(svc).ok().unwrap().shutdown();
    }

    #[test]
    fn sparse_service_roundtrip_matches_direct_sparse_solve() {
        let mut cfg = native_cfg(2);
        cfg.sparse = Some(1.0);
        cfg.solver_threads = 2;
        let svc = Service::start(cfg).unwrap();
        let p = Problem::random(24, 24, 0.8, 5);
        let solved = svc.solve_blocking(p.clone()).unwrap();
        assert_eq!(solved.backend, Backend::Native);
        let plan = solved.response.plan().expect("sparse responses stay dense");
        assert_eq!((plan.rows(), plan.cols()), (24, 24));
        // The served result is the densified CSR solve, bit-for-bit.
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut direct = SolverSession::builder(SolverKind::MapUot)
            .threads(2)
            .stop(svc.config().stop)
            .build_sparse(&sp);
        let direct_report = direct.solve_sparse(&sp).unwrap();
        assert_eq!(solved.report.iters, direct_report.iters);
        assert_eq!(
            plan.as_slice(),
            direct.sparse_plan().unwrap().to_dense().as_slice()
        );
        svc.shutdown();
    }

    #[test]
    fn sparse_service_rejects_threshold_that_wipes_the_plan() {
        // Plan entries are in [0.05, 2.0); a 2.5 threshold drops them all.
        let mut cfg = native_cfg(1);
        cfg.sparse = Some(2.5);
        let svc = Service::start(cfg).unwrap();
        match svc.solve_blocking(Problem::random(16, 16, 0.7, 3)) {
            Err(Error::InvalidProblem(msg)) => {
                assert!(msg.contains("dropped every plan entry"), "{msg}")
            }
            other => panic!("expected InvalidProblem, got {other:?}"),
        }
        assert_eq!(svc.metrics().failed, 1);
        svc.shutdown();
    }

    #[test]
    fn sparse_service_rejects_bad_config_at_start() {
        let mut cfg = native_cfg(1);
        cfg.sparse = Some(1.0);
        cfg.solver = SolverKind::Pot;
        assert!(Service::start(cfg).is_err(), "sparse + POT must fail fast");
        let mut cfg = native_cfg(1);
        cfg.sparse = Some(f32::NAN);
        assert!(Service::start(cfg).is_err(), "NaN threshold must fail fast");
        let mut cfg = native_cfg(1);
        cfg.sparse = Some(-1.0);
        assert!(Service::start(cfg).is_err(), "negative threshold must fail fast");
    }

    #[test]
    fn matfree_service_roundtrip_matches_direct_matfree_solve() {
        use crate::algo::{CostKind, GeomProblem};
        let mut cfg = native_cfg(2);
        cfg.matfree = true;
        cfg.solver_threads = 2;
        let svc = Service::start(cfg).unwrap();
        let g = GeomProblem::random(24, 18, 3, CostKind::SqEuclidean, 0.25, 0.8, 5);
        let solved = svc.solve_geom_blocking(g.clone()).unwrap();
        assert_eq!(solved.backend, Backend::Native);
        // d = 3 SqEuclidean is not 1D-eligible, so the iterative matfree
        // path serves it — as scaling vectors, never a densified plan.
        let (u, v) = solved.response.scaling().expect("geom responses are Scaling");
        assert_eq!((u.len(), v.len()), (24, 18));
        assert!(solved.response.transport().is_none(), "matfree leaves no transport list");
        // The served scalings are the direct matfree solve, bit-for-bit.
        let mut direct = SolverSession::builder(SolverKind::MapUot)
            .threads(2)
            .stop(svc.config().stop)
            .build_matfree(&g);
        let direct_report = direct.solve_matfree(&g).unwrap();
        assert_eq!(solved.report.iters, direct_report.iters);
        let (du, dv) = direct.matfree_scaling().unwrap();
        assert_eq!(u, du);
        assert_eq!(v, dv);
        // Dense requests still work on the same matfree-enabled service.
        let dense = svc.solve_blocking(Problem::random(16, 16, 0.7, 1)).unwrap();
        assert!(dense.report.iters > 0);
        svc.shutdown();
    }

    /// Satellite 1 + tentpole routing: a `d == 1` Euclidean request
    /// auto-routes to the exact 1D sweep and answers with the scaling
    /// vectors plus the sparse monotone transport list, bit-equal to a
    /// direct `solve_oned` on a fresh session.
    #[test]
    fn oned_service_roundtrip_matches_direct_oned_solve() {
        use crate::algo::{CostKind, GeomProblem};
        let mut cfg = native_cfg(2);
        cfg.matfree = true;
        let svc = Service::start(cfg).unwrap();
        let g = GeomProblem::random(24, 18, 1, CostKind::Euclidean, 0.5, 0.8, 5);
        let solved = svc.solve_geom_blocking(g.clone()).unwrap();
        assert_eq!(solved.backend, Backend::Native);
        let (u, v) = solved.response.scaling().expect("geom responses are Scaling");
        let transport = solved.response.transport().expect("the 1D path couples its answer");

        let mut direct = SolverSession::builder(SolverKind::MapUot)
            .stop(svc.config().stop)
            .build_oned(&g);
        let direct_report = direct.solve_oned(&g).unwrap();
        assert_eq!(solved.report.iters, direct_report.iters);
        let (du, dv) = direct.oned_scaling().unwrap();
        assert_eq!(u, du, "served u is the direct solve bit-for-bit");
        assert_eq!(v, dv, "served v is the direct solve bit-for-bit");
        let dt = direct.oned_transport().unwrap();
        assert_eq!(transport.entries, dt.entries);
        assert_eq!(transport.destroyed, dt.destroyed);
        assert_eq!(transport.created, dt.created);
        svc.shutdown();
    }

    /// An effectively-1D request (d = 3, one varying axis) also routes to
    /// the exact path under auto mode.
    #[test]
    fn oned_service_detects_effectively_1d_requests() {
        use crate::algo::{CostKind, GeomProblem};
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        let svc = Service::start(cfg).unwrap();
        let mut g = GeomProblem::random(16, 12, 3, CostKind::Euclidean, 0.5, 0.8, 7);
        for point in g.x.chunks_exact_mut(3).chain(g.y.chunks_exact_mut(3)) {
            point[0] = 0.5;
            point[2] = 0.25;
        }
        let solved = svc.solve_geom_blocking(g).unwrap();
        assert!(solved.report.converged);
        assert!(
            solved.response.transport().is_some(),
            "a transport list proves the exact 1D path served the request"
        );
        svc.shutdown();
    }

    /// `oned = on` makes ineligibility a typed per-request error;
    /// `oned = off` pins even eligible requests to matfree.
    #[test]
    fn oned_policy_on_rejects_and_off_pins_to_matfree() {
        use crate::algo::{CostKind, GeomProblem};
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.oned = OnedMode::On;
        let svc = Service::start(cfg).unwrap();
        let bad = GeomProblem::random(8, 8, 3, CostKind::SqEuclidean, 0.5, 0.7, 3);
        match svc.solve_geom_blocking(bad) {
            Err(Error::InvalidProblem(msg)) => {
                assert!(msg.contains("not 1D-eligible"), "{msg}")
            }
            other => panic!("oned = on must reject ineligible requests, got {other:?}"),
        }
        svc.shutdown();

        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.oned = OnedMode::Off;
        let svc = Service::start(cfg).unwrap();
        let eligible = GeomProblem::random(8, 8, 1, CostKind::Euclidean, 0.5, 0.7, 3);
        let solved = svc.solve_geom_blocking(eligible).unwrap();
        assert!(
            solved.response.transport().is_none(),
            "oned = off must serve the request on the matfree path"
        );
        svc.shutdown();
    }

    #[test]
    fn oned_service_rejects_bad_config_at_start() {
        let mut cfg = native_cfg(1);
        cfg.oned = OnedMode::On;
        assert!(Service::start(cfg).is_err(), "oned = on without matfree must fail fast");
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.oned = OnedMode::On;
        cfg.eps_schedule = Some((2.0, 3));
        assert!(Service::start(cfg).is_err(), "oned = on + eps_schedule must fail fast");
    }

    #[test]
    fn geom_requests_rejected_without_matfree_config() {
        use crate::algo::{CostKind, GeomProblem};
        let svc = Service::start(native_cfg(1)).unwrap();
        let g = GeomProblem::random(8, 8, 2, CostKind::Euclidean, 0.5, 0.7, 1);
        match svc.submit_geom(g) {
            Err(Error::Config(msg)) => assert!(msg.contains("matfree"), "{msg}"),
            other => panic!("expected Config error, got {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn matfree_service_rejects_bad_config_at_start() {
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.solver = SolverKind::Coffee;
        assert!(Service::start(cfg).is_err(), "matfree + COFFEE must fail fast");
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.backend = Backend::Pjrt;
        assert!(Service::start(cfg).is_err(), "matfree + PJRT must fail fast");
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.sparse = Some(0.5);
        assert!(Service::start(cfg).is_err(), "matfree + sparse must fail fast");
    }

    #[test]
    fn accelerator_config_rejected_at_start() {
        let mut cfg = native_cfg(1);
        cfg.ti = true;
        cfg.solver = SolverKind::Pot;
        assert!(Service::start(cfg).is_err(), "ti + POT must fail fast");
        let mut cfg = native_cfg(1);
        cfg.eps_schedule = Some((2.0, 3));
        assert!(Service::start(cfg).is_err(), "eps_schedule without matfree must fail fast");
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.eps_schedule = Some((f32::NAN, 3));
        assert!(Service::start(cfg).is_err(), "NaN ladder start must fail fast");
        let mut cfg = native_cfg(1);
        cfg.matfree = true;
        cfg.eps_schedule = Some((2.0, 0));
        assert!(Service::start(cfg).is_err(), "zero-rung ladder must fail fast");
    }

    /// A warm-enabled single-worker service re-serves a repeated request
    /// from its session cache: fewer iterations the second time, and the
    /// per-request iteration histogram sees both solves.
    #[test]
    fn warm_service_reuses_cached_scalings() {
        let mut cfg = native_cfg(1);
        cfg.warm = 4;
        let svc = Service::start(cfg).unwrap();
        let p = Problem::random(24, 24, 0.7, 9);
        let first = svc.solve_blocking(p.clone()).unwrap();
        let second = svc.solve_blocking(p).unwrap();
        assert!(first.report.converged && second.report.converged);
        assert!(
            second.report.iters <= first.report.iters,
            "warm {} vs cold {} iterations",
            second.report.iters,
            first.report.iters
        );
        let m = svc.metrics();
        assert_eq!(m.iter_requests, 2);
        assert_eq!(
            m.iterations,
            first.report.iters as u64 + second.report.iters as u64
        );
        svc.shutdown();
    }

    /// PR 10: end-to-end latency decomposes into queue wait + solve at
    /// the batcher seam, and the labeled surface sees every request.
    #[test]
    fn stats_surface_decomposes_wait_and_labels_backends() {
        let mut cfg = native_cfg(1);
        cfg.warm = 4;
        let svc = Service::start(cfg).unwrap();
        let p = Problem::random(24, 24, 0.7, 11);
        svc.solve_blocking(p.clone()).unwrap();
        svc.solve_blocking(p).unwrap();
        let m = svc.metrics();
        assert_eq!(m.wait_count, 2, "every completed request records its wait");
        let o = svc.obs();
        assert_eq!(o.labels[0].count, 2, "both solves land on the dense label");
        assert_eq!(o.in_flight, 0, "enter/exit pairs balance");
        assert_eq!(o.warm_hits + o.warm_misses, 2, "warm deltas folded per batch");
        assert_eq!(o.warm_hits, 1, "the repeat solve hit the warm cache");
        let json = svc.stats_json();
        assert!(json.starts_with("{\"schema_version\":"), "{json}");
        assert!(json.contains("\"dense\":{\"count\":2"), "{json}");
        assert!(json.contains("\"wait_ms\":{\"mean\":"), "{json}");
        svc.shutdown();
    }

    /// PR 10 tentpole: a traced service exports a valid Perfetto trace of
    /// the solve's spans on shutdown.
    #[test]
    fn traced_service_exports_a_valid_trace_on_shutdown() {
        let _g = crate::util::telemetry::test_guard();
        let dir = std::env::temp_dir().join("mapuot_service_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("svc.trace.json");
        let mut cfg = native_cfg(1);
        cfg.trace = Some(path.to_string_lossy().into_owned());
        let svc = Service::start(cfg).unwrap();
        svc.solve_blocking(Problem::random(24, 24, 0.7, 3)).unwrap();
        svc.shutdown();
        crate::util::telemetry::set_enabled(false);
        crate::util::telemetry::reset();
        let json = std::fs::read_to_string(&path).unwrap();
        let events = crate::util::telemetry::validate_perfetto(&json).unwrap();
        assert!(events > 0, "a traced solve leaves spans in the export");
        assert!(json.contains("\"name\":\"solve\""), "the solve envelope span is present");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shutdown_completes_pending() {
        let svc = Service::start(native_cfg(1)).unwrap();
        let rx = svc.submit(Problem::random(16, 16, 0.7, 5)).unwrap();
        svc.shutdown();
        assert!(rx.recv().unwrap().result.is_ok());
    }

    #[test]
    fn rejects_when_queue_full() {
        let mut cfg = native_cfg(1);
        cfg.queue_cap = 1;
        cfg.batch_wait_us = 50_000; // slow the worker's batch window
        let svc = Service::start(cfg).unwrap();
        // Stuff the queue faster than one worker drains it; expect at
        // least one rejection out of a burst.
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for seed in 0..64u64 {
            match svc.submit(Problem::random(32, 32, 0.7, seed)) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(rejected > 0, "expected load shedding");
        assert_eq!(svc.metrics().rejected, rejected);
        svc.shutdown();
    }
}
