//! Warm-start scaling cache: seed solves from the nearest converged answer.
//!
//! Production traffic is repetitive — same shapes, drifting marginals — and
//! every solver in this crate is a diagonal-scaling iteration: the state it
//! converges to is `plan = diag(u) · plan₀ · diag(v)` for some positive
//! vectors `u, v` (explicitly carried on the matfree path, implicit in the
//! dense/CSR plan). Those vectors are therefore a complete, O(m + n) summary
//! of a converged solve, and an excellent seed for the *next* solve of a
//! nearby problem: seeding rescales the initial plan **within the diagonal
//! family the iteration searches anyway**, so the fixed point is unchanged
//! (the property suite pins warm-seeded plans to cold plans at 1e-5) while
//! the transient the iteration would spend re-deriving the scalings is
//! skipped.
//!
//! [`WarmCache`] is a fixed-capacity LRU over such `(u, v)` pairs:
//!
//! * **Key** ([`Fingerprint`]): an exact structural part — shape, solve
//!   path (dense/CSR/matfree), solver kind, quantized `fi` and (matfree)
//!   quantized `ln ε` — plus a coarse marginal sketch (total masses and
//!   normalized first moments of `rpd`/`cpd`). Lookups match the
//!   structural part exactly and take the **nearest** sketch, so a
//!   drifting-marginal stream keeps hitting the entry it drifted from.
//! * **Eviction**: least-recently-used by a monotone tick; storing a
//!   fingerprint whose sketch is (numerically) the one already cached
//!   overwrites that entry in place.
//! * **Allocation contract**: `lookup` never allocates; `store_with` only
//!   allocates while the cache is filling or when an evicted entry's
//!   buffers must grow. A steady-state stream over warmed shapes is
//!   allocation-free end to end (asserted in `rust/tests/alloc_free.rs`).
//!
//! Dense and CSR sessions do not carry `u, v` explicitly, so the session
//! recovers them at store time from the untouched initial plan and the
//! solved plan ([`derive_dense_scaling`] / [`derive_csr_scaling`]): the row
//! factors come from final-vs-initial row sums, the column factors from the
//! final column sums against the row-rescaled initial plan. The recovery is
//! exact when the solve's net effect is a diagonal rescaling (it is, up to
//! f32 rounding) and merely approximate otherwise — which is safe either
//! way, because a seed only relocates the start point; the solve still runs
//! to its own stop rule.

use crate::algo::matfree::GeomProblem;
use crate::algo::problem::Problem;
use crate::algo::sparse::{CsrMatrix, SparseProblem};
use crate::algo::SolverKind;
use crate::util::Matrix;

/// Which solve path a cached scaling belongs to. Paths never share entries:
/// a dense `(u, v)` recovered at one shape is meaningless to the matfree
/// sweep's explicit scaling vectors even at the same shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PathKind {
    /// Dense fused sweep ([`crate::algo::session::SolverSession::solve`]).
    Dense,
    /// CSR sweep (`solve_sparse`).
    Sparse,
    /// Materialization-free scaling-form sweep (`solve_matfree`).
    Matfree,
}

/// `fi` quantization step: 1/256 ≈ 0.004 — coarser than any fi two
/// problems would meaningfully differ by, fine enough that distinct
/// relaxation regimes never share seeds.
const FI_QUANT: f32 = 256.0;
/// `ln ε` quantization step: 1/16 — entries within ~6% bandwidth reuse
/// each other's scalings (the ε-schedule's own rung ratio is far coarser).
const EPS_QUANT: f32 = 16.0;
/// Squared relative sketch distance below which a store overwrites the
/// cached entry instead of inserting a sibling: numerically the same
/// problem re-solved.
const SAME_SKETCH: f32 = 1e-9;

/// Exact-match structural half of a [`Fingerprint`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FingerprintKey {
    pub rows: usize,
    pub cols: usize,
    pub path: PathKind,
    pub solver: SolverKind,
    /// `round(fi · 256)`.
    pub fi_q: i32,
    /// `round(ln ε · 16)` on the matfree path, 0 elsewhere.
    pub eps_q: i32,
}

/// Problem fingerprint: exact structural key + coarse marginal sketch
/// (`[Σ rpd, Σ cpd, first moment of rpd, first moment of cpd]`, moments
/// normalized to `[0, 1]` by index and total mass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fingerprint {
    pub key: FingerprintKey,
    pub sketch: [f32; 4],
}

fn mass_of(w: &[f32]) -> f32 {
    w.iter().sum()
}

/// Normalized first moment of a marginal: `Σ_i ((i + ½)/len) · w_i / Σ w` —
/// a one-number shape summary that separates "mass moved left" from "mass
/// moved right" drifts the totals alone cannot see.
fn moment_of(w: &[f32]) -> f32 {
    let total = mass_of(w);
    if !(total > 0.0) {
        return 0.0;
    }
    let scale = 1.0 / w.len() as f32;
    let mut acc = 0f32;
    for (i, &x) in w.iter().enumerate() {
        acc += (i as f32 + 0.5) * scale * x;
    }
    acc / total
}

fn sketch_of(rpd: &[f32], cpd: &[f32]) -> [f32; 4] {
    [mass_of(rpd), mass_of(cpd), moment_of(rpd), moment_of(cpd)]
}

/// Squared relative L2 distance between sketches (component-wise relative,
/// so a 1% mass drift and a 1% moment drift weigh the same).
fn sketch_distance(a: &[f32; 4], b: &[f32; 4]) -> f32 {
    let mut acc = 0f32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let denom = x.abs().max(y.abs()).max(1e-6);
        let d = (x - y) / denom;
        acc += d * d;
    }
    acc
}

fn quantize(x: f32, steps: f32) -> i32 {
    (x * steps).round() as i32
}

/// Fingerprint of a dense problem solved by `solver`.
pub fn fingerprint_dense(solver: SolverKind, p: &Problem) -> Fingerprint {
    Fingerprint {
        key: FingerprintKey {
            rows: p.rows(),
            cols: p.cols(),
            path: PathKind::Dense,
            solver,
            fi_q: quantize(p.fi, FI_QUANT),
            eps_q: 0,
        },
        sketch: sketch_of(&p.rpd, &p.cpd),
    }
}

/// Fingerprint of a CSR problem (always the fused MAP-UOT sweep).
pub fn fingerprint_sparse(p: &SparseProblem) -> Fingerprint {
    Fingerprint {
        key: FingerprintKey {
            rows: p.rows(),
            cols: p.cols(),
            path: PathKind::Sparse,
            solver: SolverKind::MapUot,
            fi_q: quantize(p.fi, FI_QUANT),
            eps_q: 0,
        },
        sketch: sketch_of(&p.rpd, &p.cpd),
    }
}

/// Fingerprint of a geometric problem (always the scaling-form MAP-UOT
/// sweep; the bandwidth enters the structural key because the scaling
/// vectors of one ε are poor seeds for a very different ε).
pub fn fingerprint_matfree(p: &GeomProblem) -> Fingerprint {
    Fingerprint {
        key: FingerprintKey {
            rows: p.rows(),
            cols: p.cols(),
            path: PathKind::Matfree,
            solver: SolverKind::MapUot,
            fi_q: quantize(p.fi, FI_QUANT),
            eps_q: quantize(p.epsilon.ln(), EPS_QUANT),
        },
        sketch: sketch_of(&p.rpd, &p.cpd),
    }
}

/// One cached converged scaling. Buffers are retained across eviction and
/// resized in place, so steady-state stores never allocate.
#[derive(Debug)]
struct Entry {
    key: FingerprintKey,
    sketch: [f32; 4],
    u: Vec<f32>,
    v: Vec<f32>,
    tick: u64,
}

/// Fixed-capacity LRU cache of converged diagonal scalings, keyed by
/// [`Fingerprint`]. See the module docs for the matching and allocation
/// contracts.
#[derive(Debug)]
pub struct WarmCache {
    cap: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    entries: Vec<Entry>,
}

impl WarmCache {
    /// Cache holding at most `cap` scalings (`cap` is clamped to ≥ 1 — a
    /// zero-capacity cache is "warm start off", which the session models
    /// by not carrying a cache at all).
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), tick: 0, hits: 0, misses: 0, entries: Vec::new() }
    }

    /// Maximum number of cached scalings.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Cached scalings right now.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups that returned a seed.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that found no structurally matching entry.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// The cached `(u, v)` nearest to `fp`: structural key matched
    /// exactly, nearest sketch wins. Bumps the entry's LRU tick. Never
    /// allocates.
    pub fn lookup(&mut self, fp: &Fingerprint) -> Option<(&[f32], &[f32])> {
        let mut best: Option<(usize, f32)> = None;
        for (idx, e) in self.entries.iter().enumerate() {
            if e.key != fp.key {
                continue;
            }
            let d = sketch_distance(&e.sketch, &fp.sketch);
            if best.is_none_or(|(_, bd)| d < bd) {
                best = Some((idx, d));
            }
        }
        match best {
            Some((idx, _)) => {
                self.hits += 1;
                self.tick += 1;
                let e = &mut self.entries[idx];
                e.tick = self.tick;
                Some((&e.u, &e.v))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store a scaling for `fp`, writing `u` (length `m`) and `v` (length
    /// `n`) through `fill` directly into the entry's buffers. A
    /// numerically identical fingerprint overwrites its entry; otherwise
    /// the LRU entry is evicted (buffers reused) once the cache is full.
    pub fn store_with(
        &mut self,
        fp: &Fingerprint,
        m: usize,
        n: usize,
        fill: impl FnOnce(&mut [f32], &mut [f32]),
    ) {
        self.tick += 1;
        let slot = self.slot_for(fp);
        let e = &mut self.entries[slot];
        e.key = fp.key;
        e.sketch = fp.sketch;
        e.tick = self.tick;
        e.u.resize(m, 0.0);
        e.v.resize(n, 0.0);
        fill(&mut e.u, &mut e.v);
    }

    /// Index to write `fp` into: its same-sketch twin, a fresh slot while
    /// below capacity, or the LRU victim.
    fn slot_for(&mut self, fp: &Fingerprint) -> usize {
        if let Some(idx) = self.entries.iter().position(|e| {
            e.key == fp.key && sketch_distance(&e.sketch, &fp.sketch) <= SAME_SKETCH
        }) {
            return idx;
        }
        if self.entries.len() < self.cap {
            self.entries.push(Entry {
                key: fp.key,
                sketch: fp.sketch,
                u: Vec::new(),
                v: Vec::new(),
                tick: 0,
            });
            return self.entries.len() - 1;
        }
        self.entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.tick)
            .map(|(idx, _)| idx)
            .expect("cap >= 1, so a full cache has at least one entry")
    }
}

/// Clamp a recovered diagonal factor: non-finite or non-positive ratios
/// (empty row in the initial plan, marginal of zero mass) fall back to the
/// cold seed 1, and the magnitude is bounded so a seeded f32 plan can
/// never overflow to inf and poison the factor computation.
fn sanitize(x: f32) -> f32 {
    if x.is_finite() && x > 0.0 {
        x.clamp(1e-12, 1e12)
    } else {
        1.0
    }
}

/// Seed a dense plan in place: `plan_ij ← u_i · plan_ij · v_j`.
pub fn scale_dense_plan(plan: &mut Matrix, u: &[f32], v: &[f32]) {
    debug_assert_eq!(plan.rows(), u.len());
    debug_assert_eq!(plan.cols(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        for (x, &vj) in plan.row_mut(i).iter_mut().zip(v.iter()) {
            *x *= ui * vj;
        }
    }
}

/// Seed a CSR plan in place: `values_k ← u_row(k) · values_k · v_col(k)`.
/// The sparse support is untouched — a diagonal rescale by positive
/// factors never creates or destroys nonzeros.
pub fn scale_csr_plan(plan: &mut CsrMatrix, u: &[f32], v: &[f32]) {
    debug_assert_eq!(plan.m, u.len());
    debug_assert_eq!(plan.n, v.len());
    let CsrMatrix { row_ptr, col_idx, values, .. } = plan;
    for (i, &ui) in u.iter().enumerate() {
        for k in row_ptr[i]..row_ptr[i + 1] {
            values[k] *= ui * v[col_idx[k] as usize];
        }
    }
}

/// Recover the net diagonal scaling `fin ≈ diag(u) · init · diag(v)` of a
/// finished dense solve: `u` from final-vs-initial row sums, then `v` from
/// the final (carried) column sums against the row-rescaled initial plan.
/// Degenerate rows/columns sanitize to the cold factor 1.
pub fn derive_dense_scaling(
    init: &Matrix,
    fin: &Matrix,
    fin_colsum: &[f32],
    u: &mut [f32],
    v: &mut [f32],
) {
    debug_assert_eq!(init.rows(), fin.rows());
    debug_assert_eq!(init.cols(), fin.cols());
    for (i, ui) in u.iter_mut().enumerate() {
        let s0: f32 = init.row(i).iter().sum();
        let s1: f32 = fin.row(i).iter().sum();
        *ui = sanitize(s1 / s0);
    }
    v.fill(0.0);
    for (i, &ui) in u.iter().enumerate() {
        for (acc, &w) in v.iter_mut().zip(init.row(i).iter()) {
            *acc += w * ui;
        }
    }
    for (vj, &cs) in v.iter_mut().zip(fin_colsum.iter()) {
        *vj = sanitize(cs / *vj);
    }
}

/// CSR twin of [`derive_dense_scaling`]. `init` and `fin` must share their
/// sparsity structure (the session's CSR state is a structure-preserving
/// copy of the submitted plan, so they always do).
pub fn derive_csr_scaling(
    init: &CsrMatrix,
    fin: &CsrMatrix,
    fin_colsum: &[f32],
    u: &mut [f32],
    v: &mut [f32],
) {
    debug_assert_eq!(init.m, fin.m);
    debug_assert_eq!(init.n, fin.n);
    debug_assert_eq!(init.nnz(), fin.nnz());
    for (i, ui) in u.iter_mut().enumerate() {
        let r = init.row_ptr[i]..init.row_ptr[i + 1];
        let s0: f32 = init.values[r.clone()].iter().sum();
        let s1: f32 = fin.values[r].iter().sum();
        *ui = sanitize(s1 / s0);
    }
    v.fill(0.0);
    for (i, &ui) in u.iter().enumerate() {
        for k in init.row_ptr[i]..init.row_ptr[i + 1] {
            v[init.col_idx[k] as usize] += init.values[k] * ui;
        }
    }
    for (vj, &cs) in v.iter_mut().zip(fin_colsum.iter()) {
        *vj = sanitize(cs / *vj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(rows: usize, cols: usize, sketch: [f32; 4]) -> Fingerprint {
        Fingerprint {
            key: FingerprintKey {
                rows,
                cols,
                path: PathKind::Dense,
                solver: SolverKind::MapUot,
                fi_q: 179, // 0.7 * 256
                eps_q: 0,
            },
            sketch,
        }
    }

    fn store_consts(cache: &mut WarmCache, f: &Fingerprint, m: usize, n: usize, cu: f32, cv: f32) {
        cache.store_with(f, m, n, |u, v| {
            u.fill(cu);
            v.fill(cv);
        });
    }

    #[test]
    fn lookup_on_empty_cache_misses() {
        let mut cache = WarmCache::new(4);
        assert!(cache.lookup(&fp(8, 8, [1.0, 1.0, 0.5, 0.5])).is_none());
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn nearest_sketch_wins_within_a_structural_key() {
        let mut cache = WarmCache::new(4);
        store_consts(&mut cache, &fp(8, 8, [1.0, 1.0, 0.5, 0.5]), 8, 8, 2.0, 2.0);
        store_consts(&mut cache, &fp(8, 8, [4.0, 4.0, 0.5, 0.5]), 8, 8, 3.0, 3.0);
        assert_eq!(cache.len(), 2);
        let (u, _) = cache.lookup(&fp(8, 8, [3.7, 3.9, 0.5, 0.5])).unwrap();
        assert_eq!(u[0], 3.0);
        let (u, _) = cache.lookup(&fp(8, 8, [1.1, 0.9, 0.5, 0.5])).unwrap();
        assert_eq!(u[0], 2.0);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn structural_mismatch_never_hits() {
        let mut cache = WarmCache::new(4);
        store_consts(&mut cache, &fp(8, 8, [1.0, 1.0, 0.5, 0.5]), 8, 8, 2.0, 2.0);
        // Different shape.
        assert!(cache.lookup(&fp(8, 9, [1.0, 1.0, 0.5, 0.5])).is_none());
        // Different path at the same shape.
        let mut other = fp(8, 8, [1.0, 1.0, 0.5, 0.5]);
        other.key.path = PathKind::Matfree;
        assert!(cache.lookup(&other).is_none());
        // Different quantized fi.
        let mut other = fp(8, 8, [1.0, 1.0, 0.5, 0.5]);
        other.key.fi_q = 128;
        assert!(cache.lookup(&other).is_none());
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn same_sketch_store_overwrites_in_place() {
        let mut cache = WarmCache::new(4);
        let f = fp(8, 8, [1.0, 1.0, 0.5, 0.5]);
        store_consts(&mut cache, &f, 8, 8, 2.0, 2.0);
        store_consts(&mut cache, &f, 8, 8, 5.0, 5.0);
        assert_eq!(cache.len(), 1);
        let (u, v) = cache.lookup(&f).unwrap();
        assert_eq!(u[0], 5.0);
        assert_eq!(v[0], 5.0);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let mut cache = WarmCache::new(2);
        let a = fp(8, 8, [1.0, 1.0, 0.5, 0.5]);
        let b = fp(8, 8, [2.0, 2.0, 0.5, 0.5]);
        let c = fp(8, 8, [8.0, 8.0, 0.5, 0.5]);
        store_consts(&mut cache, &a, 8, 8, 1.0, 1.0);
        store_consts(&mut cache, &b, 8, 8, 2.0, 2.0);
        // Touch `a` so `b` is the LRU victim.
        assert!(cache.lookup(&a).is_some());
        store_consts(&mut cache, &c, 8, 8, 3.0, 3.0);
        assert_eq!(cache.len(), 2);
        // `a` survived, the nearest match for b's sketch is now `a`.
        let (u, _) = cache.lookup(&b).unwrap();
        assert_eq!(u[0], 1.0);
        // `c` is present.
        let (u, _) = cache.lookup(&c).unwrap();
        assert_eq!(u[0], 3.0);
    }

    #[test]
    fn cross_shape_entries_are_isolated() {
        let mut cache = WarmCache::new(4);
        store_consts(&mut cache, &fp(8, 8, [1.0, 1.0, 0.5, 0.5]), 8, 8, 2.0, 2.0);
        store_consts(&mut cache, &fp(16, 4, [1.0, 1.0, 0.5, 0.5]), 16, 4, 7.0, 7.0);
        let (u, v) = cache.lookup(&fp(16, 4, [1.0, 1.0, 0.5, 0.5])).unwrap();
        assert_eq!((u.len(), v.len()), (16, 4));
        assert_eq!(u[0], 7.0);
        let (u, v) = cache.lookup(&fp(8, 8, [1.0, 1.0, 0.5, 0.5])).unwrap();
        assert_eq!((u.len(), v.len()), (8, 8));
        assert_eq!(u[0], 2.0);
    }

    #[test]
    fn dense_scaling_roundtrip_recovers_diagonal_factors() {
        let m = 5;
        let n = 4;
        let init = Matrix::from_fn(m, n, |i, j| 0.3 + (i * n + j) as f32 * 0.1);
        let u_true = [0.5f32, 1.0, 2.0, 0.25, 4.0];
        let v_true = [3.0f32, 1.0, 0.5, 2.0];
        let mut fin = init.clone();
        scale_dense_plan(&mut fin, &u_true, &v_true);
        let colsum = fin.col_sums();
        let mut u = vec![0f32; m];
        let mut v = vec![0f32; n];
        derive_dense_scaling(&init, &fin, &colsum, &mut u, &mut v);
        // Recovery is exact up to the diagonal gauge (u·c, v/c): compare
        // the product u_i · v_j, which is gauge-free.
        for i in 0..m {
            for j in 0..n {
                let got = u[i] * v[j];
                let want = u_true[i] * v_true[j];
                assert!(
                    (got - want).abs() <= 1e-4 * want,
                    "({i},{j}): got {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn sanitize_guards_degenerate_factors() {
        assert_eq!(sanitize(f32::NAN), 1.0);
        assert_eq!(sanitize(f32::INFINITY), 1.0);
        assert_eq!(sanitize(-3.0), 1.0);
        assert_eq!(sanitize(0.0), 1.0);
        assert_eq!(sanitize(1e30), 1e12);
        assert_eq!(sanitize(1e-30), 1e-12);
        assert_eq!(sanitize(2.5), 2.5);
    }
}
