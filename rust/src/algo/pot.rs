//! POT baseline: the NumPy 4-sweep formulation (paper Fig. 1).
//!
//! One iteration touches the matrix in four independent full sweeps —
//!   1. `colsum = A.sum(0)`            (read M·N)
//!   2. `A *= Factor_col[None, :]`     (read + write M·N)
//!   3. `rowsum = A.sum(1)`            (read M·N)
//!   4. `A *= Factor_row[:, None]`     (read + write M·N)
//! — 6·M·N element accesses per iteration, the traffic the paper's Eq. 1
//! plugs into the Roofline model. Each sweep is a simple contiguous loop
//! (NumPy's ufuncs are vectorized C loops; pessimizing them would fake the
//! comparison), so the gap to MAP-UOT comes from *sweep count*, exactly as
//! in the paper.

use crate::algo::scaling::factors_into;
use crate::util::Matrix;

/// One POT iteration: column rescaling then row rescaling (ref.py order),
/// allocation-free: `fcol` (length N) and `rowsum` (length M) are
/// caller-provided scratch (see `session::Workspace`).
///
/// `colsum` is ignored as carried state (POT recomputes sums every sweep —
/// it doubles as the sweep-1 accumulator here) but holds fresh column sums
/// on exit so the caller's convergence bookkeeping works across kinds.
pub fn iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    rowsum: &mut [f32],
) {
    let m = plan.rows();

    // Sweep 1: column sums (row-major accumulation, as numpy's sum(0)).
    plan.col_sums_into(colsum);

    // Sweep 2: column rescaling.
    factors_into(fcol, cpd, colsum, fi);
    for i in 0..m {
        for (v, &f) in plan.row_mut(i).iter_mut().zip(fcol.iter()) {
            *v *= f;
        }
    }

    // Sweep 3: row sums (16-lane reduction — NumPy's pairwise-sum ufunc is
    // similarly vectorized, so a serial fold would pessimize the baseline).
    for i in 0..m {
        rowsum[i] = wide_sum(plan.row(i));
    }

    // Sweep 4: row rescaling.
    for i in 0..m {
        let fr = crate::algo::scaling::factor(rpd[i], rowsum[i], fi);
        for v in plan.row_mut(i) {
            *v *= fr;
        }
    }

    // Refresh carried colsum for the uniform driver.
    plan.col_sums_into(colsum);
}

/// [`iterate_into`] with in-sweep delta tracking; returns the iteration's
/// max element change. At sweep 4 each element holds
/// `v1 = v0 · Factor_col[j]`, so the pre-iteration value is recovered as
/// `v1 · inv_fcol[j]` — no snapshot of the previous plan.
#[allow(clippy::too_many_arguments)]
pub fn iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
) -> f32 {
    let m = plan.rows();

    plan.col_sums_into(colsum);

    factors_into(fcol, cpd, colsum, fi);
    crate::algo::scaling::recip_into(inv_fcol, fcol);
    for i in 0..m {
        for (v, &f) in plan.row_mut(i).iter_mut().zip(fcol.iter()) {
            *v *= f;
        }
    }

    for i in 0..m {
        rowsum[i] = wide_sum(plan.row(i));
    }

    let mut delta = 0f32;
    for i in 0..m {
        let fr = crate::algo::scaling::factor(rpd[i], rowsum[i], fi);
        for (v, &inv) in plan.row_mut(i).iter_mut().zip(inv_fcol.iter()) {
            let old = *v * inv;
            *v *= fr;
            delta = delta.max((*v - old).abs());
        }
    }

    plan.col_sums_into(colsum);
    delta
}

/// One POT iteration; allocates its own scratch — prefer [`iterate_into`]
/// on hot paths.
// uotlint: allow(alloc) — documented legacy wrapper, not a hot path.
pub fn iterate(plan: &mut Matrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let mut fcol = vec![0f32; plan.cols()];
    let mut rowsum = vec![0f32; plan.rows()];
    iterate_into(plan, colsum, rpd, cpd, fi, &mut fcol, &mut rowsum);
}

/// Vectorizable 16-lane sum, now shared via [`crate::util::simd`] (it was
/// copy-pasted here and in `mapuot` before the kernel subsystem).
pub use crate::util::simd::wide_sum;

/// The paper's Fig. 1 *C-language* column rescaling: `j` outer, `i` inner —
/// the stride-N access pattern §3.1 blames for the baseline's cache misses.
/// Only used by the cache-simulation figures; `iterate` models NumPy.
pub fn column_rescale_strided(plan: &mut Matrix, fcol: &[f32]) {
    let (m, n) = (plan.rows(), plan.cols());
    let data = plan.as_mut_slice();
    for j in 0..n {
        let f = fcol[j];
        for i in 0..m {
            data[i * n + j] *= f;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::problem::Problem;

    #[test]
    fn fixed_point_is_identity() {
        let p = Problem::random(6, 5, 0.5, 1);
        let mut plan = p.plan.clone();
        let rpd = plan.row_sums();
        let cpd = plan.col_sums();
        let mut cs = plan.col_sums();
        let orig = plan.clone();
        iterate(&mut plan, &mut cs, &rpd, &cpd, 0.5);
        assert!(plan.max_abs_diff(&orig) < 1e-5);
    }

    #[test]
    fn balanced_row_marginals_exact_after_iteration() {
        let p = Problem::random(8, 7, 1.0, 2);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, 1.0);
        for (rs, &t) in plan.row_sums().iter().zip(&p.rpd) {
            assert!((rs - t).abs() < 1e-4, "{rs} vs {t}");
        }
    }

    #[test]
    fn strided_equals_broadcast_rescale() {
        let p = Problem::random(5, 4, 0.5, 3);
        let fcol = vec![0.5, 2.0, 1.0, 0.25];
        let mut a = p.plan.clone();
        let mut b = p.plan.clone();
        column_rescale_strided(&mut a, &fcol);
        for i in 0..5 {
            for (v, &f) in b.row_mut(i).iter_mut().zip(&fcol) {
                *v *= f;
            }
        }
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }
}
