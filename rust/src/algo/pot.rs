//! POT baseline: the NumPy 4-sweep formulation (paper Fig. 1).
//!
//! One iteration touches the matrix in four independent full sweeps —
//!   1. `colsum = A.sum(0)`            (read M·N)
//!   2. `A *= Factor_col[None, :]`     (read + write M·N)
//!   3. `rowsum = A.sum(1)`            (read M·N)
//!   4. `A *= Factor_row[:, None]`     (read + write M·N)
//! — 6·M·N element accesses per iteration, the traffic the paper's Eq. 1
//! plugs into the Roofline model. Each sweep is a simple contiguous loop
//! (NumPy's ufuncs are vectorized C loops; pessimizing them would fake the
//! comparison), so the gap to MAP-UOT comes from *sweep count*, exactly as
//! in the paper.

use crate::algo::scaling::factors_into;
use crate::util::Matrix;

/// One POT iteration: column rescaling then row rescaling (ref.py order).
///
/// `colsum` is ignored as carried state (POT recomputes sums every sweep)
/// but is refreshed on exit so the caller's convergence bookkeeping works
/// across solver kinds.
pub fn iterate(plan: &mut Matrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let (m, n) = (plan.rows(), plan.cols());

    // Sweep 1: column sums (row-major accumulation, as numpy's sum(0)).
    let mut sums = vec![0f32; n];
    for i in 0..m {
        for (s, &v) in sums.iter_mut().zip(plan.row(i)) {
            *s += v;
        }
    }

    // Sweep 2: column rescaling.
    let mut fcol = vec![0f32; n];
    factors_into(&mut fcol, cpd, &sums, fi);
    for i in 0..m {
        for (v, &f) in plan.row_mut(i).iter_mut().zip(&fcol) {
            *v *= f;
        }
    }

    // Sweep 3: row sums (16-lane reduction — NumPy's pairwise-sum ufunc is
    // similarly vectorized, so a serial fold would pessimize the baseline).
    let rowsum: Vec<f32> = (0..m).map(|i| wide_sum(plan.row(i))).collect();

    // Sweep 4: row rescaling.
    for i in 0..m {
        let fr = crate::algo::scaling::factor(rpd[i], rowsum[i], fi);
        for v in plan.row_mut(i) {
            *v *= fr;
        }
    }

    // Refresh carried colsum for the uniform driver.
    colsum.fill(0.0);
    for i in 0..m {
        for (s, &v) in colsum.iter_mut().zip(plan.row(i)) {
            *s += v;
        }
    }
}

/// Vectorizable 16-lane sum (see `mapuot::scale_by_vec_and_sum` §Perf note).
#[inline]
pub fn wide_sum(xs: &[f32]) -> f32 {
    const W: usize = 16;
    let mut acc = [0f32; W];
    let chunks = xs.len() / W;
    let (h, t) = xs.split_at(chunks * W);
    for w in h.chunks_exact(W) {
        for k in 0..W {
            acc[k] += w[k];
        }
    }
    acc.iter().sum::<f32>() + t.iter().sum::<f32>()
}

/// The paper's Fig. 1 *C-language* column rescaling: `j` outer, `i` inner —
/// the stride-N access pattern §3.1 blames for the baseline's cache misses.
/// Only used by the cache-simulation figures; `iterate` models NumPy.
pub fn column_rescale_strided(plan: &mut Matrix, fcol: &[f32]) {
    let (m, n) = (plan.rows(), plan.cols());
    let data = plan.as_mut_slice();
    for j in 0..n {
        let f = fcol[j];
        for i in 0..m {
            data[i * n + j] *= f;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::problem::Problem;

    #[test]
    fn fixed_point_is_identity() {
        let p = Problem::random(6, 5, 0.5, 1);
        let mut plan = p.plan.clone();
        let rpd = plan.row_sums();
        let cpd = plan.col_sums();
        let mut cs = plan.col_sums();
        let orig = plan.clone();
        iterate(&mut plan, &mut cs, &rpd, &cpd, 0.5);
        assert!(plan.max_abs_diff(&orig) < 1e-5);
    }

    #[test]
    fn balanced_row_marginals_exact_after_iteration() {
        let p = Problem::random(8, 7, 1.0, 2);
        let mut plan = p.plan.clone();
        let mut cs = plan.col_sums();
        iterate(&mut plan, &mut cs, &p.rpd, &p.cpd, 1.0);
        for (rs, &t) in plan.row_sums().iter().zip(&p.rpd) {
            assert!((rs - t).abs() < 1e-4, "{rs} vs {t}");
        }
    }

    #[test]
    fn strided_equals_broadcast_rescale() {
        let p = Problem::random(5, 4, 0.5, 3);
        let fcol = vec![0.5, 2.0, 1.0, 0.25];
        let mut a = p.plan.clone();
        let mut b = p.plan.clone();
        column_rescale_strided(&mut a, &fcol);
        for i in 0..5 {
            for (v, &f) in b.row_mut(i).iter_mut().zip(&fcol) {
                *v *= f;
            }
        }
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }
}
