//! MAP-UOT: the paper's fused interweaved iteration (Algorithm 1, serial).
//!
//! One double-loop per iteration. For each row, while it is cache-resident:
//!   Computation I   — multiply by `Factor_col` (column rescaling)
//!   Computation II  — accumulate `Sum_row`
//!   Computation III — multiply by `Factor_row = (RPD_i/Sum_row)^fi`
//!   Computation IV  — accumulate `NextSum_col`
//! The matrix streams through DRAM once (one read + one write, 2·M·N
//! element accesses — the Roofline-model minimum of §3.1); the second inner
//! loop re-touches the same row out of L1/L2. All accesses are contiguous.
//!
//! The inner loops are written as 16-lane unrolled chunk loops; LLVM turns
//! them into the AVX2 code the paper writes by hand (verified against the
//! plain form in `tests::unrolled_matches_plain` and in the perf log).
//! These free functions double as the [`crate::algo::kernels`] `Unrolled`
//! backend; the hand-written AVX2+FMA backend and the cache-tiled sweep
//! live behind [`fused_rows_policy`] / [`fused_rows_tracked_policy`].

use crate::algo::kernels::KernelPolicy;
use crate::algo::scaling::{factor, factors_into};
use crate::util::{simd, Matrix};

/// Fused pass over one row: `row *= fcol` element-wise, returns the row sum.
/// (Computations I + II.)
#[inline]
pub fn scale_by_vec_and_sum(row: &mut [f32], fcol: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), fcol.len());
    // 16 independent accumulator lanes (`util::simd::LANES`): wide enough
    // for AVX2/AVX-512 auto-vectorization AND to break the add-latency
    // dependency chain (4 lanes capped the primitive at ~47% of streaming
    // peak — §Perf log).
    const W: usize = simd::LANES;
    let mut acc = [0f32; W];
    let chunks = row.len() / W;
    let (rh, rt) = row.split_at_mut(chunks * W);
    let (fh, ft) = fcol.split_at(chunks * W);
    for (rw, fw) in rh.chunks_exact_mut(W).zip(fh.chunks_exact(W)) {
        for k in 0..W {
            rw[k] *= fw[k];
            acc[k] += rw[k];
        }
    }
    let mut s = simd::fold(&acc);
    for (r, &f) in rt.iter_mut().zip(ft) {
        *r *= f;
        s += *r;
    }
    s
}

/// Fused pass over one row: `row *= fr`, accumulating into `next_colsum`.
/// (Computations III + IV.) Same 16-lane unroll as
/// [`scale_by_vec_and_sum`] — the plain zip loop left the column
/// accumulation add-latency-bound.
#[inline]
pub fn scale_by_scalar_and_accumulate(row: &mut [f32], fr: f32, next_colsum: &mut [f32]) {
    debug_assert_eq!(row.len(), next_colsum.len());
    const W: usize = simd::LANES;
    let chunks = row.len() / W;
    let (rh, rt) = row.split_at_mut(chunks * W);
    let (sh, st) = next_colsum.split_at_mut(chunks * W);
    for (rw, sw) in rh.chunks_exact_mut(W).zip(sh.chunks_exact_mut(W)) {
        for k in 0..W {
            rw[k] *= fr;
            sw[k] += rw[k];
        }
    }
    for (v, s) in rt.iter_mut().zip(st.iter_mut()) {
        *v *= fr;
        *s += *v;
    }
}

/// [`scale_by_scalar_and_accumulate`] that also returns the row's max
/// element change for this iteration, recovered in-register: the incoming
/// `row` holds `v1 = v0 · Factor_col[j]`, so the pre-iteration value is
/// `v1 · inv_fcol[j]` and the new value is `v1 · fr` — no snapshot needed.
/// The per-lane delta maxima fold at the end; `max` is order-independent,
/// so the result is bit-identical to the sequential form.
#[inline]
pub fn scale_by_scalar_and_accumulate_tracked(
    row: &mut [f32],
    fr: f32,
    inv_fcol: &[f32],
    next_colsum: &mut [f32],
) -> f32 {
    debug_assert_eq!(row.len(), next_colsum.len());
    debug_assert_eq!(row.len(), inv_fcol.len());
    const W: usize = simd::LANES;
    let mut dl = [0f32; W];
    let chunks = row.len() / W;
    let (rh, rt) = row.split_at_mut(chunks * W);
    let (sh, st) = next_colsum.split_at_mut(chunks * W);
    let (ih, it) = inv_fcol.split_at(chunks * W);
    for ((rw, sw), iw) in rh
        .chunks_exact_mut(W)
        .zip(sh.chunks_exact_mut(W))
        .zip(ih.chunks_exact(W))
    {
        for k in 0..W {
            let old = rw[k] * iw[k];
            rw[k] *= fr;
            sw[k] += rw[k];
            dl[k] = dl[k].max((rw[k] - old).abs());
        }
    }
    let mut delta = dl.iter().copied().fold(0f32, f32::max);
    for ((v, s), &inv) in rt.iter_mut().zip(st.iter_mut()).zip(it) {
        let old = *v * inv;
        *v *= fr;
        *s += *v;
        delta = delta.max((*v - old).abs());
    }
    delta
}

/// One MAP-UOT iteration over a contiguous block of rows.
///
/// This is the body every execution mode shares: the serial solver calls it
/// once over all rows; each thread of the parallel solver calls it over its
/// row block with a private `next_colsum` (Algorithm 1, lines 5–15).
pub fn fused_rows(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
) {
    debug_assert_eq!(rows.len(), rpd_block.len() * n);
    for (i, row) in rows.chunks_exact_mut(n).enumerate() {
        let sum_row = scale_by_vec_and_sum(row, fcol);
        let fr = factor(rpd_block[i], sum_row, fi);
        scale_by_scalar_and_accumulate(row, fr, next_colsum);
    }
}

/// [`fused_rows`] with in-sweep delta tracking; returns the block's max
/// element change (see [`scale_by_scalar_and_accumulate_tracked`]).
pub fn fused_rows_tracked(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    inv_fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
) -> f32 {
    debug_assert_eq!(rows.len(), rpd_block.len() * n);
    let mut delta = 0f32;
    for (i, row) in rows.chunks_exact_mut(n).enumerate() {
        let sum_row = scale_by_vec_and_sum(row, fcol);
        let fr = factor(rpd_block[i], sum_row, fi);
        delta = delta.max(scale_by_scalar_and_accumulate_tracked(row, fr, inv_fcol, next_colsum));
    }
    delta
}

/// [`fused_rows`] under an explicit [`KernelPolicy`]: kernel-backend
/// dispatch (scalar / unrolled / AVX2+FMA), non-temporal stores past the
/// LLC threshold, and cache-aware column tiling at large `n`.
///
/// `sum_row` is caller scratch of at least `rpd_block.len()` floats (the
/// workspace's `rowsum`); it carries each row's `Sum_row` across column
/// panels in the tiled sweep and is untouched when the policy is untiled.
#[allow(clippy::too_many_arguments)]
pub fn fused_rows_policy(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
) {
    let stream = policy.stream_for(rows.len());
    fused_rows_opt(rows, n, rpd_block, fcol, None, fi, next_colsum, sum_row, policy, stream);
}

/// [`fused_rows_policy`] with in-sweep delta tracking; returns the block's
/// max element change.
#[allow(clippy::too_many_arguments)]
pub fn fused_rows_tracked_policy(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    inv_fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
) -> f32 {
    let stream = policy.stream_for(rows.len());
    fused_rows_opt(
        rows,
        n,
        rpd_block,
        fcol,
        Some(inv_fcol),
        fi,
        next_colsum,
        sum_row,
        policy,
        stream,
    )
}

/// Shared body of the policy-driven fused sweep (tracked when `inv` is
/// given). `stream` is the caller's non-temporal-store decision: the
/// parallel engines compute it from the **whole** plan, not the block —
/// all row blocks of one iteration stream the same matrix.
///
/// Untiled, the loop is the classic Algorithm 1 double-loop through the
/// selected kernel. Tiled, each L2-sized row chunk runs two panel-major
/// phases — (I+II) accumulating `Sum_row` across panels, then (III+IV)
/// with the per-row factors — so `Factor_col`/`inv_fcol`/`NextSum_col`
/// panels stay L1-resident across the chunk's rows while the chunk itself
/// stays L2-resident between the phases. DRAM traffic is unchanged (the
/// chunk is read once and written once per iteration); only the cache
/// behavior above DRAM improves.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_rows_opt(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    inv: Option<&[f32]>,
    fi: f32,
    next_colsum: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
    stream: bool,
) -> f32 {
    use crate::algo::kernels::{KernelKind, ScalarKernel, UnrolledKernel};
    // Dispatch the backend ONCE per sweep, not per row: the generic body
    // monomorphizes per kernel, so the per-row primitive calls stay
    // statically dispatched (and the unrolled free functions inline,
    // exactly as they did before the kernel subsystem existed).
    match policy.kind() {
        KernelKind::Scalar => fused_rows_generic(
            &ScalarKernel, rows, n, rpd_block, fcol, inv, fi, next_colsum, sum_row, policy, stream,
        ),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelKind::Avx2 => fused_rows_generic(
            &crate::algo::kernels::AVX2_FMA_KERNEL,
            rows,
            n,
            rpd_block,
            fcol,
            inv,
            fi,
            next_colsum,
            sum_row,
            policy,
            stream,
        ),
        _ => fused_rows_generic(
            &UnrolledKernel, rows, n, rpd_block, fcol, inv, fi, next_colsum, sum_row, policy,
            stream,
        ),
    }
}

/// Monomorphized body of [`fused_rows_opt`] — see its docs.
#[allow(clippy::too_many_arguments)]
fn fused_rows_generic<K: crate::algo::kernels::Kernel>(
    k: &K,
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    inv: Option<&[f32]>,
    fi: f32,
    next_colsum: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
    stream: bool,
) -> f32 {
    debug_assert_eq!(rows.len(), rpd_block.len() * n);
    let mut delta = 0f32;
    match policy.tile_for(n) {
        None => {
            for (i, row) in rows.chunks_exact_mut(n).enumerate() {
                let s = k.scale_by_vec_and_sum(row, fcol);
                let fr = factor(rpd_block[i], s, fi);
                match inv {
                    Some(iv) => {
                        delta = delta.max(k.scale_by_scalar_and_accumulate_tracked(
                            row,
                            fr,
                            iv,
                            next_colsum,
                            stream,
                        ));
                    }
                    None => k.scale_by_scalar_and_accumulate(row, fr, next_colsum, stream),
                }
            }
        }
        Some(tile) => {
            let m_block = rpd_block.len();
            debug_assert!(sum_row.len() >= m_block, "sum_row scratch too small");
            let chunk_rows = policy.row_chunk(n);
            let mut r0 = 0usize;
            while r0 < m_block {
                let r1 = (r0 + chunk_rows).min(m_block);
                let chunk = &mut rows[r0 * n..r1 * n];
                let srow = &mut sum_row[..r1 - r0];
                srow.fill(0.0);
                // Phase 1 (Computations I+II), panel-major: each fcol
                // panel serves every row of the chunk while L1-hot.
                let mut j0 = 0usize;
                while j0 < n {
                    let j1 = (j0 + tile).min(n);
                    for (i, row) in chunk.chunks_exact_mut(n).enumerate() {
                        srow[i] += k.scale_by_vec_and_sum(&mut row[j0..j1], &fcol[j0..j1]);
                    }
                    j0 = j1;
                }
                // Row factors once per row (not once per row × panel —
                // powf is the only non-streaming cost in the sweep).
                for (i, s) in srow.iter_mut().enumerate() {
                    *s = factor(rpd_block[r0 + i], *s, fi);
                }
                // Phase 2 (Computations III+IV), panel-major again; the
                // chunk re-reads from L2, never DRAM.
                let mut j0 = 0usize;
                while j0 < n {
                    let j1 = (j0 + tile).min(n);
                    for (i, row) in chunk.chunks_exact_mut(n).enumerate() {
                        let fr = srow[i];
                        match inv {
                            Some(iv) => {
                                delta = delta.max(k.scale_by_scalar_and_accumulate_tracked(
                                    &mut row[j0..j1],
                                    fr,
                                    &iv[j0..j1],
                                    &mut next_colsum[j0..j1],
                                    stream,
                                ));
                            }
                            None => k.scale_by_scalar_and_accumulate(
                                &mut row[j0..j1],
                                fr,
                                &mut next_colsum[j0..j1],
                                stream,
                            ),
                        }
                    }
                    j0 = j1;
                }
                r0 = r1;
            }
        }
    }
    delta
}

/// One full MAP-UOT iteration (Algorithm 1, serial), allocation-free:
/// `fcol` is caller-provided scratch (see `session::Workspace`).
pub fn iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
) {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows(plan.as_mut_slice(), n, rpd, fcol, fi, colsum);
}

/// [`iterate_into`] with in-sweep delta tracking; returns the iteration's
/// max element change. `fcol` and `inv_fcol` are caller-provided scratch.
pub fn iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
) -> f32 {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    crate::algo::scaling::recip_into(inv_fcol, fcol);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows_tracked(plan.as_mut_slice(), n, rpd, fcol, inv_fcol, fi, colsum)
}

/// [`iterate_into`] under an explicit [`KernelPolicy`] (the session path):
/// kernel dispatch + tiling + NT stores. `sum_row` is workspace scratch of
/// at least `plan.rows()` floats.
#[allow(clippy::too_many_arguments)]
pub fn iterate_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
) {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows_policy(plan.as_mut_slice(), n, rpd, fcol, fi, colsum, sum_row, policy);
}

/// [`iterate_policy`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn iterate_tracked_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    sum_row: &mut [f32],
    policy: &KernelPolicy,
) -> f32 {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    crate::algo::scaling::recip_into(inv_fcol, fcol);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows_tracked_policy(
        plan.as_mut_slice(),
        n,
        rpd,
        fcol,
        inv_fcol,
        fi,
        colsum,
        sum_row,
        policy,
    )
}

/// One full MAP-UOT iteration (Algorithm 1, serial); allocates its own
/// column-factor scratch — prefer [`iterate_into`] on hot paths.
// uotlint: allow(alloc) — documented legacy wrapper, not a hot path.
pub fn iterate(plan: &mut Matrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let mut fcol = vec![0f32; plan.cols()];
    iterate_into(plan, colsum, rpd, cpd, fi, &mut fcol);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{coffee, pot, problem::Problem};

    #[test]
    fn matches_pot_one_iteration() {
        for seed in 0..5 {
            let p = Problem::random(13, 9, 0.6, seed);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);

            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            pot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
            assert!(a.max_rel_diff(&b, 1e-6) < 1e-4, "seed={seed}");
        }
    }

    #[test]
    fn matches_coffee_many_iterations() {
        let p = Problem::random(16, 24, 0.8, 11);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..20 {
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);
            coffee::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
        }
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3);
    }

    #[test]
    fn unrolled_matches_plain() {
        let mut rng = crate::util::XorShift::new(4);
        for n in [1usize, 3, 4, 7, 8, 15, 33, 257] {
            let mut row: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let fcol: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let mut plain = row.clone();
            let mut plain_sum = 0f32;
            for (v, &f) in plain.iter_mut().zip(&fcol) {
                *v *= f;
                plain_sum += *v;
            }
            let s = scale_by_vec_and_sum(&mut row, &fcol);
            assert_eq!(row, plain, "n={n}");
            assert!((s - plain_sum).abs() <= 1e-4 * plain_sum.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn unrolled_accumulate_matches_plain() {
        let mut rng = crate::util::XorShift::new(9);
        for n in [1usize, 3, 4, 7, 8, 15, 16, 33, 257] {
            let row0: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let inv: Vec<f32> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
            let cs0: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
            let fr = 0.875f32;

            // Plain forms (the pre-unroll loops).
            let mut row_p = row0.clone();
            let mut cs_p = cs0.clone();
            for (v, s) in row_p.iter_mut().zip(cs_p.iter_mut()) {
                *v *= fr;
                *s += *v;
            }
            let mut row = row0.clone();
            let mut cs = cs0.clone();
            scale_by_scalar_and_accumulate(&mut row, fr, &mut cs);
            assert_eq!(row, row_p, "n={n}");
            assert_eq!(cs, cs_p, "n={n}");

            let mut row_p = row0.clone();
            let mut cs_p = cs0.clone();
            let mut d_p = 0f32;
            for ((v, s), &iv) in row_p.iter_mut().zip(cs_p.iter_mut()).zip(&inv) {
                let old = *v * iv;
                *v *= fr;
                *s += *v;
                d_p = d_p.max((*v - old).abs());
            }
            let mut row = row0.clone();
            let mut cs = cs0.clone();
            let d = scale_by_scalar_and_accumulate_tracked(&mut row, fr, &inv, &mut cs);
            assert_eq!(row, row_p, "tracked n={n}");
            assert_eq!(cs, cs_p, "tracked n={n}");
            assert_eq!(d.to_bits(), d_p.to_bits(), "tracked delta n={n}");
        }
    }

    #[test]
    fn tiled_policy_matches_untiled() {
        use crate::algo::kernels::{KernelKind, KernelPolicy};
        // Tile widths crossing every edge: divides n, doesn't divide n,
        // exceeds n (degenerates to untiled), and n = 1.
        for (m, n) in [(7usize, 129usize), (5, 64), (1, 1), (3, 8), (16, 33)] {
            let p = Problem::random(m, n, 0.7, (m + n) as u64);
            for tile in [3usize, 7, 16, 64, 1000] {
                let policy = KernelPolicy::explicit(KernelKind::Unrolled, tile, None);
                let mut a = p.plan.clone();
                let mut cs_a = a.col_sums();
                let mut fcol = vec![0f32; n];
                let mut srow = vec![0f32; m];
                let mut b = p.plan.clone();
                let mut cs_b = b.col_sums();
                for _ in 0..3 {
                    iterate_policy(
                        &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut fcol, &mut srow, &policy,
                    );
                    iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
                }
                assert!(
                    a.max_rel_diff(&b, 1e-6) < 1e-5,
                    "{m}x{n} tile={tile}: {}",
                    a.max_rel_diff(&b, 1e-6)
                );
                for (x, y) in cs_a.iter().zip(&cs_b) {
                    assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0), "{m}x{n} tile={tile}");
                }
            }
        }
    }

    #[test]
    fn nextsum_col_equals_fresh_colsum() {
        let p = Problem::random(10, 17, 0.5, 9);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi);
        for (carried, fresh) in cs.iter().zip(a.col_sums()) {
            assert!((carried - fresh).abs() < 1e-4);
        }
    }

    #[test]
    fn single_row_and_single_col_edge_cases() {
        for (m, n) in [(1, 8), (8, 1), (1, 1)] {
            let p = Problem::random(m, n, 0.5, 21);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            pot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
            assert!(a.max_rel_diff(&b, 1e-6) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn zero_column_stays_zero() {
        // A column with zero mass gets factor 0 (guard) and must remain 0.
        let mut plan = Matrix::from_fn(4, 3, |_, j| if j == 1 { 0.0 } else { 1.0 });
        let mut cs = plan.col_sums();
        let rpd = vec![1.0; 4];
        let cpd = vec![1.0; 3];
        iterate(&mut plan, &mut cs, &rpd, &cpd, 0.5);
        for i in 0..4 {
            assert_eq!(plan.get(i, 1), 0.0);
        }
        assert!(plan.as_slice().iter().all(|v| v.is_finite()));
    }
}
