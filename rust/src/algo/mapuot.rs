//! MAP-UOT: the paper's fused interweaved iteration (Algorithm 1, serial).
//!
//! One double-loop per iteration. For each row, while it is cache-resident:
//!   Computation I   — multiply by `Factor_col` (column rescaling)
//!   Computation II  — accumulate `Sum_row`
//!   Computation III — multiply by `Factor_row = (RPD_i/Sum_row)^fi`
//!   Computation IV  — accumulate `NextSum_col`
//! The matrix streams through DRAM once (one read + one write, 2·M·N
//! element accesses — the Roofline-model minimum of §3.1); the second inner
//! loop re-touches the same row out of L1/L2. All accesses are contiguous.
//!
//! The inner loops are written as 4-way unrolled chunk loops; LLVM turns
//! them into the AVX2 code the paper writes by hand (verified against the
//! plain form in `tests::unrolled_matches_plain` and in the perf log).

use crate::algo::scaling::{factor, factors_into};
use crate::util::Matrix;

/// Fused pass over one row: `row *= fcol` element-wise, returns the row sum.
/// (Computations I + II.)
#[inline]
pub fn scale_by_vec_and_sum(row: &mut [f32], fcol: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), fcol.len());
    // 16 independent accumulator lanes: wide enough for AVX2/AVX-512
    // auto-vectorization AND to break the add-latency dependency chain
    // (4 lanes capped the primitive at ~47% of streaming peak — §Perf log).
    const W: usize = 16;
    let mut acc = [0f32; W];
    let chunks = row.len() / W;
    let (rh, rt) = row.split_at_mut(chunks * W);
    let (fh, ft) = fcol.split_at(chunks * W);
    for (rw, fw) in rh.chunks_exact_mut(W).zip(fh.chunks_exact(W)) {
        for k in 0..W {
            rw[k] *= fw[k];
            acc[k] += rw[k];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for (r, &f) in rt.iter_mut().zip(ft) {
        *r *= f;
        s += *r;
    }
    s
}

/// Fused pass over one row: `row *= fr`, accumulating into `next_colsum`.
/// (Computations III + IV.)
#[inline]
pub fn scale_by_scalar_and_accumulate(row: &mut [f32], fr: f32, next_colsum: &mut [f32]) {
    debug_assert_eq!(row.len(), next_colsum.len());
    for (v, s) in row.iter_mut().zip(next_colsum.iter_mut()) {
        *v *= fr;
        *s += *v;
    }
}

/// [`scale_by_scalar_and_accumulate`] that also returns the row's max
/// element change for this iteration, recovered in-register: the incoming
/// `row` holds `v1 = v0 · Factor_col[j]`, so the pre-iteration value is
/// `v1 · inv_fcol[j]` and the new value is `v1 · fr` — no snapshot needed.
#[inline]
pub fn scale_by_scalar_and_accumulate_tracked(
    row: &mut [f32],
    fr: f32,
    inv_fcol: &[f32],
    next_colsum: &mut [f32],
) -> f32 {
    debug_assert_eq!(row.len(), next_colsum.len());
    debug_assert_eq!(row.len(), inv_fcol.len());
    let mut delta = 0f32;
    for ((v, s), &inv) in row.iter_mut().zip(next_colsum.iter_mut()).zip(inv_fcol) {
        let old = *v * inv;
        *v *= fr;
        *s += *v;
        delta = delta.max((*v - old).abs());
    }
    delta
}

/// One MAP-UOT iteration over a contiguous block of rows.
///
/// This is the body every execution mode shares: the serial solver calls it
/// once over all rows; each thread of the parallel solver calls it over its
/// row block with a private `next_colsum` (Algorithm 1, lines 5–15).
pub fn fused_rows(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
) {
    debug_assert_eq!(rows.len(), rpd_block.len() * n);
    for (i, row) in rows.chunks_exact_mut(n).enumerate() {
        let sum_row = scale_by_vec_and_sum(row, fcol);
        let fr = factor(rpd_block[i], sum_row, fi);
        scale_by_scalar_and_accumulate(row, fr, next_colsum);
    }
}

/// [`fused_rows`] with in-sweep delta tracking; returns the block's max
/// element change (see [`scale_by_scalar_and_accumulate_tracked`]).
pub fn fused_rows_tracked(
    rows: &mut [f32],
    n: usize,
    rpd_block: &[f32],
    fcol: &[f32],
    inv_fcol: &[f32],
    fi: f32,
    next_colsum: &mut [f32],
) -> f32 {
    debug_assert_eq!(rows.len(), rpd_block.len() * n);
    let mut delta = 0f32;
    for (i, row) in rows.chunks_exact_mut(n).enumerate() {
        let sum_row = scale_by_vec_and_sum(row, fcol);
        let fr = factor(rpd_block[i], sum_row, fi);
        delta = delta.max(scale_by_scalar_and_accumulate_tracked(row, fr, inv_fcol, next_colsum));
    }
    delta
}

/// One full MAP-UOT iteration (Algorithm 1, serial), allocation-free:
/// `fcol` is caller-provided scratch (see `session::Workspace`).
pub fn iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
) {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows(plan.as_mut_slice(), n, rpd, fcol, fi, colsum);
}

/// [`iterate_into`] with in-sweep delta tracking; returns the iteration's
/// max element change. `fcol` and `inv_fcol` are caller-provided scratch.
pub fn iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
) -> f32 {
    let n = plan.cols();
    factors_into(fcol, cpd, colsum, fi);
    crate::algo::scaling::recip_into(inv_fcol, fcol);
    colsum.fill(0.0); // becomes NextSum_col
    fused_rows_tracked(plan.as_mut_slice(), n, rpd, fcol, inv_fcol, fi, colsum)
}

/// One full MAP-UOT iteration (Algorithm 1, serial); allocates its own
/// column-factor scratch — prefer [`iterate_into`] on hot paths.
pub fn iterate(plan: &mut Matrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let mut fcol = vec![0f32; plan.cols()];
    iterate_into(plan, colsum, rpd, cpd, fi, &mut fcol);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{coffee, pot, problem::Problem};

    #[test]
    fn matches_pot_one_iteration() {
        for seed in 0..5 {
            let p = Problem::random(13, 9, 0.6, seed);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);

            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            pot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
            assert!(a.max_rel_diff(&b, 1e-6) < 1e-4, "seed={seed}");
        }
    }

    #[test]
    fn matches_coffee_many_iterations() {
        let p = Problem::random(16, 24, 0.8, 11);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..20 {
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);
            coffee::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
        }
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3);
    }

    #[test]
    fn unrolled_matches_plain() {
        let mut rng = crate::util::XorShift::new(4);
        for n in [1usize, 3, 4, 7, 8, 15, 33, 257] {
            let mut row: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let fcol: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let mut plain = row.clone();
            let mut plain_sum = 0f32;
            for (v, &f) in plain.iter_mut().zip(&fcol) {
                *v *= f;
                plain_sum += *v;
            }
            let s = scale_by_vec_and_sum(&mut row, &fcol);
            assert_eq!(row, plain, "n={n}");
            assert!((s - plain_sum).abs() <= 1e-4 * plain_sum.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn nextsum_col_equals_fresh_colsum() {
        let p = Problem::random(10, 17, 0.5, 9);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi);
        for (carried, fresh) in cs.iter().zip(a.col_sums()) {
            assert!((carried - fresh).abs() < 1e-4);
        }
    }

    #[test]
    fn single_row_and_single_col_edge_cases() {
        for (m, n) in [(1, 8), (8, 1), (1, 1)] {
            let p = Problem::random(m, n, 0.5, 21);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            pot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
            assert!(a.max_rel_diff(&b, 1e-6) < 1e-4, "{m}x{n}");
        }
    }

    #[test]
    fn zero_column_stays_zero() {
        // A column with zero mass gets factor 0 (guard) and must remain 0.
        let mut plan = Matrix::from_fn(4, 3, |_, j| if j == 1 { 0.0 } else { 1.0 });
        let mut cs = plan.col_sums();
        let rpd = vec![1.0; 4];
        let cpd = vec![1.0; 3];
        iterate(&mut plan, &mut cs, &rpd, &cpd, 0.5);
        for i in 0..4 {
            assert_eq!(plan.get(i, 1), 0.0);
        }
        assert!(plan.as_slice().iter().all(|v| v.is_finite()));
    }
}
