//! Generality of the interweaving strategy (paper §1: "broad generality
//! for algorithms with similar iterations of row and column rescaling" —
//! e.g. Sinkhorn-Knopp matrix balancing and the Sinkhorn-distance/EMD
//! kernel of Cuturi).
//!
//! This module applies the fused double-loop to two cousins of UOT:
//!
//! * **Doubly-stochastic balancing** (Sinkhorn–Knopp): scale a positive
//!   matrix until every row and column sums to 1 — UOT with uniform
//!   marginals and `fi = 1`.
//! * **Sinkhorn distance**: run balanced Sinkhorn on the Gibbs kernel of a
//!   cost matrix and return `Σ_ij P_ij · C_ij` — the entropic OT cost.
//!
//! Both reuse `mapuot::fused_rows` unchanged, which is the generality
//! claim in executable form.

use crate::algo::mapuot;
use crate::algo::scaling::factors_into;
use crate::util::Matrix;

/// Fused Sinkhorn–Knopp balancing step: one pass, uniform marginals.
pub fn balance_iterate(a: &mut Matrix, colsum: &mut [f32]) {
    let (m, n) = (a.rows(), a.cols());
    let rpd = vec![1.0f32; m];
    let cpd = vec![1.0f32; n];
    let mut fcol = vec![0f32; n];
    factors_into(&mut fcol, &cpd, colsum, 1.0);
    colsum.fill(0.0);
    mapuot::fused_rows(a.as_mut_slice(), n, &rpd, &fcol, 1.0, colsum);
}

/// Balance `a` to row/col sums of 1 within `tol`; returns iterations used
/// (or `max_iter` if the budget ran out).
pub fn balance(a: &mut Matrix, tol: f32, max_iter: usize) -> usize {
    let mut colsum = a.col_sums();
    for it in 0..max_iter {
        balance_iterate(a, &mut colsum);
        let row_err = a
            .row_sums()
            .iter()
            .map(|r| (r - 1.0).abs())
            .fold(0f32, f32::max);
        let col_err = colsum.iter().map(|c| (c - 1.0).abs()).fold(0f32, f32::max);
        if row_err.max(col_err) <= tol {
            return it + 1;
        }
    }
    max_iter
}

/// Entropic OT (Sinkhorn distance, Cuturi 2013): `min <P, C> + entropy`,
/// solved by balanced Sinkhorn on `K = exp(-C/eps)` with marginals
/// `(r, c)`, via the same fused pass. Returns `(P, distance)`.
pub fn sinkhorn_distance(
    cost: &Matrix,
    r: &[f32],
    c: &[f32],
    eps: f32,
    iters: usize,
) -> (Matrix, f32) {
    let (m, n) = (cost.rows(), cost.cols());
    let mut p = Matrix::from_fn(m, n, |i, j| (-cost.get(i, j) / eps).exp());
    let mut colsum = p.col_sums();
    let mut fcol = vec![0f32; n];
    for _ in 0..iters {
        factors_into(&mut fcol, c, &colsum, 1.0);
        colsum.fill(0.0);
        mapuot::fused_rows(p.as_mut_slice(), n, r, &fcol, 1.0, &mut colsum);
    }
    let dist: f32 = (0..m)
        .map(|i| {
            p.row(i)
                .iter()
                .zip(cost.row(i))
                .map(|(&pv, &cv)| pv * cv)
                .sum::<f32>()
        })
        .sum();
    (p, dist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn balancing_converges_to_doubly_stochastic() {
        let mut rng = XorShift::new(1);
        // Square positive matrix scaled so total mass == n (required for
        // doubly-stochastic feasibility).
        let n = 16;
        let mut a = Matrix::from_fn(n, n, |_, _| rng.uniform(0.2, 2.0));
        let iters = balance(&mut a, 1e-4, 500);
        assert!(iters < 500, "did not converge");
        for rs in a.row_sums() {
            assert!((rs - 1.0).abs() < 1e-3, "{rs}");
        }
        for cs in a.col_sums() {
            assert!((cs - 1.0).abs() < 1e-3, "{cs}");
        }
    }

    #[test]
    fn sinkhorn_distance_identity_cost_is_cheap() {
        // Cost 0 on the diagonal, 1 elsewhere: optimal plan concentrates on
        // the diagonal, so the entropic cost is far below uniform.
        let n = 12;
        let cost = Matrix::from_fn(n, n, |i, j| if i == j { 0.0 } else { 1.0 });
        let marg = vec![1.0 / n as f32; n];
        let (p, d) = sinkhorn_distance(&cost, &marg, &marg, 0.05, 200);
        let uniform_cost = (n as f32 - 1.0) / n as f32; // <U, C>
        assert!(d < 0.2 * uniform_cost, "d={d} uniform={uniform_cost}");
        // Plan marginals hold.
        for rs in p.row_sums() {
            assert!((rs - 1.0 / n as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn sinkhorn_distance_is_symmetric_for_symmetric_cost() {
        let mut rng = XorShift::new(3);
        let n = 8;
        let mut cost = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = if i == j { 0.0 } else { rng.uniform(0.2, 1.0) };
                cost.set(i, j, v);
                cost.set(j, i, v);
            }
        }
        let marg = vec![1.0 / n as f32; n];
        let (_, d1) = sinkhorn_distance(&cost, &marg, &marg, 0.1, 100);
        // Transpose problem: same distance for symmetric cost + equal marginals.
        let cost_t = Matrix::from_fn(n, n, |i, j| cost.get(j, i));
        let (_, d2) = sinkhorn_distance(&cost_t, &marg, &marg, 0.1, 100);
        assert!((d1 - d2).abs() < 1e-4, "{d1} vs {d2}");
    }
}
