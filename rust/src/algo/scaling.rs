//! Shared rescaling primitives.
//!
//! Every solver variant funnels through [`factor`] so that the guard for
//! empty rows/columns (zero mass ⇒ factor 0, leaving the row/column zero
//! instead of producing inf/NaN) is uniform across POT, COFFEE and MAP-UOT,
//! keeping them numerically interchangeable.

/// Rescaling factor `(target / sum)^fi` (paper §2.1), guarded for `sum = 0`.
#[inline(always)]
pub fn factor(target: f32, sum: f32, fi: f32) -> f32 {
    if sum > 0.0 {
        (target / sum).powf(fi)
    } else {
        0.0
    }
}

/// Fill `out[j] = factor(target[j], sums[j], fi)` (parts ①/③ of §4, O(N)).
pub fn factors_into(out: &mut [f32], target: &[f32], sums: &[f32], fi: f32) {
    debug_assert_eq!(out.len(), target.len());
    debug_assert_eq!(out.len(), sums.len());
    for ((o, &t), &s) in out.iter_mut().zip(target).zip(sums) {
        *o = factor(t, s, fi);
    }
}

/// Fill `out[j] = 1 / factors[j]`, with the same zero guard as [`factor`]
/// (`factors[j] = 0` ⇒ `0`). Used by the in-sweep `plan_delta` tracking:
/// the pre-iteration value is recovered as `cur · (1 / Factor_col)`.
///
/// The zero guard is exact under the [`Problem`](crate::algo::Problem)
/// invariant that marginals are strictly positive (enforced by
/// `Problem::new` and all in-crate generators): then a zero column factor
/// can only come from a zero column sum, i.e. an already-zero column, and
/// the recovered `old = 0` is the true previous value. A hand-built
/// problem that bypasses validation with a zero/negative `cpd[j]` over a
/// nonzero column would see that column's collapse under-reported in the
/// tracked delta for the one iteration where it happens.
pub fn recip_into(out: &mut [f32], factors: &[f32]) {
    debug_assert_eq!(out.len(), factors.len());
    for (o, &f) in out.iter_mut().zip(factors) {
        *o = if f > 0.0 { 1.0 / f } else { 0.0 };
    }
}

/// Per-iteration DRAM traffic in matrix-element accesses (paper §3.1),
/// given `accesses_per_element` from
/// [`SolverKind::accesses_per_element`](crate::algo::SolverKind::accesses_per_element):
/// POT 6·M·N, COFFEE 4·M·N, MAP-UOT 2·M·N (the Roofline minimum).
pub fn traffic_elements(m: usize, n: usize, accesses_per_element: usize) -> usize {
    accesses_per_element * m * n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_matches_pow() {
        let f = factor(2.0, 0.5, 0.7);
        assert!((f - 4f32.powf(0.7)).abs() < 1e-6);
    }

    #[test]
    fn factor_identity_when_satisfied() {
        assert_eq!(factor(1.3, 1.3, 0.42), 1.0);
    }

    #[test]
    fn factor_guards_zero_sum() {
        assert_eq!(factor(1.0, 0.0, 0.5), 0.0);
        assert_eq!(factor(1.0, -0.0, 0.5), 0.0);
    }

    #[test]
    fn factors_into_vectorized() {
        let mut out = [0f32; 3];
        factors_into(&mut out, &[1.0, 2.0, 3.0], &[1.0, 1.0, 0.0], 1.0);
        assert_eq!(out, [1.0, 2.0, 0.0]);
    }
}
