//! Shared rescaling primitives.
//!
//! Every solver variant funnels through [`factor`] so that the guard for
//! empty rows/columns (zero mass ⇒ factor 0, leaving the row/column zero
//! instead of producing inf/NaN) is uniform across POT, COFFEE and MAP-UOT,
//! keeping them numerically interchangeable.

/// Rescaling factor `(target / sum)^fi` (paper §2.1), guarded for `sum = 0`.
#[inline(always)]
pub fn factor(target: f32, sum: f32, fi: f32) -> f32 {
    if sum > 0.0 {
        (target / sum).powf(fi)
    } else {
        0.0
    }
}

/// Fill `out[j] = factor(target[j], sums[j], fi)` (parts ①/③ of §4, O(N)).
pub fn factors_into(out: &mut [f32], target: &[f32], sums: &[f32], fi: f32) {
    debug_assert_eq!(out.len(), target.len());
    debug_assert_eq!(out.len(), sums.len());
    for ((o, &t), &s) in out.iter_mut().zip(target).zip(sums) {
        *o = factor(t, s, fi);
    }
}

/// Fill `out[j] = 1 / factors[j]`, with the same zero guard as [`factor`]
/// (`factors[j] = 0` ⇒ `0`). Used by the in-sweep `plan_delta` tracking:
/// the pre-iteration value is recovered as `cur · (1 / Factor_col)`.
///
/// The zero guard is exact under the [`Problem`](crate::algo::Problem)
/// invariant that marginals are strictly positive (enforced by
/// `Problem::new` and all in-crate generators): then a zero column factor
/// can only come from a zero column sum, i.e. an already-zero column, and
/// the recovered `old = 0` is the true previous value. A hand-built
/// problem that bypasses validation with a zero/negative `cpd[j]` over a
/// nonzero column would see that column's collapse under-reported in the
/// tracked delta for the one iteration where it happens.
pub fn recip_into(out: &mut [f32], factors: &[f32]) {
    debug_assert_eq!(out.len(), factors.len());
    for (o, &f) in out.iter_mut().zip(factors) {
        *o = if f > 0.0 { 1.0 / f } else { 0.0 };
    }
}

/// The total plan mass the damped alternating rescaling is stationary at.
///
/// One sweep moves the total mass `s` toward `Σcpd` with exponent `fi`
/// (column stage) and then toward `Σrpd` with exponent `fi` (row stage);
/// in log-mass the stationary point of that composition is
/// `ln M* = ((1 − fi)·ln Σcpd + ln Σrpd) / (2 − fi)`, i.e.
/// `M* = (Σcpd^(1−fi) · Σrpd)^(1/(2−fi))`. This is where a *plain* solve
/// ends up, so it is the only translation target the TI correction
/// ([`ti_rescale`]) may aim at without moving the converged plan. For
/// `fi = 1` with equal masses it degenerates to the classic balanced
/// total, as it must.
pub fn ti_mass_target(rpd_total: f32, cpd_total: f32, fi: f32) -> f32 {
    (cpd_total.powf(1.0 - fi) * rpd_total).powf(1.0 / (2.0 - fi))
}

/// Translation-invariant pre-sweep correction (after Séjourné–Vialard–
/// Peyré, arXiv:2201.00730, adapted to the carried-colsum iteration):
/// rescale the carried column sums by `β = (s / M*)^((1−fi)/fi)` with
/// `s = Σ colsum` and `M*` from [`ti_mass_target`], so the next column
/// factors gain the global term `(M*/s)^(1−fi)` and the column stage
/// corrects the **global mass mode with effective exponent 1** instead of
/// `fi`. Plain damped sweeps contract that mode by only `(1 − fi)²` per
/// iteration — the slowest transient a drifting-marginal stream excites —
/// while the TI-corrected sweep removes it in one iteration.
///
/// Correctness: at the plain iteration's stationary point `s = M*` exactly
/// (see [`ti_mass_target`]), so `β = 1` and TI solves share the plain
/// fixed point — the property suite pins TI plans to plain plans at 1e-5.
/// The tracked `plan_delta` machinery needs no adaptation: factors
/// computed from the rescaled sums are the factors actually applied, so
/// in-sweep recovery via their reciprocals stays exact.
///
/// No-op (returns 1) for `fi ≥ 1` (undamped sweeps already correct mass
/// with exponent 1), degenerate sums, or a non-finite β. Allocation-free.
pub fn ti_rescale(colsum: &mut [f32], mass_target: f32, fi: f32) -> f32 {
    if !(fi > 0.0 && fi < 1.0) || !(mass_target > 0.0) {
        return 1.0;
    }
    let s: f32 = colsum.iter().sum();
    if !(s > 0.0) {
        return 1.0;
    }
    let beta = (s / mass_target).powf((1.0 - fi) / fi);
    if !beta.is_finite() || beta <= 0.0 || beta == 1.0 {
        return 1.0;
    }
    for c in colsum.iter_mut() {
        *c *= beta;
    }
    beta
}

/// Per-iteration DRAM traffic in matrix-element accesses (paper §3.1),
/// given `accesses_per_element` from
/// [`SolverKind::accesses_per_element`](crate::algo::SolverKind::accesses_per_element):
/// POT 6·M·N, COFFEE 4·M·N, MAP-UOT 2·M·N (the Roofline minimum).
pub fn traffic_elements(m: usize, n: usize, accesses_per_element: usize) -> usize {
    accesses_per_element * m * n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_matches_pow() {
        let f = factor(2.0, 0.5, 0.7);
        assert!((f - 4f32.powf(0.7)).abs() < 1e-6);
    }

    #[test]
    fn factor_identity_when_satisfied() {
        assert_eq!(factor(1.3, 1.3, 0.42), 1.0);
    }

    #[test]
    fn factor_guards_zero_sum() {
        assert_eq!(factor(1.0, 0.0, 0.5), 0.0);
        assert_eq!(factor(1.0, -0.0, 0.5), 0.0);
    }

    #[test]
    fn factors_into_vectorized() {
        let mut out = [0f32; 3];
        factors_into(&mut out, &[1.0, 2.0, 3.0], &[1.0, 1.0, 0.0], 1.0);
        assert_eq!(out, [1.0, 2.0, 0.0]);
    }

    #[test]
    fn ti_mass_target_interpolates_the_totals() {
        // Balanced totals: the stationary mass is that total for any fi.
        assert!((ti_mass_target(3.0, 3.0, 0.5) - 3.0).abs() < 1e-6);
        // Unbalanced: strictly between the two totals, and equal to the
        // closed form (t_c^(1-fi) · t_r)^(1/(2-fi)).
        let t = ti_mass_target(8.0, 2.0, 0.5);
        let want = (2f32.powf(0.5) * 8.0).powf(1.0 / 1.5);
        assert!((t - want).abs() < 1e-5, "{t} vs {want}");
        assert!(t > 2.0 && t < 8.0);
    }

    #[test]
    fn ti_rescale_is_identity_at_the_stationary_mass() {
        // Column sums already totalling M*: β = 1, sums untouched.
        let mut colsum = [1.5f32, 0.5, 1.0];
        let before = colsum;
        let beta = ti_rescale(&mut colsum, 3.0, 0.6);
        assert_eq!(beta, 1.0);
        assert_eq!(colsum, before);
    }

    #[test]
    fn ti_rescale_moves_sums_toward_the_target() {
        // Total 6 against target 3 with fi = 0.5: β = (6/3)^1 = 2 — the
        // *factors* computed from the doubled sums then shrink the plan by
        // the full (3/6)^(1-fi) global term.
        let mut colsum = [4.0f32, 2.0];
        let beta = ti_rescale(&mut colsum, 3.0, 0.5);
        assert!((beta - 2.0).abs() < 1e-6);
        assert_eq!(colsum, [8.0, 4.0]);
    }

    #[test]
    fn ti_rescale_guards_degenerate_inputs() {
        // fi = 1 (undamped) is a documented no-op.
        let mut colsum = [1.0f32, 2.0];
        assert_eq!(ti_rescale(&mut colsum, 3.0, 1.0), 1.0);
        assert_eq!(colsum, [1.0, 2.0]);
        // Zero column mass cannot produce a correction.
        let mut zeros = [0.0f32; 2];
        assert_eq!(ti_rescale(&mut zeros, 3.0, 0.5), 1.0);
        // Degenerate target leaves the sums alone.
        assert_eq!(ti_rescale(&mut colsum, 0.0, 0.5), 1.0);
        assert_eq!(colsum, [1.0, 2.0]);
    }
}
