//! Double-precision solvers (paper §5.1: "we obtain similar performance
//! improvement when using double-precision floating-point numbers").
//!
//! f64 doubles every solver's byte traffic, so the *ratios* between POT /
//! COFFEE / MAP-UOT are unchanged (all scale by the same factor) while
//! absolute times roughly double in the DRAM-bound regime — which is
//! exactly what `benches/ablation_fp64.rs` verifies. Kept as a separate,
//! self-contained f64 implementation rather than genericizing the f32 hot
//! path (monomorphization would be free, but the f32 path's layout
//! guarantees and tests stay simpler untouched).

use crate::util::telemetry::{self, Phase};
use crate::util::XorShift;

/// One fused MAP-UOT iteration over a row-major f64 matrix,
/// allocation-free: `fcol` (length N) is caller-provided scratch — the
/// hot-path form the PR 1 allocation contract requires, mirroring the f32
/// path's `mapuot::iterate_into`. (The previous `mapuot_iterate` body
/// allocated a fresh `fcol` every iteration, so the f64 ablation was
/// timing the allocator alongside the sweep.)
pub fn mapuot_iterate_into(
    plan: &mut [f64],
    n: usize,
    colsum: &mut [f64],
    rpd: &[f64],
    cpd: &[f64],
    fi: f64,
    fcol: &mut [f64],
) {
    debug_assert_eq!(plan.len(), rpd.len() * n);
    debug_assert_eq!(fcol.len(), n);
    let _sweep = telemetry::span(Phase::FusedSweep);
    for ((f, &t), &s) in fcol.iter_mut().zip(cpd).zip(colsum.iter()) {
        *f = if s > 0.0 { (t / s).powf(fi) } else { 0.0 };
    }
    colsum.fill(0.0);
    for (i, row) in plan.chunks_exact_mut(n).enumerate() {
        // Computations I + II (8-lane accumulator: AVX-width for f64).
        const W: usize = 8;
        let mut acc = [0f64; W];
        let chunks = n / W;
        let (rh, rt) = row.split_at_mut(chunks * W);
        let (fh, ft) = fcol.split_at(chunks * W);
        for (rw, fw) in rh.chunks_exact_mut(W).zip(fh.chunks_exact(W)) {
            for k in 0..W {
                rw[k] *= fw[k];
                acc[k] += rw[k];
            }
        }
        let mut s = acc.iter().sum::<f64>();
        for (r, &f) in rt.iter_mut().zip(ft) {
            *r *= f;
            s += *r;
        }
        // Computations III + IV.
        let fr = if s > 0.0 { (rpd[i] / s).powf(fi) } else { 0.0 };
        for (v, cs) in row.iter_mut().zip(colsum.iter_mut()) {
            *v *= fr;
            *cs += *v;
        }
    }
}

/// [`mapuot_iterate_into`] with its own column-factor scratch — prefer
/// the `_into` form on hot paths (kept as the convenient test entry
/// point, like the f32 `mapuot::iterate`).
pub fn mapuot_iterate(
    plan: &mut [f64],
    n: usize,
    colsum: &mut [f64],
    rpd: &[f64],
    cpd: &[f64],
    fi: f64,
) {
    let mut fcol = vec![0f64; n];
    mapuot_iterate_into(plan, n, colsum, rpd, cpd, fi, &mut fcol);
}

/// One POT (4-sweep) iteration over f64 — comparator for the ablation.
pub fn pot_iterate(
    plan: &mut [f64],
    n: usize,
    colsum: &mut [f64],
    rpd: &[f64],
    cpd: &[f64],
    fi: f64,
) {
    let m = plan.len() / n;
    // Sweep 1.
    let mut sums = vec![0f64; n];
    for row in plan.chunks_exact(n) {
        for (s, &v) in sums.iter_mut().zip(row) {
            *s += v;
        }
    }
    let mut fcol = vec![0f64; n];
    for ((f, &t), &s) in fcol.iter_mut().zip(cpd).zip(&sums) {
        *f = if s > 0.0 { (t / s).powf(fi) } else { 0.0 };
    }
    // Sweep 2.
    for row in plan.chunks_exact_mut(n) {
        for (v, &f) in row.iter_mut().zip(&fcol) {
            *v *= f;
        }
    }
    // Sweep 3.
    let rowsum: Vec<f64> = plan.chunks_exact(n).map(|r| r.iter().sum()).collect();
    // Sweep 4.
    for (i, row) in plan.chunks_exact_mut(n).enumerate() {
        let fr = if rowsum[i] > 0.0 { (rpd[i] / rowsum[i]).powf(fi) } else { 0.0 };
        for v in row {
            *v *= fr;
        }
    }
    // Refresh carried colsum.
    colsum.fill(0.0);
    for row in plan.chunks_exact(n) {
        for (s, &v) in colsum.iter_mut().zip(row) {
            *s += v;
        }
    }
    let _ = m;
}

/// Deterministic random f64 problem matching `Problem::random`'s ranges.
pub fn random_problem(m: usize, n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = XorShift::new(seed);
    let plan = (0..m * n).map(|_| rng.uniform(0.05, 2.0) as f64).collect();
    let rpd = (0..m).map(|_| rng.uniform(0.3, 1.7) as f64).collect();
    let cpd = (0..n).map(|_| rng.uniform(0.3, 1.7) as f64).collect();
    (plan, rpd, cpd)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn colsums(plan: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0f64; n];
        for row in plan.chunks_exact(n) {
            for (s, &v) in out.iter_mut().zip(row) {
                *s += v;
            }
        }
        out
    }

    #[test]
    fn into_variant_is_bit_identical_to_wrapper() {
        let (plan0, rpd, cpd) = random_problem(13, 9, 7);
        let mut a = plan0.clone();
        let mut b = plan0;
        let mut cs_a = colsums(&a, 9);
        let mut cs_b = colsums(&b, 9);
        let mut fcol = vec![0f64; 9];
        for _ in 0..6 {
            mapuot_iterate(&mut a, 9, &mut cs_a, &rpd, &cpd, 0.7);
            mapuot_iterate_into(&mut b, 9, &mut cs_b, &rpd, &cpd, 0.7, &mut fcol);
        }
        assert_eq!(a, b);
        assert_eq!(cs_a, cs_b);
    }

    #[test]
    fn fp64_mapuot_matches_fp64_pot() {
        let (plan0, rpd, cpd) = random_problem(15, 11, 3);
        let mut a = plan0.clone();
        let mut b = plan0.clone();
        let mut cs_a = colsums(&a, 11);
        let mut cs_b = colsums(&b, 11);
        for _ in 0..8 {
            mapuot_iterate(&mut a, 11, &mut cs_a, &rpd, &cpd, 0.7);
            pot_iterate(&mut b, 11, &mut cs_b, &rpd, &cpd, 0.7);
        }
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10 * y.abs().max(1e-10), "{x} vs {y}");
        }
    }

    #[test]
    fn fp64_matches_fp32_to_single_precision() {
        // Same problem through the f32 path: answers agree to f32 accuracy.
        let (plan64, rpd64, cpd64) = random_problem(12, 9, 5);
        let p32 = crate::algo::Problem::random(12, 9, 0.7, 5);
        let mut a64 = plan64.clone();
        let mut cs64 = colsums(&a64, 9);
        let mut a32 = p32.plan.clone();
        let mut cs32 = a32.col_sums();
        for _ in 0..5 {
            mapuot_iterate(&mut a64, 9, &mut cs64, &rpd64, &cpd64, 0.7);
            crate::algo::mapuot::iterate(&mut a32, &mut cs32, &p32.rpd, &p32.cpd, 0.7);
        }
        for (x64, x32) in a64.iter().zip(a32.as_slice()) {
            assert!(
                (x64 - *x32 as f64).abs() < 1e-4 * x64.abs().max(1e-4),
                "{x64} vs {x32}"
            );
        }
    }

    #[test]
    fn fp64_higher_precision_on_long_runs() {
        // After many iterations the carried f64 colsum drifts less from the
        // fresh colsum than f32 does on an equivalent problem.
        let (mut a, rpd, cpd) = random_problem(32, 24, 9);
        let mut cs = colsums(&a, 24);
        for _ in 0..200 {
            mapuot_iterate(&mut a, 24, &mut cs, &rpd, &cpd, 0.9);
        }
        let fresh = colsums(&a, 24);
        let drift = cs
            .iter()
            .zip(&fresh)
            .map(|(c, f)| (c - f).abs() / f.abs().max(1e-12))
            .fold(0f64, f64::max);
        assert!(drift < 1e-9, "f64 drift {drift}");
    }
}
