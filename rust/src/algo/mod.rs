//! Native UOT solvers: POT baseline, COFFEE comparator, MAP-UOT.
//!
//! All three share one semantics (see `python/compile/kernels/ref.py`, the
//! cross-layer oracle): per iteration, a column rescaling from the carried
//! column sums followed by a row rescaling, with relaxation exponent `fi`.
//! They differ **only** in how many times the matrix streams through memory
//! — which is the paper's entire subject. Two numbers describe that, and
//! they are *not* the same thing:
//!
//! * **passes/iter** — how many times the loop nest walks the full matrix
//!   ([`SolverKind::passes_per_iter`]);
//! * **element accesses** — DRAM traffic per matrix element per iteration,
//!   counting a read-only pass as 1 access and a read+write pass as 2
//!   ([`SolverKind::accesses_per_element`]). This is the multiplier the
//!   sim layer's traffic models and the Roofline `Q` use.
//!
//! | solver  | passes/iter          | element accesses | layout    |
//! |---------|----------------------|------------------|-----------|
//! | POT     | 4 (2 ro + 2 rw)      | 6·M·N            | row-major |
//! | COFFEE  | 2 (both rw)          | 4·M·N            | row-major |
//! | MAP-UOT | 1 (fused rw)         | 2·M·N            | row-major |
//!
//! The public solving surface is the workspace-centric [`session`] API:
//! [`SolverSession`] for reusable, observer-instrumented, allocation-free
//! solves, and the [`Solver`] trait + [`Workspace`] for direct iteration
//! control (benches, golden tests). Threaded iterations run on the
//! persistent worker-pool engine ([`pool::ThreadPool`], the default
//! [`ParallelBackend::Pool`]) — workers spawned once, parked between
//! epoch-barrier dispatches — with the legacy scope-per-iteration path
//! kept as [`ParallelBackend::SpawnPerIter`] for benchmarking. The MAP-UOT
//! inner loops themselves run on a runtime-dispatched kernel backend
//! ([`kernels`]: scalar / unrolled / AVX2+FMA with non-temporal stores)
//! under a cache-aware tiling policy ([`KernelPolicy`]). The free
//! functions [`solve`] and [`iterate_once`] remain as deprecated
//! one-release shims.
//!
//! Sparse workloads (paper §6 future work) run the same fused iteration
//! over CSR storage ([`sparse`]): one pass over nnz instead of M·N, with
//! nnz-balanced row partitioning on both threaded engines — entered
//! through [`SolverSession::solve_sparse`] / [`SessionBuilder::build_sparse`],
//! the CLI `solve --sparse <threshold>`, or the `[solver] sparse` config
//! key.
//!
//! Geometric point-cloud workloads run **materialization-free**
//! ([`matfree`]): the plan is never stored — only the scaling vectors
//! `u, v` of `plan = diag(u)·A·diag(v)`, with kernel entries
//! `A_ij = exp(-c(x_i, y_j)/ε)` regenerated on the fly by a SIMD fast-exp
//! primitive ([`kernels`]). O(m + n) resident state instead of O(m·n) —
//! shapes the dense and CSR backends cannot even allocate. Entered through
//! [`SolverSession::solve_matfree`] / [`SessionBuilder::build_matfree`],
//! the CLI `solve --matfree <epsilon>`, or the `[solver] matfree` config
//! key (service `submit_geom`).
//!
//! One-dimensional geometry (`d == 1`, separable `|x − y|` cost) has an
//! **exact near-linear fast path** ([`oned`]): the Laplace kernel factors
//! over sorted supports, so `A·v` / `Aᵀ·u` cost O(m + n) per iteration —
//! no m·n work of any kind — and the converged solve emits a sparse
//! monotone [`TransportList`] alongside the scaling vectors. Entered
//! through [`SolverSession::solve_oned`] / [`SessionBuilder::build_oned`],
//! the CLI `solve --oned auto|on|off`, or the `[solver] oned` config key;
//! `coordinator::router::classify_geom` routes eligible service requests
//! there automatically.

pub mod balancing;
pub mod coffee;
pub mod convergence;
pub mod fp64;
pub mod kernels;
pub mod lazy;
pub mod mapuot;
pub mod matfree;
pub mod oned;
pub mod parallel;
pub mod pool;
pub mod pot;
pub mod problem;
pub mod scaling;
pub mod session;
pub mod sparse;
pub mod warmstart;

pub use convergence::StopRule;
pub use kernels::{kernel_for, Kernel, KernelKind, KernelPolicy, TileSpec};
pub use matfree::{CostKind, GeomProblem, MatfreeWorkspace};
pub use oned::{OnedWorkspace, Transport, TransportList};
pub use pool::{AccArena, AffinityHint, PaddedSlots, ParallelBackend, ThreadPool};
pub use problem::Problem;
pub use session::{
    solver_for, CheckEvent, CoffeeSolver, ConvergenceObserver, Deadline, MapUotSolver,
    ObserverAction, PotSolver, SessionBuilder, Solver, SolverSession, Workspace,
};
pub use sparse::{CsrMatrix, NnzPartition, SparseProblem, SparseWorkspace};
pub use warmstart::{Fingerprint, FingerprintKey, PathKind, WarmCache};

use crate::util::Matrix;

/// Which solver implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SolverKind {
    /// POT / NumPy 4-pass baseline.
    Pot,
    /// COFFEE phase-fused 2-pass comparator.
    Coffee,
    /// MAP-UOT fused single-pass (the paper's contribution).
    MapUot,
}

impl SolverKind {
    pub const ALL: [SolverKind; 3] = [SolverKind::Pot, SolverKind::Coffee, SolverKind::MapUot];

    pub fn name(self) -> &'static str {
        match self {
            SolverKind::Pot => "POT",
            SolverKind::Coffee => "COFFEE",
            SolverKind::MapUot => "MAP-UOT",
        }
    }

    /// Full-matrix passes per iteration — how many times the loop nest
    /// walks the plan (the module-header table's first column).
    pub fn passes_per_iter(self) -> usize {
        match self {
            SolverKind::Pot => 4,    // sum(0), col-rescale, sum(1), row-rescale
            SolverKind::Coffee => 2, // two fused read+write phases
            SolverKind::MapUot => 1, // single fused read+write pass
        }
    }

    /// DRAM element accesses per matrix element per iteration — the traffic
    /// multiplier the sims and the Roofline `Q` plug in. A read-only pass
    /// costs 1 access per element, a read+write pass costs 2: POT's 4
    /// passes (2 ro + 2 rw) ⇒ 6, COFFEE's 2 rw passes ⇒ 4, MAP-UOT's one
    /// fused rw pass ⇒ 2 (the streaming minimum).
    pub fn accesses_per_element(self) -> usize {
        match self {
            SolverKind::Pot => 6,
            SolverKind::Coffee => 4,
            SolverKind::MapUot => 2,
        }
    }

    /// Former name of [`SolverKind::accesses_per_element`]; it never counted
    /// passes, despite the name.
    #[deprecated(
        note = "use `accesses_per_element` (traffic multiplier) or `passes_per_iter` (loop-nest walks)"
    )]
    pub fn sweeps_per_iter(self) -> usize {
        self.accesses_per_element()
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pot" | "baseline" | "numpy" => Some(SolverKind::Pot),
            "coffee" => Some(SolverKind::Coffee),
            "mapuot" | "map-uot" | "map_uot" => Some(SolverKind::MapUot),
            _ => None,
        }
    }
}

/// Execution options for the deprecated [`solve`] shim (the session builder
/// carries the same knobs: [`SolverSession::builder`]).
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// Worker threads (1 = serial paths).
    pub threads: usize,
    /// Stopping criteria.
    pub stop: StopRule,
    /// Evaluate the stop rule every this many iterations (convergence
    /// checks cost one extra sweep, so they are amortized — same rationale
    /// as the AOT chunk size at L2/L3).
    pub check_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self { threads: 1, stop: StopRule::default(), check_every: 8 }
    }
}

/// Outcome of a solve.
#[derive(Debug, Clone, Copy)]
pub struct SolveReport {
    pub iters: usize,
    pub err: f32,
    /// Plan motion over the final check interval, tracked inside the fused
    /// sweep (sum of per-iteration max element changes — an upper bound on
    /// the old snapshot-based `plan_delta`; see [`session`]).
    pub delta: f32,
    pub converged: bool,
    pub seconds: f64,
}

/// Advance one iteration of `kind` (serial if `threads == 1`).
#[deprecated(note = "use `solver_for(kind).iterate(...)` with a reusable `Workspace`")]
pub fn iterate_once(
    kind: SolverKind,
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let mut ws = Workspace::new(plan.rows(), plan.cols(), threads);
    solver_for(kind).iterate(plan, colsum, rpd, cpd, fi, &mut ws);
}

/// Solve `problem` to the stop rule; returns the final plan and a report.
///
/// One-release shim over [`SolverSession`]: it builds (and throws away) a
/// session per call, so it pays the warmup allocations every time and
/// cannot observe or cancel.
#[deprecated(note = "use `SolverSession::builder(kind)...build(&problem)` — reusable \
                     workspaces, observers, typed errors, batch solve")]
pub fn solve(kind: SolverKind, problem: &Problem, opts: SolveOptions) -> (Matrix, SolveReport) {
    let mut session = SolverSession::builder(kind)
        .threads(opts.threads)
        .stop(opts.stop)
        .check_every(opts.check_every)
        .build(problem);
    let report = session
        .solve(problem)
        .expect("observer-free solve cannot be canceled");
    (session.into_plan(), report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(kind: SolverKind, p: &Problem, check_every: usize, stop: StopRule) -> (Matrix, SolveReport) {
        let mut session = SolverSession::builder(kind)
            .check_every(check_every)
            .stop(stop)
            .build(p);
        let report = session.solve(p).unwrap();
        (session.into_plan(), report)
    }

    #[test]
    fn all_kinds_agree_after_full_solve() {
        let p = Problem::random(24, 18, 0.8, 42);
        let stop = StopRule::default();
        let (a, ra) = run(SolverKind::MapUot, &p, 4, stop);
        let (b, rb) = run(SolverKind::Pot, &p, 4, stop);
        let (c, rc) = run(SolverKind::Coffee, &p, 4, stop);
        assert!(ra.converged && rb.converged && rc.converged);
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-2);
        assert!(a.max_rel_diff(&c, 1e-6) < 1e-2);
    }

    #[test]
    fn balanced_solve_hits_marginals() {
        // fi = 1 with equal total masses: classic Sinkhorn feasibility.
        let mut p = Problem::random(16, 16, 1.0, 7);
        let total_r: f32 = p.rpd.iter().sum();
        let total_c: f32 = p.cpd.iter().sum();
        for v in &mut p.cpd {
            *v *= total_r / total_c;
        }
        let stop = StopRule { tol: 1e-4, delta_tol: 0.0, max_iter: 5_000 };
        let (plan, report) = run(SolverKind::MapUot, &p, 8, stop);
        assert!(report.converged, "err={}", report.err);
        for (rs, &t) in plan.row_sums().iter().zip(&p.rpd) {
            assert!((rs - t).abs() < 1e-3);
        }
    }

    #[test]
    fn parallel_solve_matches_serial_solve() {
        let p = Problem::random(32, 20, 0.6, 9);
        let mut serial = SolverSession::builder(SolverKind::MapUot).build(&p);
        let mut par = SolverSession::builder(SolverKind::MapUot).threads(4).build(&p);
        serial.solve(&p).unwrap();
        par.solve(&p).unwrap();
        assert!(serial.plan().max_rel_diff(par.plan(), 1e-6) < 1e-3);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_session() {
        let p = Problem::random(20, 14, 0.7, 11);
        let opts = SolveOptions { check_every: 4, ..Default::default() };
        let (shim_plan, shim_report) = solve(SolverKind::MapUot, &p, opts);
        let (plan, report) = run(SolverKind::MapUot, &p, 4, opts.stop);
        assert_eq!(shim_plan.as_slice(), plan.as_slice());
        assert_eq!(shim_report.iters, report.iters);

        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        iterate_once(SolverKind::MapUot, &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, 1);
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        let mut ws = Workspace::new(20, 14, 1);
        solver_for(SolverKind::MapUot).iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SolverKind::parse("map-uot"), Some(SolverKind::MapUot));
        assert_eq!(SolverKind::parse("POT"), Some(SolverKind::Pot));
        assert_eq!(SolverKind::parse("coffee"), Some(SolverKind::Coffee));
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn traffic_accounting_is_consistent() {
        // Element accesses strictly order the solvers, POT 6 > COFFEE 4 >
        // MAP-UOT 2, and relate to passes as "read-only pass = 1 access,
        // read+write pass = 2": POT has 2 ro + 2 rw, the fused kinds are
        // all-rw, so accesses = 2·passes there.
        assert_eq!(SolverKind::Pot.accesses_per_element(), 6);
        assert_eq!(SolverKind::Coffee.accesses_per_element(), 4);
        assert_eq!(SolverKind::MapUot.accesses_per_element(), 2);
        assert_eq!(SolverKind::Pot.passes_per_iter(), 4);
        assert_eq!(SolverKind::Coffee.passes_per_iter(), 2);
        assert_eq!(SolverKind::MapUot.passes_per_iter(), 1);
        for kind in [SolverKind::Coffee, SolverKind::MapUot] {
            assert_eq!(kind.accesses_per_element(), 2 * kind.passes_per_iter());
        }
        assert_eq!(
            SolverKind::Pot.accesses_per_element(),
            SolverKind::Pot.passes_per_iter() + 2 // the 2 rw passes count twice
        );
    }
}
