//! Native UOT solvers: POT baseline, COFFEE comparator, MAP-UOT.
//!
//! All three share one semantics (see `python/compile/kernels/ref.py`, the
//! cross-layer oracle): per iteration, a column rescaling from the carried
//! column sums followed by a row rescaling, with relaxation exponent `fi`.
//! They differ **only** in how many times the matrix streams through memory
//! — which is the paper's entire subject:
//!
//! | solver  | sweeps/iter | element traffic | layout        |
//! |---------|-------------|-----------------|---------------|
//! | POT     | 4           | 6·M·N           | row-major     |
//! | COFFEE  | 2           | 4·M·N           | row-major     |
//! | MAP-UOT | 1 (fused)   | 2·M·N           | row-major     |

pub mod balancing;
pub mod coffee;
pub mod convergence;
pub mod fp64;
pub mod lazy;
pub mod mapuot;
pub mod sparse;
pub mod parallel;
pub mod pot;
pub mod problem;
pub mod scaling;

pub use convergence::StopRule;
pub use problem::Problem;

use crate::util::{Matrix, Timer};

/// Which solver implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SolverKind {
    /// POT / NumPy 4-sweep baseline.
    Pot,
    /// COFFEE phase-fused 2-sweep comparator.
    Coffee,
    /// MAP-UOT fused single-sweep (the paper's contribution).
    MapUot,
}

impl SolverKind {
    pub const ALL: [SolverKind; 3] = [SolverKind::Pot, SolverKind::Coffee, SolverKind::MapUot];

    pub fn name(self) -> &'static str {
        match self {
            SolverKind::Pot => "POT",
            SolverKind::Coffee => "COFFEE",
            SolverKind::MapUot => "MAP-UOT",
        }
    }

    /// Matrix-touching sweeps per iteration (drives traffic models & sims).
    pub fn sweeps_per_iter(self) -> usize {
        match self {
            SolverKind::Pot => 6,    // 4 passes, 2 of them read+write
            SolverKind::Coffee => 4, // 2 read+write passes
            SolverKind::MapUot => 2, // 1 read + 1 write
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pot" | "baseline" | "numpy" => Some(SolverKind::Pot),
            "coffee" => Some(SolverKind::Coffee),
            "mapuot" | "map-uot" | "map_uot" => Some(SolverKind::MapUot),
            _ => None,
        }
    }
}

/// Execution options for [`solve`].
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// Worker threads (1 = serial paths).
    pub threads: usize,
    /// Stopping criteria.
    pub stop: StopRule,
    /// Evaluate the stop rule every this many iterations (convergence
    /// checks cost one extra sweep, so they are amortized — same rationale
    /// as the AOT chunk size at L2/L3).
    pub check_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self { threads: 1, stop: StopRule::default(), check_every: 8 }
    }
}

/// Outcome of a [`solve`] run.
#[derive(Debug, Clone, Copy)]
pub struct SolveReport {
    pub iters: usize,
    pub err: f32,
    pub delta: f32,
    pub converged: bool,
    pub seconds: f64,
}

/// Advance one iteration of `kind` (serial if `threads == 1`).
pub fn iterate_once(
    kind: SolverKind,
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    match (kind, threads) {
        (SolverKind::Pot, 1) => pot::iterate(plan, colsum, rpd, cpd, fi),
        (SolverKind::Coffee, 1) => coffee::iterate(plan, colsum, rpd, cpd, fi),
        (SolverKind::MapUot, 1) => mapuot::iterate(plan, colsum, rpd, cpd, fi),
        (SolverKind::Pot, t) => parallel::pot_iterate(plan, colsum, rpd, cpd, fi, t),
        (SolverKind::Coffee, t) => parallel::coffee_iterate(plan, colsum, rpd, cpd, fi, t),
        (SolverKind::MapUot, t) => parallel::mapuot_iterate(plan, colsum, rpd, cpd, fi, t),
    }
}

/// Solve `problem` to the stop rule; returns the final plan and a report.
pub fn solve(kind: SolverKind, problem: &Problem, opts: SolveOptions) -> (Matrix, SolveReport) {
    let timer = Timer::start();
    let mut plan = problem.plan.clone();
    let mut colsum = plan.col_sums();
    let (rpd, cpd, fi) = (&problem.rpd, &problem.cpd, problem.fi);

    let mut iters = 0;
    let mut prev = plan.clone();
    let (mut err, mut delta);
    loop {
        let steps = opts.check_every.max(1);
        for _ in 0..steps {
            iterate_once(kind, &mut plan, &mut colsum, rpd, cpd, fi, opts.threads);
        }
        iters += steps;
        err = convergence::marginal_error(&plan, rpd, cpd);
        delta = convergence::plan_delta(&prev, &plan);
        if opts.stop.is_done(err, delta, iters) {
            break;
        }
        prev = plan.clone();
    }

    let converged = err <= opts.stop.tol || delta <= opts.stop.delta_tol;
    (
        plan,
        SolveReport { iters, err, delta, converged, seconds: timer.elapsed().as_secs_f64() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_agree_after_full_solve() {
        let p = Problem::random(24, 18, 0.8, 42);
        let opts = SolveOptions { check_every: 4, ..Default::default() };
        let (a, ra) = solve(SolverKind::MapUot, &p, opts);
        let (b, rb) = solve(SolverKind::Pot, &p, opts);
        let (c, rc) = solve(SolverKind::Coffee, &p, opts);
        assert!(ra.converged && rb.converged && rc.converged);
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-2);
        assert!(a.max_rel_diff(&c, 1e-6) < 1e-2);
    }

    #[test]
    fn balanced_solve_hits_marginals() {
        // fi = 1 with equal total masses: classic Sinkhorn feasibility.
        let mut p = Problem::random(16, 16, 1.0, 7);
        let total_r: f32 = p.rpd.iter().sum();
        let total_c: f32 = p.cpd.iter().sum();
        for v in &mut p.cpd {
            *v *= total_r / total_c;
        }
        let opts = SolveOptions {
            stop: StopRule { tol: 1e-4, delta_tol: 0.0, max_iter: 5_000 },
            ..Default::default()
        };
        let (plan, report) = solve(SolverKind::MapUot, &p, opts);
        assert!(report.converged, "err={}", report.err);
        for (rs, &t) in plan.row_sums().iter().zip(&p.rpd) {
            assert!((rs - t).abs() < 1e-3);
        }
    }

    #[test]
    fn parallel_solve_matches_serial_solve() {
        let p = Problem::random(32, 20, 0.6, 9);
        let serial = SolveOptions::default();
        let par = SolveOptions { threads: 4, ..Default::default() };
        let (a, _) = solve(SolverKind::MapUot, &p, serial);
        let (b, _) = solve(SolverKind::MapUot, &p, par);
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3);
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SolverKind::parse("map-uot"), Some(SolverKind::MapUot));
        assert_eq!(SolverKind::parse("POT"), Some(SolverKind::Pot));
        assert_eq!(SolverKind::parse("coffee"), Some(SolverKind::Coffee));
        assert_eq!(SolverKind::parse("nope"), None);
    }

    #[test]
    fn traffic_ordering() {
        assert!(SolverKind::Pot.sweeps_per_iter() > SolverKind::Coffee.sweeps_per_iter());
        assert!(SolverKind::Coffee.sweeps_per_iter() > SolverKind::MapUot.sweeps_per_iter());
    }
}
