//! Exact near-linear 1D MAP-UOT: sorted-support sweeps over the Laplace
//! kernel, O(m + n) per iteration, no plan matrix — ever.
//!
//! Every backend so far *iterates over pairs*: dense and CSR stream the
//! plan, matfree regenerates m·n kernel entries per sweep. But when the
//! supports are one-dimensional and the ground cost is the separable
//! `|x − y|` distance ([`CostKind::Euclidean`]), the Gibbs kernel is the
//! **Laplace kernel** `A_ij = exp(-|x_i − y_j|/ε)`, and the exponential of
//! a distance *factors across sorted supports*:
//!
//! ```text
//! (A·v)_i = Σ_{y_j ≤ x_i} v_j·e^{-(x_i−y_j)/ε}  +  Σ_{y_j > x_i} v_j·e^{-(y_j−x_i)/ε}
//!         =        L_i (prefix, decaying right) +        R_i (suffix, decaying left)
//! ```
//!
//! Both prefix sums obey a two-pointer merge recursion over the sorted
//! event sequence — between consecutive events at positions `p < q` the
//! accumulator just decays by `e^{-(q−p)/ε}` — so **one forward and one
//! backward sweep compute the exact m·n kernel product in O(m + n)**
//! (this is the classical semiseparable-matrix identity behind the exact
//! 1D transport line of work, arXiv:2311.17704, applied to the scaling
//! iteration; the TI analysis in arXiv:2201.00730 shows how much real
//! workload is in this class). The MAP-UOT iteration itself is unchanged
//! — the same column-factor / row-factor algebra as [`matfree`](crate::algo::matfree),
//! same fixed point, same unbalanced `fi` relaxation — only `A·v` and
//! `Aᵀ·u` stop costing m·n work. Per-solve total: O((m+n)·log(m+n)) for
//! the one support sort, O(m + n) per iteration after it. Resident state
//! is O(m + n): sorted positions, sort orders, two f64 apply buffers and
//! the carried marginals. The squared-Euclidean (Gaussian) kernel does
//! **not** factor this way — [`check_eligible`] rejects it with a typed
//! error and the router falls back to matfree.
//!
//! # Output: monotone transport list
//!
//! The converged iterate is `plan = diag(u)·A·diag(v)` — still never
//! materialized. For 1D output the solver instead extracts the **monotone
//! quantile coupling** of the converged transported marginals
//! ([`fused_monotone_coupling`]): a two-pointer walk over the sorted
//! supports pairing row mass with column mass in position order, ≤ m+n−1
//! entries (exact arithmetic), with the unbalanced creation/destruction
//! slack per side recorded on the [`TransportList`]. For convex 1D costs
//! the monotone coupling is the ε → 0 optimal rearrangement of those
//! marginals, which makes it the canonical sparse representative of the
//! entropic plan's transported mass.
//!
//! # Numerics
//!
//! The sweeps accumulate in f64 (the decay recursion is a long product of
//! factors in (0, 1]; f32 would lose the tail) and cast each result back
//! to f32 before the shared [`scaling::factor`](crate::algo::scaling::factor)
//! guard, so factor semantics (zero-sum ⇒ factor 0) are bit-compatible
//! with every other backend. Ties are counted exactly once: the forward
//! sweep takes a source event *before* a coincident target (the pair
//! contributes `e^0 = 1` to the prefix), the backward sweep takes sources
//! only *strictly after* the target. Duplicate and unsorted support
//! positions therefore need no pre-deduplication.
//!
//! The tracked per-iteration delta is **marginal-space motion** — the
//! L∞ change of the carried row/column sums — not the dense backends'
//! plan-element motion (tracking that would cost the very m·n the module
//! exists to avoid). Both are Cauchy-style stop signals; equivalence
//! tests pin against dense runs under fixed iteration budgets.
//!
//! # Allocation contract
//!
//! Construction and [`OnedWorkspace::ensure_shape`] growth may allocate;
//! [`OnedWorkspace::prepare`] (the in-place `sort_unstable_by` support
//! sort included), the sweeps and the coupling extraction must not —
//! same contract as every hot path, enforced by `tools/uotlint` and the
//! counting-allocator legs in `rust/tests/alloc_free.rs` (which also
//! prove the headline claim: an m = n = 1_000_000 solve performs no
//! allocation within orders of magnitude of O(m·n)).

use crate::algo::matfree::{CostKind, GeomProblem};
use crate::algo::scaling::factor;
use crate::error::{Error, Result};

/// One entry of the sparse monotone transport list: `mass` units moved
/// from row support point `from` to column support point `to` (original,
/// pre-sort indices).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transport {
    pub from: u32,
    pub to: u32,
    pub mass: f32,
}

/// Sparse monotone coupling of the converged transported marginals, plus
/// the unbalanced slack per side. `destroyed = Σrpd − transported` is the
/// row-target mass the relaxed plan chose not to move; `created = Σcpd −
/// transported` the column-side analogue. Either may be negative when the
/// stationary plan mass overshoots that side's target (the damped
/// unbalanced fixed point sits *between* the two totals — see
/// [`scaling::ti_mass_target`](crate::algo::scaling::ti_mass_target)).
#[derive(Debug, Clone, Default)]
pub struct TransportList {
    /// Monotone in sorted support order: successive entries never cross.
    pub entries: Vec<Transport>,
    pub destroyed: f32,
    pub created: f32,
}

impl TransportList {
    /// Reserve the worst-case m + n entry capacity so
    /// [`fused_monotone_coupling`] never reallocates.
    pub fn reserve_for(&mut self, m: usize, n: usize) {
        self.entries.clear();
        self.entries.reserve(m + n);
    }

    /// Total transported mass (f64 accumulation).
    pub fn transported(&self) -> f32 {
        self.entries.iter().map(|t| t.mass as f64).sum::<f64>() as f32
    }
}

/// Typed eligibility gate for the 1D fast path. The router and the
/// session both funnel through this so the rejection text is uniform.
pub fn check_eligible(p: &GeomProblem) -> Result<()> {
    if p.d != 1 {
        return Err(Error::InvalidProblem(format!(
            "the 1D fast path requires d == 1 supports (got d = {}) — route d > 1 \
             geometry through matfree, or project an effectively-1D cloud first \
             (coordinator::router::classify_geom)",
            p.d
        )));
    }
    if p.cost != CostKind::Euclidean {
        return Err(Error::InvalidProblem(format!(
            "the 1D fast path needs the separable |x - y| cost (cost = euclid): the \
             Laplace kernel factors into prefix/suffix decay recursions, the {} \
             (Gaussian) kernel does not — route it through matfree",
            p.cost.name()
        )));
    }
    if p.rows() > u32::MAX as usize || p.cols() > u32::MAX as usize {
        return Err(Error::InvalidProblem(format!(
            "1D supports are indexed u32 in the transport list: {} x {} exceeds u32",
            p.rows(),
            p.cols()
        )));
    }
    Ok(())
}

/// Exact Laplace-kernel apply over sorted supports: for every target
/// event `k`, `out[tord[k]] = Σ_s sw[sord[s]] · exp(-|tpos[k] − spos[s]|/ε)`
/// — the full m·n kernel product in two O(m + n) sweeps. `tpos`/`spos`
/// are the sorted positions, `tord`/`sord` the original indices in sorted
/// order, `sw` the source weights in *original* order; `out` is written
/// in original order. Allocation-free; f64 accumulation throughout.
pub fn fused_kernel_apply(
    tpos: &[f64],
    tord: &[u32],
    spos: &[f64],
    sord: &[u32],
    sw: &[f32],
    inv_eps: f64,
    out: &mut [f64],
) {
    debug_assert_eq!(tpos.len(), tord.len());
    debug_assert_eq!(spos.len(), sord.len());
    debug_assert_eq!(out.len(), tord.len());
    let (nt, ns) = (tpos.len(), spos.len());

    // Forward sweep: prefix sums L, decaying rightward. A source at the
    // same position as a target is taken first (contributes e^0 = 1).
    let mut acc = 0f64;
    let mut prev = 0f64;
    let mut started = false;
    let (mut it, mut is) = (0usize, 0usize);
    while it < nt {
        let take_src = is < ns && spos[is] <= tpos[it];
        let pos = if take_src { spos[is] } else { tpos[it] };
        if started {
            acc *= (-(pos - prev) * inv_eps).exp();
        }
        started = true;
        prev = pos;
        if take_src {
            acc += sw[sord[is] as usize] as f64;
            is += 1;
        } else {
            out[tord[it] as usize] = acc;
            it += 1;
        }
    }

    // Backward sweep: suffix sums R, decaying leftward. A coincident
    // source is NOT taken (strict `>`), so ties are counted exactly once.
    acc = 0.0;
    started = false;
    let (mut it, mut is) = (nt, ns);
    while it > 0 {
        let take_src = is > 0 && spos[is - 1] > tpos[it - 1];
        let pos = if take_src { spos[is - 1] } else { tpos[it - 1] };
        if started {
            acc *= (-(prev - pos) * inv_eps).exp();
        }
        started = true;
        prev = pos;
        if take_src {
            acc += sw[sord[is - 1] as usize] as f64;
            is -= 1;
        } else {
            out[tord[it - 1] as usize] += acc;
            it -= 1;
        }
    }
}

/// Extract the monotone quantile coupling of the transported marginals:
/// walk both sorted supports in position order, pairing `min(remaining
/// row mass, remaining column mass)` at each step. Pushes into
/// `out.entries` within the capacity [`TransportList::reserve_for`]
/// provisioned (≤ m + n entries — every push exhausts at least one side,
/// and IEEE `a − a = 0` makes exhaustion exact), so the walk is
/// allocation-free. Fills the unbalanced `destroyed`/`created` slacks
/// against the problem targets `rpd`/`cpd`.
#[allow(clippy::too_many_arguments)]
pub fn fused_monotone_coupling(
    sx_ord: &[u32],
    sy_ord: &[u32],
    rowsum: &[f32],
    colsum: &[f32],
    rpd: &[f32],
    cpd: &[f32],
    out: &mut TransportList,
) {
    out.entries.clear();
    let (m, n) = (sx_ord.len(), sy_ord.len());
    let mut transported = 0f64;
    let (mut ix, mut iy) = (0usize, 0usize);
    let mut ra = 0f64; // remaining mass of the current (sorted) row
    let mut ca = 0f64; // remaining mass of the current (sorted) column
    while ix < m && iy < n {
        if ra == 0.0 {
            ra = rowsum[sx_ord[ix] as usize] as f64;
            if ra <= 0.0 {
                ra = 0.0;
                ix += 1;
                continue;
            }
        }
        if ca == 0.0 {
            ca = colsum[sy_ord[iy] as usize] as f64;
            if ca <= 0.0 {
                ca = 0.0;
                iy += 1;
                continue;
            }
        }
        let mv = ra.min(ca);
        out.entries.push(Transport {
            from: sx_ord[ix],
            to: sy_ord[iy],
            mass: mv as f32,
        });
        transported += mv;
        ra -= mv;
        ca -= mv;
        if ra == 0.0 {
            ix += 1;
        }
        if ca == 0.0 {
            iy += 1;
        }
    }
    let rpd_total: f64 = rpd.iter().map(|&t| t as f64).sum();
    let cpd_total: f64 = cpd.iter().map(|&t| t as f64).sum();
    out.destroyed = (rpd_total - transported) as f32;
    out.created = (cpd_total - transported) as f32;
}

// ---------------------------------------------------------------------------
// OnedWorkspace
// ---------------------------------------------------------------------------

/// Scratch for exact 1D solves — the near-linear twin of
/// [`MatfreeWorkspace`](crate::algo::matfree::MatfreeWorkspace). Holds the
/// sorted supports, their sort orders, the two f64 apply buffers and the
/// previous-marginal snapshots for delta tracking. Everything is O(m + n);
/// there is no engine — the sweeps are sequential recursions (each event
/// depends on the previous accumulator), and at O(m + n) work per
/// iteration they sit far below the shapes where fan-out pays.
#[derive(Debug)]
pub struct OnedWorkspace {
    shape: (usize, usize),
    /// Row support positions, sorted ascending (f64 for the decay math).
    sxp: Vec<f64>,
    /// Original row index of each sorted row event.
    sx_ord: Vec<u32>,
    /// Column support positions, sorted ascending.
    syp: Vec<f64>,
    /// Original column index of each sorted column event.
    sy_ord: Vec<u32>,
    /// `(A·v)_i` apply buffer, original row order.
    av: Vec<f64>,
    /// `(Aᵀ·u)_j` apply buffer, original column order.
    bu: Vec<f64>,
    /// Previous carried marginals for the tracked delta.
    prev_rowsum: Vec<f32>,
    prev_colsum: Vec<f32>,
}

impl OnedWorkspace {
    /// Workspace for `m × n` 1D problems.
    pub fn new(m: usize, n: usize) -> Self {
        Self {
            shape: (m, n),
            sxp: vec![0f64; m],
            sx_ord: vec![0u32; m],
            syp: vec![0f64; n],
            sy_ord: vec![0u32; n],
            av: vec![0f64; m],
            bu: vec![0f64; n],
            prev_rowsum: vec![0f32; m],
            prev_colsum: vec![0f32; n],
        }
    }

    /// Current `(rows, cols)` shape.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }

    /// Resize for a new shape. No-op (and allocation-free) when unchanged;
    /// growing past any previously seen size reallocates.
    pub fn ensure_shape(&mut self, m: usize, n: usize) {
        if self.shape == (m, n) {
            return;
        }
        self.shape = (m, n);
        self.sxp.resize(m, 0.0);
        self.sx_ord.resize(m, 0);
        self.syp.resize(n, 0.0);
        self.sy_ord.resize(n, 0);
        self.av.resize(m, 0.0);
        self.bu.resize(n, 0.0);
        self.prev_rowsum.resize(m, 0.0);
        self.prev_colsum.resize(n, 0.0);
    }

    /// Validate eligibility, size scratch and sort both supports — the
    /// per-solve setup, O((m+n)·log(m+n)) via the in-place (non-allocating)
    /// `sort_unstable_by`. Unsorted and duplicate positions are fine; the
    /// sort is where the module's worst-case log factor lives.
    pub fn prepare(&mut self, p: &GeomProblem) -> Result<()> {
        check_eligible(p)?;
        let (m, n) = (p.rows(), p.cols());
        self.ensure_shape(m, n);
        for (k, o) in self.sx_ord.iter_mut().enumerate() {
            *o = k as u32;
        }
        let xs = &p.x;
        self.sx_ord
            .sort_unstable_by(|&a, &b| xs[a as usize].total_cmp(&xs[b as usize]));
        for (sp, &o) in self.sxp.iter_mut().zip(self.sx_ord.iter()) {
            *sp = xs[o as usize] as f64;
        }
        for (k, o) in self.sy_ord.iter_mut().enumerate() {
            *o = k as u32;
        }
        let ys = &p.y;
        self.sy_ord
            .sort_unstable_by(|&a, &b| ys[a as usize].total_cmp(&ys[b as usize]));
        for (sp, &o) in self.syp.iter_mut().zip(self.sy_ord.iter()) {
            *sp = ys[o as usize] as f64;
        }
        Ok(())
    }

    /// Sorted row support order (valid after [`OnedWorkspace::prepare`]).
    pub fn row_order(&self) -> &[u32] {
        &self.sx_ord
    }

    /// Sorted column support order (valid after [`OnedWorkspace::prepare`]).
    pub fn col_order(&self) -> &[u32] {
        &self.sy_ord
    }

    /// Seed the carried column sums of a scaling state: `out[j] = v_j ·
    /// (Aᵀ·u)_j`, exact, one backward+forward sweep pair — the 1D analogue
    /// of `MatfreeWorkspace::seed_col_sums`, run once per solve (cold
    /// all-ones or warm-started scalings). Allocation-free.
    pub fn seed_col_sums(&mut self, p: &GeomProblem, u: &[f32], v: &[f32], out: &mut [f32]) {
        debug_assert_eq!(self.shape, (p.rows(), p.cols()));
        let inv_eps = 1.0 / p.epsilon as f64;
        fused_kernel_apply(&self.syp, &self.sy_ord, &self.sxp, &self.sx_ord, u, inv_eps, &mut self.bu);
        for ((o, &vj), &b) in out.iter_mut().zip(v.iter()).zip(self.bu.iter()) {
            *o = (vj as f64 * b) as f32;
        }
    }

    /// One MAP-UOT scaling iteration with exact O(m + n) kernel products —
    /// the same column-factor / row-factor / carried-colsum algebra as the
    /// matfree sweep (same fixed point), with `A·v` and `Aᵀ·u_new` computed
    /// by the sorted-support recursions instead of m·n generation.
    /// `u`/`v`/`colsum`/`rowsum` are the carried solver state.
    pub fn iterate(
        &mut self,
        p: &GeomProblem,
        u: &mut [f32],
        v: &mut [f32],
        colsum: &mut [f32],
        rowsum: &mut [f32],
    ) {
        debug_assert_eq!(self.shape, (p.rows(), p.cols()));
        let inv_eps = 1.0 / p.epsilon as f64;
        // Column stage: fold the column factors into v.
        for ((vj, &t), &s) in v.iter_mut().zip(p.cpd.iter()).zip(colsum.iter()) {
            *vj *= factor(t, s, p.fi);
        }
        // Exact (A·v)_i at every row support, then the row stage.
        fused_kernel_apply(&self.sxp, &self.sx_ord, &self.syp, &self.sy_ord, v, inv_eps, &mut self.av);
        for (((ui, &t), &a), rs) in u
            .iter_mut()
            .zip(p.rpd.iter())
            .zip(self.av.iter())
            .zip(rowsum.iter_mut())
        {
            let s = (*ui as f64 * a) as f32;
            let fr = factor(t, s, p.fi);
            *ui *= fr;
            *rs = fr * s;
        }
        // Carried colsum of the new iterate: colsum[j] = v_j · (Aᵀ·u_new)_j.
        fused_kernel_apply(&self.syp, &self.sy_ord, &self.sxp, &self.sx_ord, u, inv_eps, &mut self.bu);
        for ((cs, &vj), &b) in colsum.iter_mut().zip(v.iter()).zip(self.bu.iter()) {
            *cs = (vj as f64 * b) as f32;
        }
    }

    /// [`OnedWorkspace::iterate`] with delta tracking; returns the
    /// iteration's L∞ **marginal** motion (see the module docs — plan-space
    /// motion would cost the m·n this backend exists to avoid).
    pub fn iterate_tracked(
        &mut self,
        p: &GeomProblem,
        u: &mut [f32],
        v: &mut [f32],
        colsum: &mut [f32],
        rowsum: &mut [f32],
    ) -> f32 {
        self.prev_rowsum.copy_from_slice(rowsum);
        self.prev_colsum.copy_from_slice(colsum);
        self.iterate(p, u, v, colsum, rowsum);
        let mut delta = 0f32;
        for (&new, &old) in rowsum.iter().zip(self.prev_rowsum.iter()) {
            delta = delta.max((new - old).abs());
        }
        for (&new, &old) in colsum.iter().zip(self.prev_colsum.iter()) {
            delta = delta.max((new - old).abs());
        }
        delta
    }

    /// Bytes of resident workspace scratch — the figure the 1D ablation
    /// reports against the dense plan's `4·m·n`.
    pub fn resident_bytes(&self) -> usize {
        let (m, n) = self.shape;
        // sxp/av/prev_rowsum + sx_ord per row; syp/bu/prev_colsum + sy_ord
        // per column.
        m * (8 + 8 + 4 + 4) + n * (8 + 8 + 4 + 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::matfree::MatfreeWorkspace;
    use crate::util::XorShift;

    fn oned_problem(m: usize, n: usize, eps: f32, fi: f32, seed: u64) -> GeomProblem {
        GeomProblem::random(m, n, 1, CostKind::Euclidean, eps, fi, seed)
    }

    #[test]
    fn eligibility_is_typed_and_specific() {
        let ok = oned_problem(6, 5, 0.5, 0.7, 1);
        assert!(check_eligible(&ok).is_ok());
        let d2 = GeomProblem::random(6, 5, 2, CostKind::Euclidean, 0.5, 0.7, 1);
        match check_eligible(&d2) {
            Err(Error::InvalidProblem(msg)) => assert!(msg.contains("d == 1"), "{msg}"),
            other => panic!("expected InvalidProblem, got {other:?}"),
        }
        let gauss = GeomProblem::random(6, 5, 1, CostKind::SqEuclidean, 0.5, 0.7, 1);
        match check_eligible(&gauss) {
            Err(Error::InvalidProblem(msg)) => assert!(msg.contains("euclid"), "{msg}"),
            other => panic!("expected InvalidProblem, got {other:?}"),
        }
    }

    /// The two-sweep apply equals the brute-force m·n kernel product, on
    /// unsorted supports with deliberate duplicates.
    #[test]
    fn fused_kernel_apply_matches_brute_force() {
        let mut rng = XorShift::new(7);
        for (m, n) in [(1usize, 1usize), (1, 9), (9, 1), (13, 17), (40, 33)] {
            let mut p = oned_problem(m, n, 0.37, 0.7, (m * 31 + n) as u64);
            // Seed duplicates: copy a few positions across and within clouds.
            if m > 2 && n > 2 {
                p.x[1] = p.x[0];
                p.y[2] = p.x[0];
                p.y[1] = p.y[0];
            }
            let w: Vec<f32> = (0..n).map(|_| 0.25 + rng.next_f32()).collect();
            let mut ws = OnedWorkspace::new(m, n);
            ws.prepare(&p).unwrap();
            let mut out = vec![0f64; m];
            fused_kernel_apply(&ws.sxp, &ws.sx_ord, &ws.syp, &ws.sy_ord, &w, 1.0 / p.epsilon as f64, &mut out);
            for i in 0..m {
                let want: f64 = (0..n)
                    .map(|j| {
                        w[j] as f64
                            * (-((p.x[i] as f64 - p.y[j] as f64).abs()) / p.epsilon as f64).exp()
                    })
                    .sum();
                assert!(
                    (out[i] - want).abs() <= 1e-12 * want.abs().max(1e-9),
                    "{m}x{n} row {i}: {} vs {want}",
                    out[i]
                );
            }
        }
    }

    /// The exact sweep runs the same iteration as matfree: identical
    /// carried state to tolerance, iteration by iteration.
    #[test]
    fn iterations_track_the_matfree_sweep() {
        for (m, n) in [(9usize, 7usize), (16, 12), (5, 40), (1, 6), (6, 1)] {
            let p = oned_problem(m, n, 0.3, 0.7, (m + 3 * n) as u64);
            let mut mf = MatfreeWorkspace::new(m, n, 1);
            mf.prepare(m, n);
            let mut od = OnedWorkspace::new(m, n);
            od.prepare(&p).unwrap();
            let (mut ua, mut va) = (vec![1f32; m], vec![1f32; n]);
            let (mut ub, mut vb) = (vec![1f32; m], vec![1f32; n]);
            let (mut ca, mut ra) = (vec![0f32; n], vec![0f32; m]);
            let (mut cb, mut rb) = (vec![0f32; n], vec![0f32; m]);
            mf.seed_col_sums(&p, &ua, &va, &mut ca);
            od.seed_col_sums(&p, &ub, &vb, &mut cb);
            for (j, (a, b)) in ca.iter().zip(&cb).enumerate() {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1e-4), "seed col {j}: {a} vs {b}");
            }
            for it in 0..8 {
                mf.iterate(&p, &mut ua, &mut va, &mut ca, &mut ra);
                od.iterate(&p, &mut ub, &mut vb, &mut cb, &mut rb);
                for (j, (a, b)) in ca.iter().zip(&cb).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-3 * b.abs().max(1e-3),
                        "{m}x{n} it={it} col {j}: {a} vs {b}"
                    );
                }
                for (i, (a, b)) in ra.iter().zip(&rb).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-3 * b.abs().max(1e-3),
                        "{m}x{n} it={it} row {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn tracked_iteration_is_bit_identical_to_untracked() {
        let p = oned_problem(14, 11, 0.5, 0.8, 9);
        let (m, n) = (14, 11);
        let mut ws_a = OnedWorkspace::new(m, n);
        let mut ws_b = OnedWorkspace::new(m, n);
        ws_a.prepare(&p).unwrap();
        ws_b.prepare(&p).unwrap();
        let (mut ua, mut va) = (vec![1f32; m], vec![1f32; n]);
        let (mut ub, mut vb) = (vec![1f32; m], vec![1f32; n]);
        let (mut ca, mut ra) = (vec![0f32; n], vec![0f32; m]);
        let (mut cb, mut rb) = (vec![0f32; n], vec![0f32; m]);
        ws_a.seed_col_sums(&p, &ua, &va, &mut ca);
        ws_b.seed_col_sums(&p, &ub, &vb, &mut cb);
        let mut last_delta = f32::INFINITY;
        for _ in 0..5 {
            ws_a.iterate(&p, &mut ua, &mut va, &mut ca, &mut ra);
            last_delta = ws_b.iterate_tracked(&p, &mut ub, &mut vb, &mut cb, &mut rb);
        }
        assert_eq!(ua, ub);
        assert_eq!(va, vb);
        assert_eq!(ca, cb);
        assert_eq!(ra, rb);
        assert!(last_delta.is_finite() && last_delta >= 0.0);
    }

    /// Hand-walked quantile coupling (same fixture as
    /// `data/golden_oned_quantile.txt`): balanced masses, m+n−1 entries,
    /// monotone, conservative.
    #[test]
    fn monotone_coupling_hand_example() {
        let rowsum = [0.5f32, 1.0, 0.25, 1.25];
        let colsum = [1.2f32, 0.8, 1.0];
        let sx_ord = [0u32, 1, 2, 3];
        let sy_ord = [0u32, 1, 2];
        let mut out = TransportList::default();
        out.reserve_for(4, 3);
        fused_monotone_coupling(&sx_ord, &sy_ord, &rowsum, &colsum, &rowsum, &colsum, &mut out);
        let want = [
            (0u32, 0u32, 0.5f32),
            (1, 0, 0.7),
            (1, 1, 0.3),
            (2, 1, 0.25),
            (3, 1, 0.25),
            (3, 2, 1.0),
        ];
        assert_eq!(out.entries.len(), want.len());
        for (got, &(f, t, mass)) in out.entries.iter().zip(&want) {
            assert_eq!((got.from, got.to), (f, t));
            assert!((got.mass - mass).abs() <= 1e-6, "{got:?} vs mass {mass}");
        }
        assert!((out.transported() - 3.0).abs() <= 1e-6);
        assert!(out.destroyed.abs() <= 1e-6 && out.created.abs() <= 1e-6);
    }

    /// Coupling properties on random marginals: monotone in sorted rank,
    /// per-row/per-column mass conservation, ≤ m+n entries, slack totals.
    #[test]
    fn monotone_coupling_properties() {
        let mut rng = XorShift::new(23);
        for (m, n) in [(1usize, 1usize), (7, 5), (12, 31), (30, 4)] {
            let rowsum: Vec<f32> = (0..m).map(|_| 0.1 + rng.next_f32()).collect();
            // Column masses rescaled to a different total: the walk stops
            // at the smaller side and the slacks record the difference.
            let colsum: Vec<f32> = (0..n).map(|_| 0.1 + rng.next_f32()).collect();
            let sx_ord: Vec<u32> = (0..m as u32).collect();
            let sy_ord: Vec<u32> = (0..n as u32).collect();
            let mut out = TransportList::default();
            out.reserve_for(m, n);
            fused_monotone_coupling(&sx_ord, &sy_ord, &rowsum, &colsum, &rowsum, &colsum, &mut out);
            assert!(out.entries.len() <= m + n);
            let mut prev = (0u32, 0u32);
            let mut row_mass = vec![0f64; m];
            let mut col_mass = vec![0f64; n];
            for t in &out.entries {
                assert!(t.from >= prev.0 && t.to >= prev.1, "crossing at {t:?}");
                prev = (t.from, t.to);
                assert!(t.mass > 0.0);
                row_mass[t.from as usize] += t.mass as f64;
                col_mass[t.to as usize] += t.mass as f64;
            }
            let rt: f64 = rowsum.iter().map(|&v| v as f64).sum();
            let ct: f64 = colsum.iter().map(|&v| v as f64).sum();
            let transported = out.transported() as f64;
            assert!((transported - rt.min(ct)).abs() <= 1e-5 * rt.min(ct));
            // The exhausted side's per-point masses are met exactly.
            if rt <= ct {
                for (i, &got) in row_mass.iter().enumerate() {
                    assert!((got - rowsum[i] as f64).abs() <= 1e-6, "row {i}");
                }
            } else {
                for (j, &got) in col_mass.iter().enumerate() {
                    assert!((got - colsum[j] as f64).abs() <= 1e-6, "col {j}");
                }
            }
            assert!((out.destroyed as f64 - (rt - transported)).abs() <= 1e-5);
            assert!((out.created as f64 - (ct - transported)).abs() <= 1e-5);
        }
    }

    #[test]
    fn resident_state_is_o_m_plus_n() {
        let ws = OnedWorkspace::new(1 << 20, 1 << 20);
        // 24 bytes per support point per side; nowhere near 4·m·n.
        assert_eq!(ws.resident_bytes(), 2 * (1 << 20) * 24);
    }
}
