//! Lazy-rescaling MAP-UOT: a §Perf experiment *beyond* the paper —
//! measured SLOWER than the eager fused loop and kept as a documented
//! negative result (EXPERIMENTS.md §Perf step 2). Opt-in only; nothing in
//! the default solve path uses it.
//!
//! Idea: the iterate is `diag(f_row) · A · diag(f_col)`, so instead of
//! applying `f_row` immediately (Algorithm 1's second store pass), carry
//! it and fold it into the *next* iteration's column pass:
//!
//! ```text
//! pass A (per row): a' = A[i][j] · f_row_prev[i] · f_col[j]   (1 store)
//!                   rowsum += a'            → f_row[i] for this iter
//! pass B (cached) : colsum[j] += f_row[i] · a'  (re-read, NO store)
//! ```
//!
//! `f_row[i]` is only known after the row's pass A completes, so the
//! column sums of the true iterate must come from a cached re-read
//! (pass B) — but that re-read no longer *writes*, halving store traffic
//! versus Algorithm 1 (1 write/cell/iter instead of 2, on write-allocate
//! caches a 2× store saving).
//!
//! Why it loses in practice on this host: pass A carries an extra multiply
//! per element and pass B's read-after-write of the just-stored row stalls
//! on store-to-load forwarding, which costs more than the saved writeback
//! bandwidth. See the `perf_kernel` bench for the numbers.
//!
//! The stored plan lags one row-scaling behind the true iterate;
//! [`LazySolver::flush`] applies the pending factors (one extra pass),
//! which the driver does before any convergence check or when returning
//! the plan.

use crate::algo::scaling::{factor, factors_into};
use crate::util::Matrix;

/// Carried state of the lazy solver.
pub struct LazySolver {
    plan: Matrix,
    /// Pending row factors not yet applied to `plan` (all 1.0 initially).
    pending_frow: Vec<f32>,
    /// Column sums of the *true* iterate (post both rescalings).
    colsum: Vec<f32>,
    /// Scratch: this iteration's column factors (reused every iterate).
    fcol: Vec<f32>,
    rpd: Vec<f32>,
    cpd: Vec<f32>,
    fi: f32,
    iters: usize,
}

impl LazySolver {
    pub fn new(plan: Matrix, rpd: Vec<f32>, cpd: Vec<f32>, fi: f32) -> Self {
        let colsum = plan.col_sums();
        let m = plan.rows();
        let fcol = vec![0f32; plan.cols()];
        Self { plan, pending_frow: vec![1.0; m], colsum, fcol, rpd, cpd, fi, iters: 0 }
    }

    pub fn iters(&self) -> usize {
        self.iters
    }

    /// One iteration: single fused pass with the pending row factors
    /// folded in, plus a cached colsum re-read (no store).
    pub fn iterate(&mut self) {
        let (m, n) = (self.plan.rows(), self.plan.cols());
        factors_into(&mut self.fcol, &self.cpd, &self.colsum, self.fi);
        self.colsum.fill(0.0);

        for i in 0..m {
            let fp = self.pending_frow[i];
            let row = self.plan.row_mut(i);
            // Pass A: fold pending row factor + new column factor, one
            // write per element, accumulate the row sum.
            const W: usize = 16;
            let mut acc = [0f32; W];
            let chunks = n / W;
            let (rh, rt) = row.split_at_mut(chunks * W);
            let (fh, ft) = self.fcol.split_at(chunks * W);
            for (rw, fw) in rh.chunks_exact_mut(W).zip(fh.chunks_exact(W)) {
                for k in 0..W {
                    rw[k] *= fp * fw[k];
                    acc[k] += rw[k];
                }
            }
            let mut s = acc.iter().sum::<f32>();
            for (r, &f) in rt.iter_mut().zip(ft) {
                *r *= fp * f;
                s += *r;
            }
            // New row factor — NOT applied to the row (deferred), but the
            // carried colsum must reflect it, so the cached re-read
            // accumulates `fr · row` without storing.
            let fr = factor(self.rpd[i], s, self.fi);
            self.pending_frow[i] = fr;
            for (v, cs) in row.iter().zip(self.colsum.iter_mut()) {
                *cs += fr * *v;
            }
        }
        self.iters += 1;
    }

    /// Apply pending row factors; afterwards `plan()` is the true iterate.
    pub fn flush(&mut self) {
        for i in 0..self.plan.rows() {
            let fr = self.pending_frow[i];
            if fr != 1.0 {
                for v in self.plan.row_mut(i) {
                    *v *= fr;
                }
            }
            self.pending_frow[i] = 1.0;
        }
    }

    /// The (possibly lagged) plan; call [`flush`] first for the true one.
    pub fn plan(&self) -> &Matrix {
        &self.plan
    }

    /// Finish: flush and return the plan.
    pub fn into_plan(mut self) -> Matrix {
        self.flush();
        self.plan
    }

    pub fn colsum(&self) -> &[f32] {
        &self.colsum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{mapuot, problem::Problem};

    #[test]
    fn lazy_matches_eager_exactly_enough() {
        for seed in [1u64, 7, 13] {
            let p = Problem::random(19, 23, 0.7, seed);
            let mut lazy = LazySolver::new(p.plan.clone(), p.rpd.clone(), p.cpd.clone(), p.fi);
            let mut eager = p.plan.clone();
            let mut cs = eager.col_sums();
            for _ in 0..7 {
                lazy.iterate();
                mapuot::iterate(&mut eager, &mut cs, &p.rpd, &p.cpd, p.fi);
            }
            // Carried colsums agree even before flush.
            for (a, b) in lazy.colsum().iter().zip(&cs) {
                assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{a} vs {b}");
            }
            let plan = lazy.into_plan();
            assert!(plan.max_rel_diff(&eager, 1e-6) < 1e-3, "seed={seed}");
        }
    }

    #[test]
    fn flush_is_idempotent() {
        let p = Problem::random(8, 8, 0.5, 3);
        let mut lazy = LazySolver::new(p.plan.clone(), p.rpd.clone(), p.cpd.clone(), p.fi);
        lazy.iterate();
        lazy.flush();
        let once = lazy.plan().clone();
        lazy.flush();
        assert_eq!(lazy.plan().max_abs_diff(&once), 0.0);
    }

    #[test]
    fn iterating_after_flush_still_correct() {
        let p = Problem::random(11, 9, 0.8, 5);
        let mut lazy = LazySolver::new(p.plan.clone(), p.rpd.clone(), p.cpd.clone(), p.fi);
        lazy.iterate();
        lazy.flush(); // mid-solve convergence check would do this
        lazy.iterate();
        let plan = lazy.into_plan();

        let mut eager = p.plan.clone();
        let mut cs = eager.col_sums();
        for _ in 0..2 {
            mapuot::iterate(&mut eager, &mut cs, &p.rpd, &p.cpd, p.fi);
        }
        assert!(plan.max_rel_diff(&eager, 1e-6) < 1e-3);
    }
}
