//! Kernel backends: runtime-dispatched SIMD implementations of the two
//! fused row primitives, plus the cache-aware tiling/streaming policy.
//!
//! The paper's whole argument is that UOT iteration is memory-bound, so the
//! inner loops must run as close to the hardware as the hardware allows.
//! This module turns the two fused row primitives of `algo::mapuot` —
//! `scale_by_vec_and_sum` (Computations I+II) and
//! `scale_by_scalar_and_accumulate{,_tracked}` (Computations III+IV) — into
//! a [`Kernel`] trait with three implementations:
//!
//! * [`KernelKind::Scalar`] — plain element loops; the portable reference
//!   every other backend is property-tested against
//!   (`rust/tests/prop_kernels.rs`).
//! * [`KernelKind::Unrolled`] — the 16-lane unrolled loops (LLVM
//!   auto-vectorizes them); today's default numerics, bit-identical to the
//!   free functions in `algo::mapuot`.
//! * [`KernelKind::Avx2`] — hand-written `std::arch` AVX2+FMA intrinsics,
//!   with optional **non-temporal stores** in Computations III/IV: once the
//!   plan exceeds the last-level cache, every iteration streams it from
//!   DRAM anyway, so the plan write pays a read-for-ownership (RFO) line
//!   fill it never uses — ~3 matrix transfers per iteration instead of the
//!   Roofline-minimum 2. `_mm256_stream_ps` bypasses the RFO; below the LLC
//!   threshold regular stores keep the matrix cache-resident across
//!   iterations, which is strictly better, so streaming is gated on
//!   [`KernelPolicy::stream_for`] (threshold = detected LLC size,
//!   `util::cputopo`).
//!
//! Selection happens **once per session build** ([`KernelPolicy::for_shape`]):
//! explicit CLI/config choice wins, then the `MAP_UOT_KERNEL` /
//! `MAP_UOT_TILE` environment overrides, then runtime CPUID detection
//! (`is_x86_feature_detected!`). Requesting `avx2` on hardware without it
//! falls back to `unrolled` — no `target-cpu` compile flag is ever required
//! for correctness, only for letting LLVM use wider codegen in the
//! portable paths.
//!
//! **Tiling.** [`KernelPolicy`] also owns the column-tiling parameters the
//! tiled fused sweep (`mapuot::fused_rows_policy`) uses at large `n`:
//! column panels of [`KernelPolicy::tile_cols`] columns keep `Factor_col` +
//! `inv_fcol` + `NextSum_col` + a row panel L1-resident, and row chunks of
//! [`KernelPolicy::row_chunk`] rows keep the chunk L2-resident between the
//! two phases, with `Sum_row` carried across panels in workspace scratch.
//! `auto` sizes both from the detected topology; `tune` additionally runs a
//! one-shot measurement ([`autotune_tile_cols`]) at workspace build.

use crate::util::{cputopo, simd};

// ---------------------------------------------------------------------------
// Kinds and parsing
// ---------------------------------------------------------------------------

/// Which kernel backend to run (CLI `--kernel`, config `[solver] kernel`,
/// env `MAP_UOT_KERNEL`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Runtime CPUID dispatch: AVX2+FMA when detected, else unrolled.
    Auto,
    /// Plain element loops — the portable reference.
    Scalar,
    /// 16-lane unrolled loops (auto-vectorized by LLVM).
    Unrolled,
    /// Hand-written AVX2+FMA intrinsics (falls back to unrolled when the
    /// host lacks the features).
    Avx2,
}

impl KernelKind {
    /// Parse from a CLI/config/env string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" | "detect" => Some(KernelKind::Auto),
            "scalar" | "ref" => Some(KernelKind::Scalar),
            "unrolled" | "lanes" => Some(KernelKind::Unrolled),
            "avx2" | "avx2fma" | "simd" => Some(KernelKind::Avx2),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Unrolled => "unrolled",
            KernelKind::Avx2 => "avx2",
        }
    }

    /// The best backend this host supports, by runtime feature detection.
    pub fn detect() -> Self {
        if avx2_available() {
            KernelKind::Avx2
        } else {
            KernelKind::Unrolled
        }
    }

    /// Every backend that can actually execute on this host (what the
    /// property tests sweep). Always starts with the scalar reference.
    pub fn available() -> Vec<KernelKind> {
        let mut v = vec![KernelKind::Scalar, KernelKind::Unrolled];
        if avx2_available() {
            v.push(KernelKind::Avx2);
        }
        v
    }

    /// Resolve `Auto` and unsupported requests to a concrete, runnable kind.
    pub fn resolve(self) -> Self {
        match self {
            KernelKind::Auto => Self::detect(),
            KernelKind::Avx2 if !avx2_available() => KernelKind::Unrolled,
            k => k,
        }
    }
}

/// Runtime AVX2+FMA detection (false on non-x86 targets).
pub fn avx2_available() -> bool {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
    {
        false
    }
}

/// Column-tiling request (CLI `--tile`, config `[solver] tile`, env
/// `MAP_UOT_TILE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileSpec {
    /// Size panels from the detected cache topology.
    Auto,
    /// Untiled sweep (today's single-pass row order).
    Off,
    /// One-shot auto-tuner: measure a few candidates at workspace build.
    Tune,
    /// Explicit panel width in columns.
    Cols(usize),
}

impl TileSpec {
    /// Parse from a CLI/config/env string: `auto | off | tune | <cols>`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(TileSpec::Auto),
            "off" | "none" | "0" => Some(TileSpec::Off),
            "tune" => Some(TileSpec::Tune),
            other => other.parse::<usize>().ok().map(TileSpec::Cols),
        }
    }

    pub fn describe(self) -> String {
        match self {
            TileSpec::Auto => "auto".into(),
            TileSpec::Off => "off".into(),
            TileSpec::Tune => "tune".into(),
            TileSpec::Cols(c) => c.to_string(),
        }
    }
}

// ---------------------------------------------------------------------------
// The Kernel trait and its three implementations
// ---------------------------------------------------------------------------

/// Object-safe interface over the two fused row primitives (plus the
/// matfree generation primitive).
///
/// `stream` requests non-temporal plan stores in Computations III/IV; only
/// the AVX2 backend honors it (scalar/unrolled stores always go through the
/// cache), and callers should pass `policy.stream_for(plan_elements)`.
pub trait Kernel: Sync {
    /// Concrete kind of this backend.
    fn kind(&self) -> KernelKind;

    /// Computations I+II: `row *= fcol` element-wise, returns the row sum.
    fn scale_by_vec_and_sum(&self, row: &mut [f32], fcol: &[f32]) -> f32;

    /// Matfree generation (the scaling-form Computations I+II): `buf`
    /// enters holding a panel of kernel costs `c(x_i, y_j)` and leaves
    /// holding the scaled kernel entries
    /// `exp(-c · inv_eps) · scale · v[j]`; returns the panel sum.
    ///
    /// The scalar backend evaluates `f32::exp` (the libm reference); the
    /// unrolled and AVX2 backends run the shared `util::simd::fast_exp`
    /// scheme, which agrees with libm within 1e-6 relative across the
    /// whole magnitude range including gradual underflow
    /// (`rust/tests/prop_kernels.rs::fast_exp_matches_libm_reference`).
    fn exp_scale_and_sum(&self, buf: &mut [f32], inv_eps: f32, scale: f32, v: &[f32]) -> f32;

    /// Computations III+IV: `row *= fr`, accumulating into `next_colsum`.
    fn scale_by_scalar_and_accumulate(
        &self,
        row: &mut [f32],
        fr: f32,
        next_colsum: &mut [f32],
        stream: bool,
    );

    /// Tracked Computations III+IV: also returns the row's max element
    /// change, recovering the pre-iteration value as `v · inv_fcol[j]`.
    fn scale_by_scalar_and_accumulate_tracked(
        &self,
        row: &mut [f32],
        fr: f32,
        inv_fcol: &[f32],
        next_colsum: &mut [f32],
        stream: bool,
    ) -> f32;
}

/// The [`Kernel`] implementation for `kind`, resolved to something runnable
/// on this host (stateless, `'static`).
pub fn kernel_for(kind: KernelKind) -> &'static dyn Kernel {
    match kind.resolve() {
        KernelKind::Scalar => &ScalarKernel,
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelKind::Avx2 => &AVX2_FMA_KERNEL,
        _ => &UnrolledKernel,
    }
}

/// Portable reference: plain element loops, no unrolling.
pub struct ScalarKernel;

impl Kernel for ScalarKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Scalar
    }

    fn scale_by_vec_and_sum(&self, row: &mut [f32], fcol: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), fcol.len());
        let mut s = 0f32;
        for (v, &f) in row.iter_mut().zip(fcol) {
            *v *= f;
            s += *v;
        }
        s
    }

    fn exp_scale_and_sum(&self, buf: &mut [f32], inv_eps: f32, scale: f32, v: &[f32]) -> f32 {
        debug_assert_eq!(buf.len(), v.len());
        let mut s = 0f32;
        for (b, &vj) in buf.iter_mut().zip(v) {
            let w = (-*b * inv_eps).exp() * (scale * vj);
            *b = w;
            s += w;
        }
        s
    }

    fn scale_by_scalar_and_accumulate(
        &self,
        row: &mut [f32],
        fr: f32,
        next_colsum: &mut [f32],
        _stream: bool,
    ) {
        debug_assert_eq!(row.len(), next_colsum.len());
        for (v, s) in row.iter_mut().zip(next_colsum.iter_mut()) {
            *v *= fr;
            *s += *v;
        }
    }

    fn scale_by_scalar_and_accumulate_tracked(
        &self,
        row: &mut [f32],
        fr: f32,
        inv_fcol: &[f32],
        next_colsum: &mut [f32],
        _stream: bool,
    ) -> f32 {
        debug_assert_eq!(row.len(), next_colsum.len());
        debug_assert_eq!(row.len(), inv_fcol.len());
        let mut delta = 0f32;
        for ((v, s), &inv) in row.iter_mut().zip(next_colsum.iter_mut()).zip(inv_fcol) {
            let old = *v * inv;
            *v *= fr;
            *s += *v;
            delta = delta.max((*v - old).abs());
        }
        delta
    }
}

/// The 16-lane unrolled loops — delegates to the free functions in
/// `algo::mapuot`, so it is bit-identical to the pre-kernel-subsystem
/// behavior (which every existing bit-match test pins down).
pub struct UnrolledKernel;

impl Kernel for UnrolledKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Unrolled
    }

    fn scale_by_vec_and_sum(&self, row: &mut [f32], fcol: &[f32]) -> f32 {
        crate::algo::mapuot::scale_by_vec_and_sum(row, fcol)
    }

    fn exp_scale_and_sum(&self, buf: &mut [f32], inv_eps: f32, scale: f32, v: &[f32]) -> f32 {
        debug_assert_eq!(buf.len(), v.len());
        // 16 fast_exp lanes: pure ALU/bit math, so LLVM vectorizes the
        // chunk body the same way it does the other unrolled primitives.
        const W: usize = simd::LANES;
        let mut acc = [0f32; W];
        let chunks = buf.len() / W;
        let (bh, bt) = buf.split_at_mut(chunks * W);
        let (vh, vt) = v.split_at(chunks * W);
        for (bw, vw) in bh.chunks_exact_mut(W).zip(vh.chunks_exact(W)) {
            for k in 0..W {
                let w = simd::fast_exp(-bw[k] * inv_eps) * (scale * vw[k]);
                bw[k] = w;
                acc[k] += w;
            }
        }
        let mut s = simd::fold(&acc);
        for (b, &vj) in bt.iter_mut().zip(vt) {
            let w = simd::fast_exp(-*b * inv_eps) * (scale * vj);
            *b = w;
            s += w;
        }
        s
    }

    fn scale_by_scalar_and_accumulate(
        &self,
        row: &mut [f32],
        fr: f32,
        next_colsum: &mut [f32],
        _stream: bool,
    ) {
        crate::algo::mapuot::scale_by_scalar_and_accumulate(row, fr, next_colsum)
    }

    fn scale_by_scalar_and_accumulate_tracked(
        &self,
        row: &mut [f32],
        fr: f32,
        inv_fcol: &[f32],
        next_colsum: &mut [f32],
        _stream: bool,
    ) -> f32 {
        crate::algo::mapuot::scale_by_scalar_and_accumulate_tracked(row, fr, inv_fcol, next_colsum)
    }
}

/// Hand-written AVX2+FMA backend. Not publicly constructible: the only
/// instances are crate-internal and handed out behind [`avx2_available`]
/// (see [`kernel_for`]), which is what discharges the trait methods'
/// obligation when they call the `#[target_feature]` bodies in [`avx2`]
/// from a context that does not itself enable the features.
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub struct Avx2FmaKernel {
    _detection_gated: (),
}

/// The crate-internal AVX2 instance — use only behind [`avx2_available`].
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub(crate) const AVX2_FMA_KERNEL: Avx2FmaKernel = Avx2FmaKernel { _detection_gated: () };

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
impl Kernel for Avx2FmaKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Avx2
    }

    fn scale_by_vec_and_sum(&self, row: &mut [f32], fcol: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), fcol.len());
        // SAFETY: kernel_for only hands out this backend when AVX2+FMA are
        // runtime-detected.
        unsafe { avx2::scale_by_vec_and_sum(row, fcol) }
    }

    fn exp_scale_and_sum(&self, buf: &mut [f32], inv_eps: f32, scale: f32, v: &[f32]) -> f32 {
        debug_assert_eq!(buf.len(), v.len());
        // SAFETY: feature-gated construction, see above.
        unsafe { avx2::exp_scale_and_sum(buf, inv_eps, scale, v) }
    }

    fn scale_by_scalar_and_accumulate(
        &self,
        row: &mut [f32],
        fr: f32,
        next_colsum: &mut [f32],
        stream: bool,
    ) {
        debug_assert_eq!(row.len(), next_colsum.len());
        // SAFETY: feature-gated construction, see above.
        unsafe { avx2::scale_by_scalar_and_accumulate(row, fr, next_colsum, stream) }
    }

    fn scale_by_scalar_and_accumulate_tracked(
        &self,
        row: &mut [f32],
        fr: f32,
        inv_fcol: &[f32],
        next_colsum: &mut [f32],
        stream: bool,
    ) -> f32 {
        debug_assert_eq!(row.len(), next_colsum.len());
        debug_assert_eq!(row.len(), inv_fcol.len());
        // SAFETY: feature-gated construction, see above.
        unsafe {
            avx2::scale_by_scalar_and_accumulate_tracked(row, fr, inv_fcol, next_colsum, stream)
        }
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod avx2 {
    //! The intrinsic bodies. All loads are unaligned (`loadu`): tiled
    //! panels start at arbitrary column offsets. Non-temporal stores need
    //! 32-byte-aligned addresses, so the streaming paths peel a scalar
    //! head up to alignment and a scalar tail, and fence (`sfence`) before
    //! returning — MOVNT stores are weakly ordered, and the pool barrier's
    //! release/acquire pair does not order them on its own.
    //!
    //! Every function here is a **safe** `#[target_feature]` fn: the
    //! register-only intrinsics are safe inside a matching-feature context,
    //! so `unsafe` shrinks to exactly the pointer loads/stores, each with a
    //! bounds argument on it. Callers *without* an AVX2+FMA context (the
    //! `Avx2FmaKernel` trait methods) still need an `unsafe` block — their
    //! obligation is runtime feature detection, discharged by
    //! [`super::avx2_available`]-gated construction.

    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// Horizontal sum of one 8-lane register.
    #[inline]
    #[target_feature(enable = "avx")]
    fn hsum(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Horizontal max of one 8-lane register (non-negative inputs).
    #[inline]
    #[target_feature(enable = "avx")]
    fn hmax(v: __m256) -> f32 {
        let s = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_max_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// 8-lane `e^x`: the same Cody–Waite reduction + degree-5 minimax +
    /// two-factor exponent reconstruction as `util::simd::fast_exp` (the
    /// constants are shared), with FMA contractions — ~2 ulp, overflow to
    /// +inf, gradual underflow to 0.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    fn exp_ps(x: __m256) -> __m256 {
        use crate::util::simd::{EXP_HI_CLAMP, EXP_LN2_HI, EXP_LN2_LO, EXP_LO_CLAMP, EXP_POLY};
        let x = _mm256_max_ps(
            _mm256_min_ps(x, _mm256_set1_ps(EXP_HI_CLAMP)),
            _mm256_set1_ps(EXP_LO_CLAMP),
        );
        let n = _mm256_round_ps(
            _mm256_mul_ps(x, _mm256_set1_ps(std::f32::consts::LOG2_E)),
            _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC,
        );
        let r = _mm256_fnmadd_ps(
            n,
            _mm256_set1_ps(EXP_LN2_LO),
            _mm256_fnmadd_ps(n, _mm256_set1_ps(EXP_LN2_HI), x),
        );
        let mut p = _mm256_set1_ps(EXP_POLY[0]);
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY[1]));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY[2]));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY[3]));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY[4]));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(EXP_POLY[5]));
        let e = _mm256_add_ps(
            _mm256_fmadd_ps(_mm256_mul_ps(p, r), r, r),
            _mm256_set1_ps(1.0),
        );
        // 2^n via two factors (see fast_exp): keeps every biased exponent
        // a valid normal bit pattern and lets underflow round gradually.
        let ni = _mm256_cvtps_epi32(n);
        let half = _mm256_srai_epi32(ni, 1);
        let bias = _mm256_set1_epi32(127);
        let a = _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_add_epi32(half, bias), 23));
        let b = _mm256_castsi256_ps(_mm256_slli_epi32(
            _mm256_add_epi32(_mm256_sub_epi32(ni, half), bias),
            23,
        ));
        _mm256_mul_ps(a, _mm256_mul_ps(b, e))
    }

    /// Matfree generation: `buf[j] = exp(-buf[j] · inv_eps) · scale · v[j]`
    /// (buf enters holding the cost panel), returning the panel sum. Two
    /// independent 8-lane accumulators — exp's ALU chain dominates, so two
    /// suffice to hide the add latency.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub fn exp_scale_and_sum(buf: &mut [f32], inv_eps: f32, scale: f32, v: &[f32]) -> f32 {
        assert_eq!(buf.len(), v.len(), "panel/v length mismatch");
        let n = buf.len();
        let b = buf.as_mut_ptr();
        let vp = v.as_ptr();
        let neg_inv = _mm256_set1_ps(-inv_eps);
        let vs = _mm256_set1_ps(scale);
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 16 <= n {
            // SAFETY: the loop guard keeps j..j+16 inside both slices
            // (equal lengths asserted above), so every lane of each
            // load/store is in bounds.
            unsafe {
                let e0 = exp_ps(_mm256_mul_ps(_mm256_loadu_ps(b.add(j)), neg_inv));
                let e1 = exp_ps(_mm256_mul_ps(_mm256_loadu_ps(b.add(j + 8)), neg_inv));
                let w0 = _mm256_mul_ps(e0, _mm256_mul_ps(vs, _mm256_loadu_ps(vp.add(j))));
                let w1 = _mm256_mul_ps(e1, _mm256_mul_ps(vs, _mm256_loadu_ps(vp.add(j + 8))));
                _mm256_storeu_ps(b.add(j), w0);
                _mm256_storeu_ps(b.add(j + 8), w1);
                acc0 = _mm256_add_ps(acc0, w0);
                acc1 = _mm256_add_ps(acc1, w1);
            }
            j += 16;
        }
        while j + 8 <= n {
            // SAFETY: the loop guard keeps j..j+8 inside both slices.
            unsafe {
                let e = exp_ps(_mm256_mul_ps(_mm256_loadu_ps(b.add(j)), neg_inv));
                let w = _mm256_mul_ps(e, _mm256_mul_ps(vs, _mm256_loadu_ps(vp.add(j))));
                _mm256_storeu_ps(b.add(j), w);
                acc0 = _mm256_add_ps(acc0, w);
            }
            j += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while j < n {
            // SAFETY: j < n — one in-bounds element of each slice.
            unsafe {
                let w = crate::util::simd::fast_exp(-*b.add(j) * inv_eps) * (scale * *vp.add(j));
                *b.add(j) = w;
                s += w;
            }
            j += 1;
        }
        s
    }

    /// Computations I+II: four independent 8-lane FMA accumulators (32
    /// floats per step) break the add-latency chain exactly like the
    /// portable kernel's 16 scalar lanes.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub fn scale_by_vec_and_sum(row: &mut [f32], fcol: &[f32]) -> f32 {
        assert_eq!(row.len(), fcol.len(), "row/fcol length mismatch");
        let n = row.len();
        let r = row.as_mut_ptr();
        let f = fcol.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 32 <= n {
            // SAFETY: the loop guard keeps j..j+32 inside both slices
            // (equal lengths asserted above), so every lane of each
            // load/store is in bounds.
            unsafe {
                let v0 = _mm256_loadu_ps(r.add(j));
                let v1 = _mm256_loadu_ps(r.add(j + 8));
                let v2 = _mm256_loadu_ps(r.add(j + 16));
                let v3 = _mm256_loadu_ps(r.add(j + 24));
                let f0 = _mm256_loadu_ps(f.add(j));
                let f1 = _mm256_loadu_ps(f.add(j + 8));
                let f2 = _mm256_loadu_ps(f.add(j + 16));
                let f3 = _mm256_loadu_ps(f.add(j + 24));
                _mm256_storeu_ps(r.add(j), _mm256_mul_ps(v0, f0));
                _mm256_storeu_ps(r.add(j + 8), _mm256_mul_ps(v1, f1));
                _mm256_storeu_ps(r.add(j + 16), _mm256_mul_ps(v2, f2));
                _mm256_storeu_ps(r.add(j + 24), _mm256_mul_ps(v3, f3));
                // FMA accumulation: the sum sees the unrounded products (≤ 1
                // ulp/element from the stored values — inside every agreement
                // tolerance, and one add cheaper per vector).
                acc0 = _mm256_fmadd_ps(v0, f0, acc0);
                acc1 = _mm256_fmadd_ps(v1, f1, acc1);
                acc2 = _mm256_fmadd_ps(v2, f2, acc2);
                acc3 = _mm256_fmadd_ps(v3, f3, acc3);
            }
            j += 32;
        }
        while j + 8 <= n {
            // SAFETY: the loop guard keeps j..j+8 inside both slices.
            unsafe {
                let v = _mm256_loadu_ps(r.add(j));
                let fv = _mm256_loadu_ps(f.add(j));
                _mm256_storeu_ps(r.add(j), _mm256_mul_ps(v, fv));
                acc0 = _mm256_fmadd_ps(v, fv, acc0);
            }
            j += 8;
        }
        let mut s = hsum(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
        while j < n {
            // SAFETY: j < n — one in-bounds element of each slice.
            unsafe {
                let v = *r.add(j) * *f.add(j);
                *r.add(j) = v;
                s += v;
            }
            j += 1;
        }
        s
    }

    /// Computations III+IV. `stream = true` writes the plan with
    /// `_mm256_stream_ps` (no RFO); `next_colsum` always goes through the
    /// cache — it is re-read every row.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub fn scale_by_scalar_and_accumulate(
        row: &mut [f32],
        fr: f32,
        next_colsum: &mut [f32],
        stream: bool,
    ) {
        assert_eq!(row.len(), next_colsum.len(), "row/colsum length mismatch");
        let n = row.len();
        let r = row.as_mut_ptr();
        let c = next_colsum.as_mut_ptr();
        let vf = _mm256_set1_ps(fr);
        let mut j = 0usize;
        if stream {
            while j < n && (r as usize + j * 4) % 32 != 0 {
                // SAFETY: j < n — one in-bounds element of each slice.
                unsafe {
                    let v = *r.add(j) * fr;
                    *r.add(j) = v;
                    *c.add(j) += v;
                }
                j += 1;
            }
            // An f32 pointer is 4-byte aligned, so stepping one element at
            // a time must reach a 32-byte boundary within 8 steps (or run
            // out of row) — the requirement MOVNT stores add below.
            debug_assert!(
                j == n || (r as usize + j * 4) % 32 == 0,
                "streaming head peel failed to reach 32-byte alignment"
            );
            while j + 8 <= n {
                // SAFETY: the loop guard keeps j..j+8 inside both slices,
                // and the head peel left `r.add(j)` 32-byte aligned as
                // `_mm256_stream_ps` requires.
                unsafe {
                    let p = _mm256_mul_ps(_mm256_loadu_ps(r.add(j)), vf);
                    _mm256_stream_ps(r.add(j), p);
                    _mm256_storeu_ps(c.add(j), _mm256_add_ps(_mm256_loadu_ps(c.add(j)), p));
                }
                j += 8;
            }
        } else {
            while j + 8 <= n {
                // SAFETY: the loop guard keeps j..j+8 inside both slices.
                unsafe {
                    let p = _mm256_mul_ps(_mm256_loadu_ps(r.add(j)), vf);
                    _mm256_storeu_ps(r.add(j), p);
                    _mm256_storeu_ps(c.add(j), _mm256_add_ps(_mm256_loadu_ps(c.add(j)), p));
                }
                j += 8;
            }
        }
        while j < n {
            // SAFETY: j < n — one in-bounds element of each slice.
            unsafe {
                let v = *r.add(j) * fr;
                *r.add(j) = v;
                *c.add(j) += v;
            }
            j += 1;
        }
        if stream {
            // Drain the weakly-ordered MOVNT write-combining buffers before
            // the pool barrier's release store publishes this part.
            _mm_sfence();
        }
    }

    /// Tracked Computations III+IV: per-lane |new − old| maxima folded at
    /// the end (max is order-independent, so this matches the scalar fold
    /// bit-for-bit).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub fn scale_by_scalar_and_accumulate_tracked(
        row: &mut [f32],
        fr: f32,
        inv_fcol: &[f32],
        next_colsum: &mut [f32],
        stream: bool,
    ) -> f32 {
        assert_eq!(row.len(), next_colsum.len(), "row/colsum length mismatch");
        assert_eq!(row.len(), inv_fcol.len(), "row/inv_fcol length mismatch");
        let n = row.len();
        let r = row.as_mut_ptr();
        let c = next_colsum.as_mut_ptr();
        let iv = inv_fcol.as_ptr();
        let vf = _mm256_set1_ps(fr);
        let abs_mask = _mm256_set1_ps(-0.0);
        let mut dmax = _mm256_setzero_ps();
        let mut d = 0f32;
        let mut j = 0usize;
        if stream {
            while j < n && (r as usize + j * 4) % 32 != 0 {
                // SAFETY: j < n — one in-bounds element of each slice.
                unsafe {
                    let v = *r.add(j);
                    let old = v * *iv.add(j);
                    let p = v * fr;
                    *r.add(j) = p;
                    *c.add(j) += p;
                    d = d.max((p - old).abs());
                }
                j += 1;
            }
            // See the untracked form: 4-byte element steps must reach a
            // 32-byte boundary before the MOVNT loop needs one.
            debug_assert!(
                j == n || (r as usize + j * 4) % 32 == 0,
                "streaming head peel failed to reach 32-byte alignment"
            );
            while j + 8 <= n {
                // SAFETY: the loop guard keeps j..j+8 inside all three
                // equal-length slices, and the head peel left `r.add(j)`
                // 32-byte aligned as `_mm256_stream_ps` requires.
                unsafe {
                    let v = _mm256_loadu_ps(r.add(j));
                    let p = _mm256_mul_ps(v, vf);
                    let old = _mm256_mul_ps(v, _mm256_loadu_ps(iv.add(j)));
                    _mm256_stream_ps(r.add(j), p);
                    _mm256_storeu_ps(c.add(j), _mm256_add_ps(_mm256_loadu_ps(c.add(j)), p));
                    dmax = _mm256_max_ps(dmax, _mm256_andnot_ps(abs_mask, _mm256_sub_ps(p, old)));
                }
                j += 8;
            }
        } else {
            while j + 8 <= n {
                // SAFETY: the loop guard keeps j..j+8 inside all three
                // equal-length slices.
                unsafe {
                    let v = _mm256_loadu_ps(r.add(j));
                    let p = _mm256_mul_ps(v, vf);
                    let old = _mm256_mul_ps(v, _mm256_loadu_ps(iv.add(j)));
                    _mm256_storeu_ps(r.add(j), p);
                    _mm256_storeu_ps(c.add(j), _mm256_add_ps(_mm256_loadu_ps(c.add(j)), p));
                    dmax = _mm256_max_ps(dmax, _mm256_andnot_ps(abs_mask, _mm256_sub_ps(p, old)));
                }
                j += 8;
            }
        }
        while j < n {
            // SAFETY: j < n — one in-bounds element of each slice.
            unsafe {
                let v = *r.add(j);
                let old = v * *iv.add(j);
                let p = v * fr;
                *r.add(j) = p;
                *c.add(j) += p;
                d = d.max((p - old).abs());
            }
            j += 1;
        }
        if stream {
            // Drain the weakly-ordered MOVNT write-combining buffers before
            // the pool barrier's release store publishes this part.
            _mm_sfence();
        }
        d.max(hmax(dmax))
    }
}

// ---------------------------------------------------------------------------
// CSR row primitives (sparse MAP-UOT)
// ---------------------------------------------------------------------------
//
// The sparse fused sweep (`algo::sparse::fused_csr_rows`) runs on these
// two primitives — the CSR analogues of `scale_by_vec_and_sum` and
// `scale_by_scalar_and_accumulate{,_tracked}`. The gathers/scatters stay
// scalar (there is no contiguity to exploit and no AVX2 gather is worth
// its latency at these row lengths), but the multiply/sum runs on
// `util::simd::LANES` independent accumulator lanes with the shared
// sequential fold, so the row sum does not serialize on add latency and
// the numerics match the dense kernels' conventions. The scatter adds
// preserve element order within each unrolled chunk, so the tracked and
// untracked forms (and any chunking) are bit-identical to the plain loop.

/// CSR Computations I+II over one row's nonzeros:
/// `vals[k] *= fcol[cols[k]]`, returning the sum of the scaled values.
pub fn csr_scale_by_cols_and_sum(vals: &mut [f32], cols: &[u32], fcol: &[f32]) -> f32 {
    debug_assert_eq!(vals.len(), cols.len());
    const W: usize = simd::LANES;
    let mut acc = [0f32; W];
    let chunks = vals.len() / W;
    let (vh, vt) = vals.split_at_mut(chunks * W);
    let (ch, ct) = cols.split_at(chunks * W);
    for (vw, cw) in vh.chunks_exact_mut(W).zip(ch.chunks_exact(W)) {
        for k in 0..W {
            vw[k] *= fcol[cw[k] as usize];
            acc[k] += vw[k];
        }
    }
    let mut s = simd::fold(&acc);
    for (v, &c) in vt.iter_mut().zip(ct) {
        *v *= fcol[c as usize];
        s += *v;
    }
    s
}

/// CSR Computations III+IV: `vals[k] *= fr`, scatter-accumulating the new
/// values into `next_colsum[cols[k]]`.
pub fn csr_scale_and_accumulate(
    vals: &mut [f32],
    cols: &[u32],
    fr: f32,
    next_colsum: &mut [f32],
) {
    debug_assert_eq!(vals.len(), cols.len());
    const W: usize = simd::LANES;
    let chunks = vals.len() / W;
    let (vh, vt) = vals.split_at_mut(chunks * W);
    let (ch, ct) = cols.split_at(chunks * W);
    for (vw, cw) in vh.chunks_exact_mut(W).zip(ch.chunks_exact(W)) {
        for k in 0..W {
            vw[k] *= fr;
        }
        // Scatter in element order — same accumulation order as the plain
        // loop, so colsum bits do not depend on the unroll width.
        for k in 0..W {
            next_colsum[cw[k] as usize] += vw[k];
        }
    }
    for (v, &c) in vt.iter_mut().zip(ct) {
        *v *= fr;
        next_colsum[c as usize] += *v;
    }
}

/// Tracked CSR Computations III+IV: also returns the row's max element
/// change, recovering the pre-iteration value as `v · inv_fcol[col]`
/// (same reciprocal-factor trick as the dense tracked kernels; the lane
/// maxima fold at the end, and `max` is order-independent, so the delta
/// is bit-identical to the sequential form).
pub fn csr_scale_and_accumulate_tracked(
    vals: &mut [f32],
    cols: &[u32],
    fr: f32,
    inv_fcol: &[f32],
    next_colsum: &mut [f32],
) -> f32 {
    debug_assert_eq!(vals.len(), cols.len());
    const W: usize = simd::LANES;
    let mut dl = [0f32; W];
    let chunks = vals.len() / W;
    let (vh, vt) = vals.split_at_mut(chunks * W);
    let (ch, ct) = cols.split_at(chunks * W);
    for (vw, cw) in vh.chunks_exact_mut(W).zip(ch.chunks_exact(W)) {
        for k in 0..W {
            let old = vw[k] * inv_fcol[cw[k] as usize];
            vw[k] *= fr;
            dl[k] = dl[k].max((vw[k] - old).abs());
        }
        for k in 0..W {
            next_colsum[cw[k] as usize] += vw[k];
        }
    }
    let mut delta = dl.iter().copied().fold(0f32, f32::max);
    for (v, &c) in vt.iter_mut().zip(ct) {
        let old = *v * inv_fcol[c as usize];
        *v *= fr;
        next_colsum[c as usize] += *v;
        delta = delta.max((*v - old).abs());
    }
    delta
}

// ---------------------------------------------------------------------------
// Policy: resolved kernel + tiling + streaming thresholds
// ---------------------------------------------------------------------------

/// Auto tile width from the L1 budget: a panel touches four f32 streams
/// per column (row element, `Factor_col`, `inv_fcol`, `NextSum_col`), and
/// we target half of L1d to leave room for `Sum_row` and prefetch depth.
fn auto_tile_cols(topo: cputopo::CacheTopo) -> usize {
    ((topo.l1d / 2 / 16) / simd::LANES * simd::LANES).max(128)
}

/// Resolved execution policy for the fused sweep: which kernel backend,
/// whether/how to tile, and when to engage non-temporal stores. Built once
/// per `Workspace` ([`KernelPolicy::for_shape`]) and copied around freely.
#[derive(Clone, Copy)]
pub struct KernelPolicy {
    /// Concrete (resolved, runnable) backend kind — the sweep dispatches
    /// on it once per call and then runs monomorphized, so no `dyn` call
    /// ever lands in the per-row loop.
    kind: KernelKind,
    /// Column panel width; 0 = untiled.
    tile_cols: usize,
    /// L2 budget for the phase-resident row chunk.
    l2_bytes: usize,
    /// Plan bytes beyond which Computations III/IV use streaming stores
    /// (`usize::MAX` disables).
    nt_bytes: usize,
}

impl KernelPolicy {
    /// Resolve `(kind, tile)` for an `m × n` workspace: explicit choices
    /// win, `Auto` consults `MAP_UOT_KERNEL` / `MAP_UOT_TILE`, then runtime
    /// detection and the cache topology. `MAP_UOT_NT=off` disables
    /// streaming stores. `TileSpec::Tune` measures candidates once, here.
    pub fn for_shape(kind: KernelKind, tile: TileSpec, m: usize, n: usize) -> Self {
        let kind = match kind {
            KernelKind::Auto => env_kernel().unwrap_or(KernelKind::Auto).resolve(),
            k => k.resolve(),
        };
        let topo = cputopo::get();
        let tile = match tile {
            TileSpec::Auto => env_tile().unwrap_or(TileSpec::Auto),
            t => t,
        };
        let tile_cols = match tile {
            TileSpec::Off => 0,
            TileSpec::Cols(c) => c,
            TileSpec::Auto => auto_tile_cols(topo),
            // A degenerate probe shape (e.g. the 1×1 placeholder a
            // sparse-first session builds its dense buffers at) would
            // "tune" on pure timer noise and that width would stick for
            // any later real-shape solve — fall back to the topology
            // width instead of measuring. Reachable via an explicit
            // `tune` or the MAP_UOT_TILE=tune env override on Auto.
            TileSpec::Tune if m.saturating_mul(n) < 64 * 64 => auto_tile_cols(topo),
            TileSpec::Tune => autotune_tile_cols(kernel_for(kind), m, n, topo),
        };
        let nt_off = matches!(
            std::env::var("MAP_UOT_NT").as_deref(),
            Ok("off") | Ok("0") | Ok("false")
        );
        Self {
            kind,
            tile_cols,
            l2_bytes: topo.l2,
            nt_bytes: if nt_off { usize::MAX } else { topo.llc },
        }
    }

    /// The pre-subsystem behavior: unrolled kernel, untiled, no streaming
    /// stores. The legacy free-function entry points use this, so their
    /// numerics are bit-stable across the refactor.
    pub fn legacy() -> Self {
        Self {
            kind: KernelKind::Unrolled,
            tile_cols: 0,
            l2_bytes: cputopo::FALLBACK.l2,
            nt_bytes: usize::MAX,
        }
    }

    /// Fully explicit policy (benches and property tests). `nt_bytes =
    /// None` disables streaming stores.
    pub fn explicit(kind: KernelKind, tile_cols: usize, nt_bytes: Option<usize>) -> Self {
        Self {
            kind: kind.resolve(),
            tile_cols,
            l2_bytes: cputopo::get().l2,
            nt_bytes: nt_bytes.unwrap_or(usize::MAX),
        }
    }

    /// The resolved (concrete) backend kind.
    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// The kernel implementation (trait-object view; the hot sweep instead
    /// dispatches on [`KernelPolicy::kind`] once and runs monomorphized).
    pub fn kernel(&self) -> &'static dyn Kernel {
        kernel_for(self.kind)
    }

    /// Column panel width; 0 = untiled.
    pub fn tile_cols(&self) -> usize {
        self.tile_cols
    }

    /// `Some(panel_width)` when an `n`-column sweep should tile: a panel
    /// narrower than the row is the only case where tiling changes the
    /// access pattern at all.
    pub fn tile_for(&self, n: usize) -> Option<usize> {
        (self.tile_cols > 0 && self.tile_cols < n).then_some(self.tile_cols)
    }

    /// Rows per L2-resident chunk for an `n`-column tiled sweep (the chunk
    /// is re-read by phase 2, so it targets half of L2).
    pub fn row_chunk(&self, n: usize) -> usize {
        ((self.l2_bytes / 2) / (n.max(1) * 4)).max(1)
    }

    /// Whether a sweep over `elements` plan cells should use non-temporal
    /// stores: only once the plan exceeds the LLC — below that, regular
    /// stores keep it cache-resident for the *next* iteration.
    pub fn stream_for(&self, elements: usize) -> bool {
        elements.saturating_mul(4) > self.nt_bytes
    }
}

impl std::fmt::Debug for KernelPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelPolicy")
            .field("kind", &self.kind.name())
            .field("tile_cols", &self.tile_cols)
            .field("nt_bytes", &self.nt_bytes)
            .finish()
    }
}

/// `MAP_UOT_KERNEL` override, ignoring unset/empty/invalid values.
fn env_kernel() -> Option<KernelKind> {
    std::env::var("MAP_UOT_KERNEL").ok().as_deref().and_then(KernelKind::parse)
}

/// `MAP_UOT_TILE` override, ignoring unset/empty/invalid values.
fn env_tile() -> Option<TileSpec> {
    std::env::var("MAP_UOT_TILE").ok().as_deref().and_then(TileSpec::parse)
}

/// One-shot tile auto-tuner: time the tiled fused sweep over a synthetic
/// row block of this shape for a few topology-derived candidates (plus
/// untiled) and return the fastest panel width. Runs at workspace build —
/// the one place the allocation contract permits setup cost.
pub fn autotune_tile_cols(
    kernel: &'static dyn Kernel,
    m: usize,
    n: usize,
    topo: cputopo::CacheTopo,
) -> usize {
    let base = auto_tile_cols(topo);
    let mut candidates = vec![0usize, base / 2, base, base * 2];
    candidates.dedup();
    // Cap the probe block so tuning stays a few milliseconds even at
    // service-scale shapes.
    let rows = m.clamp(1, 64.max((topo.l2 / 2) / (n.max(1) * 4)).min(256));
    let mut rowbuf = vec![1.0f32; rows * n];
    let fcol = vec![1.000_001f32; n];
    let rpd = vec![1.0f32; rows];
    let mut colsum = vec![0f32; n];
    let mut sum_row = vec![0f32; rows];
    let mut best = (f64::INFINITY, 0usize);
    for &tile in &candidates {
        let policy = KernelPolicy {
            kind: kernel.kind(),
            tile_cols: tile,
            l2_bytes: topo.l2,
            nt_bytes: usize::MAX,
        };
        let mut elapsed = f64::INFINITY;
        for _ in 0..3 {
            let t = crate::util::Timer::start();
            crate::algo::mapuot::fused_rows_policy(
                &mut rowbuf,
                n,
                &rpd,
                &fcol,
                1.0,
                &mut colsum,
                &mut sum_row,
                &policy,
            );
            elapsed = elapsed.min(t.elapsed().as_secs_f64());
        }
        if elapsed < best.0 {
            best = (elapsed, tile);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::XorShift::new(seed);
        let row: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let fcol: Vec<f32> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let inv: Vec<f32> = fcol.iter().map(|f| 1.0 / f).collect();
        let colsum: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
        (row, fcol, inv, colsum)
    }

    fn assert_close(a: f32, b: f32, what: &str) {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0), "{what}: {a} vs {b}");
    }

    /// Every available backend reproduces the scalar reference on both
    /// primitives, across awkward lengths and both store modes.
    #[test]
    fn backends_match_scalar_reference() {
        for kind in KernelKind::available() {
            let k = kernel_for(kind);
            assert_eq!(k.kind(), kind, "{:?} resolved to {:?}", kind, k.kind());
            for n in [0usize, 1, 3, 7, 8, 9, 31, 32, 33, 257, 1000] {
                for stream in [false, true] {
                    let (row0, fcol, inv, colsum0) = rand_vecs(n, 11 + n as u64);

                    let mut r_ref = row0.clone();
                    let s_ref = ScalarKernel.scale_by_vec_and_sum(&mut r_ref, &fcol);
                    let mut r = row0.clone();
                    let s = k.scale_by_vec_and_sum(&mut r, &fcol);
                    assert_close(s, s_ref, "rowsum");
                    for (a, b) in r.iter().zip(&r_ref) {
                        // Element-wise products are identical in every
                        // backend — same multiply, same rounding.
                        assert_eq!(a, b, "{} n={n}", kind.name());
                    }

                    let mut cs_ref = colsum0.clone();
                    let d_ref = ScalarKernel.scale_by_scalar_and_accumulate_tracked(
                        &mut r_ref, 0.9, &inv, &mut cs_ref, false,
                    );
                    let mut cs = colsum0.clone();
                    let d = k.scale_by_scalar_and_accumulate_tracked(
                        &mut r, 0.9, &inv, &mut cs, stream,
                    );
                    assert_close(d, d_ref, "delta");
                    for (a, b) in r.iter().zip(&r_ref) {
                        assert_eq!(a, b, "{} n={n} stream={stream}", kind.name());
                    }
                    for (a, b) in cs.iter().zip(&cs_ref) {
                        assert_close(*a, *b, "colsum");
                    }
                }
            }
        }
    }

    /// Streaming and cached stores must produce identical bits (streaming
    /// changes the cache protocol, never the values).
    #[test]
    fn stream_mode_is_bit_identical() {
        for kind in KernelKind::available() {
            let k = kernel_for(kind);
            // Offset sub-slices exercise the unaligned head/tail peeling.
            for (n, off) in [(64usize, 0usize), (65, 1), (130, 3), (17, 5)] {
                let (row0, _, inv, colsum0) = rand_vecs(n + off, 3 + n as u64);
                let mut a = row0.clone();
                let mut ca = colsum0.clone();
                k.scale_by_scalar_and_accumulate(&mut a[off..], 1.1, &mut ca[off..], false);
                let mut b = row0.clone();
                let mut cb = colsum0.clone();
                k.scale_by_scalar_and_accumulate(&mut b[off..], 1.1, &mut cb[off..], true);
                assert_eq!(a, b, "{} n={n} off={off}", kind.name());
                assert_eq!(ca, cb, "{} n={n} off={off}", kind.name());

                let mut da_in = row0.clone();
                let mut dca = colsum0.clone();
                let da = k.scale_by_scalar_and_accumulate_tracked(
                    &mut da_in[off..], 0.8, &inv[off..], &mut dca[off..], false,
                );
                let mut db_in = row0.clone();
                let mut dcb = colsum0.clone();
                let db = k.scale_by_scalar_and_accumulate_tracked(
                    &mut db_in[off..], 0.8, &inv[off..], &mut dcb[off..], true,
                );
                assert_eq!(da_in, db_in, "{} tracked n={n} off={off}", kind.name());
                assert_eq!(dca, dcb, "{} tracked n={n} off={off}", kind.name());
                assert_eq!(da.to_bits(), db.to_bits(), "{} delta n={n}", kind.name());
            }
        }
    }

    #[test]
    fn parsing_and_resolution() {
        assert_eq!(KernelKind::parse("AVX2"), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("scalar"), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("auto"), Some(KernelKind::Auto));
        assert_eq!(KernelKind::parse("sse9"), None);
        assert_eq!(TileSpec::parse("off"), Some(TileSpec::Off));
        assert_eq!(TileSpec::parse("auto"), Some(TileSpec::Auto));
        assert_eq!(TileSpec::parse("tune"), Some(TileSpec::Tune));
        assert_eq!(TileSpec::parse("384"), Some(TileSpec::Cols(384)));
        assert_eq!(TileSpec::parse("0"), Some(TileSpec::Off));
        assert_eq!(TileSpec::parse("wide"), None);
        // Auto always resolves to something runnable, and an avx2 request
        // never escapes unresolved on hosts without the features.
        let r = KernelKind::Auto.resolve();
        assert_ne!(r, KernelKind::Auto);
        let a = KernelKind::Avx2.resolve();
        assert!(a == KernelKind::Avx2 && avx2_available() || a == KernelKind::Unrolled);
    }

    #[test]
    fn policy_thresholds() {
        let p = KernelPolicy::explicit(KernelKind::Unrolled, 256, Some(1024 * 1024));
        assert_eq!(p.tile_for(1000), Some(256));
        assert_eq!(p.tile_for(256), None, "panel == row width: untiled");
        assert_eq!(p.tile_for(64), None);
        assert!(p.row_chunk(1024) >= 1);
        assert!(!p.stream_for(1024), "4 KiB plan must not stream");
        assert!(p.stream_for(1024 * 1024), "4 MiB plan exceeds the 1 MiB LLC");
        let legacy = KernelPolicy::legacy();
        assert_eq!(legacy.kind(), KernelKind::Unrolled);
        assert_eq!(legacy.tile_for(1 << 20), None);
        assert!(!legacy.stream_for(usize::MAX / 8));
    }

    /// The CSR primitives reproduce plain gather/scatter loops exactly
    /// (values and colsum bit-identical; sums/deltas within lane-fold
    /// tolerance) across awkward nnz counts.
    #[test]
    fn csr_primitives_match_plain_loops() {
        let mut rng = crate::util::XorShift::new(5);
        let ncols = 40u32;
        for nnz in [0usize, 1, 7, 15, 16, 17, 33, 257] {
            let cols: Vec<u32> = (0..nnz)
                .map(|_| (rng.next_f32() * ncols as f32) as u32 % ncols)
                .collect();
            let vals0: Vec<f32> = (0..nnz).map(|_| rng.uniform(0.1, 2.0)).collect();
            let fcol: Vec<f32> = (0..ncols).map(|_| rng.uniform(0.1, 2.0)).collect();
            let inv: Vec<f32> = fcol.iter().map(|f| 1.0 / f).collect();
            let cs0: Vec<f32> = (0..ncols).map(|_| rng.uniform(0.0, 1.0)).collect();

            // Computations I+II vs the plain loop.
            let mut vp = vals0.clone();
            let mut sp = 0f32;
            for (v, &c) in vp.iter_mut().zip(&cols) {
                *v *= fcol[c as usize];
                sp += *v;
            }
            let mut v = vals0.clone();
            let s = csr_scale_by_cols_and_sum(&mut v, &cols, &fcol);
            assert_eq!(v, vp, "nnz={nnz}");
            assert!((s - sp).abs() <= 1e-5 * sp.abs().max(1.0), "nnz={nnz}: {s} vs {sp}");

            // Computations III+IV, untracked.
            let mut cs_p = cs0.clone();
            for (v, &c) in vp.iter_mut().zip(&cols) {
                *v *= 0.9;
                cs_p[c as usize] += *v;
            }
            let mut cs = cs0.clone();
            csr_scale_and_accumulate(&mut v, &cols, 0.9, &mut cs);
            assert_eq!(v, vp, "nnz={nnz}");
            assert_eq!(cs, cs_p, "nnz={nnz}");

            // Tracked: identical updates plus the plain-loop delta bits.
            let mut vt_p = vals0.clone();
            let mut cst_p = cs0.clone();
            let mut d_p = 0f32;
            for (v, &c) in vt_p.iter_mut().zip(&cols) {
                let old = *v * inv[c as usize];
                *v *= 1.2;
                cst_p[c as usize] += *v;
                d_p = d_p.max((*v - old).abs());
            }
            let mut vt = vals0.clone();
            let mut cst = cs0.clone();
            let d = csr_scale_and_accumulate_tracked(&mut vt, &cols, 1.2, &inv, &mut cst);
            assert_eq!(vt, vt_p, "tracked nnz={nnz}");
            assert_eq!(cst, cst_p, "tracked nnz={nnz}");
            assert_eq!(d.to_bits(), d_p.to_bits(), "tracked delta nnz={nnz}");
        }
    }

    #[test]
    fn autotune_returns_a_candidate() {
        let topo = cputopo::get();
        let k = kernel_for(KernelKind::Unrolled);
        let tile = autotune_tile_cols(k, 64, 512, topo);
        let base = auto_tile_cols(topo);
        assert!(
            [0, base / 2, base, base * 2].contains(&tile),
            "tile {tile} not among candidates (base {base})"
        );
    }
}
