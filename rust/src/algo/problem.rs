//! UOT problem definition and workload generators.

use crate::error::{Error, Result};
use crate::util::{Matrix, XorShift};

/// An entropic unbalanced optimal transport instance.
///
/// The solver iterates row/column rescalings of `plan` toward the marginal
/// constraints `rpd` (length M) and `cpd` (length N), with relaxation
/// exponent `fi = er / (er + ep)` (paper §2.1; `fi = 1` is balanced
/// Sinkhorn).
#[derive(Debug, Clone)]
pub struct Problem {
    /// Initial transport plan (usually the Gibbs kernel `exp(-C/eps)`).
    pub plan: Matrix,
    /// Row probability distribution (target row marginals), length M.
    pub rpd: Vec<f32>,
    /// Column probability distribution (target column marginals), length N.
    pub cpd: Vec<f32>,
    /// Relaxation exponent in `(0, 1]`.
    pub fi: f32,
}

impl Problem {
    /// Validated constructor.
    pub fn new(plan: Matrix, rpd: Vec<f32>, cpd: Vec<f32>, fi: f32) -> Result<Self> {
        if rpd.len() != plan.rows() {
            return Err(Error::InvalidProblem(format!(
                "rpd length {} != rows {}",
                rpd.len(),
                plan.rows()
            )));
        }
        if cpd.len() != plan.cols() {
            return Err(Error::InvalidProblem(format!(
                "cpd length {} != cols {}",
                cpd.len(),
                plan.cols()
            )));
        }
        if !(fi > 0.0 && fi <= 1.0) {
            return Err(Error::InvalidProblem(format!("fi={fi} outside (0, 1]")));
        }
        if plan.as_slice().iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err(Error::InvalidProblem("plan has negative/non-finite entries".into()));
        }
        if rpd.iter().chain(cpd.iter()).any(|v| !v.is_finite() || *v <= 0.0) {
            return Err(Error::InvalidProblem("marginals must be positive and finite".into()));
        }
        Ok(Self { plan, rpd, cpd, fi })
    }

    pub fn rows(&self) -> usize {
        self.plan.rows()
    }

    pub fn cols(&self) -> usize {
        self.plan.cols()
    }

    /// Random dense instance: plan entries uniform in `[0.05, 2)`, marginals
    /// uniform in `[0.3, 1.7)` — the distribution the paper's figures use
    /// ("randomly generated matrices") and the same ranges as the Python
    /// hypothesis sweeps, so golden values transfer across layers.
    pub fn random(m: usize, n: usize, fi: f32, seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        let plan = Matrix::from_fn(m, n, |_, _| rng.uniform(0.05, 2.0));
        let rpd = rng.uniform_vec(m, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);
        Self { plan, rpd, cpd, fi }
    }

    /// Gibbs-kernel instance from two point clouds: `K = exp(-||x−y||²/eps)`
    /// with uniform marginals — the entry point used by the applications
    /// (color transfer, domain adaptation).
    pub fn from_point_clouds(xs: &[[f32; 3]], ys: &[[f32; 3]], eps: f32, fi: f32) -> Self {
        let (m, n) = (xs.len(), ys.len());
        let plan = Matrix::from_fn(m, n, |i, j| {
            let d2: f32 = (0..3).map(|k| (xs[i][k] - ys[j][k]).powi(2)).sum();
            (-d2 / eps).exp()
        });
        Self {
            plan,
            rpd: vec![1.0 / m as f32; m],
            cpd: vec![1.0 / n as f32; n],
            fi,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_is_deterministic() {
        let a = Problem::random(8, 6, 0.5, 7);
        let b = Problem::random(8, 6, 0.5, 7);
        assert_eq!(a.plan.as_slice(), b.plan.as_slice());
        assert_eq!(a.rpd, b.rpd);
    }

    #[test]
    fn validation_rejects_bad_inputs() {
        let plan = Matrix::zeros(2, 3);
        assert!(Problem::new(plan.clone(), vec![1.0; 3], vec![1.0; 3], 0.5).is_err());
        assert!(Problem::new(plan.clone(), vec![1.0; 2], vec![1.0; 2], 0.5).is_err());
        assert!(Problem::new(plan.clone(), vec![1.0; 2], vec![1.0; 3], 0.0).is_err());
        assert!(Problem::new(plan.clone(), vec![1.0; 2], vec![1.0; 3], 1.5).is_err());
        assert!(Problem::new(plan, vec![1.0, -1.0], vec![1.0; 3], 0.5).is_err());
    }

    #[test]
    fn gibbs_kernel_in_unit_range() {
        let xs = [[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]];
        let ys = [[0.0, 0.0, 0.0], [0.5, 0.5, 0.0]];
        let p = Problem::from_point_clouds(&xs, &ys, 0.5, 1.0);
        assert!(p.plan.as_slice().iter().all(|&v| v > 0.0 && v <= 1.0));
        assert_eq!(p.plan.get(0, 0), 1.0); // identical points
    }
}
