//! Model-checking shim for the [`super`] epoch-barrier protocol
//! (`model_check` feature only; nothing here exists in normal builds).
//!
//! The real pool runs the protocol through `AtomicU64`/`AtomicUsize`
//! loads and stores plus `park`/`unpark`. This module re-states that
//! protocol as an explicit-state machine in which **every shared-memory
//! operation is one step**: the dispatcher and each worker carry a
//! program counter, the atomics become plain fields of a [`State`], and
//! park/unpark follow `std::thread` token semantics — an `unpark` sets a
//! token, a `park` consumes one or blocks. Spurious wakeups are
//! deliberately *not* modeled: the protocol must not need them, and
//! granting them would mask lost-wakeup bugs.
//!
//! An external driver (uotlint's `sched` module) exhaustively enumerates
//! thread interleavings over these steps — sequential consistency, DFS
//! with visited-state pruning — and checks:
//!
//! * **no deadlock**: whenever a thread is not done, some thread can run;
//! * **job-slot validity**: a participating worker always reads the job
//!   published for the epoch generation it observed;
//! * **exact-once**: every part of every epoch executes exactly once;
//! * **barrier-drain-on-panic**: a panicking part still drains the
//!   barrier, and the dispatcher's `poisoned` swap observes the panic
//!   (and only then);
//! * **termination**: every maximal run ends with all threads done.
//!
//! The epoch packing reuses the real constants ([`super::PARTS_BITS`] /
//! [`super::PARTS_MASK`]), so a repack of the epoch word breaks the
//! model too. Why one writer: `run_dyn` serializes dispatchers on the
//! dispatch lock, so a single modeled caller is faithful.
//!
//! [`Bug`] enumerates seedable protocol mutations. Each deletes or
//! reorders exactly one step the way a plausible refactor might, and the
//! checker's mutation matrix proves every one of them is caught — the
//! gate can actually fail.

use std::rc::Rc;

use super::{PARTS_BITS, PARTS_MASK};

/// One scenario: pool shape, dispatched epochs, optional seeded panic
/// and/or protocol mutation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Config {
    /// Spawned workers (their loop indices are `1..=workers`).
    pub workers: usize,
    /// Parts per dispatch; the caller runs part 0, workers `1..parts`
    /// participate, workers `parts..=workers` must sleep through.
    pub parts: usize,
    /// Dispatches before shutdown. Two epochs are the minimum that
    /// exercises re-publish over parked workers (where the lost-wakeup
    /// and stale-token hazards live).
    pub epochs: usize,
    /// Seed a contained panic in `(epoch, part)`; part 0 is the caller.
    pub panic: Option<(usize, usize)>,
    /// Seeded protocol mutation (mutation tests); `None` = faithful.
    pub bug: Option<Bug>,
}

impl Config {
    pub fn describe(&self) -> String {
        let mut s = format!(
            "{}w/{}p/{}e",
            self.workers, self.parts, self.epochs
        );
        if let Some((e, p)) = self.panic {
            s.push_str(&format!(" panic@({e},{p})"));
        }
        if let Some(bug) = self.bug {
            s.push_str(&format!(" bug={bug:?}"));
        }
        s
    }
}

/// Seedable single-step protocol mutations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bug {
    /// The barrier-closing worker skips `caller.unpark()`.
    DropWorkerUnpark,
    /// The dispatcher skips unparking the participants.
    DropCallerUnpark,
    /// The dispatcher clears the job slot before the barrier drains.
    ClearJobBeforeBarrier,
    /// The epoch is published before the job slot is written.
    PublishBeforeJobWrite,
    /// The dispatcher forgets `remaining.store(parts - 1)`.
    SkipRemainingStore,
}

/// Every seedable mutation, for the mutation matrix.
pub const BUGS: [Bug; 5] = [
    Bug::DropWorkerUnpark,
    Bug::DropCallerUnpark,
    Bug::ClearJobBeforeBarrier,
    Bug::PublishBeforeJobWrite,
    Bug::SkipRemainingStore,
];

/// Dispatcher program counter (one epoch of `run_dyn`, then `Drop`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum CallerPc {
    WriteJob,
    StoreRemaining,
    Publish,
    /// Unparking participant `k + 1` (field is `k`).
    Unpark,
    RunOwnPart,
    BarrierRead,
    BarrierParked,
    ClearJob,
    SwapPoison,
    ShutStore,
    ShutPublish,
    ShutUnpark,
    Join,
    Done,
}

/// Worker program counter (`worker_loop`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum WorkerPc {
    LoadEpoch,
    /// Epoch unchanged: the pre-park shutdown check.
    CheckShutSpin,
    Park,
    /// New epoch observed: the post-wake shutdown check.
    CheckShutNew,
    ReadJob,
    Exec,
    FetchSub,
    UnparkCaller,
    Done,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Caller {
    pc: CallerPc,
    /// Epoch being dispatched (0-based).
    epoch: usize,
    /// Unpark loop counter.
    k: usize,
    /// `poisoned` value observed by each epoch's post-barrier swap.
    observed: Vec<bool>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Worker {
    pc: WorkerPc,
    /// Last packed epoch this worker consumed (`seen` in the real loop).
    seen: u64,
    /// The packed word the current wake observed.
    packed: u64,
    /// Whether this worker's `fetch_sub` closed the barrier.
    was_last: bool,
}

/// The modeled shared memory (the real pool's `Shared`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct SharedMem {
    /// Packed `(generation << PARTS_BITS) | parts`.
    epoch: u64,
    remaining: usize,
    /// The job slot, modeled as "the epoch index this job belongs to".
    job: Option<usize>,
    shutdown: bool,
    poisoned: bool,
}

/// One interleaving state: all thread frames + shared memory + park
/// tokens + the execution ledger the properties are checked against.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct State {
    caller: Caller,
    workers: Vec<Worker>,
    shared: SharedMem,
    caller_token: bool,
    worker_tokens: Vec<bool>,
    /// `executed[epoch][part]` run counts.
    executed: Vec<Vec<u8>>,
}

/// Result of stepping one thread.
pub enum Step {
    /// The op ran; here is the next state and a trace label.
    Next(State, String),
    /// The op exposed a property violation.
    Violation(String),
}

impl State {
    pub fn initial(cfg: &Config) -> State {
        State {
            caller: Caller { pc: CallerPc::WriteJob, epoch: 0, k: 0, observed: Vec::new() },
            workers: (0..cfg.workers)
                .map(|_| Worker { pc: WorkerPc::LoadEpoch, seen: 0, packed: 0, was_last: false })
                .collect(),
            shared: SharedMem {
                epoch: 0,
                remaining: 0,
                job: None,
                shutdown: false,
                poisoned: false,
            },
            caller_token: false,
            worker_tokens: vec![false; cfg.workers],
            executed: vec![vec![0; cfg.parts]; cfg.epochs],
        }
    }

    /// Thread ids that can take a step: 0 is the caller, `i + 1` is
    /// worker `i`. Parked threads without a token (and a joining caller
    /// with live workers) are blocked.
    pub fn runnable(&self) -> Vec<usize> {
        let mut out = Vec::new();
        match self.caller.pc {
            CallerPc::Done => {}
            CallerPc::BarrierParked => {
                if self.caller_token {
                    out.push(0);
                }
            }
            CallerPc::Join => {
                if self.workers.iter().all(|w| w.pc == WorkerPc::Done) {
                    out.push(0);
                }
            }
            _ => out.push(0),
        }
        for (i, w) in self.workers.iter().enumerate() {
            let blocked = w.pc == WorkerPc::Done
                || (w.pc == WorkerPc::Park && !self.worker_tokens[i]);
            if !blocked {
                out.push(i + 1);
            }
        }
        out
    }

    /// All threads done: a maximal run to check final properties on.
    pub fn is_final(&self) -> bool {
        self.caller.pc == CallerPc::Done && self.workers.iter().all(|w| w.pc == WorkerPc::Done)
    }

    /// One shared-memory op of thread `tid`.
    pub fn step(&self, tid: usize, cfg: &Config) -> Step {
        if tid == 0 {
            self.step_caller(cfg)
        } else {
            self.step_worker(tid - 1, cfg)
        }
    }

    fn step_caller(&self, cfg: &Config) -> Step {
        let mut st = self.clone();
        let e = st.caller.epoch;
        let bug = cfg.bug;
        let label = match st.caller.pc {
            CallerPc::WriteJob => {
                if bug == Some(Bug::PublishBeforeJobWrite) {
                    // Mutation: epoch bump first; the job write lands one
                    // step later, racing the workers it just woke.
                    st.publish(cfg.parts);
                    st.caller.pc = CallerPc::StoreRemaining;
                    format!("caller: publish epoch {e} BEFORE job write (bug)")
                } else {
                    st.shared.job = Some(e);
                    st.caller.pc = CallerPc::StoreRemaining;
                    format!("caller: job = epoch {e}")
                }
            }
            CallerPc::StoreRemaining => {
                if bug == Some(Bug::PublishBeforeJobWrite) {
                    st.shared.job = Some(e);
                    st.shared.remaining = cfg.parts - 1;
                    st.caller.pc = CallerPc::Unpark;
                    st.caller.k = 0;
                    "caller: late job write (bug)".to_string()
                } else {
                    if bug != Some(Bug::SkipRemainingStore) {
                        st.shared.remaining = cfg.parts - 1;
                    }
                    st.caller.pc = CallerPc::Publish;
                    format!("caller: remaining = {}", st.shared.remaining)
                }
            }
            CallerPc::Publish => {
                st.publish(cfg.parts);
                st.caller.pc = CallerPc::Unpark;
                st.caller.k = 0;
                format!("caller: publish epoch {e} (parts {})", cfg.parts)
            }
            CallerPc::Unpark => {
                if st.caller.k >= cfg.parts - 1 {
                    st.caller.pc = CallerPc::RunOwnPart;
                    "caller: all participants unparked".to_string()
                } else {
                    let k = st.caller.k;
                    st.caller.k += 1;
                    if bug == Some(Bug::DropCallerUnpark) {
                        format!("caller: unpark worker {} DROPPED (bug)", k + 1)
                    } else {
                        st.worker_tokens[k] = true;
                        format!("caller: unpark worker {}", k + 1)
                    }
                }
            }
            CallerPc::RunOwnPart => {
                if let Err(v) = st.record_exec(e, 0) {
                    return Step::Violation(v);
                }
                st.caller.pc = if bug == Some(Bug::ClearJobBeforeBarrier) {
                    CallerPc::ClearJob
                } else {
                    CallerPc::BarrierRead
                };
                let panicked = cfg.panic == Some((e, 0));
                format!(
                    "caller: run part 0 of epoch {e}{}",
                    if panicked { " (panics, contained)" } else { "" }
                )
            }
            CallerPc::BarrierRead => {
                if st.shared.remaining == 0 {
                    st.caller.pc = if bug == Some(Bug::ClearJobBeforeBarrier) {
                        CallerPc::SwapPoison
                    } else {
                        CallerPc::ClearJob
                    };
                    "caller: remaining == 0, barrier drained".to_string()
                } else {
                    st.caller.pc = CallerPc::BarrierParked;
                    format!("caller: remaining == {}, parking", st.shared.remaining)
                }
            }
            CallerPc::BarrierParked => {
                // Only runnable with a token; consume it and re-check.
                st.caller_token = false;
                st.caller.pc = CallerPc::BarrierRead;
                "caller: unparked, re-checking barrier".to_string()
            }
            CallerPc::ClearJob => {
                st.shared.job = None;
                st.caller.pc = if bug == Some(Bug::ClearJobBeforeBarrier) {
                    CallerPc::BarrierRead
                } else {
                    CallerPc::SwapPoison
                };
                if bug == Some(Bug::ClearJobBeforeBarrier) {
                    "caller: clear job BEFORE barrier (bug)".to_string()
                } else {
                    "caller: clear job".to_string()
                }
            }
            CallerPc::SwapPoison => {
                let observed = st.shared.poisoned;
                st.shared.poisoned = false;
                st.caller.observed.push(observed);
                if e + 1 < cfg.epochs {
                    st.caller = Caller {
                        pc: CallerPc::WriteJob,
                        epoch: e + 1,
                        k: 0,
                        observed: st.caller.observed,
                    };
                    format!("caller: observed poisoned = {observed}, next epoch")
                } else {
                    st.caller.pc = CallerPc::ShutStore;
                    format!("caller: observed poisoned = {observed}, shutting down")
                }
            }
            CallerPc::ShutStore => {
                st.shared.shutdown = true;
                st.caller.pc = CallerPc::ShutPublish;
                "caller: shutdown = true".to_string()
            }
            CallerPc::ShutPublish => {
                st.publish(0);
                st.caller.pc = CallerPc::ShutUnpark;
                st.caller.k = 0;
                "caller: publish shutdown epoch (parts 0)".to_string()
            }
            CallerPc::ShutUnpark => {
                if st.caller.k >= st.workers.len() {
                    st.caller.pc = CallerPc::Join;
                    "caller: all workers unparked for shutdown".to_string()
                } else {
                    let k = st.caller.k;
                    st.caller.k += 1;
                    st.worker_tokens[k] = true;
                    format!("caller: unpark worker {} for shutdown", k + 1)
                }
            }
            CallerPc::Join => {
                st.caller.pc = CallerPc::Done;
                "caller: joined all workers".to_string()
            }
            CallerPc::Done => unreachable!("done caller stepped"),
        };
        Step::Next(st, label)
    }

    fn step_worker(&self, i: usize, cfg: &Config) -> Step {
        let mut st = self.clone();
        let idx = i + 1; // worker_loop index: workers are parts 1..
        let w = st.workers[i].clone();
        let label = match w.pc {
            WorkerPc::LoadEpoch => {
                if st.shared.epoch != w.seen {
                    let packed = st.shared.epoch;
                    st.workers[i] = Worker {
                        pc: WorkerPc::CheckShutNew,
                        seen: packed,
                        packed,
                        was_last: w.was_last,
                    };
                    format!(
                        "worker {idx}: epoch load -> gen {} parts {}",
                        packed >> PARTS_BITS,
                        packed & PARTS_MASK
                    )
                } else {
                    st.workers[i].pc = WorkerPc::CheckShutSpin;
                    format!("worker {idx}: epoch load -> unchanged")
                }
            }
            WorkerPc::CheckShutSpin => {
                if st.shared.shutdown {
                    st.workers[i].pc = WorkerPc::Done;
                    format!("worker {idx}: shutdown observed, exiting")
                } else {
                    st.workers[i].pc = WorkerPc::Park;
                    format!("worker {idx}: no new epoch, parking")
                }
            }
            WorkerPc::Park => {
                // Only runnable with a token; consume it and re-load.
                st.worker_tokens[i] = false;
                st.workers[i].pc = WorkerPc::LoadEpoch;
                format!("worker {idx}: unparked")
            }
            WorkerPc::CheckShutNew => {
                if st.shared.shutdown {
                    st.workers[i].pc = WorkerPc::Done;
                    format!("worker {idx}: shutdown observed, exiting")
                } else if idx >= (w.packed & PARTS_MASK) as usize {
                    st.workers[i].pc = WorkerPc::LoadEpoch;
                    format!("worker {idx}: non-participant, back to waiting")
                } else {
                    st.workers[i].pc = WorkerPc::ReadJob;
                    format!("worker {idx}: participating")
                }
            }
            WorkerPc::ReadJob => {
                // Generations are 1-based (publish pre-increments), so
                // generation g carries the job of epoch index g - 1.
                let gen = (w.packed >> PARTS_BITS) as usize;
                if st.shared.job != Some(gen - 1) {
                    return Step::Violation(format!(
                        "worker {idx} read job slot {:?} while executing epoch \
                         generation {gen} (expected the epoch-{} job)",
                        st.shared.job,
                        gen - 1
                    ));
                }
                st.workers[i].pc = WorkerPc::Exec;
                format!("worker {idx}: job read ok (epoch {})", gen - 1)
            }
            WorkerPc::Exec => {
                let e = (w.packed >> PARTS_BITS) as usize - 1;
                if let Err(v) = st.record_exec(e, idx) {
                    return Step::Violation(v);
                }
                let panicked = cfg.panic == Some((e, idx));
                if panicked {
                    st.shared.poisoned = true;
                }
                st.workers[i].pc = WorkerPc::FetchSub;
                format!(
                    "worker {idx}: run part {idx} of epoch {e}{}",
                    if panicked { " (panics -> poisoned)" } else { "" }
                )
            }
            WorkerPc::FetchSub => {
                if st.shared.remaining == 0 {
                    return Step::Violation(format!(
                        "worker {idx}: `remaining` underflow (fetch_sub at 0)"
                    ));
                }
                let was = st.shared.remaining;
                st.shared.remaining -= 1;
                st.workers[i].pc = WorkerPc::UnparkCaller;
                st.workers[i].was_last = was == 1;
                format!("worker {idx}: remaining {} -> {}", was, was - 1)
            }
            WorkerPc::UnparkCaller => {
                let closing = w.was_last;
                st.workers[i].pc = WorkerPc::LoadEpoch;
                st.workers[i].was_last = false;
                if closing {
                    if cfg.bug == Some(Bug::DropWorkerUnpark) {
                        format!("worker {idx}: last out — unpark caller DROPPED (bug)")
                    } else {
                        st.caller_token = true;
                        format!("worker {idx}: last out, unpark caller")
                    }
                } else {
                    format!("worker {idx}: not last, no unpark")
                }
            }
            WorkerPc::Done => unreachable!("done worker stepped"),
        };
        Step::Next(st, label)
    }

    /// Check the end-state properties of a maximal run.
    pub fn check_final(&self, cfg: &Config) -> Result<(), String> {
        for (e, parts) in self.executed.iter().enumerate() {
            for (p, &count) in parts.iter().enumerate() {
                if count != 1 {
                    return Err(format!("part {p} of epoch {e} executed {count} times"));
                }
            }
        }
        for e in 0..cfg.epochs {
            let want = matches!(cfg.panic, Some((pe, pp)) if pe == e && pp >= 1);
            let got = self.caller.observed.get(e).copied();
            if got != Some(want) {
                return Err(format!(
                    "epoch {e}: dispatcher observed poisoned = {got:?}, expected {want}"
                ));
            }
        }
        Ok(())
    }

    /// Thread snapshot for deadlock reports.
    pub fn describe_threads(&self) -> String {
        let workers: Vec<String> =
            self.workers.iter().map(|w| format!("{:?}", w.pc)).collect();
        format!("caller {:?}, workers [{}]", self.caller.pc, workers.join(", "))
    }

    fn publish(&mut self, parts: usize) {
        let generation = self.shared.epoch >> PARTS_BITS;
        self.shared.epoch = ((generation + 1) << PARTS_BITS) | parts as u64;
    }

    fn record_exec(&mut self, epoch: usize, part: usize) -> Result<(), String> {
        self.executed[epoch][part] += 1;
        if self.executed[epoch][part] > 1 {
            return Err(format!("part {part} of epoch {epoch} executed twice"));
        }
        Ok(())
    }
}

/// Immutable trace spine: DFS shares prefixes instead of cloning label
/// vectors per state.
#[derive(Debug)]
pub struct TraceNode {
    pub label: String,
    pub prev: Option<Rc<TraceNode>>,
}

/// Materialize a trace (oldest step first).
pub fn trace_to_vec(tail: &Option<Rc<TraceNode>>) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = tail.clone();
    while let Some(node) = cur {
        out.push(node.label.clone());
        cur = node.prev.clone();
    }
    out.reverse();
    out
}
