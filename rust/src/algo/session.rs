//! Workspace-centric solver surface: reusable sessions, observers, batch solve.
//!
//! The paper's thesis is that UOT is memory-bound, so the public API must not
//! reintroduce the matrix traffic the kernels removed. The old `algo::solve`
//! free function cloned the plan on entry and re-cloned it into a `prev`
//! snapshot every check interval just to compute `plan_delta` — 1–2 extra
//! M·N passes per check — and re-allocated every scratch buffer per call.
//!
//! This module replaces that with three layers:
//!
//! * [`Workspace`] — owns every scratch buffer one solve needs (column
//!   factors, reciprocal factors for in-sweep delta tracking, row sums,
//!   per-thread `NextSum_col` blocks, a marginal-error scratch). Build once,
//!   reuse forever.
//! * [`Solver`] — object-safe trait over the three kernels (POT, COFFEE,
//!   MAP-UOT). `iterate` advances one iteration allocation-free out of a
//!   workspace; `iterate_tracked` additionally folds the `plan_delta`
//!   computation *into the sweep* (no `prev` snapshot, no extra pass).
//! * [`SolverSession`] — the service-facing API:
//!   `SolverSession::builder(kind).threads(t).stop(rule).observer(cb).build(&p)`.
//!   Repeated [`SolverSession::solve`] calls on same-shape problems perform
//!   **zero heap allocations after warmup** (see the allocation contract on
//!   [`Workspace`]), fire a [`ConvergenceObserver`] on every check boundary,
//!   and can be cancelled mid-solve ([`crate::error::Error::Canceled`]).
//!
//! Incremental delta tracking: one iteration maps each element
//! `v0 → v0 · Factor_col[j] · Factor_row[i]`. Inside the fused sweep the
//! post-column-rescale value `v1 = v0 · Factor_col[j]` is in registers, so
//! `|Δ| = |v1 · Factor_row[i] − v1 / Factor_col[j]|` needs only the
//! precomputed reciprocal factors ([`crate::algo::scaling::recip_into`]) —
//! no snapshot of the previous plan, only a handful of extra ALU ops per
//! element, which a memory-bound kernel absorbs for free. The session sums
//! the per-iteration maxima across each check interval, so the reported
//! `delta` **upper-bounds** the old `plan_delta(prev, cur)` snapshot diff
//! (triangle inequality); a `delta_tol` stop can only fire later than it
//! would have under the old criterion, never earlier.
//!
//! # Threading model
//!
//! With `threads == 1` every iteration runs serially on the calling thread.
//! With `threads > 1` the workspace carries a parallel execution engine,
//! selected by [`ParallelBackend`]:
//!
//! * **`Pool`** (default) — a persistent [`ThreadPool`] owned by the
//!   workspace (or shared, see below). Its `threads - 1` workers are
//!   spawned **once** at build time, parked between dispatches, and
//!   coordinated by an epoch barrier (atomic generation counter +
//!   park/unpark), so an iteration performs **zero thread spawns and zero
//!   heap allocations** — the same contract as the serial path, extended
//!   to the threaded one (asserted in `rust/tests/alloc_free.rs`). The
//!   per-thread `NextSum_col` partials live in one cache-line-padded
//!   [`AccArena`] and the final reduction is column-parallel on the pool.
//!   An [`AffinityHint`] optionally pins workers to cores.
//! * **`SpawnPerIter`** — the legacy `thread::scope` create/join per
//!   sweep group, kept for head-to-head benchmarking (`fig12`).
//!
//! Both backends bit-match each other (`rust/tests/prop_pool.rs`).
//!
//! **Pool lifetime and sharing.** The pool lives as long as its
//! `Arc<ThreadPool>`: a session built with [`SessionBuilder::threads`]
//! owns one pool for its whole life, so [`SolverSession::solve_batch`]
//! reuses one pool across the entire batch, and each coordinator worker
//! (one session per OS thread — see [`crate::coordinator::Service`])
//! reuses one pool across every request it serves. To share a pool across
//! sessions explicitly, build it once and pass the `Arc` to each builder
//! via [`SessionBuilder::pool`]; `ThreadPool::run` serializes concurrent
//! dispatches internally, so sharing trades parallelism for memory, never
//! correctness.
//!
//! # Kernel selection and tiling
//!
//! The MAP-UOT hot path runs on a kernel backend ([`crate::algo::kernels`])
//! resolved **once at build time** into the workspace's [`KernelPolicy`]:
//!
//! * [`SessionBuilder::kernel`] picks the backend —
//!   `auto` (default: runtime CPUID dispatch, AVX2+FMA where detected),
//!   `scalar` (portable reference), `unrolled` (16-lane auto-vectorized),
//!   or `avx2` (hand-written intrinsics; falls back to `unrolled` on hosts
//!   without the features, so no `target-cpu` flag is ever needed for
//!   correctness).
//! * [`SessionBuilder::tile`] controls the cache-aware column tiling of
//!   the fused sweep — `auto` (panel width from the detected L1d, row
//!   chunks from L2, via `util::cputopo`), `off`, `tune` (one-shot
//!   measured auto-tune at build), or an explicit panel width. Tiling
//!   composes with the row partition: each thread tiles its own row
//!   block, with `Sum_row` carried across panels in workspace scratch.
//! * Past the LLC threshold the AVX2 backend switches the plan writes of
//!   Computations III/IV to non-temporal stores (`_mm256_stream_ps`),
//!   cutting per-iteration DRAM traffic from ~3 matrix transfers
//!   (read + RFO + writeback) to the Roofline-minimum 2; below it,
//!   regular stores keep the plan cache-resident across iterations.
//!
//! Environment overrides `MAP_UOT_KERNEL` / `MAP_UOT_TILE` apply whenever
//! the builder is left on `auto` (that is how CI forces the scalar
//! fallback). All backends × tile settings agree within 1e-5 relative and
//! are property-tested in `rust/tests/prop_kernels.rs`; POT and COFFEE
//! keep their fixed comparator loops, so cross-solver speedup figures are
//! like-for-like only under `--kernel unrolled` (see EXPERIMENTS.md).
//!
//! # Sparse problems
//!
//! The same session drives the fused **CSR** sweep (paper §6 future work)
//! through [`SolverSession::solve_sparse`]: a [`SparseProblem`] (CSR plan
//! + marginals) solved with the session's stop rule, check cadence,
//! observer and execution engine — serial, scope, or the *same* persistent
//! pool the dense path uses. Build with [`SessionBuilder::build_sparse`]
//! when the workload is sparse-first (the dense buffers stay at a 1×1
//! placeholder), or call `solve_sparse` on any MAP-UOT session. Row blocks
//! are **nnz-balanced** ([`crate::algo::sparse::NnzPartition`] — CSR row
//! lengths are skewed, so an even-rows split would leave stragglers), the
//! per-thread `NextSum_col` partials reuse the padded [`AccArena`], and
//! scope/pool engines are bit-identical for any fixed partition
//! (`rust/tests/prop_sparse.rs`). The allocation contract carries over:
//! after the first solve on a structure, same-structure solves are
//! allocation-free end to end (`rust/tests/alloc_free.rs`). The sparse
//! path runs the unrolled CSR kernel primitives — the dense
//! kernel/tiling policy does not apply to it.
//!
//! # Materialization-free (matfree) problems
//!
//! Geometric point-cloud problems ([`GeomProblem`]: clouds `x: m×d`,
//! `y: n×d`, cost kind, bandwidth ε) solve without ever storing the plan
//! ([`SolverSession::solve_matfree`] / [`SessionBuilder::build_matfree`]):
//! the session carries only the scaling vectors `u, v` of
//! `plan = diag(u)·A·diag(v)` plus O(m + n) scratch, regenerating kernel
//! entries `A_ij = exp(-c(x_i, y_j)/ε)` on the fly inside the fused sweep
//! (see [`crate::algo::matfree`] for the sweep derivation). Backend
//! selection guidance:
//!
//! * **dense** — the plan fits comfortably in memory and is re-used
//!   across iterations from DRAM at streaming speed;
//! * **sparse** — the plan is mostly zero (nnz ≪ M·N);
//! * **matfree** — the problem *is geometric* (points + an entropic
//!   kernel), and either the plan cannot be allocated at all or kernel
//!   regeneration (one SIMD exp per cell) is cheaper than re-streaming
//!   8 bytes per cell from DRAM. Marginal errors come from the carried
//!   `u, v` sums, so convergence checks are O(m + n);
//! * **oned** — the geometry is one-dimensional (`d == 1`) with the
//!   separable `|x − y|` cost: the Laplace kernel factors over sorted
//!   supports, so each sweep costs O(m + n) *total* — not per row — and
//!   the answer includes a sparse monotone [`TransportList`]. Strictly
//!   dominates matfree on eligible problems at every shape.
//!
//! The full routing decision table (`coordinator::router::classify_geom`
//! applies the geometric rows automatically for service requests):
//!
//! | problem                                   | backend  | per-sweep cost |
//! |-------------------------------------------|----------|----------------|
//! | dense plan, general cost                  | dense    | O(m·n) stream  |
//! | mostly-zero plan                          | sparse   | O(nnz)         |
//! | geometric, `d > 1` or Gaussian kernel     | matfree  | O(m·n) exp     |
//! | geometric, `d == 1`, `\|x − y\|` cost     | **oned** | O(m + n) exact |
//! | geometric, one varying axis (within tol)  | **oned** | O(m + n) exact |
//!
//! Ineligible geometry handed to [`SolverSession::solve_oned`] fails with
//! a typed [`Error::InvalidProblem`] naming the fallback — nothing is
//! silently rerouted at the session layer (the service's `oned = auto`
//! mode is where graceful fallback lives).
//!
//! The matfree path shares the session's stop rule, check cadence,
//! observer, cancellation and execution engine (serial / scope / the same
//! persistent pool), and the **kernel policy does apply**: the generation
//! primitive ([`crate::algo::kernels::Kernel::exp_scale_and_sum`]) runs
//! scalar (libm), unrolled (`util::simd::fast_exp`) or AVX2, and the tile
//! width panels the cost fill. Results: [`SolverSession::matfree_scaling`]
//! (the O(m + n) answer), [`SolverSession::matfree_plan_row`] /
//! [`SolverSession::matfree_materialize`] for on-demand dense output.
//! Same allocation contract: after the first solve on a shape,
//! same-shape matfree solves are allocation-free end to end — and no
//! O(m·n) allocation ever happens on the solve path, proven at
//! m = n = 16384 in `rust/tests/alloc_free.rs`. Serial/scope/pool matfree
//! iterations are bit-identical for any fixed partition
//! (`rust/tests/prop_matfree.rs`).
//!
//! # Exact 1D problems
//!
//! A `d == 1` [`GeomProblem`] with [`CostKind::Euclidean`](crate::algo::CostKind)
//! cost solves on the exact near-linear path
//! ([`SolverSession::solve_oned`] / [`SessionBuilder::build_oned`]): the
//! same MAP-UOT scaling iteration — same fixed point, same stop rule,
//! observer and cancellation — but with `A·v` / `Aᵀ·u` computed exactly
//! in O(m + n) by the sorted-support sweeps of [`crate::algo::oned`]
//! instead of m·n kernel generation. Results:
//! [`SolverSession::oned_scaling`] (the scaling vectors),
//! [`SolverSession::oned_transport`] (the sparse monotone coupling of the
//! converged transported marginals), and
//! [`SolverSession::oned_materialize`] for on-demand dense output. TI
//! sweeps compose; the ε ladder does not (a near-linear solve has no
//! expensive iterations to amortize — typed error). Warm starting
//! interoperates with matfree **by design**: a 1D solve stores its
//! scalings under the same fingerprint a matfree solve of the identical
//! geometry would, so either path seeds the other. Same allocation
//! contract, proven at m = n = 1_000_000 in `rust/tests/alloc_free.rs`.
//!
//! # Iteration-count accelerators
//!
//! Three composable knobs attack the *number* of sweeps rather than the
//! cost of one (every sweep already runs at the Roofline minimum, so the
//! remaining perf lever is iterations-to-tolerance):
//!
//! * **Warm starting** ([`SessionBuilder::warm`]) — a per-session LRU
//!   cache ([`crate::algo::warmstart::WarmCache`]) of converged diagonal
//!   scalings keyed by a problem fingerprint (shape, solve path, solver,
//!   quantized `fi`/ε, coarse marginal sketch). A solve on a problem near
//!   a cached one starts from the cached scaling family instead of the
//!   raw input plan — exact, because every iterate of the damped
//!   alternating rescaling stays in `diag(u)·plan0·diag(v)` form, so
//!   re-seeding only moves *along* the iteration's own trajectory space.
//!   Entries store back on convergence; the steady state is
//!   allocation-free (asserted in `rust/tests/alloc_free.rs`).
//! * **Translation-invariant sweeps** ([`SessionBuilder::ti`], after
//!   Séjourné–Vialard–Peyré, arXiv:2201.00730) — a pre-sweep O(n)
//!   rescale of the carried column sums
//!   ([`crate::algo::scaling::ti_rescale`]) that corrects the global-mass
//!   mode with effective exponent 1 instead of letting the damped sweeps
//!   contract it by `(1 − fi)²` per iteration. The correction targets the
//!   plain iteration's own stationary mass, so TI solves converge to the
//!   same plan (property-pinned at 1e-5 in
//!   `rust/tests/prop_warmstart.rs`), just in fewer iterations. MAP-UOT
//!   only; dispatcher-side, so serial/scope/pool stay bit-identical.
//! * **ε-scheduling** ([`SessionBuilder::eps_schedule`], matfree only) —
//!   a geometric coarse-to-fine bandwidth ladder (cf. ε-scaling,
//!   arXiv:2002.03293): solve a few cheap rungs at large ε, carry the
//!   dual potentials down via [`crate::algo::matfree::carry_potentials`],
//!   and finish at the target ε already near the fixed point. A warm-start
//!   hit skips the ladder (the cache seed is better than a coarse solve).
//!
//! [`Deadline`] turns any of these into an *anytime* solve: it is a
//! [`ConvergenceObserver`] that cancels at a wall-clock budget, and the
//! returned [`Error::Canceled`] carries the iterations completed.
//!
//! # Correctness tooling
//!
//! The allocation contract above and the pool's unsafe disjoint-split
//! arguments are enforced *statically* by the repo's own lint
//! (`cargo run -p uotlint`: SAFETY-comment coverage, a call-graph-aware
//! allocation ban — any fn reachable from a hot loop, not just the loop
//! body itself — panic-free service layers, lock-poison recovery,
//! spawn/intrinsic encapsulation), *exhaustively* for the pool's
//! park/unpark protocol by the interleaving checker
//! (`cargo run -p uotlint -- --model-check`, over
//! `algo::pool::model`), and *dynamically* by the Miri /
//! ThreadSanitizer / AddressSanitizer CI legs over
//! `rust/tests/miri_edges.rs` and the property suites.
//!
//! Marker vocabulary, for when a rule is right to ask but the site is
//! deliberate: `// uotlint: allow(alloc) — reason` above a fn or
//! allocation line grants an allocation exemption (fn-level markers
//! also cut the fn's outgoing call edges from the reachability walk);
//! `// uotlint: allow(panic) — reason` justifies a provably-infallible
//! `unwrap`/index in `coordinator/`, `config/` or `runtime/`. Every
//! marker is counted in the lint summary, so exemption drift is as
//! visible as violation drift. See `EXPERIMENTS.md` §Correctness
//! tooling for how to run each gate locally.
//!
//! # Observability
//!
//! Every solve path is instrumented with [`crate::util::telemetry`]
//! spans at check-burst granularity: a `solve` envelope per call,
//! `kernel_generate` around per-solve state derivation (matfree/oned
//! seeding, support sort), `fused_sweep` around each `check_every`-burst
//! and `convergence_check` around each boundary error evaluation. The
//! overhead contract (see the telemetry module docs): with tracing off
//! each site costs one relaxed atomic load; with tracing on, recording
//! is allocation-free after a thread's first span, so the session's
//! allocation contract holds under tracing too (asserted in
//! `rust/tests/alloc_free_trace.rs`).
//!
//! Capture a trace: [`SessionBuilder::trace`] names an export path and
//! turns recording on; after solving, [`SolverSession::export_trace`]
//! writes a chrome://tracing JSON (open in `ui.perfetto.dev`) or a JSONL
//! event log for a `.jsonl` path:
//!
//! ```no_run
//! use map_uot::algo::{Problem, SolverKind, SolverSession};
//! let p = Problem::random(256, 256, 0.7, 1);
//! let mut s = SolverSession::builder(SolverKind::MapUot)
//!     .trace("solve.trace.json")
//!     .build(&p);
//! s.solve(&p).unwrap();
//! s.export_trace().unwrap();
//! ```
//!
//! The CLI exposes the same flow as `solve --trace <path>` (plus a
//! `roofline:` report line from [`crate::util::telemetry::Roofline`])
//! and `stats` for the service's machine-readable metrics JSON.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::algo::convergence::{self, StopRule};
use crate::algo::kernels::{KernelKind, KernelPolicy, TileSpec};
use crate::algo::matfree::{self, GeomProblem, MatfreeWorkspace};
use crate::algo::oned::{self, OnedWorkspace, TransportList};
use crate::algo::pool::{AccArena, AffinityHint, PaddedSlots, ParallelBackend, ThreadPool};
use crate::algo::problem::Problem;
use crate::algo::scaling;
use crate::algo::sparse::{CsrMatrix, SparseProblem, SparseWorkspace};
use crate::algo::warmstart::{self, WarmCache};
use crate::algo::{coffee, mapuot, parallel, pot, SolveReport, SolverKind};
use crate::error::{Error, Result};
use crate::util::telemetry::{self, Phase};
use crate::util::{Matrix, Timer};

/// Scratch buffers for one solver shape, reused across iterations and solves.
///
/// # Allocation contract
///
/// The hot path is allocation-free; only explicit (re)sizing allocates:
///
/// * **May allocate:** [`Workspace::new`], [`Workspace::ensure_shape`] with a
///   shape larger than any seen before, [`SessionBuilder::build`],
///   [`SolverSession::solve_cloned`] / [`SolverSession::solve_batch`] (they
///   clone the result plan out), and the first [`SolverSession::solve`] on a
///   new shape.
/// * **Must not allocate:** [`Solver::iterate`] / [`Solver::iterate_tracked`]
///   on the serial path (`threads == 1`) **and** on the pool backend
///   (`threads > 1`, [`ParallelBackend::Pool`] — the default), and the
///   whole of [`SolverSession::solve`] for a same-shape problem after the
///   first solve (asserted by the counting-allocator test
///   `rust/tests/alloc_free.rs`).
/// * **Spawn-backend caveat:** with [`ParallelBackend::SpawnPerIter`] the
///   workspace buffers are still reused, but `std::thread::scope` itself
///   allocates when spawning OS threads each iteration; that legacy
///   backend exists only for head-to-head benchmarking.
#[derive(Debug)]
pub struct Workspace {
    rows: usize,
    cols: usize,
    threads: usize,
    backend: ParallelBackend,
    /// Column rescaling factors (`Factor_col`), length N.
    fcol: Vec<f32>,
    /// Reciprocals of `fcol` (zero-guarded) for in-sweep delta tracking.
    inv_fcol: Vec<f32>,
    /// Row sums for the phase-split kernels (POT sweep 3, COFFEE phase A).
    rowsum: Vec<f32>,
    /// Scratch column sums for the marginal-error check.
    err_scratch: Vec<f32>,
    /// Per-thread `NextSum_col` partials (Algorithm 1 lines 5–15) as one
    /// cache-line-padded arena.
    acc: AccArena,
    /// Per-thread tracked-delta maxima, one cache line each.
    delta_slots: PaddedSlots,
    /// The persistent execution engine (pool backend, `threads > 1`).
    pool: Option<Arc<ThreadPool>>,
    /// Resolved kernel backend + tiling/streaming policy (MAP-UOT path).
    policy: KernelPolicy,
}

impl Workspace {
    /// Workspace for `m × n` problems solved with `threads` workers on the
    /// default pool backend (workers spawned here, once).
    pub fn new(m: usize, n: usize, threads: usize) -> Self {
        Self::with_backend(m, n, threads, ParallelBackend::Pool, AffinityHint::None)
    }

    /// Workspace with an explicit parallel backend and affinity hint.
    pub fn with_backend(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        affinity: AffinityHint,
    ) -> Self {
        let policy = KernelPolicy::for_shape(KernelKind::Auto, TileSpec::Auto, m, n);
        Self::with_backend_policy(m, n, threads, backend, affinity, policy)
    }

    /// [`Workspace::with_backend`] with an already-resolved kernel/tiling
    /// policy (the session builder resolves exactly once and passes it
    /// here, so `tune` never measures twice per build).
    pub fn with_backend_policy(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        affinity: AffinityHint,
        policy: KernelPolicy,
    ) -> Self {
        let threads = threads.max(1);
        let pool = (threads > 1 && backend == ParallelBackend::Pool)
            .then(|| Arc::new(ThreadPool::with_affinity(threads, affinity)));
        Self::assemble(m, n, threads, backend, pool, policy)
    }

    /// Workspace sharing an existing pool (its thread count wins). The
    /// pool serializes concurrent dispatches, so any number of workspaces
    /// may share one `Arc`.
    pub fn with_pool(m: usize, n: usize, pool: Arc<ThreadPool>) -> Self {
        let policy = KernelPolicy::for_shape(KernelKind::Auto, TileSpec::Auto, m, n);
        Self::with_pool_policy(m, n, pool, policy)
    }

    /// [`Workspace::with_pool`] with an already-resolved policy.
    pub fn with_pool_policy(
        m: usize,
        n: usize,
        pool: Arc<ThreadPool>,
        policy: KernelPolicy,
    ) -> Self {
        let threads = pool.threads();
        Self::assemble(m, n, threads, ParallelBackend::Pool, Some(pool), policy)
    }

    fn assemble(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        pool: Option<Arc<ThreadPool>>,
        policy: KernelPolicy,
    ) -> Self {
        Self {
            rows: m,
            cols: n,
            threads,
            backend,
            fcol: vec![0f32; n],
            inv_fcol: vec![0f32; n],
            rowsum: vec![0f32; m],
            err_scratch: vec![0f32; n],
            acc: AccArena::padded(threads, n),
            delta_slots: PaddedSlots::new(threads),
            pool,
            policy,
        }
    }

    /// The resolved kernel/tiling policy driving the MAP-UOT hot path.
    pub fn policy(&self) -> KernelPolicy {
        self.policy
    }

    /// Replace the kernel/tiling policy (benches, property tests, and
    /// [`SessionBuilder::build`] when the builder carries explicit
    /// `kernel`/`tile` choices).
    pub fn set_policy(&mut self, policy: KernelPolicy) {
        self.policy = policy;
    }

    /// Current `(rows, cols)` shape.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Worker threads this workspace is provisioned for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Which parallel execution engine drives `threads > 1` iterations.
    pub fn backend(&self) -> ParallelBackend {
        self.backend
    }

    /// The persistent pool, when the pool backend is active — share it
    /// with other workspaces via [`Workspace::with_pool`].
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Resize for a new shape. No-op (and allocation-free) when the shape is
    /// unchanged; growing past any previously seen size reallocates.
    pub fn ensure_shape(&mut self, m: usize, n: usize) {
        if self.rows == m && self.cols == n {
            return;
        }
        self.rows = m;
        self.cols = n;
        self.fcol.resize(n, 0.0);
        self.inv_fcol.resize(n, 0.0);
        self.rowsum.resize(m, 0.0);
        self.err_scratch.resize(n, 0.0);
        self.acc.ensure_cols(n);
    }

    /// Marginal L-inf error of `plan` using workspace scratch (no allocation).
    pub fn marginal_error(&mut self, plan: &Matrix, rpd: &[f32], cpd: &[f32]) -> f32 {
        convergence::marginal_error_with(plan, rpd, cpd, &mut self.err_scratch)
    }
}

/// Object-safe interface over the three iteration kernels.
///
/// `plan` and `colsum` are the algorithm state (carried across iterations;
/// seed `colsum` with the plan's column sums); the [`Workspace`] supplies
/// every scratch buffer, so neither method allocates on the serial path.
pub trait Solver: Sync {
    /// Which kernel this is.
    fn kind(&self) -> SolverKind;

    /// Advance one iteration in place.
    fn iterate(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    );

    /// Advance one iteration and return the max element-wise change of the
    /// plan (`plan_delta` of this single iteration), tracked inside the
    /// sweep — no snapshot, no extra pass over the matrix.
    fn iterate_tracked(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) -> f32;
}

/// The POT / NumPy 4-pass baseline as a [`Solver`].
pub struct PotSolver;
/// The COFFEE phase-fused 2-pass comparator as a [`Solver`].
pub struct CoffeeSolver;
/// The MAP-UOT fused single-pass kernel as a [`Solver`].
pub struct MapUotSolver;

impl Solver for PotSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Pot
    }

    fn iterate(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) {
        if ws.threads <= 1 {
            pot::iterate_into(plan, colsum, rpd, cpd, fi, &mut ws.fcol, &mut ws.rowsum);
        } else if let Some(pool) = &ws.pool {
            parallel::pot_iterate_pool(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            );
        } else {
            parallel::pot_iterate_into(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            );
        }
    }

    fn iterate_tracked(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) -> f32 {
        if ws.threads <= 1 {
            pot::iterate_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
            )
        } else if let Some(pool) = &ws.pool {
            parallel::pot_iterate_pool_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &mut ws.delta_slots,
            )
        } else {
            parallel::pot_iterate_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            )
        }
    }
}

impl Solver for CoffeeSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Coffee
    }

    fn iterate(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) {
        if ws.threads <= 1 {
            coffee::iterate_into(plan, colsum, rpd, cpd, fi, &mut ws.fcol, &mut ws.rowsum);
        } else if let Some(pool) = &ws.pool {
            parallel::coffee_iterate_pool(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            );
        } else {
            parallel::coffee_iterate_into(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            );
        }
    }

    fn iterate_tracked(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) -> f32 {
        if ws.threads <= 1 {
            coffee::iterate_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
            )
        } else if let Some(pool) = &ws.pool {
            parallel::coffee_iterate_pool_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &mut ws.delta_slots,
            )
        } else {
            parallel::coffee_iterate_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
            )
        }
    }
}

impl Solver for MapUotSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::MapUot
    }

    fn iterate(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) {
        let policy = ws.policy;
        if ws.threads <= 1 {
            mapuot::iterate_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut ws.fcol,
                &mut ws.rowsum,
                &policy,
            );
        } else if let Some(pool) = &ws.pool {
            parallel::mapuot_iterate_pool_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &policy,
            );
        } else {
            parallel::mapuot_iterate_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &policy,
            );
        }
    }

    fn iterate_tracked(
        &self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
        ws: &mut Workspace,
    ) -> f32 {
        let policy = ws.policy;
        if ws.threads <= 1 {
            mapuot::iterate_tracked_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &policy,
            )
        } else if let Some(pool) = &ws.pool {
            parallel::mapuot_iterate_pool_tracked_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &mut ws.delta_slots,
                &policy,
            )
        } else {
            parallel::mapuot_iterate_tracked_policy(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                ws.threads,
                &mut ws.fcol,
                &mut ws.inv_fcol,
                &mut ws.rowsum,
                &mut ws.acc,
                &policy,
            )
        }
    }
}

/// The [`Solver`] implementation for `kind` (stateless, `'static`).
pub fn solver_for(kind: SolverKind) -> &'static dyn Solver {
    match kind {
        SolverKind::Pot => &PotSolver,
        SolverKind::Coffee => &CoffeeSolver,
        SolverKind::MapUot => &MapUotSolver,
    }
}

/// Snapshot handed to a [`ConvergenceObserver`] at each check boundary.
#[derive(Debug, Clone, Copy)]
pub struct CheckEvent {
    /// Iterations completed so far.
    pub iters: usize,
    /// Marginal L-inf error at this boundary.
    pub err: f32,
    /// In-sweep tracked plan motion over this check interval (sum of
    /// per-iteration max element changes; upper-bounds the snapshot diff).
    pub delta: f32,
}

/// What an observer wants the solve to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObserverAction {
    /// Keep iterating.
    Continue,
    /// Abort: the solve returns [`Error::Canceled`] within `check_every`
    /// iterations of the request.
    Cancel,
}

/// Per-check callback: convergence telemetry + cancellation.
///
/// Fires on **every** check boundary (every `check_every` iterations),
/// including the final one. Must not allocate if the session's
/// allocation-free contract is to hold end to end.
pub trait ConvergenceObserver: Send {
    /// Called at each check boundary with the latest metrics.
    fn on_check(&mut self, event: CheckEvent) -> ObserverAction;
}

impl<F: FnMut(CheckEvent) -> ObserverAction + Send> ConvergenceObserver for F {
    fn on_check(&mut self, event: CheckEvent) -> ObserverAction {
        self(event)
    }
}

/// Builder for [`SolverSession`] — see the module docs for the full flow.
pub struct SessionBuilder {
    kind: SolverKind,
    threads: usize,
    backend: ParallelBackend,
    affinity: AffinityHint,
    pool: Option<Arc<ThreadPool>>,
    kernel: KernelKind,
    tile: TileSpec,
    stop: StopRule,
    check_every: usize,
    observer: Option<Box<dyn ConvergenceObserver>>,
    warm: usize,
    ti: bool,
    eps_schedule: Option<(f32, usize)>,
    trace: Option<String>,
}

impl SessionBuilder {
    /// Worker threads (1 = serial path). Default 1. With the default
    /// [`ParallelBackend::Pool`], `build` spawns the workers once and every
    /// solve reuses them.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Parallel execution engine for `threads > 1`. Default
    /// [`ParallelBackend::Pool`].
    pub fn backend(mut self, backend: ParallelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Core-affinity hint for pool workers. Default [`AffinityHint::None`].
    pub fn affinity(mut self, affinity: AffinityHint) -> Self {
        self.affinity = affinity;
        self
    }

    /// Share an existing pool instead of spawning one (overrides
    /// [`SessionBuilder::threads`] with the pool's thread count and forces
    /// the pool backend).
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Kernel backend for the MAP-UOT hot path. Default
    /// [`KernelKind::Auto`] (runtime CPUID dispatch, honoring the
    /// `MAP_UOT_KERNEL` environment override).
    pub fn kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }

    /// Column-tiling policy for the fused sweep. Default
    /// [`TileSpec::Auto`] (cache-topology sizing, honoring the
    /// `MAP_UOT_TILE` environment override).
    pub fn tile(mut self, tile: TileSpec) -> Self {
        self.tile = tile;
        self
    }

    /// Stopping criteria. Default [`StopRule::default`].
    pub fn stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }

    /// Evaluate the stop rule (and fire the observer) every `k` iterations.
    /// Checks cost one extra sweep, so they are amortized. Default 8.
    pub fn check_every(mut self, k: usize) -> Self {
        self.check_every = k.max(1);
        self
    }

    /// Attach a per-check [`ConvergenceObserver`] (closure or struct).
    pub fn observer(mut self, observer: impl ConvergenceObserver + 'static) -> Self {
        self.observer = Some(Box::new(observer));
        self
    }

    /// Warm-start cache capacity (entries). `0` (the default) disables
    /// warm starting; `cap > 0` attaches a [`WarmCache`] holding up to
    /// `cap` converged scalings, LRU-evicted. See the module docs
    /// (*Iteration-count accelerators*) for the exactness argument.
    pub fn warm(mut self, cap: usize) -> Self {
        self.warm = cap;
        self
    }

    /// Enable translation-invariant sweeps
    /// ([`crate::algo::scaling::ti_rescale`]): a pre-sweep O(n) mass
    /// correction that removes the slowest-converging global mode.
    /// MAP-UOT only — other kinds fail at solve time with
    /// [`Error::InvalidProblem`]. Default off.
    pub fn ti(mut self, on: bool) -> Self {
        self.ti = on;
        self
    }

    /// ε-scheduling for matfree solves: a geometric ladder of `steps`
    /// coarse rungs from bandwidth `from` down to the problem's ε, duals
    /// carried between rungs. Matfree-only — dense/sparse solves fail with
    /// [`Error::InvalidProblem`], as does `from ≤ ε` or `steps == 0` (the
    /// ladder must actually descend). Default off.
    pub fn eps_schedule(mut self, from: f32, steps: usize) -> Self {
        self.eps_schedule = Some((from, steps));
        self
    }

    /// Record a span trace of every solve on this session and remember
    /// `path` as its export destination ([`SolverSession::export_trace`];
    /// chrome://tracing JSON, or JSONL events when the path ends in
    /// `.jsonl`). Turns the process-wide recorder on at build — see the
    /// module docs (*Observability*) for the overhead contract. Default
    /// off.
    pub fn trace(mut self, path: impl Into<String>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Build a session sized for `problem`'s shape. This is the warmup
    /// allocation (including the one-time pool spawn); subsequent
    /// same-shape solves are allocation-free.
    pub fn build(self, problem: &Problem) -> SolverSession {
        self.build_for_shape(problem.rows(), problem.cols())
    }

    /// Build a session for a **sparse** problem: the dense buffers are
    /// provisioned at a minimal 1×1 placeholder (they resize on the first
    /// dense [`SolverSession::solve`], if any), the persistent pool (when
    /// threaded) spawns here, and the CSR state — plan clone plus
    /// [`SparseWorkspace`] — is warmed up so the first
    /// [`SolverSession::solve_sparse`] on this structure is already
    /// allocation-free. Sparse solves require `SolverKind::MapUot`
    /// (enforced at solve time, with a typed error).
    pub fn build_sparse(self, problem: &SparseProblem) -> SolverSession {
        // The sparse sweep ignores the dense kernel policy; a `tune` tile
        // (explicit or via MAP_UOT_TILE) degrades to the topology width at
        // the 1×1 placeholder shape instead of measuring timer noise — see
        // the degenerate-shape guard in `KernelPolicy::for_shape`.
        let mut session = self.build_for_shape(1, 1);
        session.ensure_sparse(problem);
        session
    }

    /// Build a session for a **materialization-free** geometric problem:
    /// the dense buffers stay at a 1×1 placeholder, the persistent pool
    /// (when threaded) spawns here, and the matfree state — scaling
    /// vectors, carried marginal sums, [`MatfreeWorkspace`] — is sized so
    /// the first [`SolverSession::solve_matfree`] on this shape is already
    /// allocation-free. Nothing O(m·n) is ever allocated. Matfree solves
    /// require `SolverKind::MapUot` (enforced at solve time, with a typed
    /// error). A `tune` tile degrades to the topology width (the
    /// degenerate-shape guard in `KernelPolicy::for_shape`); every other
    /// kernel/tile choice applies to the generation sweep as-is.
    pub fn build_matfree(self, problem: &GeomProblem) -> SolverSession {
        let mut session = self.build_for_shape(1, 1);
        // Size the O(m + n) state only: solve_matfree re-derives the
        // scaling vectors and carried sums from the problem on every call
        // anyway, so seeding here would be a full (serial) m×n kernel
        // generation pass thrown away by the first solve.
        session.size_matfree(problem);
        session
    }

    /// Build a session for an **exact 1D** geometric problem: the dense
    /// buffers stay at a 1×1 placeholder and the oned state — scaling
    /// vectors, carried marginal sums, sorted-support [`OnedWorkspace`],
    /// transport-list capacity — is sized so the first
    /// [`SolverSession::solve_oned`] on this shape is already
    /// allocation-free. Eligibility (`d == 1`, `|x − y|` cost) is enforced
    /// at solve time with a typed error, like every other per-solve
    /// precondition; building against an ineligible problem just sizes
    /// O(m + n) buffers that the first eligible solve reuses.
    pub fn build_oned(self, problem: &GeomProblem) -> SolverSession {
        let mut session = self.build_for_shape(1, 1);
        session.size_oned(problem);
        session
    }

    fn build_for_shape(self, m: usize, n: usize) -> SolverSession {
        if self.trace.is_some() {
            telemetry::set_enabled(true);
        }
        // Resolved exactly once per build (a `tune` tile measures here).
        let policy = KernelPolicy::for_shape(self.kernel, self.tile, m, n);
        let ws = match self.pool {
            Some(pool) => Workspace::with_pool_policy(m, n, pool, policy),
            None => Workspace::with_backend_policy(
                m,
                n,
                self.threads,
                self.backend,
                self.affinity,
                policy,
            ),
        };
        SolverSession {
            solver: solver_for(self.kind),
            stop: self.stop,
            check_every: self.check_every,
            observer: self.observer,
            ws,
            plan: Matrix::zeros(m, n),
            colsum: vec![0f32; n],
            sparse: None,
            matfree: None,
            oned: None,
            warm: (self.warm > 0).then(|| WarmCache::new(self.warm)),
            ti: self.ti,
            eps_schedule: self.eps_schedule,
            trace: self.trace,
        }
    }
}

/// A reusable solve session: one [`Workspace`], one result plan buffer,
/// stopping policy and optional observer. `Send`, so one session per worker
/// thread is the intended service topology.
pub struct SolverSession {
    solver: &'static dyn Solver,
    stop: StopRule,
    check_every: usize,
    observer: Option<Box<dyn ConvergenceObserver>>,
    ws: Workspace,
    plan: Matrix,
    colsum: Vec<f32>,
    /// CSR state, populated by the first sparse solve (or `build_sparse`)
    /// and reused across same-structure sparse solves.
    sparse: Option<SparseState>,
    /// Matfree state, populated by the first matfree solve (or
    /// `build_matfree`) and reused across same-shape matfree solves.
    matfree: Option<MatfreeState>,
    /// Exact-1D state, populated by the first oned solve (or `build_oned`)
    /// and reused across same-shape oned solves.
    oned: Option<OnedState>,
    /// Warm-start cache of converged diagonal scalings (`None` = off).
    warm: Option<WarmCache>,
    /// Translation-invariant pre-sweep mass correction (MAP-UOT only).
    ti: bool,
    /// Geometric ε ladder `(from, steps)` for matfree solves.
    eps_schedule: Option<(f32, usize)>,
    /// Span-trace export path ([`SessionBuilder::trace`]; `None` = off).
    trace: Option<String>,
}

/// The sparse twin of the session's `(plan, colsum, ws)` triple.
struct SparseState {
    plan: CsrMatrix,
    colsum: Vec<f32>,
    ws: SparseWorkspace,
}

/// The matfree twin: the whole carried solver state is O(m + n) — the
/// scaling vectors of `plan = diag(u)·A·diag(v)` plus the carried
/// marginal sums (which double as the convergence metrics).
struct MatfreeState {
    u: Vec<f32>,
    v: Vec<f32>,
    colsum: Vec<f32>,
    rowsum: Vec<f32>,
    ws: MatfreeWorkspace,
}

/// The exact-1D twin: the same O(m + n) carried scaling state as matfree
/// plus the sorted-support workspace and the converged monotone transport
/// list (entry capacity pre-reserved, so extraction never allocates).
struct OnedState {
    u: Vec<f32>,
    v: Vec<f32>,
    colsum: Vec<f32>,
    rowsum: Vec<f32>,
    transport: TransportList,
    ws: OnedWorkspace,
}

impl SolverSession {
    /// Start building a session for `kind`.
    pub fn builder(kind: SolverKind) -> SessionBuilder {
        SessionBuilder {
            kind,
            threads: 1,
            backend: ParallelBackend::Pool,
            affinity: AffinityHint::None,
            pool: None,
            kernel: KernelKind::Auto,
            tile: TileSpec::Auto,
            stop: StopRule::default(),
            check_every: 8,
            observer: None,
            warm: 0,
            ti: false,
            eps_schedule: None,
            trace: None,
        }
    }

    /// Warm-cache `(hits, misses)` counters, `None` when warm starting is
    /// off. Lets services and benches read hit rates without holding the
    /// cache itself.
    pub fn warm_stats(&self) -> Option<(u64, u64)> {
        self.warm.as_ref().map(|c| (c.hits(), c.misses()))
    }

    /// Export every span recorded so far (all lanes — pool workers
    /// included) to the [`SessionBuilder::trace`] path: chrome://tracing
    /// JSON, or JSONL events when the path ends in `.jsonl`. Returns the
    /// event count. Cold; call after solving, not between bursts.
    /// [`Error::Config`] when the session was built without a trace
    /// path; [`Error::Io`] when the write fails.
    pub fn export_trace(&self) -> Result<usize> {
        let path = self.trace.as_deref().ok_or_else(|| {
            Error::Config("session was built without a trace path (SessionBuilder::trace)".into())
        })?;
        let events = telemetry::snapshot_spans();
        telemetry::export_trace(path, &events).map_err(Error::Io)?;
        Ok(events.len())
    }

    /// The resolved kernel/tiling policy of this session's workspace.
    pub fn policy(&self) -> KernelPolicy {
        self.ws.policy()
    }

    /// Which kernel this session runs.
    pub fn kind(&self) -> SolverKind {
        self.solver.kind()
    }

    /// The plan produced by the most recent [`SolverSession::solve`]
    /// (borrow; use [`SolverSession::solve_cloned`] to own it).
    pub fn plan(&self) -> &Matrix {
        &self.plan
    }

    /// Consume the session, keeping the final plan.
    pub fn into_plan(self) -> Matrix {
        self.plan
    }

    /// Solve `problem` in the session's plan buffer.
    ///
    /// Allocation-free for a same-shape problem after the first solve
    /// (serial path — see the contract on [`Workspace`]); a shape change
    /// re-sizes the buffers. Returns [`Error::Canceled`] if the observer
    /// cancels; cancellation takes effect at the next check boundary, i.e.
    /// within `check_every` iterations.
    pub fn solve(&mut self, problem: &Problem) -> Result<SolveReport> {
        self.check_accelerators(false)?;
        let timer = Timer::start();
        let _solve_span = telemetry::span(Phase::Solve);
        let (m, n) = (problem.rows(), problem.cols());
        if self.plan.rows() != m || self.plan.cols() != n {
            self.plan = problem.plan.clone();
            self.colsum = vec![0f32; n];
            self.ws.ensure_shape(m, n);
        } else {
            self.plan
                .as_mut_slice()
                .copy_from_slice(problem.plan.as_slice());
        }
        self.plan.col_sums_into(&mut self.colsum);
        let (rpd, cpd, fi) = (&problem.rpd, &problem.cpd, problem.fi);

        // Warm start: seed from the nearest cached converged scaling. Every
        // iterate stays in the family diag(u)·plan0·diag(v), so rescaling
        // the input plan by a cached (u, v) only moves the start *along*
        // the iteration's own trajectory space — same fixed point, fewer
        // sweeps when the cached problem is nearby.
        let fp = self
            .warm
            .as_ref()
            .map(|_| warmstart::fingerprint_dense(self.solver.kind(), problem));
        if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
            if let Some((uc, vc)) = cache.lookup(fp) {
                warmstart::scale_dense_plan(&mut self.plan, uc, vc);
                self.plan.col_sums_into(&mut self.colsum);
            }
        }
        let ti_target = self
            .ti
            .then(|| scaling::ti_mass_target(rpd.iter().sum(), cpd.iter().sum(), fi));

        let solver = self.solver;
        let (plan, colsum, ws) = (&mut self.plan, &mut self.colsum, &mut self.ws);
        let report =
            drive_loop(timer, self.stop, self.check_every, &mut self.observer, |steps| {
                let sweep = telemetry::span(Phase::FusedSweep);
                let mut delta = 0f32;
                for _ in 0..steps {
                    if let Some(t) = ti_target {
                        scaling::ti_rescale(colsum, t, fi);
                    }
                    delta += solver.iterate_tracked(plan, colsum, rpd, cpd, fi, ws);
                }
                drop(sweep);
                let _check = telemetry::span(Phase::ConvergenceCheck);
                let err = ws.marginal_error(plan, rpd, cpd);
                (delta, err)
            })?;
        if report.converged {
            if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
                let (plan, colsum) = (&self.plan, &self.colsum);
                cache.store_with(fp, m, n, |u, v| {
                    warmstart::derive_dense_scaling(&problem.plan, plan, colsum, u, v);
                });
            }
        }
        Ok(report)
    }

    /// Solve a **sparse** (CSR) problem — the sparse twin of
    /// [`SolverSession::solve`], sharing the session's stop rule, check
    /// cadence, observer and execution engine (serial / scope / the same
    /// persistent pool). The result plan stays in CSR form; read it with
    /// [`SolverSession::sparse_plan`].
    ///
    /// The fused CSR sweep *is* the MAP-UOT algorithm, so the session must
    /// be built for [`SolverKind::MapUot`]; any other kind returns
    /// [`Error::InvalidProblem`] (never panics — malformed CSR cannot even
    /// be constructed, see [`CsrMatrix::new`]).
    ///
    /// Allocation contract: the first call on a new structure (different
    /// shape or nnz) clones the plan and sizes the [`SparseWorkspace`];
    /// after that, same-structure solves are allocation-free end to end —
    /// values are refreshed in place and the nnz-balanced partition is
    /// rebuilt into retained capacity (asserted in
    /// `rust/tests/alloc_free.rs`). Returns [`Error::Canceled`] if the
    /// observer cancels at a check boundary.
    pub fn solve_sparse(&mut self, problem: &SparseProblem) -> Result<SolveReport> {
        if self.solver.kind() != SolverKind::MapUot {
            return Err(Error::InvalidProblem(format!(
                "sparse solves run the fused MAP-UOT CSR kernel; this session is {} — \
                 build it with SolverKind::MapUot",
                self.solver.kind().name()
            )));
        }
        self.check_accelerators(false)?;
        let timer = Timer::start();
        let _solve_span = telemetry::span(Phase::Solve);
        {
            let _gen = telemetry::span(Phase::KernelGenerate);
            self.ensure_sparse(problem);
        }
        let (rpd, cpd, fi) = (&problem.rpd, &problem.cpd, problem.fi);
        let (m, n) = (problem.plan.m, problem.plan.n);

        // Warm start on the retained sparsity pattern: the CSR sweep never
        // fills structural zeros in or out, so rescaling the seeded values
        // by a cached (u, v) is the exact sparse analogue of the dense
        // diagonal-family argument.
        let fp = self.warm.as_ref().map(|_| warmstart::fingerprint_sparse(problem));
        if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
            if let Some((uc, vc)) = cache.lookup(fp) {
                let st = self.sparse.as_mut().expect("ensure_sparse populated the state");
                warmstart::scale_csr_plan(&mut st.plan, uc, vc);
                st.plan.col_sums_into(&mut st.colsum);
            }
        }
        let ti_target = self
            .ti
            .then(|| scaling::ti_mass_target(rpd.iter().sum(), cpd.iter().sum(), fi));

        let st = self.sparse.as_mut().expect("ensure_sparse populated the state");
        let SparseState { plan, colsum, ws } = st;
        let report =
            drive_loop(timer, self.stop, self.check_every, &mut self.observer, |steps| {
                let sweep = telemetry::span(Phase::FusedSweep);
                let mut delta = 0f32;
                for _ in 0..steps {
                    if let Some(t) = ti_target {
                        scaling::ti_rescale(colsum, t, fi);
                    }
                    delta += ws.iterate_tracked(plan, colsum, rpd, cpd, fi);
                }
                drop(sweep);
                let _check = telemetry::span(Phase::ConvergenceCheck);
                let err = ws.marginal_error(plan, rpd, cpd);
                (delta, err)
            })?;
        if report.converged {
            if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
                let st = self.sparse.as_ref().expect("state retained across the solve");
                cache.store_with(fp, m, n, |u, v| {
                    warmstart::derive_csr_scaling(&problem.plan, &st.plan, &st.colsum, u, v);
                });
            }
        }
        Ok(report)
    }

    /// The CSR plan produced by the most recent
    /// [`SolverSession::solve_sparse`] (`None` before the first sparse
    /// solve). Densify with [`CsrMatrix::to_dense`] if a dense result is
    /// needed.
    pub fn sparse_plan(&self) -> Option<&CsrMatrix> {
        self.sparse.as_ref().map(|st| &st.plan)
    }

    /// Size (or reuse) the CSR state for `problem` and seed the carried
    /// column sums. Same-structure problems (matching shape and nnz) reuse
    /// every buffer — structure and values are copied in place; anything
    /// else re-clones (the documented warmup allocation). The sparse
    /// workspace shares the session's engine: same thread count, same
    /// backend, same pool `Arc`.
    fn ensure_sparse(&mut self, problem: &SparseProblem) {
        let p = &problem.plan;
        let reusable = self.sparse.as_ref().is_some_and(|st| {
            st.plan.m == p.m && st.plan.n == p.n && st.plan.nnz() == p.nnz()
        });
        if reusable {
            let st = self.sparse.as_mut().expect("checked above");
            st.plan.row_ptr.copy_from_slice(&p.row_ptr);
            st.plan.col_idx.copy_from_slice(&p.col_idx);
            st.plan.values.copy_from_slice(&p.values);
        } else {
            let ws = SparseWorkspace::with_engine(
                p.m,
                p.n,
                self.ws.threads(),
                self.ws.backend(),
                self.ws.pool().cloned(),
            );
            self.sparse = Some(SparseState {
                plan: p.clone(),
                colsum: vec![0f32; p.n],
                ws,
            });
        }
        let st = self.sparse.as_mut().expect("just ensured");
        st.ws.prepare(&st.plan);
        st.plan.col_sums_into(&mut st.colsum);
    }

    /// Solve a **materialization-free** geometric problem — the matfree
    /// twin of [`SolverSession::solve`], sharing the session's stop rule,
    /// check cadence, observer and execution engine (serial / scope / the
    /// same persistent pool). The plan is never stored: the session
    /// carries only the scaling vectors `u, v` (read them with
    /// [`SolverSession::matfree_scaling`]; regenerate plan entries with
    /// [`SolverSession::matfree_plan_row`] /
    /// [`SolverSession::matfree_materialize`]).
    ///
    /// The scaling-form sweep *is* the MAP-UOT algorithm, so the session
    /// must be built for [`SolverKind::MapUot`]; any other kind returns
    /// [`Error::InvalidProblem`].
    ///
    /// The report's `err` is the carried-marginal L-inf error — computed
    /// in O(m + n) from the sweep's own row/column sums, no extra
    /// generation pass (the carried sums drift from fresh sums by at most
    /// per-sweep f32 rounding, the same tolerance the dense carried
    /// `colsum` accepts).
    ///
    /// Allocation contract: the first call on a new shape sizes the
    /// O(m + n) state; after that, same-shape solves are allocation-free
    /// end to end, and **no O(m·n) allocation ever occurs** — proven at
    /// m = n = 16384 by the counting-allocator test in
    /// `rust/tests/alloc_free.rs`. Returns [`Error::Canceled`] if the
    /// observer cancels at a check boundary.
    pub fn solve_matfree(&mut self, problem: &GeomProblem) -> Result<SolveReport> {
        if self.solver.kind() != SolverKind::MapUot {
            return Err(Error::InvalidProblem(format!(
                "matfree solves run the scaling-form MAP-UOT sweep; this session is {} — \
                 build it with SolverKind::MapUot",
                self.solver.kind().name()
            )));
        }
        self.check_accelerators(true)?;
        if let Some((from, steps)) = self.eps_schedule {
            if !(from.is_finite() && from > problem.epsilon) {
                return Err(Error::InvalidProblem(format!(
                    "eps_schedule start bandwidth {from} must be finite and above the \
                     problem's target ε = {} (the ladder descends)",
                    problem.epsilon
                )));
            }
            if steps == 0 {
                return Err(Error::InvalidProblem(
                    "eps_schedule needs at least one coarse rung (steps >= 1)".into(),
                ));
            }
        }
        let timer = Timer::start();
        let _solve_span = telemetry::span(Phase::Solve);
        {
            let _gen = telemetry::span(Phase::KernelGenerate);
            self.ensure_matfree(problem);
        }
        let (m, n) = (problem.rows(), problem.cols());
        let fi = problem.fi;

        // Warm start: copy the cached scaling vectors straight in — for the
        // matfree path (u, v) *is* the solver state, so the seed is exact by
        // construction — and re-derive the carried column sums they imply.
        let fp = self.warm.as_ref().map(|_| warmstart::fingerprint_matfree(problem));
        let mut warm_hit = false;
        if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
            if let Some((uc, vc)) = cache.lookup(fp) {
                let st = self.matfree.as_mut().expect("ensure_matfree populated the state");
                st.u.copy_from_slice(uc);
                st.v.copy_from_slice(vc);
                let MatfreeState { u, v, colsum, ws, .. } = st;
                ws.seed_col_sums(problem, u, v, colsum);
                warm_hit = true;
            }
        }
        let ti_target = self.ti.then(|| {
            scaling::ti_mass_target(problem.rpd.iter().sum(), problem.cpd.iter().sum(), fi)
        });

        let st = self.matfree.as_mut().expect("ensure_matfree populated the state");
        let MatfreeState { u, v, colsum, rowsum, ws } = st;

        // ε ladder: a few relaxed-tolerance rungs at geometrically shrinking
        // bandwidth, duals carried down between rungs (u ← u^(ε_old/ε_new)
        // holds φ = ε·ln u fixed). A warm hit skips the ladder — the cached
        // scaling is already at the target ε and better than a coarse solve.
        let mut prior_iters = 0usize;
        if !warm_hit {
            if let Some((from, steps)) = self.eps_schedule {
                let mut coarse = problem.clone();
                let ratio = (problem.epsilon / from).powf(1.0 / steps as f32);
                // Coarse rungs only position the duals; they neither need the
                // final tolerance nor deserve the full iteration budget.
                const EPS_RUNG_TOL_FACTOR: f32 = 10.0;
                let rung_stop = StopRule {
                    tol: self.stop.tol * EPS_RUNG_TOL_FACTOR,
                    delta_tol: self.stop.delta_tol * EPS_RUNG_TOL_FACTOR,
                    max_iter: (self.stop.max_iter / (steps + 1)).max(self.check_every),
                };
                let mut eps_prev = from;
                for k in 0..steps {
                    coarse.epsilon = from * ratio.powi(k as i32);
                    if k > 0 {
                        matfree::carry_potentials(u, eps_prev, coarse.epsilon);
                        matfree::carry_potentials(v, eps_prev, coarse.epsilon);
                    }
                    ws.seed_col_sums(&coarse, u, v, colsum);
                    eps_prev = coarse.epsilon;
                    let cp = &coarse;
                    let rung = drive_loop(
                        Timer::start(),
                        rung_stop,
                        self.check_every,
                        &mut self.observer,
                        |burst| {
                            let sweep = telemetry::span(Phase::FusedSweep);
                            let mut delta = 0f32;
                            for _ in 0..burst {
                                if let Some(t) = ti_target {
                                    scaling::ti_rescale(colsum, t, fi);
                                }
                                delta += ws.iterate_tracked(cp, u, v, colsum, rowsum);
                            }
                            drop(sweep);
                            let _check = telemetry::span(Phase::ConvergenceCheck);
                            let err = matfree::carried_marginal_error(
                                rowsum, colsum, &cp.rpd, &cp.cpd,
                            );
                            (delta, err)
                        },
                    )
                    .map_err(|e| match e {
                        Error::Canceled { iters } => {
                            Error::Canceled { iters: iters + prior_iters }
                        }
                        other => other,
                    })?;
                    prior_iters += rung.iters;
                }
                matfree::carry_potentials(u, eps_prev, problem.epsilon);
                matfree::carry_potentials(v, eps_prev, problem.epsilon);
                ws.seed_col_sums(problem, u, v, colsum);
            }
        }

        let mut report =
            drive_loop(timer, self.stop, self.check_every, &mut self.observer, |steps| {
                let sweep = telemetry::span(Phase::FusedSweep);
                let mut delta = 0f32;
                for _ in 0..steps {
                    if let Some(t) = ti_target {
                        scaling::ti_rescale(colsum, t, fi);
                    }
                    delta += ws.iterate_tracked(problem, u, v, colsum, rowsum);
                }
                drop(sweep);
                let _check = telemetry::span(Phase::ConvergenceCheck);
                let err =
                    matfree::carried_marginal_error(rowsum, colsum, &problem.rpd, &problem.cpd);
                (delta, err)
            })
            .map_err(|e| match e {
                Error::Canceled { iters } => Error::Canceled { iters: iters + prior_iters },
                other => other,
            })?;
        report.iters += prior_iters;
        if report.converged {
            if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
                let st = self.matfree.as_ref().expect("state retained across the solve");
                cache.store_with(fp, m, n, |cu, cv| {
                    cu.copy_from_slice(&st.u);
                    cv.copy_from_slice(&st.v);
                });
            }
        }
        Ok(report)
    }

    /// The scaling vectors `(u, v)` of the most recent
    /// [`SolverSession::solve_matfree`] (`None` before the first matfree
    /// solve). The current plan is `plan_ij = u[i] · A_ij · v[j]` — these
    /// O(m + n) vectors *are* the full answer for a geometric problem.
    pub fn matfree_scaling(&self) -> Option<(&[f32], &[f32])> {
        self.matfree.as_ref().map(|st| (st.u.as_slice(), st.v.as_slice()))
    }

    /// Regenerate row `i` of the solved plan into `out` (length N):
    /// `out[j] = u[i] · A_ij · v[j]`, generated through the session's
    /// kernel policy. `problem` must be the instance the last
    /// [`SolverSession::solve_matfree`] ran (shape-checked; the scaling
    /// vectors are meaningless for any other geometry).
    pub fn matfree_plan_row(&self, problem: &GeomProblem, i: usize, out: &mut [f32]) -> Result<()> {
        let st = self.matfree.as_ref().ok_or_else(|| {
            Error::InvalidProblem("no matfree solve has run on this session".into())
        })?;
        let (m, n) = st.ws.shape();
        if problem.rows() != m || problem.cols() != n {
            return Err(Error::InvalidProblem(format!(
                "problem shape {}x{} does not match the solved matfree state {m}x{n}",
                problem.rows(),
                problem.cols()
            )));
        }
        if i >= m {
            return Err(Error::InvalidProblem(format!("row {i} out of range for {m} rows")));
        }
        if out.len() != n {
            return Err(Error::InvalidProblem(format!(
                "output buffer length {} != cols {n}",
                out.len()
            )));
        }
        matfree::generate_plan_row(problem, i, st.u[i], &st.v, out, &st.ws.policy());
        Ok(())
    }

    /// Materialize the full solved plan — the **one** deliberate O(m·n)
    /// allocation in the matfree path, for callers that genuinely need a
    /// dense result (the coordinator's densified responses, equivalence
    /// tests). Everything on the solve path stays O(m + n).
    pub fn matfree_materialize(&self, problem: &GeomProblem) -> Result<Matrix> {
        let st = self.matfree.as_ref().ok_or_else(|| {
            Error::InvalidProblem("no matfree solve has run on this session".into())
        })?;
        let (m, n) = st.ws.shape();
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            self.matfree_plan_row(problem, i, out.row_mut(i))?;
        }
        Ok(out)
    }

    /// Size (or reuse) the matfree state for `problem`'s shape — the
    /// warmup allocation, without touching the problem data. Same-shape
    /// problems reuse every buffer. The matfree workspace shares the
    /// session's engine and kernel policy: same thread count, same
    /// backend, same pool `Arc`.
    fn size_matfree(&mut self, problem: &GeomProblem) {
        let (m, n) = (problem.rows(), problem.cols());
        let reusable = self.matfree.as_ref().is_some_and(|st| st.ws.shape() == (m, n));
        if !reusable {
            let ws = MatfreeWorkspace::with_engine(
                m,
                n,
                self.ws.threads(),
                self.ws.backend(),
                self.ws.pool().cloned(),
                self.ws.policy(),
            );
            self.matfree = Some(MatfreeState {
                u: vec![1f32; m],
                v: vec![1f32; n],
                colsum: vec![0f32; n],
                rowsum: vec![0f32; m],
                ws,
            });
        }
    }

    /// [`SolverSession::size_matfree`] plus per-solve state derivation:
    /// reset the scaling vectors to 1 and seed the carried column sums
    /// (`u = v = 1` ⇒ one serial generation pass — the matfree analogue
    /// of the dense path's `col_sums_into`). Runs once per solve, so
    /// reuse across different same-shape problems is always sound.
    fn ensure_matfree(&mut self, problem: &GeomProblem) {
        self.size_matfree(problem);
        let st = self.matfree.as_mut().expect("just sized");
        st.u.fill(1.0);
        st.v.fill(1.0);
        st.rowsum.fill(0.0);
        st.ws.prepare(problem.rows(), problem.cols());
        st.ws.seed_col_sums(problem, &st.u, &st.v, &mut st.colsum);
    }

    /// Solve a 1D geometric `problem` **exactly** on the sorted-support
    /// fast path: the same MAP-UOT scaling iteration as
    /// [`SolverSession::solve_matfree`] — same fixed point, stop rule,
    /// `check_every` cadence, observer and cancellation — with every
    /// kernel product computed in O(m + n) by the Laplace-kernel sweeps
    /// of [`crate::algo::oned`]. On return the session additionally holds
    /// the sparse monotone [`TransportList`] of the final iterate's
    /// transported marginals ([`SolverSession::oned_transport`]).
    ///
    /// Typed rejections: non-MapUot sessions, `d != 1`, the
    /// squared-Euclidean (Gaussian) kernel, and a configured ε ladder
    /// (near-linear sweeps have nothing for the ladder to amortize). TI
    /// sweeps and warm starting compose; the warm fingerprint is shared
    /// with the matfree path on purpose, so a 1D solve seeds later
    /// matfree solves of the same geometry and vice versa.
    ///
    /// Allocation contract: the first call on a new shape sizes the
    /// O(m + n) state; after that, same-shape solves — support sort,
    /// sweeps, coupling extraction included — are allocation-free end to
    /// end, proven at m = n = 1_000_000 by the counting-allocator test in
    /// `rust/tests/alloc_free.rs`.
    pub fn solve_oned(&mut self, problem: &GeomProblem) -> Result<SolveReport> {
        if self.solver.kind() != SolverKind::MapUot {
            return Err(Error::InvalidProblem(format!(
                "the 1D fast path runs the scaling-form MAP-UOT sweep; this session is {} — \
                 build it with SolverKind::MapUot",
                self.solver.kind().name()
            )));
        }
        if let Some((from, steps)) = self.eps_schedule {
            return Err(Error::InvalidProblem(format!(
                "eps_schedule({from}, {steps}) amortizes expensive matfree sweeps; the exact \
                 1D sweep is already O(m + n) per iteration — drop the ladder for oned solves"
            )));
        }
        let timer = Timer::start();
        let _solve_span = telemetry::span(Phase::Solve);
        {
            let _gen = telemetry::span(Phase::KernelGenerate);
            self.ensure_oned(problem)?;
        }
        let (m, n) = (problem.rows(), problem.cols());
        let fi = problem.fi;

        // Warm start — deliberately the *matfree* fingerprint: an eligible
        // 1D geometry hashes identically on both paths, so each seeds the
        // other (the cache key never includes which sweep ran; seeding
        // only relocates the start point along the iteration's own
        // trajectory space, which is always sound).
        let fp = self.warm.as_ref().map(|_| warmstart::fingerprint_matfree(problem));
        if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
            if let Some((uc, vc)) = cache.lookup(fp) {
                let st = self.oned.as_mut().expect("ensure_oned populated the state");
                st.u.copy_from_slice(uc);
                st.v.copy_from_slice(vc);
                let OnedState { u, v, colsum, ws, .. } = st;
                ws.seed_col_sums(problem, u, v, colsum);
            }
        }
        let ti_target = self.ti.then(|| {
            scaling::ti_mass_target(problem.rpd.iter().sum(), problem.cpd.iter().sum(), fi)
        });

        let st = self.oned.as_mut().expect("ensure_oned populated the state");
        let OnedState { u, v, colsum, rowsum, ws, .. } = st;
        let report =
            drive_loop(timer, self.stop, self.check_every, &mut self.observer, |steps| {
                let sweep = telemetry::span(Phase::FusedSweep);
                let mut delta = 0f32;
                for _ in 0..steps {
                    if let Some(t) = ti_target {
                        scaling::ti_rescale(colsum, t, fi);
                    }
                    delta += ws.iterate_tracked(problem, u, v, colsum, rowsum);
                }
                drop(sweep);
                let _check = telemetry::span(Phase::ConvergenceCheck);
                let err =
                    matfree::carried_marginal_error(rowsum, colsum, &problem.rpd, &problem.cpd);
                (delta, err)
            })?;
        // Extract the monotone coupling of the final iterate's transported
        // marginals — O(m + n), within the reserved entry capacity.
        let st = self.oned.as_mut().expect("state retained across the solve");
        oned::fused_monotone_coupling(
            st.ws.row_order(),
            st.ws.col_order(),
            &st.rowsum,
            &st.colsum,
            &problem.rpd,
            &problem.cpd,
            &mut st.transport,
        );
        if report.converged {
            if let (Some(cache), Some(fp)) = (self.warm.as_mut(), fp.as_ref()) {
                let st = self.oned.as_ref().expect("state retained across the solve");
                cache.store_with(fp, m, n, |cu, cv| {
                    cu.copy_from_slice(&st.u);
                    cv.copy_from_slice(&st.v);
                });
            }
        }
        Ok(report)
    }

    /// The scaling vectors `(u, v)` of the most recent
    /// [`SolverSession::solve_oned`] (`None` before the first oned solve).
    /// Exactly as on the matfree path, `plan_ij = u[i] · A_ij · v[j]` —
    /// these O(m + n) vectors are the full answer.
    pub fn oned_scaling(&self) -> Option<(&[f32], &[f32])> {
        self.oned.as_ref().map(|st| (st.u.as_slice(), st.v.as_slice()))
    }

    /// The sparse monotone transport list extracted by the most recent
    /// [`SolverSession::solve_oned`] (`None` before the first oned solve):
    /// ≤ m + n entries coupling the converged transported marginals in
    /// sorted-support order, plus the unbalanced creation/destruction
    /// slack per side.
    pub fn oned_transport(&self) -> Option<&TransportList> {
        self.oned.as_ref().map(|st| &st.transport)
    }

    /// Materialize the full solved plan `u[i] · exp(-|x_i − y_j|/ε) ·
    /// v[j]` — the **one** deliberate O(m·n) allocation in the oned path,
    /// for equivalence tests and callers that genuinely need a dense
    /// result. Everything on the solve path stays O(m + n).
    pub fn oned_materialize(&self, problem: &GeomProblem) -> Result<Matrix> {
        let st = self.oned.as_ref().ok_or_else(|| {
            Error::InvalidProblem("no oned solve has run on this session".into())
        })?;
        let (m, n) = st.ws.shape();
        if problem.rows() != m || problem.cols() != n {
            return Err(Error::InvalidProblem(format!(
                "problem shape {}x{} does not match the solved oned state {m}x{n}",
                problem.rows(),
                problem.cols()
            )));
        }
        Ok(Matrix::from_fn(m, n, |i, j| {
            st.u[i] * problem.kernel_entry(i, j) * st.v[j]
        }))
    }

    /// Size (or reuse) the oned state for `problem`'s shape — the warmup
    /// allocation, without touching the problem data (eligibility is a
    /// solve-time check). Same-shape problems reuse every buffer,
    /// transport-list capacity included.
    fn size_oned(&mut self, problem: &GeomProblem) {
        let (m, n) = (problem.rows(), problem.cols());
        let reusable = self.oned.as_ref().is_some_and(|st| st.ws.shape() == (m, n));
        if !reusable {
            let mut transport = TransportList::default();
            transport.reserve_for(m, n);
            self.oned = Some(OnedState {
                u: vec![1f32; m],
                v: vec![1f32; n],
                colsum: vec![0f32; n],
                rowsum: vec![0f32; m],
                transport,
                ws: OnedWorkspace::new(m, n),
            });
        }
    }

    /// [`SolverSession::size_oned`] plus per-solve state derivation:
    /// validate eligibility, sort the supports, reset the scaling vectors
    /// to 1 and seed the carried column sums exactly (one sweep pair).
    fn ensure_oned(&mut self, problem: &GeomProblem) -> Result<()> {
        self.size_oned(problem);
        let st = self.oned.as_mut().expect("just sized");
        st.ws.prepare(problem)?;
        st.u.fill(1.0);
        st.v.fill(1.0);
        st.rowsum.fill(0.0);
        st.transport.entries.clear();
        st.ws.seed_col_sums(problem, &st.u, &st.v, &mut st.colsum);
        Ok(())
    }

    /// Shared guard for the accelerator knobs: TI is a MAP-UOT mass
    /// correction (meaningless for the POT/COFFEE comparator loops), and
    /// the ε ladder only exists where there is an ε — the matfree path.
    /// Loud typed errors beat silently ignoring a requested accelerator.
    fn check_accelerators(&self, matfree_path: bool) -> Result<()> {
        if self.ti && self.solver.kind() != SolverKind::MapUot {
            return Err(Error::InvalidProblem(format!(
                "translation-invariant sweeps correct the MAP-UOT iteration; this session \
                 is {} — build it with SolverKind::MapUot",
                self.solver.kind().name()
            )));
        }
        if !matfree_path {
            if let Some((from, steps)) = self.eps_schedule {
                return Err(Error::InvalidProblem(format!(
                    "eps_schedule({from}, {steps}) applies to the matfree bandwidth ladder \
                     only; dense and sparse solves have no ε to schedule"
                )));
            }
        }
        Ok(())
    }

    /// [`SolverSession::solve`] plus a clone of the result plan (the clone
    /// is the one permitted allocation — the hot loop stays allocation-free).
    pub fn solve_cloned(&mut self, problem: &Problem) -> Result<(Matrix, SolveReport)> {
        let report = self.solve(problem)?;
        Ok((self.plan.clone(), report))
    }

    /// Solve a batch through one workspace. Same-shape problems (the
    /// batcher's contract) reuse every buffer; a shape change re-sizes once
    /// and subsequent problems of that shape are again allocation-free.
    /// Per-item results, so one canceled/failed solve does not sink a batch.
    pub fn solve_batch(&mut self, problems: &[Problem]) -> Vec<Result<(Matrix, SolveReport)>> {
        problems.iter().map(|p| self.solve_cloned(p)).collect()
    }

    /// [`SolverSession::solve_sparse`] plus a clone of the CSR result —
    /// the sparse comparator twin of [`SolverSession::solve_cloned`], so
    /// equivalence tests and benches can hold results from several solves
    /// at once. The clone is the one permitted allocation.
    pub fn solve_sparse_cloned(
        &mut self,
        problem: &SparseProblem,
    ) -> Result<(CsrMatrix, SolveReport)> {
        let report = self.solve_sparse(problem)?;
        let plan = self.sparse.as_ref().expect("solve_sparse populated the state").plan.clone();
        Ok((plan, report))
    }

    /// Sparse batch through one workspace — same reuse and per-item-result
    /// contracts as [`SolverSession::solve_batch`].
    pub fn solve_sparse_batch(
        &mut self,
        problems: &[SparseProblem],
    ) -> Vec<Result<(CsrMatrix, SolveReport)>> {
        problems.iter().map(|p| self.solve_sparse_cloned(p)).collect()
    }

    /// [`SolverSession::solve_matfree`] plus a **materialized** dense plan —
    /// the matfree comparator twin of [`SolverSession::solve_cloned`]. This
    /// densification is the deliberate O(m·n) allocation of
    /// [`SolverSession::matfree_materialize`]; the solve itself stays
    /// O(m + n).
    pub fn solve_matfree_cloned(
        &mut self,
        problem: &GeomProblem,
    ) -> Result<(Matrix, SolveReport)> {
        let report = self.solve_matfree(problem)?;
        let plan = self.matfree_materialize(problem)?;
        Ok((plan, report))
    }

    /// Matfree batch through one workspace — same reuse and per-item-result
    /// contracts as [`SolverSession::solve_batch`]. Combined with
    /// [`SessionBuilder::warm`], a drifting stream of near-identical
    /// geometries re-seeds each solve from the previous answers.
    pub fn solve_matfree_batch(
        &mut self,
        problems: &[GeomProblem],
    ) -> Vec<Result<(Matrix, SolveReport)>> {
        problems.iter().map(|p| self.solve_matfree_cloned(p)).collect()
    }
}

/// Wall-clock budget as a [`ConvergenceObserver`]: cancels the solve at
/// the first check boundary past the deadline, turning any solve —
/// including a warm/TI/ε-scheduled one — into an *anytime* computation.
/// The [`Error::Canceled`] it produces carries the iterations completed,
/// and the session state holds the best plan so far (the matfree scaling
/// vectors / dense plan buffer are valid at every boundary).
///
/// Deadline checks cost one `Instant::now()` per check boundary — they are
/// amortized by `check_every` exactly like the stop rule, and allocate
/// nothing.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    deadline: Instant,
}

impl Deadline {
    /// Cancel solves at `budget` from now.
    pub fn within(budget: Duration) -> Self {
        Self { deadline: Instant::now() + budget }
    }

    /// Cancel solves at an absolute instant (shared across several solves:
    /// the whole sequence obeys one budget).
    pub fn at(deadline: Instant) -> Self {
        Self { deadline }
    }
}

impl ConvergenceObserver for Deadline {
    fn on_check(&mut self, _event: CheckEvent) -> ObserverAction {
        if Instant::now() >= self.deadline {
            ObserverAction::Cancel
        } else {
            ObserverAction::Continue
        }
    }
}

/// Shared convergence driver of [`SolverSession::solve`] and
/// [`SolverSession::solve_sparse`]: run `check_every`-iteration bursts
/// through `advance` — which returns the burst's summed tracked delta and
/// the marginal error at its boundary — firing the observer at every
/// boundary, until the stop rule fires or the observer cancels. `timer`
/// is started by the caller so the report's `seconds` includes per-solve
/// setup (plan copy / CSR refresh).
///
/// The tracked `delta` (sum of per-iteration max element changes over the
/// interval) upper-bounds the old cross-interval snapshot diff by the
/// triangle inequality, so a `delta_tol` stop can only fire later than
/// the old criterion, never earlier.
fn drive_loop(
    timer: Timer,
    stop: StopRule,
    check_every: usize,
    observer: &mut Option<Box<dyn ConvergenceObserver>>,
    mut advance: impl FnMut(usize) -> (f32, f32),
) -> Result<SolveReport> {
    let mut iters = 0;
    let (mut err, mut delta);
    loop {
        (delta, err) = advance(check_every);
        iters += check_every;
        if let Some(observer) = observer.as_mut() {
            if observer.on_check(CheckEvent { iters, err, delta }) == ObserverAction::Cancel {
                return Err(Error::Canceled { iters });
            }
        }
        if stop.is_done(err, delta, iters) {
            break;
        }
    }
    let converged = err <= stop.tol || delta <= stop.delta_tol;
    Ok(SolveReport {
        iters,
        err,
        delta,
        converged,
        seconds: timer.elapsed().as_secs_f64(),
    })
}

impl std::fmt::Debug for SolverSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolverSession")
            .field("kind", &self.kind())
            .field("threads", &self.ws.threads())
            .field("shape", &self.ws.shape())
            .field("observer", &self.observer.is_some())
            .field("sparse", &self.sparse.is_some())
            .field("matfree", &self.matfree.is_some())
            .field("oned", &self.oned.is_some())
            .field("warm", &self.warm.as_ref().map(|c| c.capacity()))
            .field("ti", &self.ti)
            .field("eps_schedule", &self.eps_schedule)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::convergence::plan_delta;

    /// The in-sweep tracked delta must equal the snapshot-based definition.
    #[test]
    fn tracked_delta_matches_snapshot_delta() {
        for kind in SolverKind::ALL {
            let p = Problem::random(14, 11, 0.7, 3);
            let solver = solver_for(kind);
            let mut ws = Workspace::new(14, 11, 1);
            let mut plan = p.plan.clone();
            let mut colsum = plan.col_sums();
            for it in 0..6 {
                let prev = plan.clone();
                let d =
                    solver.iterate_tracked(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, &mut ws);
                let reference = plan_delta(&prev, &plan);
                assert!(
                    (d - reference).abs() <= 1e-4 * reference.max(1e-3),
                    "{} iter {it}: tracked {d} vs snapshot {reference}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn tracked_delta_matches_snapshot_delta_threaded() {
        for kind in SolverKind::ALL {
            let p = Problem::random(23, 9, 0.6, 8);
            let solver = solver_for(kind);
            let mut ws = Workspace::new(23, 9, 3);
            let mut plan = p.plan.clone();
            let mut colsum = plan.col_sums();
            for it in 0..4 {
                let prev = plan.clone();
                let d =
                    solver.iterate_tracked(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, &mut ws);
                let reference = plan_delta(&prev, &plan);
                assert!(
                    (d - reference).abs() <= 1e-4 * reference.max(1e-3),
                    "{} iter {it}: tracked {d} vs snapshot {reference}",
                    kind.name()
                );
            }
        }
    }

    /// `iterate` and `iterate_tracked` advance the plan identically.
    #[test]
    fn tracked_iteration_is_bit_identical_to_untracked() {
        for kind in SolverKind::ALL {
            let p = Problem::random(12, 13, 0.8, 5);
            let solver = solver_for(kind);
            let mut ws_a = Workspace::new(12, 13, 1);
            let mut ws_b = Workspace::new(12, 13, 1);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            for _ in 0..5 {
                solver.iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_a);
                let _ = solver.iterate_tracked(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_b);
            }
            assert_eq!(a.as_slice(), b.as_slice(), "{}", kind.name());
            assert_eq!(cs_a, cs_b, "{}", kind.name());
        }
    }

    #[test]
    fn session_solves_and_reports() {
        let p = Problem::random(24, 18, 0.8, 42);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .build(&p);
        let report = session.solve(&p).unwrap();
        assert!(report.converged, "err={} delta={}", report.err, report.delta);
        assert_eq!(session.plan().rows(), 24);
        assert_eq!(session.plan().cols(), 18);
    }

    #[test]
    fn session_adapts_to_shape_change() {
        let small = Problem::random(8, 6, 0.7, 1);
        let big = Problem::random(20, 30, 0.7, 2);
        let mut session = SolverSession::builder(SolverKind::MapUot).build(&small);
        session.solve(&small).unwrap();
        let report = session.solve(&big).unwrap();
        assert!(report.iters > 0);
        assert_eq!(session.plan().rows(), 20);
        assert_eq!(session.plan().cols(), 30);
    }

    #[test]
    fn batch_matches_individual_solves() {
        let problems: Vec<Problem> =
            (0..4).map(|s| Problem::random(16, 16, 0.7, s)).collect();
        let mut session = SolverSession::builder(SolverKind::MapUot).build(&problems[0]);
        let batch = session.solve_batch(&problems);
        assert_eq!(batch.len(), 4);
        for (p, out) in problems.iter().zip(batch) {
            let (plan, report) = out.unwrap();
            let mut fresh = SolverSession::builder(SolverKind::MapUot).build(p);
            let fresh_report = fresh.solve(p).unwrap();
            assert_eq!(plan.as_slice(), fresh.plan().as_slice());
            assert_eq!(report.iters, fresh_report.iters);
        }
    }

    /// Pool and spawn backends are the same numerics on the same partition
    /// — bit-identical plans (the full property test is
    /// `rust/tests/prop_pool.rs`; this covers the session dispatch).
    #[test]
    fn pool_backend_bitmatches_spawn_backend() {
        for kind in SolverKind::ALL {
            let p = Problem::random(23, 9, 0.6, 8);
            let solver = solver_for(kind);
            let mut ws_spawn =
                Workspace::with_backend(23, 9, 3, ParallelBackend::SpawnPerIter, AffinityHint::None);
            let mut ws_pool = Workspace::new(23, 9, 3);
            assert!(ws_pool.pool().is_some());
            assert!(ws_spawn.pool().is_none());
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            for _ in 0..4 {
                let da = solver.iterate_tracked(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &mut ws_spawn);
                let db = solver.iterate_tracked(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, &mut ws_pool);
                assert_eq!(da, db, "{}", kind.name());
            }
            assert_eq!(a.as_slice(), b.as_slice(), "{}", kind.name());
            assert_eq!(cs_a, cs_b, "{}", kind.name());
        }
    }

    /// One pool shared across sessions: dispatches serialize internally,
    /// results match sessions with private pools.
    #[test]
    fn sessions_share_one_pool() {
        let p = Problem::random(24, 18, 0.8, 42);
        let pool = std::sync::Arc::new(ThreadPool::new(3));
        let mut shared_a = SolverSession::builder(SolverKind::MapUot)
            .pool(std::sync::Arc::clone(&pool))
            .build(&p);
        let mut shared_b = SolverSession::builder(SolverKind::Pot)
            .pool(std::sync::Arc::clone(&pool))
            .build(&p);
        let mut private = SolverSession::builder(SolverKind::MapUot).threads(3).build(&p);
        let ra = shared_a.solve(&p).unwrap();
        let rb = shared_b.solve(&p).unwrap();
        let rp = private.solve(&p).unwrap();
        assert!(ra.converged && rb.converged && rp.converged);
        assert_eq!(shared_a.plan().as_slice(), private.plan().as_slice());
        assert_eq!(ra.iters, rp.iters);
    }

    /// Builder kernel/tile choices land in the workspace policy, and an
    /// explicitly scalar+tiled session solves to the same plan as the
    /// default session (within kernel-agreement tolerance).
    #[test]
    fn builder_kernel_and_tile_are_applied() {
        let p = Problem::random(12, 300, 0.7, 17);
        let mut forced = SolverSession::builder(SolverKind::MapUot)
            .kernel(KernelKind::Scalar)
            .tile(TileSpec::Cols(64))
            .build(&p);
        assert_eq!(forced.policy().kind(), KernelKind::Scalar);
        assert_eq!(forced.policy().tile_cols(), 64);
        // Explicit choices beat the MAP_UOT_* env overrides (those only
        // apply to Auto), so this holds on the CI forced-scalar leg too.
        let mut default = SolverSession::builder(SolverKind::MapUot).build(&p);
        forced.solve(&p).unwrap();
        default.solve(&p).unwrap();
        assert!(forced.plan().max_rel_diff(default.plan(), 1e-6) < 1e-4);
    }

    #[test]
    fn observer_cancellation_is_typed() {
        let p = Problem::random(16, 16, 0.7, 9);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .observer(|_: CheckEvent| ObserverAction::Cancel)
            .build(&p);
        match session.solve(&p) {
            Err(Error::Canceled { iters }) => assert_eq!(iters, 4),
            other => panic!("expected Canceled, got {other:?}"),
        }
    }

    /// A serial sparse session solve is bit-identical to replaying the
    /// same number of serial CSR reference iterations from scratch.
    #[test]
    fn sparse_session_bitmatches_serial_reference() {
        let p = Problem::random(24, 18, 0.8, 42);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .build_sparse(&sp);
        let report = session.solve_sparse(&sp).unwrap();
        assert!(report.iters > 0);

        let mut reference = sp.plan.clone();
        let mut colsum = reference.col_sums();
        let mut fcol = vec![0f32; sp.cols()];
        let mut inv = vec![0f32; sp.cols()];
        for _ in 0..report.iters {
            crate::algo::sparse::iterate_tracked_into(
                &mut reference, &mut colsum, &sp.rpd, &sp.cpd, sp.fi, &mut fcol, &mut inv,
            );
        }
        let got = session.sparse_plan().expect("sparse solve ran");
        assert_eq!(got.values, reference.values);
        assert_eq!(got.col_idx, reference.col_idx);
    }

    #[test]
    fn sparse_session_rejects_non_mapuot_kinds() {
        let p = Problem::random(12, 12, 0.7, 3);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        for kind in [SolverKind::Pot, SolverKind::Coffee] {
            let mut session = SolverSession::builder(kind).build_sparse(&sp);
            match session.solve_sparse(&sp) {
                Err(Error::InvalidProblem(_)) => {}
                other => panic!("{}: expected InvalidProblem, got {other:?}", kind.name()),
            }
        }
    }

    #[test]
    fn sparse_session_adapts_to_structure_change() {
        let small = Problem::random(8, 6, 0.7, 1);
        let big = Problem::random(20, 30, 0.7, 2);
        let sp_small = SparseProblem::from_problem(&small, 1.0).unwrap();
        let sp_big = SparseProblem::from_problem(&big, 1.0).unwrap();
        let mut session = SolverSession::builder(SolverKind::MapUot).build_sparse(&sp_small);
        session.solve_sparse(&sp_small).unwrap();
        session.solve_sparse(&sp_big).unwrap();
        let plan = session.sparse_plan().unwrap();
        assert_eq!((plan.m, plan.n), (20, 30));
        // And back: the small structure is re-cloned, results match a
        // fresh session bit-for-bit.
        let r1 = session.solve_sparse(&sp_small).unwrap();
        let mut fresh = SolverSession::builder(SolverKind::MapUot).build_sparse(&sp_small);
        let r2 = fresh.solve_sparse(&sp_small).unwrap();
        assert_eq!(r1.iters, r2.iters);
        assert_eq!(
            session.sparse_plan().unwrap().values,
            fresh.sparse_plan().unwrap().values
        );
    }

    #[test]
    fn sparse_session_shares_the_dense_pool() {
        let p = Problem::random(24, 18, 0.8, 7);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(3)
            .build_sparse(&sp);
        // One pool serves both paths: the sparse workspace holds the same
        // Arc the dense workspace spawned.
        let dense_pool = session.ws.pool().map(Arc::as_ptr);
        let sparse_pool = session
            .sparse
            .as_ref()
            .and_then(|st| st.ws.pool().map(Arc::as_ptr));
        assert!(dense_pool.is_some());
        assert_eq!(dense_pool, sparse_pool);
        let report = session.solve_sparse(&sp).unwrap();
        assert!(report.iters > 0);
        assert!(session.sparse_plan().unwrap().values.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sparse_observer_cancellation_is_typed() {
        let p = Problem::random(16, 16, 0.7, 9);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .observer(|_: CheckEvent| ObserverAction::Cancel)
            .build_sparse(&sp);
        match session.solve_sparse(&sp) {
            Err(Error::Canceled { iters }) => assert_eq!(iters, 4),
            other => panic!("expected Canceled, got {other:?}"),
        }
    }

    #[test]
    fn matfree_session_solves_and_exposes_scaling() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = GeomProblem::random(24, 18, 3, CostKind::SqEuclidean, 0.25, 0.8, 42);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .build_matfree(&p);
        let report = session.solve_matfree(&p).unwrap();
        assert!(report.iters > 0);
        let (u, v) = session.matfree_scaling().expect("solve ran");
        assert_eq!(u.len(), 24);
        assert_eq!(v.len(), 18);
        assert!(u.iter().chain(v.iter()).all(|x| x.is_finite() && *x >= 0.0));
        // plan_row and materialize agree with the scaling definition.
        let plan = session.matfree_materialize(&p).unwrap();
        let mut row = vec![0f32; 18];
        session.matfree_plan_row(&p, 7, &mut row).unwrap();
        assert_eq!(plan.row(7), &row[..]);
        for j in 0..18 {
            let want = u[7] * p.kernel_entry(7, j) * v[j];
            assert!((row[j] - want).abs() <= 1e-5 * want.abs().max(1e-6), "{} vs {want}", row[j]);
        }
    }

    #[test]
    fn matfree_session_rejects_non_mapuot_and_mismatches() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = GeomProblem::random(12, 10, 2, CostKind::Euclidean, 0.5, 0.7, 3);
        for kind in [SolverKind::Pot, SolverKind::Coffee] {
            let mut session = SolverSession::builder(kind).build_matfree(&p);
            match session.solve_matfree(&p) {
                Err(Error::InvalidProblem(_)) => {}
                other => panic!("{}: expected InvalidProblem, got {other:?}", kind.name()),
            }
        }
        // plan_row guards: no solve yet, wrong shape, bad row, bad buffer.
        let fresh = SolverSession::builder(SolverKind::MapUot).build(&Problem::random(4, 4, 0.7, 1));
        let mut out = vec![0f32; 10];
        assert!(fresh.matfree_plan_row(&p, 0, &mut out).is_err());
        let mut solved = SolverSession::builder(SolverKind::MapUot).build_matfree(&p);
        solved.solve_matfree(&p).unwrap();
        let other = GeomProblem::random(5, 10, 2, CostKind::Euclidean, 0.5, 0.7, 4);
        assert!(solved.matfree_plan_row(&other, 0, &mut out).is_err());
        assert!(solved.matfree_plan_row(&p, 99, &mut out).is_err());
        let mut short = [0f32; 3];
        assert!(solved.matfree_plan_row(&p, 0, &mut short[..]).is_err());
    }

    #[test]
    fn matfree_session_shares_the_dense_pool_and_adapts_shape() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let small = GeomProblem::random(8, 6, 2, CostKind::SqEuclidean, 0.5, 0.7, 1);
        let big = GeomProblem::random(20, 30, 2, CostKind::SqEuclidean, 0.5, 0.7, 2);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .threads(3)
            .build_matfree(&small);
        let dense_pool = session.ws.pool().map(Arc::as_ptr);
        let mf_pool = session.matfree.as_ref().and_then(|st| st.ws.pool().map(Arc::as_ptr));
        assert!(dense_pool.is_some());
        assert_eq!(dense_pool, mf_pool, "matfree must drive the session's own workers");
        session.solve_matfree(&small).unwrap();
        session.solve_matfree(&big).unwrap();
        assert_eq!(session.matfree_scaling().unwrap().0.len(), 20);
        // Re-solving the small shape re-derives state and matches a fresh
        // session bit-for-bit.
        let r1 = session.solve_matfree(&small).unwrap();
        let mut fresh = SolverSession::builder(SolverKind::MapUot)
            .threads(3)
            .build_matfree(&small);
        let r2 = fresh.solve_matfree(&small).unwrap();
        assert_eq!(r1.iters, r2.iters);
        assert_eq!(session.matfree_scaling().unwrap().0, fresh.matfree_scaling().unwrap().0);
        assert_eq!(session.matfree_scaling().unwrap().1, fresh.matfree_scaling().unwrap().1);
    }

    #[test]
    fn matfree_observer_cancellation_is_typed() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = GeomProblem::random(16, 16, 3, CostKind::SqEuclidean, 0.4, 0.7, 9);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .observer(|_: CheckEvent| ObserverAction::Cancel)
            .build_matfree(&p);
        match session.solve_matfree(&p) {
            Err(Error::Canceled { iters }) => assert_eq!(iters, 4),
            other => panic!("expected Canceled, got {other:?}"),
        }
    }

    /// A warm re-solve of the same problem starts *at* the cached converged
    /// scaling, so it finishes at the first check boundary and reproduces
    /// the cold plan (within the cache's derive/re-apply rounding).
    #[test]
    fn warm_resolve_hits_and_matches_the_cold_plan() {
        let p = Problem::random(20, 16, 0.7, 11);
        let mut cold = SolverSession::builder(SolverKind::MapUot).check_every(2).build(&p);
        let cold_report = cold.solve(&p).unwrap();
        assert!(cold_report.converged);

        let mut warm = SolverSession::builder(SolverKind::MapUot)
            .check_every(2)
            .warm(4)
            .build(&p);
        assert_eq!(warm.warm_stats(), Some((0, 0)));
        let first = warm.solve(&p).unwrap();
        assert!(first.converged);
        assert_eq!(warm.warm_stats(), Some((0, 1)), "first solve must miss");
        let second = warm.solve(&p).unwrap();
        assert_eq!(warm.warm_stats(), Some((1, 1)), "re-solve must hit");
        assert!(
            second.iters <= first.iters,
            "warm {} vs cold {} iterations",
            second.iters,
            first.iters
        );
        assert!(warm.plan().max_rel_diff(cold.plan(), 1e-6) < 1e-5);
    }

    #[test]
    fn warm_stats_is_none_when_warm_is_off() {
        let p = Problem::random(8, 8, 0.7, 1);
        let mut session = SolverSession::builder(SolverKind::MapUot).build(&p);
        assert_eq!(session.warm_stats(), None);
        session.solve(&p).unwrap();
        assert_eq!(session.warm_stats(), None);
    }

    /// TI sweeps share the plain fixed point: same converged plan at 1e-5,
    /// never more iterations on a mass-imbalanced problem.
    #[test]
    fn ti_solve_matches_plain_plan() {
        let p = Problem::random(18, 14, 0.5, 23);
        let mut plain = SolverSession::builder(SolverKind::MapUot).check_every(1).build(&p);
        let rp = plain.solve(&p).unwrap();
        let mut ti = SolverSession::builder(SolverKind::MapUot)
            .check_every(1)
            .ti(true)
            .build(&p);
        let rt = ti.solve(&p).unwrap();
        assert!(rp.converged && rt.converged);
        assert!(ti.plan().max_rel_diff(plain.plan(), 1e-6) < 1e-5);
    }

    #[test]
    fn ti_rejects_non_mapuot_kinds() {
        let p = Problem::random(8, 8, 0.7, 1);
        for kind in [SolverKind::Pot, SolverKind::Coffee] {
            let mut session = SolverSession::builder(kind).ti(true).build(&p);
            match session.solve(&p) {
                Err(Error::InvalidProblem(_)) => {}
                other => panic!("{}: expected InvalidProblem, got {other:?}", kind.name()),
            }
        }
    }

    #[test]
    fn eps_schedule_is_matfree_only_and_validated() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = Problem::random(8, 8, 0.7, 1);
        let mut dense = SolverSession::builder(SolverKind::MapUot)
            .eps_schedule(2.0, 3)
            .build(&p);
        assert!(matches!(dense.solve(&p), Err(Error::InvalidProblem(_))));
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut sparse = SolverSession::builder(SolverKind::MapUot)
            .eps_schedule(2.0, 3)
            .build_sparse(&sp);
        assert!(matches!(sparse.solve_sparse(&sp), Err(Error::InvalidProblem(_))));
        // The ladder must descend toward the target ε and have ≥1 rung.
        let gp = GeomProblem::random(10, 8, 2, CostKind::SqEuclidean, 0.5, 0.7, 2);
        let mut flat = SolverSession::builder(SolverKind::MapUot)
            .eps_schedule(0.5, 3)
            .build_matfree(&gp);
        assert!(matches!(flat.solve_matfree(&gp), Err(Error::InvalidProblem(_))));
        let mut zero = SolverSession::builder(SolverKind::MapUot)
            .eps_schedule(2.0, 0)
            .build_matfree(&gp);
        assert!(matches!(zero.solve_matfree(&gp), Err(Error::InvalidProblem(_))));
    }

    /// The ε ladder lands on the same answer as a plain matfree solve —
    /// the coarse rungs only reposition the start.
    #[test]
    fn eps_schedule_converges_to_the_plain_answer() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = GeomProblem::random(18, 14, 3, CostKind::SqEuclidean, 0.3, 0.7, 5);
        let mut plain = SolverSession::builder(SolverKind::MapUot).check_every(1).build_matfree(&p);
        let rp = plain.solve_matfree(&p).unwrap();
        let mut laddered = SolverSession::builder(SolverKind::MapUot)
            .check_every(1)
            .eps_schedule(1.2, 3)
            .build_matfree(&p);
        let rl = laddered.solve_matfree(&p).unwrap();
        assert!(rp.converged && rl.converged);
        let a = plain.matfree_materialize(&p).unwrap();
        let b = laddered.matfree_materialize(&p).unwrap();
        assert!(b.max_rel_diff(&a, 1e-6) < 1e-4);
        // Reported iterations include the ladder rungs.
        assert!(rl.iters >= 3);
    }

    #[test]
    fn deadline_observer_cancels_with_typed_error() {
        let p = Problem::random(16, 16, 0.7, 9);
        let mut session = SolverSession::builder(SolverKind::MapUot)
            .check_every(4)
            .stop(StopRule { tol: -1.0, delta_tol: -1.0, max_iter: 1_000_000 })
            .observer(Deadline::within(Duration::from_millis(0)))
            .build(&p);
        match session.solve(&p) {
            Err(Error::Canceled { iters }) => assert_eq!(iters, 4),
            other => panic!("expected Canceled, got {other:?}"),
        }
    }

    /// The cloned/batch comparators return exactly what the in-place
    /// solves left in the session state.
    #[test]
    fn sparse_and_matfree_comparators_match_in_place_state() {
        use crate::algo::matfree::{CostKind, GeomProblem};
        let p = Problem::random(14, 12, 0.7, 8);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        let mut session = SolverSession::builder(SolverKind::MapUot).build_sparse(&sp);
        let (plan, report) = session.solve_sparse_cloned(&sp).unwrap();
        assert!(report.iters > 0);
        assert_eq!(plan.values, session.sparse_plan().unwrap().values);
        let batch = session.solve_sparse_batch(std::slice::from_ref(&sp));
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].as_ref().unwrap().0.values, plan.values);

        let gp = GeomProblem::random(12, 10, 2, CostKind::SqEuclidean, 0.4, 0.7, 3);
        let mut mf = SolverSession::builder(SolverKind::MapUot).build_matfree(&gp);
        let (dense, mf_report) = mf.solve_matfree_cloned(&gp).unwrap();
        assert!(mf_report.iters > 0);
        let materialized = mf.matfree_materialize(&gp).unwrap();
        assert_eq!(dense.as_slice(), materialized.as_slice());
        let mf_batch = mf.solve_matfree_batch(std::slice::from_ref(&gp));
        assert_eq!(mf_batch.len(), 1);
        assert_eq!(mf_batch[0].as_ref().unwrap().0.as_slice(), dense.as_slice());
    }
}
