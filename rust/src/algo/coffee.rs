//! COFFEE comparator: phase-fused, matrix-granularity sweeps.
//!
//! COFFEE (Sun et al., TPDS 2023) optimizes the Sinkhorn-Knopp loop with
//! CPU-oriented fusion: the *sum for the next phase* is folded into the
//! current scaling pass, so one iteration is two full read+write sweeps —
//!   A. column-rescale each row while accumulating its row sum
//!   B. row-rescale each row while accumulating next column sums
//! — 4·M·N element accesses per iteration, all row-major. What it does NOT
//! do (the paper's point, §1 and §2.3) is interweave the two phases at row
//! granularity: sweep B re-streams the whole matrix from DRAM because by
//! the time a row is rescaled in B, it has long been evicted. MAP-UOT's
//! single fused double-loop removes exactly that second stream.

use crate::algo::scaling::{factor, factors_into};
use crate::util::Matrix;

/// One COFFEE iteration (column then row rescaling, carried `colsum`),
/// allocation-free: `fcol` (length N) and `rowsum` (length M) are
/// caller-provided scratch (see `session::Workspace`).
pub fn iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    rowsum: &mut [f32],
) {
    let m = plan.rows();
    debug_assert_eq!(colsum.len(), plan.cols());

    // Phase A: column rescaling fused with row-sum accumulation.
    factors_into(fcol, cpd, colsum, fi);
    // Same 16-lane fused primitive as MAP-UOT: COFFEE's CPU optimizations
    // include vectorization, so the comparator gets the identical inner loop.
    for i in 0..m {
        rowsum[i] = crate::algo::mapuot::scale_by_vec_and_sum(plan.row_mut(i), fcol);
    }

    // Phase B: row rescaling fused with next-column-sum accumulation.
    colsum.fill(0.0);
    for i in 0..m {
        let fr = factor(rpd[i], rowsum[i], fi);
        for (v, s) in plan.row_mut(i).iter_mut().zip(colsum.iter_mut()) {
            *v *= fr;
            *s += *v;
        }
    }
}

/// [`iterate_into`] with in-sweep delta tracking; returns the iteration's
/// max element change. Phase B holds `v1 = v0 · Factor_col[j]`, so the
/// pre-iteration value is recovered as `v1 · inv_fcol[j]` — no snapshot.
#[allow(clippy::too_many_arguments)]
pub fn iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
) -> f32 {
    let m = plan.rows();
    debug_assert_eq!(colsum.len(), plan.cols());

    factors_into(fcol, cpd, colsum, fi);
    crate::algo::scaling::recip_into(inv_fcol, fcol);
    for i in 0..m {
        rowsum[i] = crate::algo::mapuot::scale_by_vec_and_sum(plan.row_mut(i), fcol);
    }

    colsum.fill(0.0);
    let mut delta = 0f32;
    for i in 0..m {
        let fr = factor(rpd[i], rowsum[i], fi);
        delta = delta.max(crate::algo::mapuot::scale_by_scalar_and_accumulate_tracked(
            plan.row_mut(i),
            fr,
            inv_fcol,
            colsum,
        ));
    }
    delta
}

/// One COFFEE iteration; allocates its own scratch — prefer
/// [`iterate_into`] on hot paths.
// uotlint: allow(alloc) — documented legacy wrapper, not a hot path.
pub fn iterate(plan: &mut Matrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let mut fcol = vec![0f32; plan.cols()];
    let mut rowsum = vec![0f32; plan.rows()];
    iterate_into(plan, colsum, rpd, cpd, fi, &mut fcol, &mut rowsum);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{pot, problem::Problem};

    #[test]
    fn matches_pot_one_iteration() {
        let p = Problem::random(9, 11, 0.7, 5);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        iterate(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi);

        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        pot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);

        assert!(a.max_rel_diff(&b, 1e-6) < 1e-4);
        for (x, y) in cs_a.iter().zip(&cs_b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn carried_colsum_is_exact() {
        let p = Problem::random(7, 6, 0.9, 8);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi);
        for (carried, fresh) in cs.iter().zip(a.col_sums()) {
            assert!((carried - fresh).abs() < 1e-4);
        }
    }
}
