//! Sparse MAP-UOT (paper §6 future work: "explore how to apply our
//! approach to sparse matrices").
//!
//! CSR storage, one fused pass per iteration exactly as Algorithm 1: for
//! each row, scale its nonzeros by `Factor_col[col]` while accumulating
//! `Sum_row`, then rescale by `Factor_row` while accumulating
//! `NextSum_col`. The interweaving benefit *grows* for sparse data: the
//! unfused baseline streams `values`+`col_idx` (8 B/nnz) four times per
//! iteration while the fused pass streams them once — and the column
//! rescaling of a CSR matrix is naturally row-ordered here, where a
//! column-ordered implementation would be a cache-hostile scatter.
//!
//! Zero structure is preserved exactly (rescaling never creates nonzeros),
//! so the sparse solve matches the dense solvers on the same support —
//! asserted in the tests.

use crate::algo::scaling::{factor, factors_into};
use crate::error::{Error, Result};
use crate::util::Matrix;

/// CSR matrix of nonnegative f32.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub m: usize,
    pub n: usize,
    /// Row start offsets, length m+1.
    pub row_ptr: Vec<usize>,
    /// Column indices, length nnz, ascending within a row.
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense matrix, dropping entries `<= threshold`.
    pub fn from_dense(dense: &Matrix, threshold: f32) -> Self {
        let (m, n) = (dense.rows(), dense.cols());
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for i in 0..m {
            for (j, &v) in dense.row(i).iter().enumerate() {
                if v > threshold {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Self { m, n, row_ptr, col_idx, values }
    }

    /// Validated constructor from raw CSR parts.
    pub fn new(
        m: usize,
        n: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if row_ptr.len() != m + 1 || *row_ptr.last().unwrap_or(&1) != values.len() {
            return Err(Error::InvalidProblem("bad CSR row_ptr".into()));
        }
        if col_idx.len() != values.len() {
            return Err(Error::InvalidProblem("CSR col/val length mismatch".into()));
        }
        if col_idx.iter().any(|&j| j as usize >= n) {
            return Err(Error::InvalidProblem("CSR column index out of range".into()));
        }
        if values.iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err(Error::InvalidProblem("CSR values must be nonnegative".into()));
        }
        Ok(Self { m, n, row_ptr, col_idx, values })
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column sums (one pass over nnz).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n];
        for (&j, &v) in self.col_idx.iter().zip(&self.values) {
            out[j as usize] += v;
        }
        out
    }

    /// Row sums.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.m)
            .map(|i| self.values[self.row_ptr[i]..self.row_ptr[i + 1]].iter().sum())
            .collect()
    }

    /// Densify (tests / small outputs).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.m, self.n);
        for i in 0..self.m {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                out.set(i, self.col_idx[k] as usize, self.values[k]);
            }
        }
        out
    }
}

/// One fused sparse MAP-UOT iteration (CSR Algorithm 1).
pub fn iterate(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
) {
    debug_assert_eq!(colsum.len(), a.n);
    let mut fcol = vec![0f32; a.n];
    factors_into(&mut fcol, cpd, colsum, fi);
    colsum.fill(0.0);

    for i in 0..a.m {
        let (lo, hi) = (a.row_ptr[i], a.row_ptr[i + 1]);
        // Computations I + II over the row's nonzeros.
        let mut sum_row = 0f32;
        for k in lo..hi {
            let v = a.values[k] * fcol[a.col_idx[k] as usize];
            a.values[k] = v;
            sum_row += v;
        }
        // Computations III + IV.
        let fr = factor(rpd[i], sum_row, fi);
        for k in lo..hi {
            let v = a.values[k] * fr;
            a.values[k] = v;
            colsum[a.col_idx[k] as usize] += v;
        }
    }
}

/// Unfused 4-pass sparse baseline (POT sweep structure on CSR) — the
/// comparator for the sparse ablation bench.
pub fn iterate_baseline(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
) {
    // Sweep 1: column sums.
    let sums = a.col_sums();
    let mut fcol = vec![0f32; a.n];
    factors_into(&mut fcol, cpd, &sums, fi);
    // Sweep 2: column rescale.
    for (&j, v) in a.col_idx.iter().zip(a.values.iter_mut()) {
        *v *= fcol[j as usize];
    }
    // Sweep 3: row sums.
    let rowsum = a.row_sums();
    // Sweep 4: row rescale.
    for i in 0..a.m {
        let fr = factor(rpd[i], rowsum[i], fi);
        for v in &mut a.values[a.row_ptr[i]..a.row_ptr[i + 1]] {
            *v *= fr;
        }
    }
    let fresh = a.col_sums();
    colsum.copy_from_slice(&fresh);
}

/// Solve to a fixed iteration budget; returns final column sums.
pub fn solve(a: &mut CsrMatrix, rpd: &[f32], cpd: &[f32], fi: f32, iters: usize) -> Vec<f32> {
    let mut colsum = a.col_sums();
    for _ in 0..iters {
        iterate(a, &mut colsum, rpd, cpd, fi);
    }
    colsum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mapuot;
    use crate::util::XorShift;

    fn sparse_problem(m: usize, n: usize, density: f32, seed: u64) -> (CsrMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift::new(seed);
        let dense = Matrix::from_fn(m, n, |_, _| {
            if rng.next_f32() < density { rng.uniform(0.1, 2.0) } else { 0.0 }
        });
        let a = CsrMatrix::from_dense(&dense, 0.0);
        let rpd = rng.uniform_vec(m, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);
        (a, rpd, cpd)
    }

    #[test]
    fn csr_roundtrip() {
        let (a, _, _) = sparse_problem(9, 13, 0.3, 1);
        let d = a.to_dense();
        let b = CsrMatrix::from_dense(&d, 0.0);
        assert_eq!(a.values, b.values);
        assert_eq!(a.col_idx, b.col_idx);
    }

    #[test]
    fn sparse_matches_dense_on_same_support() {
        let (mut a, rpd, cpd) = sparse_problem(17, 11, 0.4, 2);
        let mut dense = a.to_dense();
        let mut cs_sparse = a.col_sums();
        let mut cs_dense = dense.col_sums();
        for _ in 0..6 {
            iterate(&mut a, &mut cs_sparse, &rpd, &cpd, 0.7);
            mapuot::iterate(&mut dense, &mut cs_dense, &rpd, &cpd, 0.7);
        }
        assert!(a.to_dense().max_rel_diff(&dense, 1e-6) < 1e-3);
    }

    #[test]
    fn fused_matches_unfused_baseline() {
        let (a0, rpd, cpd) = sparse_problem(23, 19, 0.25, 3);
        let mut a = a0.clone();
        let mut b = a0.clone();
        let mut cs_a = a.col_sums();
        let mut cs_b = b.col_sums();
        for _ in 0..5 {
            iterate(&mut a, &mut cs_a, &rpd, &cpd, 0.6);
            iterate_baseline(&mut b, &mut cs_b, &rpd, &cpd, 0.6);
        }
        for (x, y) in a.values.iter().zip(&b.values) {
            assert!((x - y).abs() < 1e-3 * y.abs().max(1e-3), "{x} vs {y}");
        }
    }

    #[test]
    fn zero_structure_preserved() {
        let (mut a, rpd, cpd) = sparse_problem(12, 12, 0.2, 4);
        let nnz0 = a.nnz();
        let idx0 = a.col_idx.clone();
        solve(&mut a, &rpd, &cpd, 0.8, 10);
        assert_eq!(a.nnz(), nnz0);
        assert_eq!(a.col_idx, idx0);
        assert!(a.values.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn empty_rows_and_columns_are_safe() {
        // Row 1 and column 2 empty: factors guard to 0, nothing explodes.
        let dense = Matrix::from_fn(4, 4, |i, j| {
            if i == 1 || j == 2 { 0.0 } else { 1.0 }
        });
        let mut a = CsrMatrix::from_dense(&dense, 0.0);
        let rpd = vec![1.0; 4];
        let cpd = vec![1.0; 4];
        solve(&mut a, &rpd, &cpd, 0.5, 5);
        assert!(a.values.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn validation_errors() {
        assert!(CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // row_ptr len
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![5], vec![1.0]).is_err()); // col range
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![0], vec![-1.0]).is_err()); // negative
    }
}
