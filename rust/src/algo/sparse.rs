//! Sparse MAP-UOT (paper §6 future work: "explore how to apply our
//! approach to sparse matrices") — a first-class CSR backend.
//!
//! CSR storage, one fused pass per iteration exactly as Algorithm 1: for
//! each row, scale its nonzeros by `Factor_col[col]` while accumulating
//! `Sum_row`, then rescale by `Factor_row` while accumulating
//! `NextSum_col`. The interweaving benefit *grows* for sparse data: the
//! unfused baseline streams `values`+`col_idx` (8 B/nnz) four times per
//! iteration while the fused pass streams them once — and the column
//! rescaling of a CSR matrix is naturally row-ordered here, where a
//! column-ordered implementation would be a cache-hostile scatter.
//!
//! Zero structure is preserved exactly (rescaling never creates nonzeros),
//! so the sparse solve matches the dense solvers on the same support —
//! asserted in the tests and in `rust/tests/prop_sparse.rs`.
//!
//! The module owns four layers:
//!
//! * [`CsrMatrix`] — validated CSR storage. Both constructors enforce one
//!   contract (finite, nonnegative values; monotone `row_ptr` starting at
//!   0 and ending at nnz; in-range, strictly ascending column indices per
//!   row), returning [`Error::InvalidProblem`] instead of panicking later
//!   in `row_sums`/the sweep — the hardening this PR's bugfixes demanded.
//! * [`SparseProblem`] — a CSR plan plus marginals, the sparse twin of
//!   [`crate::algo::Problem`].
//! * [`NnzPartition`] — contiguous row blocks balanced by **nonzero
//!   count**, not row count: CSR row lengths are skewed, so an even-rows
//!   split (the dense [`Partition`](crate::algo::pool::Partition)) would
//!   hand one thread most of the work.
//! * [`SparseWorkspace`] — every scratch buffer a sparse solve needs
//!   (`Factor_col`, its reciprocals, the marginal-error column scratch,
//!   the per-thread `NextSum_col` [`AccArena`], tracked-delta slots, the
//!   nnz partition) plus the execution engine (serial, `thread::scope`,
//!   or a shared persistent [`ThreadPool`]). Same allocation contract as
//!   the dense [`Workspace`](crate::algo::Workspace): zero heap
//!   allocations on the hot path after warmup (asserted in
//!   `rust/tests/alloc_free.rs`).
//!
//! The service-facing entry point is
//! [`SolverSession::solve_sparse`](crate::algo::SolverSession::solve_sparse);
//! the free functions here ([`iterate_into`], [`iterate_tracked_into`])
//! are the serial CSR reference the parallel engines
//! (`crate::algo::parallel::sparse_mapuot_*`) are tested against.

use std::ops::Range;
use std::sync::Arc;

use crate::algo::kernels;
use crate::algo::parallel;
use crate::algo::pool::{AccArena, AffinityHint, PaddedSlots, ParallelBackend, ThreadPool};
use crate::algo::problem::Problem;
use crate::algo::scaling::{factor, factors_into, recip_into};
use crate::error::{Error, Result};
use crate::util::Matrix;

/// CSR matrix of nonnegative f32.
///
/// Invariants (enforced by both constructors, relied on by every sweep):
/// `row_ptr` has length `m + 1`, starts at 0, is non-decreasing and ends
/// at `values.len()`; `col_idx` has one in-range entry per value, strictly
/// ascending within each row; all values are finite and nonnegative.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub m: usize,
    pub n: usize,
    /// Row start offsets, length m+1.
    pub row_ptr: Vec<usize>,
    /// Column indices, length nnz, strictly ascending within a row.
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense matrix, dropping entries `<= threshold`.
    ///
    /// Enforces the same finite-nonnegative contract as [`CsrMatrix::new`]:
    /// a NaN entry is rejected (not silently dropped — `NaN > threshold`
    /// is false), and a negative threshold cannot smuggle negative values
    /// past validation.
    pub fn from_dense(dense: &Matrix, threshold: f32) -> Result<Self> {
        let (m, n) = (dense.rows(), dense.cols());
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for i in 0..m {
            for (j, &v) in dense.row(i).iter().enumerate() {
                // Validate inside the single conversion pass (a separate
                // prescan would stream the whole M·N matrix twice on the
                // per-request service path).
                if !v.is_finite() || v < 0.0 {
                    return Err(Error::InvalidProblem(
                        "dense source of a CSR matrix has negative/non-finite entries".into(),
                    ));
                }
                if v > threshold {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Ok(Self { m, n, row_ptr, col_idx, values })
    }

    /// Validated constructor from raw CSR parts.
    ///
    /// Returns [`Error::InvalidProblem`] for every malformed input —
    /// including a `row_ptr` that is non-monotonic or does not start at 0,
    /// which previously passed construction and panicked on slice
    /// indexing inside `row_sums`/the fused sweep.
    pub fn new(
        m: usize,
        n: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self> {
        if row_ptr.len() != m + 1 {
            return Err(Error::InvalidProblem(format!(
                "CSR row_ptr length {} != m + 1 = {}",
                row_ptr.len(),
                m + 1
            )));
        }
        if row_ptr[0] != 0 {
            return Err(Error::InvalidProblem("CSR row_ptr must start at 0".into()));
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(Error::InvalidProblem("CSR row_ptr must be non-decreasing".into()));
        }
        if *row_ptr.last().expect("length checked") != values.len() {
            return Err(Error::InvalidProblem(format!(
                "CSR row_ptr ends at {} but there are {} values",
                row_ptr.last().expect("length checked"),
                values.len()
            )));
        }
        if col_idx.len() != values.len() {
            return Err(Error::InvalidProblem("CSR col/val length mismatch".into()));
        }
        // Per-row checks are safe now: every row_ptr window is a valid,
        // ordered range into col_idx.
        for w in row_ptr.windows(2) {
            let row = &col_idx[w[0]..w[1]];
            if row.iter().any(|&j| j as usize >= n) {
                return Err(Error::InvalidProblem("CSR column index out of range".into()));
            }
            if row.windows(2).any(|c| c[0] >= c[1]) {
                return Err(Error::InvalidProblem(
                    "CSR col_idx must be strictly ascending within a row".into(),
                ));
            }
        }
        if values.iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err(Error::InvalidProblem(
                "CSR values must be finite and nonnegative".into(),
            ));
        }
        Ok(Self { m, n, row_ptr, col_idx, values })
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// nnz / (m·n), the figure the density sweep reports.
    pub fn density(&self) -> f64 {
        if self.m == 0 || self.n == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.m as f64 * self.n as f64)
        }
    }

    /// Column sums into caller scratch (one pass over nnz, no allocation —
    /// the session seeds its carried `colsum` through this).
    pub fn col_sums_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for (&j, &v) in self.col_idx.iter().zip(&self.values) {
            out[j as usize] += v;
        }
    }

    /// Column sums (one pass over nnz).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n];
        self.col_sums_into(&mut out);
        out
    }

    /// Row sums.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.m)
            .map(|i| self.values[self.row_ptr[i]..self.row_ptr[i + 1]].iter().sum())
            .collect()
    }

    /// Densify (tests / small outputs / the coordinator's response path).
    /// Requires positive dims (guaranteed for any [`SparseProblem`] plan).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.m, self.n);
        for i in 0..self.m {
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                out.set(i, self.col_idx[k] as usize, self.values[k]);
            }
        }
        out
    }
}

/// A sparse UOT instance: CSR plan plus marginals — the sparse twin of
/// [`Problem`], with the same validation contract.
#[derive(Debug, Clone)]
pub struct SparseProblem {
    /// Transport plan on its sparse support (structure is preserved by
    /// every iteration — rescaling never creates nonzeros).
    pub plan: CsrMatrix,
    /// Row probability distribution (target row marginals), length M.
    pub rpd: Vec<f32>,
    /// Column probability distribution (target column marginals), length N.
    pub cpd: Vec<f32>,
    /// Relaxation exponent in `(0, 1]`.
    pub fi: f32,
}

impl SparseProblem {
    /// Validated constructor (the plan is already CSR-validated by its own
    /// constructors).
    pub fn new(plan: CsrMatrix, rpd: Vec<f32>, cpd: Vec<f32>, fi: f32) -> Result<Self> {
        if plan.m == 0 || plan.n == 0 {
            return Err(Error::InvalidProblem("sparse problem dims must be positive".into()));
        }
        if rpd.len() != plan.m {
            return Err(Error::InvalidProblem(format!(
                "rpd length {} != rows {}",
                rpd.len(),
                plan.m
            )));
        }
        if cpd.len() != plan.n {
            return Err(Error::InvalidProblem(format!(
                "cpd length {} != cols {}",
                cpd.len(),
                plan.n
            )));
        }
        if !(fi > 0.0 && fi <= 1.0) {
            return Err(Error::InvalidProblem(format!("fi={fi} outside (0, 1]")));
        }
        if rpd.iter().chain(cpd.iter()).any(|v| !v.is_finite() || *v <= 0.0) {
            return Err(Error::InvalidProblem("marginals must be positive and finite".into()));
        }
        Ok(Self { plan, rpd, cpd, fi })
    }

    /// Sparsify a dense problem: keep plan entries `> threshold` (CSR),
    /// share the marginals. This is the CLI `solve --sparse <threshold>` /
    /// `[solver] sparse` adapter.
    pub fn from_problem(p: &Problem, threshold: f32) -> Result<Self> {
        if !threshold.is_finite() || threshold < 0.0 {
            return Err(Error::InvalidProblem(format!(
                "sparse threshold {threshold} must be finite and >= 0"
            )));
        }
        let plan = CsrMatrix::from_dense(&p.plan, threshold)?;
        Self::new(plan, p.rpd.clone(), p.cpd.clone(), p.fi)
    }

    pub fn rows(&self) -> usize {
        self.plan.m
    }

    pub fn cols(&self) -> usize {
        self.plan.n
    }

    pub fn nnz(&self) -> usize {
        self.plan.nnz()
    }
}

// ---------------------------------------------------------------------------
// nnz-balanced row partition
// ---------------------------------------------------------------------------

/// Contiguous row blocks balanced by **nonzero count**.
///
/// The dense solvers split rows evenly because every dense row costs the
/// same; CSR row lengths are skewed, so block `b` here ends at the largest
/// row whose cumulative nnz stays below the `b`-th even share (while
/// always keeping at least one row for every remaining block). Both
/// parallel engines consume the *same* partition instance, which is what
/// makes them bit-identical (see `crate::algo::parallel`).
#[derive(Debug, Clone)]
pub struct NnzPartition {
    /// Row boundaries, length blocks + 1 (`bounds[0] = 0`,
    /// `bounds[blocks] = m`).
    bounds: Vec<usize>,
}

impl NnzPartition {
    /// Partition the rows of `row_ptr` (length m+1) over at most `threads`
    /// blocks, further capped by `cap` (the number of available
    /// accumulators).
    pub fn new(row_ptr: &[usize], threads: usize, cap: usize) -> Self {
        let mut p = Self::empty(threads);
        p.rebuild(row_ptr, threads, cap);
        p
    }

    /// Placeholder partition over zero rows, with capacity for `threads`
    /// blocks; [`NnzPartition::rebuild`] before use.
    pub fn empty(threads: usize) -> Self {
        let mut bounds = Vec::with_capacity(threads.max(1) + 1);
        bounds.push(0);
        bounds.push(0);
        Self { bounds }
    }

    /// Recompute in place for a (possibly new) structure. Allocation-free
    /// whenever `threads` has not grown past the construction-time
    /// capacity — the workspace calls this once per solve.
    pub fn rebuild(&mut self, row_ptr: &[usize], threads: usize, cap: usize) {
        let m = row_ptr.len().saturating_sub(1);
        let nnz = row_ptr.last().copied().unwrap_or(0);
        let blocks = threads.max(1).min(m.max(1)).min(cap.max(1));
        self.bounds.clear();
        self.bounds.push(0);
        let mut r = 0usize;
        for b in 1..blocks {
            // Largest end whose nnz prefix stays below the b-th even
            // share, while leaving >= 1 row for every remaining block.
            let max_end = m - (blocks - b);
            let target = (nnz as u128 * b as u128 / blocks as u128) as usize;
            let mut end = r + 1;
            while end < max_end && row_ptr[end] < target {
                end += 1;
            }
            self.bounds.push(end);
            r = end;
        }
        self.bounds.push(m);
    }

    /// Number of blocks (== parts to dispatch).
    pub fn blocks(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Total rows partitioned.
    pub fn rows(&self) -> usize {
        *self.bounds.last().expect("bounds never empty")
    }

    /// Row range of block `b`.
    pub fn range(&self, b: usize) -> Range<usize> {
        self.bounds[b]..self.bounds[b + 1]
    }
}

// ---------------------------------------------------------------------------
// The fused sweep (shared block body + serial entry points)
// ---------------------------------------------------------------------------

/// Fused sparse MAP-UOT pass over the rows `rows` of a CSR matrix
/// (Computations I–IV per row over its nonzeros), accumulating
/// `NextSum_col` into `local`. `vals` is the values sub-slice covering
/// exactly those rows and `base` its offset into the full values array;
/// tracked (returns the block's max element change) when `inv` is given.
///
/// Every execution mode funnels through this body — the serial reference
/// calls it once over all rows, each thread of the parallel engines over
/// its partition block — so per-row numerics are identical everywhere.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_csr_rows(
    vals: &mut [f32],
    base: usize,
    row_ptr: &[usize],
    col_idx: &[u32],
    rows: Range<usize>,
    rpd: &[f32],
    fcol: &[f32],
    inv: Option<&[f32]>,
    fi: f32,
    local: &mut [f32],
) -> f32 {
    let mut delta = 0f32;
    for i in rows {
        let (lo, hi) = (row_ptr[i] - base, row_ptr[i + 1] - base);
        let cols = &col_idx[row_ptr[i]..row_ptr[i + 1]];
        let row = &mut vals[lo..hi];
        // Computations I + II over the row's nonzeros.
        let sum_row = kernels::csr_scale_by_cols_and_sum(row, cols, fcol);
        // Computations III + IV.
        let fr = factor(rpd[i], sum_row, fi);
        match inv {
            Some(iv) => {
                delta = delta
                    .max(kernels::csr_scale_and_accumulate_tracked(row, cols, fr, iv, local));
            }
            None => kernels::csr_scale_and_accumulate(row, cols, fr, local),
        }
    }
    delta
}

/// One fused sparse MAP-UOT iteration (CSR Algorithm 1), allocation-free:
/// `fcol` (length N) is caller scratch — the hot-path form the PR 1
/// allocation contract requires (the old `iterate` allocated a fresh
/// `fcol` every iteration).
pub fn iterate_into(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
) {
    debug_assert_eq!(colsum.len(), a.n);
    debug_assert_eq!(fcol.len(), a.n);
    factors_into(fcol, cpd, colsum, fi);
    colsum.fill(0.0); // becomes NextSum_col
    fused_csr_rows(
        &mut a.values,
        0,
        &a.row_ptr,
        &a.col_idx,
        0..a.m,
        rpd,
        fcol,
        None,
        fi,
        colsum,
    );
}

/// [`iterate_into`] with in-sweep delta tracking; returns the iteration's
/// max element change (same reciprocal-factor recovery as the dense
/// kernels — no snapshot, no extra pass). `fcol` and `inv_fcol` are
/// caller scratch of length N.
pub fn iterate_tracked_into(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
) -> f32 {
    debug_assert_eq!(colsum.len(), a.n);
    debug_assert_eq!(fcol.len(), a.n);
    debug_assert_eq!(inv_fcol.len(), a.n);
    factors_into(fcol, cpd, colsum, fi);
    recip_into(inv_fcol, fcol);
    colsum.fill(0.0); // becomes NextSum_col
    fused_csr_rows(
        &mut a.values,
        0,
        &a.row_ptr,
        &a.col_idx,
        0..a.m,
        rpd,
        &*fcol,
        Some(&*inv_fcol),
        fi,
        colsum,
    )
}

/// One fused sparse MAP-UOT iteration; allocates its own column-factor
/// scratch — prefer [`iterate_into`] on hot paths.
// uotlint: allow(alloc) — documented legacy wrapper, not a hot path.
pub fn iterate(a: &mut CsrMatrix, colsum: &mut [f32], rpd: &[f32], cpd: &[f32], fi: f32) {
    let mut fcol = vec![0f32; a.n];
    iterate_into(a, colsum, rpd, cpd, fi, &mut fcol);
}

/// Unfused 4-pass sparse baseline (POT sweep structure on CSR) — the
/// comparator for the sparse ablation bench. Allocates per call by
/// design: it models the unfused execution, not a production path.
// uotlint: allow(alloc) — unfused ablation baseline, allocates by design.
pub fn iterate_baseline(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
) {
    // Sweep 1: column sums.
    let sums = a.col_sums();
    let mut fcol = vec![0f32; a.n];
    factors_into(&mut fcol, cpd, &sums, fi);
    // Sweep 2: column rescale.
    for (&j, v) in a.col_idx.iter().zip(a.values.iter_mut()) {
        *v *= fcol[j as usize];
    }
    // Sweep 3: row sums.
    let rowsum = a.row_sums();
    // Sweep 4: row rescale.
    for i in 0..a.m {
        let fr = factor(rpd[i], rowsum[i], fi);
        for v in &mut a.values[a.row_ptr[i]..a.row_ptr[i + 1]] {
            *v *= fr;
        }
    }
    let fresh = a.col_sums();
    colsum.copy_from_slice(&fresh);
}

/// Solve to a fixed iteration budget; returns final column sums.
pub fn solve(a: &mut CsrMatrix, rpd: &[f32], cpd: &[f32], fi: f32, iters: usize) -> Vec<f32> {
    let mut colsum = a.col_sums();
    let mut fcol = vec![0f32; a.n];
    for _ in 0..iters {
        iterate_into(a, &mut colsum, rpd, cpd, fi, &mut fcol);
    }
    colsum
}

// ---------------------------------------------------------------------------
// SparseWorkspace
// ---------------------------------------------------------------------------

/// Scratch and engine for sparse solves, reused across iterations and
/// solves — the sparse twin of [`crate::algo::Workspace`].
///
/// # Allocation contract
///
/// Construction and [`SparseWorkspace::ensure_shape`] growth may allocate;
/// [`SparseWorkspace::prepare`], [`SparseWorkspace::iterate`],
/// [`SparseWorkspace::iterate_tracked`] and
/// [`SparseWorkspace::marginal_error`] must not (the nnz partition is
/// rebuilt into retained capacity). Asserted by `rust/tests/alloc_free.rs`
/// through the session path.
#[derive(Debug)]
pub struct SparseWorkspace {
    shape: (usize, usize),
    threads: usize,
    backend: ParallelBackend,
    /// Column rescaling factors (`Factor_col`), length N.
    fcol: Vec<f32>,
    /// Reciprocals of `fcol` (zero-guarded) for in-sweep delta tracking.
    inv_fcol: Vec<f32>,
    /// Column-sum scratch for the marginal-error check.
    err_cols: Vec<f32>,
    /// Per-thread `NextSum_col` partials, cache-line-padded.
    acc: AccArena,
    /// Per-thread tracked-delta maxima, one cache line each.
    delta_slots: PaddedSlots,
    /// nnz-balanced row blocks, rebuilt per solve by `prepare`.
    part: NnzPartition,
    /// The persistent execution engine (pool backend, `threads > 1`).
    pool: Option<Arc<ThreadPool>>,
}

impl SparseWorkspace {
    /// Workspace for `m × n` sparse problems with `threads` workers on the
    /// default pool backend (workers spawned here, once).
    pub fn new(m: usize, n: usize, threads: usize) -> Self {
        Self::with_backend(m, n, threads, ParallelBackend::Pool, AffinityHint::None)
    }

    /// Workspace with an explicit parallel backend and affinity hint.
    pub fn with_backend(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        affinity: AffinityHint,
    ) -> Self {
        let threads = threads.max(1);
        let pool = (threads > 1 && backend == ParallelBackend::Pool)
            .then(|| Arc::new(ThreadPool::with_affinity(threads, affinity)));
        Self::with_engine(m, n, threads, backend, pool)
    }

    /// Workspace sharing an existing pool (its thread count wins) — the
    /// form [`crate::algo::SolverSession`] uses so one session's dense and
    /// sparse paths drive the same workers.
    pub fn with_pool(m: usize, n: usize, pool: Arc<ThreadPool>) -> Self {
        let threads = pool.threads();
        Self::with_engine(m, n, threads, ParallelBackend::Pool, Some(pool))
    }

    /// Fully explicit assembly (an existing pool may be shared, or absent
    /// for the serial / scope engines).
    pub fn with_engine(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        pool: Option<Arc<ThreadPool>>,
    ) -> Self {
        let threads = match &pool {
            Some(p) => p.threads(),
            None => threads.max(1),
        };
        Self {
            shape: (m, n),
            threads,
            backend,
            fcol: vec![0f32; n],
            inv_fcol: vec![0f32; n],
            err_cols: vec![0f32; n],
            acc: AccArena::padded(threads, n),
            delta_slots: PaddedSlots::new(threads),
            part: NnzPartition::empty(threads),
            pool,
        }
    }

    /// Current `(rows, cols)` shape.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }

    /// Worker threads this workspace is provisioned for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Which parallel execution engine drives `threads > 1` iterations.
    pub fn backend(&self) -> ParallelBackend {
        self.backend
    }

    /// The persistent pool, when the pool backend is active.
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// The current nnz-balanced row partition (valid after
    /// [`SparseWorkspace::prepare`]).
    pub fn partition(&self) -> &NnzPartition {
        &self.part
    }

    /// Resize for a new shape. No-op (and allocation-free) when unchanged;
    /// growing past any previously seen size reallocates.
    pub fn ensure_shape(&mut self, m: usize, n: usize) {
        if self.shape == (m, n) {
            return;
        }
        self.shape = (m, n);
        self.fcol.resize(n, 0.0);
        self.inv_fcol.resize(n, 0.0);
        self.err_cols.resize(n, 0.0);
        self.acc.ensure_cols(n);
    }

    /// Size scratch for `plan` and rebuild the nnz partition from its
    /// structure. Allocation-free for a same-shape plan; call once per
    /// solve (or after any structure change) before iterating.
    pub fn prepare(&mut self, plan: &CsrMatrix) {
        self.ensure_shape(plan.m, plan.n);
        self.part.rebuild(&plan.row_ptr, self.threads, self.acc.rows());
    }

    /// One fused sparse iteration on this workspace's engine (serial,
    /// scope, or pool).
    pub fn iterate(
        &mut self,
        plan: &mut CsrMatrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
    ) {
        if self.threads <= 1 {
            iterate_into(plan, colsum, rpd, cpd, fi, &mut self.fcol);
        } else if let Some(pool) = &self.pool {
            parallel::sparse_mapuot_iterate_pool(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut self.fcol,
                &mut self.acc,
                &self.part,
            );
        } else {
            parallel::sparse_mapuot_iterate_into(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut self.fcol,
                &mut self.acc,
                &self.part,
            );
        }
    }

    /// [`SparseWorkspace::iterate`] with in-sweep delta tracking; returns
    /// the iteration's max element change.
    pub fn iterate_tracked(
        &mut self,
        plan: &mut CsrMatrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
    ) -> f32 {
        if self.threads <= 1 {
            iterate_tracked_into(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut self.fcol,
                &mut self.inv_fcol,
            )
        } else if let Some(pool) = &self.pool {
            parallel::sparse_mapuot_iterate_pool_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                pool,
                &mut self.fcol,
                &mut self.inv_fcol,
                &mut self.acc,
                &mut self.delta_slots,
                &self.part,
            )
        } else {
            parallel::sparse_mapuot_iterate_tracked(
                plan,
                colsum,
                rpd,
                cpd,
                fi,
                &mut self.fcol,
                &mut self.inv_fcol,
                &mut self.acc,
                &self.part,
            )
        }
    }

    /// Marginal L-inf error of `plan` against `(rpd, cpd)` in one pass
    /// over nnz, using workspace scratch (no allocation). Empty rows and
    /// columns contribute their full target mass, matching the dense
    /// definition on the same support.
    pub fn marginal_error(&mut self, plan: &CsrMatrix, rpd: &[f32], cpd: &[f32]) -> f32 {
        debug_assert_eq!(rpd.len(), plan.m);
        debug_assert_eq!(cpd.len(), plan.n);
        let cs = &mut self.err_cols[..plan.n];
        cs.fill(0.0);
        let mut row_err = 0f32;
        for i in 0..plan.m {
            let mut rs = 0f32;
            for k in plan.row_ptr[i]..plan.row_ptr[i + 1] {
                let v = plan.values[k];
                rs += v;
                cs[plan.col_idx[k] as usize] += v;
            }
            row_err = row_err.max((rs - rpd[i]).abs());
        }
        let col_err = cs
            .iter()
            .zip(cpd)
            .map(|(s, &t)| (s - t).abs())
            .fold(0f32, f32::max);
        row_err.max(col_err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mapuot;
    use crate::util::XorShift;

    fn sparse_problem(m: usize, n: usize, density: f32, seed: u64) -> (CsrMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = XorShift::new(seed);
        let dense = Matrix::from_fn(m, n, |_, _| {
            if rng.next_f32() < density { rng.uniform(0.1, 2.0) } else { 0.0 }
        });
        let a = CsrMatrix::from_dense(&dense, 0.0).expect("finite nonnegative source");
        let rpd = rng.uniform_vec(m, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);
        (a, rpd, cpd)
    }

    #[test]
    fn csr_roundtrip() {
        let (a, _, _) = sparse_problem(9, 13, 0.3, 1);
        let d = a.to_dense();
        let b = CsrMatrix::from_dense(&d, 0.0).unwrap();
        assert_eq!(a.values, b.values);
        assert_eq!(a.col_idx, b.col_idx);
    }

    #[test]
    fn sparse_matches_dense_on_same_support() {
        let (mut a, rpd, cpd) = sparse_problem(17, 11, 0.4, 2);
        let mut dense = a.to_dense();
        let mut cs_sparse = a.col_sums();
        let mut cs_dense = dense.col_sums();
        for _ in 0..6 {
            iterate(&mut a, &mut cs_sparse, &rpd, &cpd, 0.7);
            mapuot::iterate(&mut dense, &mut cs_dense, &rpd, &cpd, 0.7);
        }
        assert!(a.to_dense().max_rel_diff(&dense, 1e-6) < 1e-3);
    }

    #[test]
    fn fused_matches_unfused_baseline() {
        let (a0, rpd, cpd) = sparse_problem(23, 19, 0.25, 3);
        let mut a = a0.clone();
        let mut b = a0.clone();
        let mut cs_a = a.col_sums();
        let mut cs_b = b.col_sums();
        for _ in 0..5 {
            iterate(&mut a, &mut cs_a, &rpd, &cpd, 0.6);
            iterate_baseline(&mut b, &mut cs_b, &rpd, &cpd, 0.6);
        }
        for (x, y) in a.values.iter().zip(&b.values) {
            assert!((x - y).abs() < 1e-3 * y.abs().max(1e-3), "{x} vs {y}");
        }
    }

    #[test]
    fn tracked_iteration_is_bit_identical_to_untracked() {
        let (a0, rpd, cpd) = sparse_problem(19, 23, 0.3, 7);
        let mut a = a0.clone();
        let mut b = a0.clone();
        let mut cs_a = a.col_sums();
        let mut cs_b = b.col_sums();
        let n = a.n;
        let mut fcol_a = vec![0f32; n];
        let mut fcol_b = vec![0f32; n];
        let mut inv_b = vec![0f32; n];
        for _ in 0..5 {
            iterate_into(&mut a, &mut cs_a, &rpd, &cpd, 0.7, &mut fcol_a);
            let _ =
                iterate_tracked_into(&mut b, &mut cs_b, &rpd, &cpd, 0.7, &mut fcol_b, &mut inv_b);
        }
        assert_eq!(a.values, b.values);
        assert_eq!(cs_a, cs_b);
    }

    #[test]
    fn zero_structure_preserved() {
        let (mut a, rpd, cpd) = sparse_problem(12, 12, 0.2, 4);
        let nnz0 = a.nnz();
        let idx0 = a.col_idx.clone();
        solve(&mut a, &rpd, &cpd, 0.8, 10);
        assert_eq!(a.nnz(), nnz0);
        assert_eq!(a.col_idx, idx0);
        assert!(a.values.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn empty_rows_and_columns_are_safe() {
        // Row 1 and column 2 empty: factors guard to 0, nothing explodes.
        let dense = Matrix::from_fn(4, 4, |i, j| {
            if i == 1 || j == 2 { 0.0 } else { 1.0 }
        });
        let mut a = CsrMatrix::from_dense(&dense, 0.0).unwrap();
        let rpd = vec![1.0; 4];
        let cpd = vec![1.0; 4];
        solve(&mut a, &rpd, &cpd, 0.5, 5);
        assert!(a.values.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn validation_errors() {
        assert!(CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // row_ptr len
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![5], vec![1.0]).is_err()); // col range
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 1], vec![0], vec![-1.0]).is_err()); // negative
        // The former panics: non-monotonic row_ptr and row_ptr[0] != 0 now
        // fail validation instead of exploding in row_sums/iterate.
        assert!(CsrMatrix::new(2, 2, vec![0, 2, 1], vec![0], vec![1.0]).is_err()); // non-monotonic
        assert!(CsrMatrix::new(2, 2, vec![1, 1, 1], vec![0], vec![1.0]).is_err()); // start != 0
        assert!(CsrMatrix::new(2, 2, vec![0, 1, 2], vec![0], vec![1.0]).is_err()); // end != nnz
        assert!(CsrMatrix::new(1, 2, vec![0, 2], vec![1, 0], vec![1.0, 1.0]).is_err()); // not ascending
        assert!(CsrMatrix::new(1, 2, vec![0, 2], vec![0, 0], vec![1.0, 1.0]).is_err()); // duplicate col
        assert!(CsrMatrix::new(1, 1, vec![0, 1], vec![0], vec![f32::NAN]).is_err()); // NaN value
    }

    #[test]
    fn from_dense_enforces_the_finite_nonnegative_contract() {
        let nan = Matrix::from_fn(2, 2, |i, j| if i == 0 && j == 1 { f32::NAN } else { 1.0 });
        assert!(CsrMatrix::from_dense(&nan, 0.0).is_err(), "NaN must be rejected, not dropped");
        let neg = Matrix::from_fn(2, 2, |i, _| if i == 0 { -1.0 } else { 1.0 });
        assert!(
            CsrMatrix::from_dense(&neg, -2.0).is_err(),
            "a negative threshold must not admit negative values"
        );
    }

    #[test]
    fn sparse_problem_validation() {
        let (a, rpd, cpd) = sparse_problem(5, 4, 0.5, 9);
        assert!(SparseProblem::new(a.clone(), rpd.clone(), cpd.clone(), 0.7).is_ok());
        assert!(SparseProblem::new(a.clone(), vec![1.0; 3], cpd.clone(), 0.7).is_err());
        assert!(SparseProblem::new(a.clone(), rpd.clone(), cpd.clone(), 0.0).is_err());
        assert!(SparseProblem::new(a, vec![-1.0, 1.0, 1.0, 1.0, 1.0], cpd, 0.7).is_err());
        let p = Problem::random(6, 6, 0.7, 3);
        assert!(SparseProblem::from_problem(&p, f32::NAN).is_err());
        assert!(SparseProblem::from_problem(&p, -0.5).is_err());
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        assert!(sp.nnz() > 0 && sp.nnz() < 36);
    }

    #[test]
    fn nnz_partition_tiles_and_balances() {
        // Skewed structure: row 0 carries half the nonzeros.
        let mut rng = XorShift::new(11);
        let dense = Matrix::from_fn(16, 64, |i, _| {
            let p = if i == 0 { 1.0 } else { 0.05 };
            if rng.next_f32() < p { 1.0 } else { 0.0 }
        });
        let a = CsrMatrix::from_dense(&dense, 0.0).unwrap();
        for threads in [1usize, 2, 3, 8, 16, 64] {
            let part = NnzPartition::new(&a.row_ptr, threads, threads);
            assert!(part.blocks() <= threads.max(1));
            assert!(part.blocks() <= a.m);
            assert_eq!(part.rows(), a.m, "threads={threads}");
            // Ranges tile [0, m) with no empty block.
            let mut next = 0;
            for b in 0..part.blocks() {
                let r = part.range(b);
                assert_eq!(r.start, next, "threads={threads}");
                assert!(r.end > r.start, "threads={threads} block {b} empty");
                next = r.end;
            }
            assert_eq!(next, a.m);
            // nnz balance: no block exceeds the even share by more than
            // the largest single row (rows are atomic).
            let max_row = (0..a.m).map(|i| a.row_ptr[i + 1] - a.row_ptr[i]).max().unwrap();
            for b in 0..part.blocks() {
                let r = part.range(b);
                let block_nnz = a.row_ptr[r.end] - a.row_ptr[r.start];
                assert!(
                    block_nnz <= a.nnz() / part.blocks() + max_row,
                    "threads={threads} block {b}: {block_nnz} nnz of {}",
                    a.nnz()
                );
            }
        }
    }

    #[test]
    fn workspace_serial_matches_free_functions() {
        let (a0, rpd, cpd) = sparse_problem(14, 10, 0.4, 21);
        let mut ws = SparseWorkspace::new(14, 10, 1);
        ws.prepare(&a0);
        let mut a = a0.clone();
        let mut cs_a = a.col_sums();
        let mut b = a0.clone();
        let mut cs_b = b.col_sums();
        let mut fcol = vec![0f32; 10];
        let mut inv = vec![0f32; 10];
        for _ in 0..4 {
            let da = ws.iterate_tracked(&mut a, &mut cs_a, &rpd, &cpd, 0.7);
            let db = iterate_tracked_into(&mut b, &mut cs_b, &rpd, &cpd, 0.7, &mut fcol, &mut inv);
            assert_eq!(da.to_bits(), db.to_bits());
        }
        assert_eq!(a.values, b.values);
        assert_eq!(cs_a, cs_b);
    }

    #[test]
    fn workspace_marginal_error_matches_dense_definition() {
        let (a, rpd, cpd) = sparse_problem(9, 7, 0.5, 5);
        let mut ws = SparseWorkspace::new(9, 7, 1);
        ws.prepare(&a);
        let sparse_err = ws.marginal_error(&a, &rpd, &cpd);
        let dense_err = crate::algo::convergence::marginal_error(&a.to_dense(), &rpd, &cpd);
        assert!((sparse_err - dense_err).abs() <= 1e-5 * dense_err.max(1.0));
    }
}
