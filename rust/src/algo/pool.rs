//! Persistent pinned worker-pool execution engine (the "pool" backend).
//!
//! The paper's parallel results (§4.1.2, Figs. 10/12) assume workers that
//! live for the whole solve. The original `thread::scope`-per-iteration
//! dispatch in [`crate::algo::parallel`] instead creates and joins fresh OS
//! threads every iteration — POT pays this four times per iteration, once
//! per sweep group — so on small/medium problems thread create/join and
//! cold stacks dominate wall time and defeat the zero-allocation
//! [`Workspace`](crate::algo::Workspace) contract. Sinkhorn-family UOT
//! iterations are short, memory-bound passes, exactly the regime where
//! per-iteration dispatch overhead shows up (Pham et al. 2020; Séjourné
//! et al. 2022).
//!
//! [`ThreadPool`] replaces that with workers created **once** (optionally
//! pinned to cores via [`AffinityHint`]), parked between dispatches, and
//! coordinated by a lightweight **epoch barrier**: an atomic generation
//! counter plus `park`/`unpark`. One dispatch ([`ThreadPool::run`]) costs
//! zero thread creation and zero heap allocation:
//!
//! 1. the caller publishes a borrowed job (`&dyn Fn(usize)`) and bumps the
//!    epoch (release store), then unparks **only the participating**
//!    workers — a small job on a big shared pool wakes nobody else;
//! 2. each participating worker observes the new epoch (acquire load),
//!    runs its part, and decrements the outstanding-worker counter;
//! 3. the caller executes **part 0 itself** (a pool of `t` threads spawns
//!    only `t − 1` workers), then spins-then-parks until the counter drains
//!    — that wait *is* the sweep barrier, replacing a whole scope teardown.
//!
//! Panics are contained, never deadlocks: a panicking part (worker or
//! caller) is caught so the barrier still drains and the borrowed job
//! outlives every use, then re-raised on the dispatching thread —
//! mirroring the `join().expect(..)` semantics of the scope backend. The
//! pool itself stays usable afterwards.
//!
//! A sweep-structured solver (POT's four sweeps, COFFEE's two phases) runs
//! one `run` call per sweep: the barrier between sweeps becomes an epoch
//! wait instead of a join+respawn cycle.
//!
//! The module also owns the shared-state plumbing the pool kernels need:
//!
//! * [`Partition`] — balanced row-block partition (no straggler blocks;
//!   every block gets at least half the average rows);
//! * [`AccArena`] — the per-thread `NextSum_col` partials as one 64-byte-
//!   aligned, cache-line-padded arena (replacing `Vec<Vec<f32>>`), so the
//!   tree-free column-parallel reduction streams one contiguous buffer;
//! * [`PaddedSlots`] — one f32 per worker on its own cache line, for the
//!   tracked-delta maxima;
//! * [`SliceRef`] / [`ArenaRef`] / [`SlotsRef`] — `Sync` raw-pointer views
//!   that let the `Fn(usize)` job hand each part a disjoint sub-slice
//!   (the role `thread::scope`'s move closures played before).

use std::cell::UnsafeCell;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle, Thread};

use crate::util::matrix::{Matrix, CACHE_LINE};
use crate::util::telemetry::{self, Phase};

/// f32 lanes per cache line: arena rows are padded to a multiple of this.
const LINE_F32: usize = CACHE_LINE / std::mem::size_of::<f32>();

/// Spin iterations before falling back to `park` (epoch waits are usually
/// shorter than one memory-bound sweep, so a short spin catches most of
/// them without burning a syscall).
const SPIN_LIMIT: u32 = 4096;

/// Which parallel execution engine drives the threaded kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelBackend {
    /// Legacy `thread::scope` spawn/join per iteration (per sweep for the
    /// phase-split kernels). Kept for head-to-head benchmarking.
    SpawnPerIter,
    /// Persistent parked worker pool with an epoch barrier (default).
    Pool,
}

impl ParallelBackend {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "spawn" | "scope" | "spawn-per-iter" => Some(ParallelBackend::SpawnPerIter),
            "pool" | "persistent" => Some(ParallelBackend::Pool),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ParallelBackend::SpawnPerIter => "spawn",
            ParallelBackend::Pool => "pool",
        }
    }
}

/// Core-affinity hint for pool workers.
///
/// `Pinned` pins worker `i` to core `(i + 1) % cores` (part 0 runs on the
/// dispatching thread, which stays wherever the OS put it). Best-effort:
/// unsupported platforms and restricted cgroups silently ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AffinityHint {
    /// Let the scheduler place workers (default).
    #[default]
    None,
    /// Pin each worker to one core, round-robin.
    Pinned,
}

/// Best-effort thread pinning (Linux only; no-op elsewhere).
#[cfg(target_os = "linux")]
fn pin_to_core(core: usize) {
    const WORDS: usize = 1024 / 64; // glibc cpu_set_t is 1024 bits
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; WORDS];
    let bit = core % (WORDS * 64);
    mask[bit / 64] |= 1u64 << (bit % 64);
    // SAFETY: pid 0 targets the calling thread; the mask buffer outlives
    // the call. Failure (e.g. a restricted cpuset) is an ignorable hint.
    let _ = unsafe { sched_setaffinity(0, WORDS * 8, mask.as_ptr()) };
}

#[cfg(not(target_os = "linux"))]
fn pin_to_core(_core: usize) {}

/// Low bits of the packed epoch word that carry the participant count.
///
/// `epoch` is `(generation << PARTS_BITS) | parts`: a worker learns from
/// the **same atomic load** that woke it both that a new job exists and
/// whether it participates. Non-participants never touch the job slot —
/// they have no happens-before edge to the dispatcher's post-barrier
/// clear/republish (the barrier only waits for participants), so reading
/// the slot from them would be a data race.
const PARTS_BITS: u32 = 16;
const PARTS_MASK: u64 = (1 << PARTS_BITS) - 1;

/// The job slot: valid only between an epoch publish and the matching
/// barrier drain, while `run_dyn` keeps the original borrow alive. Read
/// **only** by participating workers (`idx < parts` from the packed
/// epoch), whose barrier decrement the dispatcher awaits before touching
/// the slot again.
struct Job {
    /// Type-erased `&(dyn Fn(usize) + Sync)` from the dispatching caller.
    task: Option<*const (dyn Fn(usize) + Sync)>,
    /// Dispatching thread, unparked by the last worker to finish.
    caller: Option<Thread>,
}

struct Shared {
    /// Packed `(generation << PARTS_BITS) | parts`; published (release)
    /// once per dispatched job. Writers are serialized (dispatch lock /
    /// exclusive Drop).
    epoch: AtomicU64,
    /// Participating workers that have not yet finished the current epoch.
    remaining: AtomicUsize,
    job: UnsafeCell<Job>,
    shutdown: AtomicBool,
    /// Set by a worker whose part panicked (the panic is contained so the
    /// barrier still drains); the dispatcher re-raises it after the wait.
    poisoned: AtomicBool,
    /// Telemetry label for worker-side part spans: `1` while the dispatch
    /// is the column-parallel reduction, `0` for sweep epochs. Relaxed —
    /// a trace label only, never part of the barrier protocol (so the
    /// `pool_model` state machine does not model it).
    reduction_hint: AtomicU8,
}

impl Shared {
    /// Publish the next packed epoch (writers are already serialized).
    fn publish_epoch(&self, parts: usize) {
        let generation = self.epoch.load(Ordering::Relaxed) >> PARTS_BITS;
        self.epoch
            .store(((generation + 1) << PARTS_BITS) | parts as u64, Ordering::Release);
    }
}

// SAFETY: moving `Shared` between threads is sound because every field is
// an atomic or an `UnsafeCell` whose `job` slot is written only by the
// dispatcher while it holds the dispatch lock; no thread-local state.
unsafe impl Send for Shared {}
// SAFETY: concurrent `&Shared` access is serialized by the protocol: the
// `job` slot is written only by the lock-holding dispatcher before the
// epoch's release bump; workers read it only after the matching acquire
// load, and the raw task pointer is dereferenced only while `run_dyn`
// keeps the underlying borrow alive. All other fields are atomics.
unsafe impl Sync for Shared {}

/// A persistent worker pool. See the module docs for the protocol.
///
/// `run` takes `&self` and serializes dispatches internally, so one pool
/// can be shared (`Arc`) by several sessions — e.g. `solve_batch` and the
/// coordinator's per-worker sessions reuse one pool for every solve.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Serializes dispatches from concurrent `run` callers.
    dispatch: Mutex<()>,
}

impl ThreadPool {
    /// Pool executing jobs over `threads` parts (spawns `threads - 1`
    /// workers; part 0 always runs on the dispatching thread).
    pub fn new(threads: usize) -> Self {
        Self::with_affinity(threads, AffinityHint::None)
    }

    /// [`ThreadPool::new`] with a core-affinity hint for the workers.
    pub fn with_affinity(threads: usize, affinity: AffinityHint) -> Self {
        // The participant count must fit the packed epoch's low bits (and
        // no OS spawns 65k threads anyway).
        let threads = threads.max(1).min(PARTS_MASK as usize);
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            remaining: AtomicUsize::new(0),
            job: UnsafeCell::new(Job { task: None, caller: None }),
            shutdown: AtomicBool::new(false),
            poisoned: AtomicBool::new(false),
            reduction_hint: AtomicU8::new(0),
        });
        let cores = thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let workers = (0..threads - 1)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("uot-pool-{}", i + 1))
                    .spawn(move || {
                        if affinity == AffinityHint::Pinned {
                            pin_to_core((i + 1) % cores);
                        }
                        worker_loop(&shared, i + 1);
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers, dispatch: Mutex::new(()) }
    }

    /// Total parts per dispatch (workers + the dispatching caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Label the worker-side telemetry spans of subsequent dispatches as
    /// the column-parallel reduction (`true`) or a fused sweep (`false`,
    /// the default). Purely a trace label; no effect on execution.
    pub(crate) fn set_reduction_hint(&self, on: bool) {
        self.shared.reduction_hint.store(on as u8, Ordering::Relaxed);
    }

    /// Execute `task(p)` for every `p in 0..parts`, in parallel, returning
    /// once all parts finished (the epoch barrier). Allocation-free and
    /// spawn-free: the steady-state cost is one atomic bump, `parts - 1`
    /// unparks and one barrier wait.
    ///
    /// `parts` must not exceed [`ThreadPool::threads`]. Concurrent callers
    /// on a shared pool serialize on an internal lock.
    pub fn run<F: Fn(usize) + Sync>(&self, parts: usize, task: F) {
        self.run_dyn(parts, &task);
    }

    fn run_dyn(&self, parts: usize, task: &(dyn Fn(usize) + Sync)) {
        let parts = parts.max(1);
        assert!(
            parts <= self.threads(),
            "{} parts dispatched to a {}-thread pool",
            parts,
            self.threads()
        );
        if self.workers.is_empty() || parts == 1 {
            // Serial fast path: no atomics, no wakeups; panics propagate
            // directly (no worker holds the closure).
            for p in 0..parts {
                task(p);
            }
            return;
        }
        // A panic inside a previous dispatch releases the lock cleanly
        // (see the guard drop below), but recover from poisoning anyway so
        // a shared pool never becomes permanently unusable.
        let guard = match self.dispatch.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Publish the job. Erasing the borrow to a raw pointer is sound
        // because this function does not return (or unwind past the
        // barrier below) until every participating worker has drained, so
        // the borrow outlives all uses.
        {
            // SAFETY: exclusive via the dispatch lock; only participating
            // workers read the slot, and only after the packed-epoch
            // publish below (release/acquire pair).
            let job = unsafe { &mut *self.shared.job.get() };
            job.task = Some(task as *const (dyn Fn(usize) + Sync));
            job.caller = Some(thread::current());
        }
        // Only workers 1..parts participate: `remaining` counts them and
        // only they are unparked — a small job on a big shared pool wakes
        // nobody else. A non-participant that spins through the epoch
        // learns `parts` from the packed word itself and never touches
        // the job slot (idle workers sleep through skipped generations;
        // the `epoch != seen` compare tolerates that).
        self.shared.remaining.store(parts - 1, Ordering::Relaxed);
        self.shared.publish_epoch(parts);
        for w in &self.workers[..parts - 1] {
            w.thread().unpark();
        }

        // The caller is part 0: it works instead of idling. Contain a
        // panic until the barrier has drained — unwinding here would drop
        // the `task` borrow while workers still execute through the
        // published raw pointer.
        let caller_result = catch_unwind(AssertUnwindSafe(|| task(0)));

        // Epoch barrier: spin briefly, then park until the last worker's
        // unpark. Spurious park returns are fine — the loop re-checks.
        let mut spins = 0u32;
        while self.shared.remaining.load(Ordering::Acquire) != 0 {
            spins += 1;
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                thread::park();
            }
        }

        // SAFETY: all participating workers are back in their wait loop;
        // clearing the slot keeps no dangling pointer past the borrow.
        let job = unsafe { &mut *self.shared.job.get() };
        job.task = None;
        job.caller = None;

        // Re-raise contained panics — worker panics first (mirroring the
        // `join().expect` semantics of the scope backend), then the
        // caller's own. Release the lock first so the pool stays usable.
        let worker_panicked = self.shared.poisoned.swap(false, Ordering::AcqRel);
        drop(guard);
        if worker_panicked {
            panic!("pool worker panicked during a dispatched part");
        }
        if let Err(payload) = caller_result {
            resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // parts = 0: no worker can mistake the shutdown bump for a job.
        self.shared.publish_epoch(0);
        for w in &self.workers {
            w.thread().unpark();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads()).finish()
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let mut seen = 0u64;
    loop {
        // Wait for a new packed epoch (or shutdown), spinning briefly then
        // parking.
        let mut spins = 0u32;
        let packed = loop {
            let e = shared.epoch.load(Ordering::Acquire);
            if e != seen {
                seen = e;
                break e;
            }
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            spins += 1;
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                thread::park();
            }
        };
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // Participation comes from the packed word itself, NOT the job
        // slot: a non-participant (idx >= parts) was neither counted in
        // `remaining` nor unparked, so the dispatcher will not wait for it
        // before clearing/republishing the slot — reading the slot here
        // would race those writes. It just goes back to waiting.
        let parts = (packed & PARTS_MASK) as usize;
        if idx >= parts {
            continue;
        }
        // SAFETY: participating worker. The acquire epoch load
        // synchronizes with the dispatcher's release publish, which
        // happens after the job slot was written; the dispatcher keeps the
        // task borrow alive (and the slot untouched) until this worker's
        // `remaining` decrement below is observed.
        let (task, caller) = unsafe {
            let job = &*shared.job.get();
            (job.task, job.caller.clone())
        };
        if let Some(task) = task {
            // Each part execution is one span on this worker's telemetry
            // lane, so traces attribute epoch work per pool thread.
            let phase = if shared.reduction_hint.load(Ordering::Relaxed) != 0 {
                Phase::Reduction
            } else {
                Phase::FusedSweep
            };
            let _part = telemetry::span(phase);
            // Contain panics so the barrier always drains: a dead or
            // unwound worker would leave the dispatcher waiting forever.
            // SAFETY: pointer valid per the publish protocol above.
            if catch_unwind(AssertUnwindSafe(|| (unsafe { &*task })(idx))).is_err() {
                shared.poisoned.store(true, Ordering::Release);
            }
        }
        if shared.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            if let Some(caller) = caller {
                caller.unpark();
            }
        }
    }
}

/// Model-checking view of the protocol above (see `pool_model.rs`):
/// every atomic op and park/unpark becomes one step of an explicit state
/// machine that uotlint's `sched` driver exhaustively interleaves. Gated
/// so normal builds carry zero extra code.
#[cfg(feature = "model_check")]
#[path = "pool_model.rs"]
pub mod model;

/// Balanced row-block partition of `rows` over at most `threads` blocks
/// (further capped by `cap`, the number of available accumulators).
///
/// Unlike the old `ceil(m/t)`-sized uniform chunks — where `m = 9, t = 8`
/// produced four 2-row blocks and one 1-row straggler on only five threads
/// — every block here gets `floor(m/b)` or `ceil(m/b)` rows, so no worker
/// receives fewer than half the average rows and all requested threads
/// participate whenever `m >= t`.
#[derive(Debug, Clone, Copy)]
pub struct Partition {
    blocks: usize,
    base: usize,
    extra: usize,
}

impl Partition {
    pub fn new(rows: usize, threads: usize, cap: usize) -> Self {
        let blocks = threads.max(1).min(rows.max(1)).min(cap.max(1));
        Partition { blocks, base: rows / blocks, extra: rows % blocks }
    }

    /// Number of non-empty blocks (== parts to dispatch).
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Rows in block `b` (the first `rows % blocks` blocks get one extra).
    pub fn len(&self, b: usize) -> usize {
        debug_assert!(b < self.blocks, "block {b} out of range ({} blocks)", self.blocks);
        self.base + usize::from(b < self.extra)
    }

    /// First row of block `b`.
    pub fn start(&self, b: usize) -> usize {
        debug_assert!(b <= self.blocks, "block {b} out of range ({} blocks)", self.blocks);
        b * self.base + b.min(self.extra)
    }

    /// Row range of block `b`. Ranges of distinct blocks are disjoint and
    /// tile `0..rows` in order (`range(b).end == range(b + 1).start`) — the
    /// property every `SliceRef::range_mut` split in the pool kernels
    /// leans on.
    pub fn range(&self, b: usize) -> Range<usize> {
        let start = self.start(b);
        debug_assert_eq!(start + self.len(b), self.start(b + 1), "partition blocks must tile");
        start..start + self.len(b)
    }
}

/// Cache-line-padded accumulator arena: the per-thread `NextSum_col`
/// partials (Algorithm 1 lines 5–15) as rows of **one** 64-byte-aligned
/// buffer, each row padded to a whole number of cache lines so adjacent
/// workers never share a line — the property Fig. 12 measures — while the
/// reduction streams a single contiguous allocation instead of chasing
/// `Vec<Vec<f32>>` pointers.
///
/// The unpadded constructor packs rows back-to-back (adjacent workers *do*
/// share lines); it exists only for the Fig. 12 false-sharing ablation.
#[derive(Debug)]
pub struct AccArena {
    buf: Matrix,
    cols: usize,
    padded: bool,
}

impl AccArena {
    /// Arena with `rows` padded accumulators of `cols` columns each.
    pub fn padded(rows: usize, cols: usize) -> Self {
        Self::build(rows, cols, true)
    }

    /// Ablation arena: rows packed contiguously, no padding (false-sharing
    /// baseline for the Fig. 12 bench).
    pub fn unpadded(rows: usize, cols: usize) -> Self {
        Self::build(rows, cols, false)
    }

    fn build(rows: usize, cols: usize, padded: bool) -> Self {
        let cols = cols.max(1);
        let stride = if padded { cols.div_ceil(LINE_F32) * LINE_F32 } else { cols };
        Self { buf: Matrix::zeros(rows.max(1), stride), cols, padded }
    }

    /// Accumulator count.
    pub fn rows(&self) -> usize {
        self.buf.rows()
    }

    /// Logical columns (N) per accumulator.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resize the logical width. Allocation-free while `cols` fits the
    /// existing stride; growing past it rebuilds the arena.
    pub fn ensure_cols(&mut self, cols: usize) {
        let cols = cols.max(1);
        if cols <= self.buf.cols() {
            self.cols = cols;
        } else {
            *self = Self::build(self.buf.rows(), cols, self.padded);
        }
    }

    /// Accumulator `b`, read-only.
    pub fn row(&self, b: usize) -> &[f32] {
        &self.buf.row(b)[..self.cols]
    }

    /// Accumulator `b`, mutable.
    pub fn row_mut(&mut self, b: usize) -> &mut [f32] {
        let cols = self.cols;
        &mut self.buf.row_mut(b)[..cols]
    }

    /// Iterate all accumulators mutably (the `thread::scope` path zips
    /// this with its spawned blocks).
    pub fn rows_mut(&mut self) -> impl Iterator<Item = &mut [f32]> + '_ {
        let cols = self.cols;
        let stride = self.buf.cols();
        self.buf.as_mut_slice().chunks_mut(stride).map(move |r| &mut r[..cols])
    }

    /// Concurrent view for pool jobs: each part touches only its own row.
    pub fn shared(&mut self) -> ArenaRef {
        ArenaRef {
            ptr: self.buf.as_mut_slice().as_mut_ptr(),
            stride: self.buf.cols(),
            cols: self.cols,
            rows: self.buf.rows(),
        }
    }
}

/// `Sync` raw view over an [`AccArena`] for in-flight pool jobs.
#[derive(Clone, Copy)]
pub struct ArenaRef {
    ptr: *mut f32,
    stride: usize,
    cols: usize,
    rows: usize,
}

// SAFETY: the view is a plain pointer + geometry; sending it to a pool
// worker is sound because the arena it points into outlives the dispatch
// (caller discipline, documented on `row_mut`).
unsafe impl Send for ArenaRef {}
// SAFETY: shared `&ArenaRef` use never aliases: every part of a pool job
// accesses a distinct row index `b`, and rows are `stride`-separated, so
// no two threads touch the same element (caller discipline on `row_mut`).
unsafe impl Sync for ArenaRef {}

impl ArenaRef {
    /// Accumulator `b` of the underlying arena.
    ///
    /// # Safety
    /// No two concurrent callers may pass the same `b`, and the arena must
    /// outlive the returned slice (both hold within one `ThreadPool::run`
    /// where part `b` is the only user of row `b`).
    #[allow(clippy::mut_from_ref)] // disjoint-row discipline, see above
    pub unsafe fn row_mut(&self, b: usize) -> &mut [f32] {
        debug_assert!(b < self.rows, "arena row {b} out of bounds ({} rows)", self.rows);
        debug_assert!(self.cols <= self.stride, "arena row overruns its stride");
        // SAFETY: `b < rows` keeps the offset inside the arena allocation,
        // `cols <= stride` keeps the row inside its padded lane, and the
        // caller guarantees exclusive use of row `b` (see `# Safety`).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(b * self.stride), self.cols) }
    }
}

/// One f32 per worker, each on its own cache line — the per-block tracked
/// `plan_delta` maxima land here without false sharing or allocation.
#[derive(Debug)]
pub struct PaddedSlots {
    buf: Matrix,
}

impl PaddedSlots {
    pub fn new(slots: usize) -> Self {
        Self { buf: Matrix::zeros(slots.max(1), LINE_F32) }
    }

    pub fn slots(&self) -> usize {
        self.buf.rows()
    }

    /// Concurrent view for pool jobs: each part writes only its own slot.
    pub fn shared(&mut self) -> SlotsRef {
        SlotsRef { ptr: self.buf.as_mut_slice().as_mut_ptr(), rows: self.buf.rows() }
    }

    /// Max over the first `used` slots.
    pub fn fold_max(&self, used: usize) -> f32 {
        (0..used.min(self.buf.rows())).map(|i| self.buf.get(i, 0)).fold(0f32, f32::max)
    }
}

/// `Sync` raw view over [`PaddedSlots`] for in-flight pool jobs.
#[derive(Clone, Copy)]
pub struct SlotsRef {
    ptr: *mut f32,
    rows: usize,
}

// SAFETY: the view is a plain pointer + row count; sending it to a pool
// worker is sound because the slots outlive the dispatch (caller
// discipline, documented on `set`).
unsafe impl Send for SlotsRef {}
// SAFETY: shared `&SlotsRef` use never aliases: each pool part writes a
// distinct slot index, one cache line apart (caller discipline on `set`).
unsafe impl Sync for SlotsRef {}

impl SlotsRef {
    /// Store `v` into slot `i`.
    ///
    /// # Safety
    /// No two concurrent callers may pass the same `i`, and the slots must
    /// outlive the call (both hold within one `ThreadPool::run` where part
    /// `i` is the only writer of slot `i`).
    pub unsafe fn set(&self, i: usize, v: f32) {
        debug_assert!(i < self.rows, "slot {i} out of bounds ({} slots)", self.rows);
        // SAFETY: `i < rows` keeps the cache-line-strided offset inside the
        // backing matrix, and the caller guarantees slot `i` has no other
        // concurrent writer (see `# Safety`).
        unsafe { *self.ptr.add(i * LINE_F32) = v };
    }
}

/// `Sync` raw view over a caller's `&mut [f32]`, handed to pool jobs that
/// carve it into disjoint ranges (plan row blocks, rowsum blocks, colsum
/// segments). The scoped-thread equivalent was `split_at_mut` + move
/// closures; a `Fn(usize)` job needs the split to happen inside the part.
#[derive(Clone, Copy)]
pub struct SliceRef {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: the view is a plain pointer + length; sending it to a pool
// worker is sound because the borrowed slice outlives the dispatch
// (caller discipline, documented on `range_mut`).
unsafe impl Send for SliceRef {}
// SAFETY: shared `&SliceRef` use never aliases: concurrent parts carve
// pairwise-disjoint ranges out of the slice (caller discipline on
// `range_mut`), so no element has two writers.
unsafe impl Sync for SliceRef {}

impl SliceRef {
    pub fn new(slice: &mut [f32]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Mutable view of `start..end`.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges, and the
    /// underlying slice must outlive the use (both hold within one
    /// `ThreadPool::run` whose parts split the slice by block).
    #[allow(clippy::mut_from_ref)] // disjoint-range discipline, see above
    pub unsafe fn range_mut(&self, start: usize, end: usize) -> &mut [f32] {
        debug_assert!(
            start <= end && end <= self.len,
            "range {start}..{end} out of bounds (len {})",
            self.len
        );
        // SAFETY: `start <= end <= len` keeps the sub-slice inside the
        // borrowed slice, and the caller guarantees concurrent ranges are
        // pairwise disjoint (see `# Safety`).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_every_part_exactly_once() {
        let pool = ThreadPool::new(4);
        for parts in 1..=4 {
            let hits: Vec<AtomicU32> = (0..parts).map(|_| AtomicU32::new(0)).collect();
            pool.run(parts, |p| {
                hits[p].fetch_add(1, Ordering::Relaxed);
            });
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "parts={parts} p={p}");
            }
        }
    }

    #[test]
    fn dispatch_survives_a_poisoned_lock() {
        // A panic while holding the dispatch lock poisons the mutex; the
        // next dispatch must recover via `PoisonError::into_inner` (the
        // tree-wide lock-discipline contract) instead of cascading.
        let pool = ThreadPool::new(2);
        let poison = catch_unwind(AssertUnwindSafe(|| {
            let _guard = pool.dispatch.lock().unwrap();
            panic!("poison the dispatch lock");
        }));
        assert!(poison.is_err());
        assert!(pool.dispatch.is_poisoned(), "lock should be poisoned");
        let total = AtomicU32::new(0);
        pool.run(2, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 2, "pool unusable after poison");
    }

    #[test]
    fn reuse_across_many_dispatches() {
        let pool = ThreadPool::new(3);
        let total = AtomicU32::new(0);
        for _ in 0..200 {
            pool.run(3, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 600);
    }

    #[test]
    fn single_thread_pool_is_serial() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let seen = AtomicU32::new(0);
        let caller = thread::current().id();
        pool.run(1, |p| {
            assert_eq!(p, 0);
            assert_eq!(thread::current().id(), caller, "part 0 must run inline");
            seen.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parts_see_disjoint_writes() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0f32; 17];
        let part = Partition::new(17, 4, usize::MAX);
        let view = SliceRef::new(&mut data);
        pool.run(part.blocks(), |b| {
            let r = part.range(b);
            // SAFETY: partition ranges are disjoint.
            for v in unsafe { view.range_mut(r.start, r.end) } {
                *v += 1.0 + b as f32;
            }
        });
        for b in 0..part.blocks() {
            for i in part.range(b) {
                assert_eq!(data[i], 1.0 + b as f32);
            }
        }
    }

    #[test]
    fn partition_is_balanced() {
        for (rows, threads) in [(9usize, 8usize), (1, 4), (100, 7), (16, 16), (3, 16), (64, 1)] {
            let part = Partition::new(rows, threads, usize::MAX);
            let total: usize = (0..part.blocks()).map(|b| part.len(b)).sum();
            assert_eq!(total, rows, "rows={rows} t={threads}");
            let min = (0..part.blocks()).map(|b| part.len(b)).min().unwrap();
            let max = (0..part.blocks()).map(|b| part.len(b)).max().unwrap();
            assert!(max - min <= 1, "rows={rows} t={threads}: {min}..{max}");
            // The satellite requirement: no block below half the average.
            assert!(
                (min * 2 * part.blocks()) >= rows,
                "rows={rows} t={threads}: min {min} below half the mean"
            );
            // Ranges tile [0, rows).
            let mut next = 0;
            for b in 0..part.blocks() {
                assert_eq!(part.range(b).start, next);
                next = part.range(b).end;
            }
            assert_eq!(next, rows);
        }
    }

    #[test]
    fn partition_caps_at_rows_and_cap() {
        assert_eq!(Partition::new(3, 16, usize::MAX).blocks(), 3);
        assert_eq!(Partition::new(100, 16, 4).blocks(), 4);
        assert_eq!(Partition::new(100, 0, 0).blocks(), 1);
    }

    #[test]
    fn arena_rows_are_line_padded_and_disjoint() {
        let mut arena = AccArena::padded(4, 9);
        assert_eq!(arena.cols(), 9);
        for b in 0..4 {
            arena.row_mut(b).fill(b as f32);
        }
        for b in 0..4 {
            assert!(arena.row(b).iter().all(|&v| v == b as f32));
            let addr = arena.row(b).as_ptr() as usize;
            assert_eq!(addr % CACHE_LINE, 0, "row {b} not line-aligned");
        }
        // Growing reallocates; shrinking is free and keeps the stride.
        arena.ensure_cols(5);
        assert_eq!(arena.cols(), 5);
        arena.ensure_cols(40);
        assert_eq!(arena.cols(), 40);
        assert_eq!(arena.rows(), 4);
    }

    #[test]
    fn unpadded_arena_packs_rows() {
        let arena = AccArena::unpadded(3, 9);
        let a0 = arena.row(0).as_ptr() as usize;
        let a1 = arena.row(1).as_ptr() as usize;
        assert_eq!(a1 - a0, 9 * 4, "ablation arena must pack rows tight");
    }

    #[test]
    fn padded_slots_fold() {
        let mut slots = PaddedSlots::new(3);
        let view = slots.shared();
        // SAFETY: distinct indices, serial test.
        unsafe {
            view.set(0, 0.5);
            view.set(1, 2.0);
            view.set(2, 1.0);
        }
        assert_eq!(slots.fold_max(3), 2.0);
        assert_eq!(slots.fold_max(1), 0.5);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.run(3, |p| {
                if p == 2 {
                    panic!("boom in worker part");
                }
            });
        }));
        assert!(outcome.is_err(), "worker panic must re-raise on the dispatcher");
        // The barrier drained and the pool is still usable.
        let total = AtomicU32::new(0);
        pool.run(3, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn caller_part_panic_waits_for_workers_then_resumes() {
        let pool = ThreadPool::new(2);
        let worker_ran = AtomicU32::new(0);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |p| {
                if p == 0 {
                    panic!("boom in caller part");
                }
                worker_ran.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(outcome.is_err());
        // The worker's part completed before the panic resumed — the
        // borrowed job was never dropped out from under it.
        assert_eq!(worker_ran.load(Ordering::Relaxed), 1);
        let total = AtomicU32::new(0);
        pool.run(2, |_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn oversubscribed_pool_still_correct() {
        // More pool threads than cores (and than work): every part must
        // still run exactly once through park/unpark cycles.
        let pool = ThreadPool::with_affinity(16, AffinityHint::Pinned);
        let total = AtomicU32::new(0);
        for _ in 0..50 {
            pool.run(5, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 250);
    }

    #[test]
    fn shared_pool_serializes_dispatch() {
        let pool = Arc::new(ThreadPool::new(4));
        let total = Arc::new(AtomicU32::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                thread::spawn(move || {
                    for _ in 0..50 {
                        pool.run(4, |_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 4);
    }
}
