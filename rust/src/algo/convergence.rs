//! Convergence metrics and stopping criteria.

use crate::util::Matrix;

/// L-inf distance of the plan's marginals from `(rpd, cpd)`, computed in a
/// single row-major sweep (same definition as `ref.marginal_error` in L1).
/// `colsum_scratch` (length N) is caller-provided so the convergence check
/// stays allocation-free on the session hot path.
pub fn marginal_error_with(
    plan: &Matrix,
    rpd: &[f32],
    cpd: &[f32],
    colsum_scratch: &mut [f32],
) -> f32 {
    debug_assert_eq!(colsum_scratch.len(), plan.cols());
    colsum_scratch.fill(0.0);
    let mut row_err = 0f32;
    for i in 0..plan.rows() {
        let mut rs = 0f32;
        for (s, &v) in colsum_scratch.iter_mut().zip(plan.row(i)) {
            rs += v;
            *s += v;
        }
        row_err = row_err.max((rs - rpd[i]).abs());
    }
    let col_err = colsum_scratch
        .iter()
        .zip(cpd)
        .map(|(s, &t)| (s - t).abs())
        .fold(0f32, f32::max);
    row_err.max(col_err)
}

/// [`marginal_error_with`] with its own scratch allocation.
pub fn marginal_error(plan: &Matrix, rpd: &[f32], cpd: &[f32]) -> f32 {
    let mut colsum = vec![0f32; plan.cols()];
    marginal_error_with(plan, rpd, cpd, &mut colsum)
}

/// Max element-wise change between consecutive plans; UOT with `fi < 1`
/// converges to a *relaxed* fixed point where the marginal error plateaus
/// at a nonzero value, so fixed-point motion is the robust criterion.
pub fn plan_delta(prev: &Matrix, cur: &Matrix) -> f32 {
    prev.max_abs_diff(cur)
}

/// Stopping rule evaluated between iteration chunks.
#[derive(Debug, Clone, Copy)]
pub struct StopRule {
    /// Stop when the marginal L-inf error is below this (used with fi = 1
    /// or when the application wants marginal feasibility).
    pub tol: f32,
    /// Also stop when the plan stops moving by more than this (the relaxed
    /// fixed point for fi < 1).
    pub delta_tol: f32,
    /// Hard iteration budget.
    pub max_iter: usize,
}

impl Default for StopRule {
    fn default() -> Self {
        Self { tol: 1e-4, delta_tol: 1e-6, max_iter: 10_000 }
    }
}

impl StopRule {
    /// Has the solve finished, given the latest metrics?
    pub fn is_done(&self, err: f32, delta: f32, iters: usize) -> bool {
        err <= self.tol || delta <= self.delta_tol || iters >= self.max_iter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_at_satisfied_marginals() {
        let m = Matrix::from_fn(3, 4, |i, j| (1 + i + j) as f32);
        let err = marginal_error(&m, &m.row_sums(), &m.col_sums());
        assert_eq!(err, 0.0);
    }

    #[test]
    fn error_reflects_worst_violation() {
        let m = Matrix::from_fn(2, 2, |_, _| 1.0);
        // row sums = [2,2], col sums = [2,2]
        let err = marginal_error(&m, &[2.0, 5.0], &[2.0, 2.0]);
        assert_eq!(err, 3.0);
    }

    #[test]
    fn stop_rule_thresholds() {
        let r = StopRule { tol: 1e-3, delta_tol: 1e-7, max_iter: 10 };
        assert!(r.is_done(1e-4, 1.0, 0));
        assert!(r.is_done(1.0, 1e-8, 0));
        assert!(r.is_done(1.0, 1.0, 10));
        assert!(!r.is_done(1.0, 1.0, 9));
    }
}
