//! Threaded variants of the three solvers (paper §4.1.2).
//!
//! The matrix is split into contiguous row blocks, one per thread — "which
//! makes the most sense since all computations are done in row order"
//! (§4.1.2). Each MAP-UOT thread runs the same fused double-loop over its
//! block with a *private* `NextSum_col` (Algorithm 1 lines 5–15); the main
//! thread reduces the per-thread sums (lines 16–20). Private, separately
//! allocated accumulators + 64-byte-aligned row blocks are what make the
//! false-sharing figure (Fig. 12) flat.
//!
//! std::thread::scope plays the role of Pthreads create/join. POT's four
//! sweeps and COFFEE's two phases need a barrier between sweeps, realized
//! as one scope per sweep group — this extra synchronization is part of
//! what Fig. 10 measures.

use std::thread;

use crate::algo::mapuot::fused_rows;
use crate::algo::scaling::{factor, factors_into};
use crate::util::Matrix;

/// Clamp a thread-count request to something usable.
pub fn effective_threads(requested: usize, rows: usize) -> usize {
    requested.max(1).min(rows.max(1))
}

/// One parallel MAP-UOT iteration with `threads` workers.
pub fn mapuot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    factors_into(&mut fcol, cpd, colsum, fi);
    let rows_per = m.div_ceil(t);

    let fcol_ref = &fcol;
    let locals: Vec<Vec<f32>> = thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .zip(rpd.chunks(rows_per))
            .map(|(block, rpd_block)| {
                s.spawn(move || {
                    // Private NextSum_col: separately allocated, so no two
                    // threads ever share a cache line of accumulator state.
                    let mut local = vec![0f32; n];
                    fused_rows(block, n, rpd_block, fcol_ref, fi, &mut local);
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    // Algorithm 1 lines 16–20: reduce per-thread NextSum_col on the main thread.
    colsum.fill(0.0);
    for local in &locals {
        for (s, &v) in colsum.iter_mut().zip(local) {
            *s += v;
        }
    }
}

/// One parallel COFFEE iteration: two phase-sweeps with a barrier between.
pub fn coffee_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    factors_into(&mut fcol, cpd, colsum, fi);
    let rows_per = m.div_ceil(t);

    // Phase A: column rescale + row sums.
    let fcol_ref = &fcol;
    let rowsum: Vec<f32> = thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .map(|block| {
                s.spawn(move || {
                    block
                        .chunks_exact_mut(n)
                        .map(|row| {
                            let mut acc = 0f32;
                            for (v, &f) in row.iter_mut().zip(fcol_ref) {
                                *v *= f;
                                acc += *v;
                            }
                            acc
                        })
                        .collect::<Vec<f32>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("worker panicked"))
            .collect()
    });

    // Phase B: row rescale + next column sums.
    let rowsum_ref = &rowsum;
    let locals: Vec<Vec<f32>> = thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .enumerate()
            .map(|(b, block)| {
                s.spawn(move || {
                    let mut local = vec![0f32; n];
                    for (i, row) in block.chunks_exact_mut(n).enumerate() {
                        let gi = b * rows_per + i;
                        let fr = factor(rpd[gi], rowsum_ref[gi], fi);
                        for (v, sl) in row.iter_mut().zip(local.iter_mut()) {
                            *v *= fr;
                            *sl += *v;
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    colsum.fill(0.0);
    for local in &locals {
        for (s, &v) in colsum.iter_mut().zip(local) {
            *s += v;
        }
    }
}

/// One parallel POT iteration: four sweeps, each row-partitioned, with
/// barriers between sweeps (the NumPy execution model under a parallel
/// BLAS-style backend).
pub fn pot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let rows_per = m.div_ceil(t);

    // Sweep 1: column sums.
    let sums = par_col_sums(plan, rows_per);
    let mut fcol = vec![0f32; n];
    factors_into(&mut fcol, cpd, &sums, fi);

    // Sweep 2: column rescale.
    let fcol_ref = &fcol;
    thread::scope(|s| {
        for block in plan.as_mut_slice().chunks_mut(rows_per * n) {
            s.spawn(move || {
                for row in block.chunks_exact_mut(n) {
                    for (v, &f) in row.iter_mut().zip(fcol_ref) {
                        *v *= f;
                    }
                }
            });
        }
    });

    // Sweep 3: row sums.
    let rowsum: Vec<f32> = thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .map(|block| {
                s.spawn(move || {
                    block
                        .chunks_exact(n)
                        .map(|row| row.iter().sum::<f32>())
                        .collect::<Vec<f32>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("worker panicked"))
            .collect()
    });

    // Sweep 4: row rescale.
    let rowsum_ref = &rowsum;
    thread::scope(|s| {
        for (b, block) in plan.as_mut_slice().chunks_mut(rows_per * n).enumerate() {
            s.spawn(move || {
                for (i, row) in block.chunks_exact_mut(n).enumerate() {
                    let gi = b * rows_per + i;
                    let fr = factor(rpd[gi], rowsum_ref[gi], fi);
                    for v in row {
                        *v *= fr;
                    }
                }
            });
        }
    });

    // Refresh carried colsum (POT recomputes it next iteration anyway).
    let fresh = par_col_sums(plan, rows_per);
    colsum.copy_from_slice(&fresh);
}

fn par_col_sums(plan: &mut Matrix, rows_per: usize) -> Vec<f32> {
    let n = plan.cols();
    let locals: Vec<Vec<f32>> = thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .map(|block| {
                s.spawn(move || {
                    let mut local = vec![0f32; n];
                    for row in block.chunks_exact(n) {
                        for (sl, &v) in local.iter_mut().zip(row) {
                            *sl += v;
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut out = vec![0f32; n];
    for local in &locals {
        for (s, &v) in out.iter_mut().zip(local) {
            *s += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{mapuot, problem::Problem};

    fn check_parallel_matches_serial(
        par: impl Fn(&mut Matrix, &mut [f32], &[f32], &[f32], f32, usize),
        threads: usize,
        seed: u64,
    ) {
        let p = Problem::random(23, 17, 0.7, seed);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        for _ in 0..5 {
            par(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, threads);
        }
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..5 {
            mapuot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
        }
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3, "threads={threads}");
    }

    #[test]
    fn mapuot_parallel_matches_serial() {
        for t in [1, 2, 3, 4, 8, 32] {
            check_parallel_matches_serial(mapuot_iterate, t, 1);
        }
    }

    #[test]
    fn coffee_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(coffee_iterate, t, 2);
        }
    }

    #[test]
    fn pot_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(pot_iterate, t, 3);
        }
    }

    #[test]
    fn more_threads_than_rows_is_safe() {
        let p = Problem::random(3, 5, 0.5, 4);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        mapuot_iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi, 64);
        assert!(a.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(0, 10), 1);
        assert_eq!(effective_threads(16, 4), 4);
        assert_eq!(effective_threads(8, 100), 8);
    }
}
